"""Benchmark harness — one benchmark per paper table/figure plus the
kernel micro-benches and the dry-run roofline summary.

Prints ``name,us_per_call,derived`` CSV (one line per measurement), and
can additionally emit a machine-readable ``BENCH_kernels.json``
(name -> us_per_call) so the perf trajectory is comparable across PRs.

    PYTHONPATH=src python -m benchmarks.run [--only fig2a,theorem1]
    PYTHONPATH=src python -m benchmarks.run --only relay_mix,fused_aggregate --json
    BENCH_ROUNDS=50 PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback


def all_benches():
    from . import (
        async_bench,
        channel_bench,
        ckpt_bench,
        kernels_bench,
        larged_bench,
        paper_figures,
        quant_bench,
        roofline_report,
        scan_bench,
        shard_bench,
        strategy_bench,
        telemetry_bench,
        theory,
    )

    return {
        "fig2a": paper_figures.bench_fig2a,
        "fig2b": paper_figures.bench_fig2b,
        "fig4": paper_figures.bench_fig4_mmwave,
        "theorem1": theory.bench_theorem1,
        "copt_alpha": theory.bench_copt_alpha,
        "relay_mix": kernels_bench.bench_relay_mix,
        "fused_aggregate": kernels_bench.bench_fused_aggregate,
        "flash_attn": kernels_bench.bench_flash_attention,
        "roofline": roofline_report.bench_dryrun_roofline,
        "channel_sampler": channel_bench.bench_channel_sampler,
        "channel_adaptive": channel_bench.bench_channel_adaptive,
        "strategies": strategy_bench.bench_strategy_matrix,
        "quant": quant_bench.bench_quant,
        "scan": scan_bench.bench_scan_engine,
        "shard_bench": shard_bench.bench_shard,
        "telemetry": telemetry_bench.bench_telemetry,
        "ckpt": ckpt_bench.bench_ckpt,
        "async_bench": async_bench.bench_async,
        "larged": larged_bench.bench_larged,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    ap.add_argument("--json", nargs="?", const="BENCH_kernels.json", default=None,
                    metavar="PATH",
                    help="also write name -> us_per_call as JSON "
                         "(default path: BENCH_kernels.json)")
    args = ap.parse_args()
    benches = all_benches()
    names = args.only.split(",") if args.only else list(benches)
    print("name,us_per_call,derived")
    results = {}
    failed = []
    for name in names:
        try:
            for row_name, us, derived in benches[name]():
                print(f"{row_name},{us:.1f},{derived}", flush=True)
                results[row_name] = round(us, 1)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, sort_keys=True)
        print(f"# wrote {args.json} ({len(results)} rows)", file=sys.stderr)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
