"""Population-scale sweep: block-sparse clustered relaying vs the dense
oracle through the compiled scan engine.

For n in {64, 256, 1024, 4096, 16384} clients (C = n/16 clusters of
m = 16), runs K communication rounds of the same per_client quadratic
task through ``make_scan_round_fn`` twice:

* **dense** — the ``colrel`` strategy on the dense form of the clustered
  topology: ``tau_dd`` traces are ``(K, n, n)``, the relay mix contracts
  the full ``(n, n)`` mixing matrix (O(n^2 d) per round).
* **clustered** — the ``clustered`` strategy on the block layout:
  ``(K, C, m, m)`` traces, per-cluster relay mix (O(C m^2 d)), the dense
  mask never materializes.

Both consume per-cluster COPT-alpha weights (every cluster of
``topology.clustered_blocks`` is identical, so one O(m^2) Gauss-Seidel
solve serves all C clusters); with C = 1 the clustered path reproduces
dense bitwise (pinned in tests/test_clustered.py), so this is the same
math at two storage layouts.

Reported per size: rounds/sec (compile excluded) and the compiled
program's memory footprint (argument + temp + output bytes from XLA's
``memory_analysis``).  The dense oracle is skipped above
``n=4096`` — its trace alone would be K x n^2 floats — which is the
point of the block layout.  Emits ``BENCH_shard.json``; the CI gate
asserts the clustered path is >= 3x rounds/sec (and smaller) than dense
at n = 1024 (``SHARD_BENCH_MIN_SPEEDUP`` / ``SHARD_BENCH_MAX_N``
override for throttled runners / smoke sweeps).
"""

from __future__ import annotations

import json
import os
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro import strategies
from repro.channel import ClusteredStaticChannel, StaticChannel
from repro.core import optimize_weights, topology
from repro.core.blocks import ClusterSpec, block_diag_from_blocks
from repro.fl.round import RoundConfig, make_scan_round_fn
from repro.optim import sgd, sgd_momentum

from .common import Row

SIZES = (64, 256, 1024, 4096, 16384)
M = 16            # cluster size (C = n / M clusters)
K = 4             # scan rounds per compiled program
D = 64            # model dim of the quadratic task
DENSE_MAX = 4096  # dense traces above this are K x n^2 floats — skipped
GATE_N = 1024
_ITERS = {64: 8, 256: 8, 1024: 4, 4096: 2, 16384: 1}


def _mem_bytes(compiled) -> int:
    mem = compiled.memory_analysis()
    total = 0
    for a in ("argument_size_in_bytes", "temp_size_in_bytes",
              "output_size_in_bytes"):
        v = getattr(mem, a, None)
        if v is not None:
            total += int(v)
    return total


def _setup(n: int, clustered: bool):
    """(compiled_scan, args) for K rounds at population n, one layout."""
    model = topology.clustered_blocks(n, 0.5, M, p_intra=0.8, rho=1.0)
    # every cluster of clustered_blocks is identical: one per-cluster
    # COPT-alpha solve (O(m^2)) broadcasts to all C blocks exactly
    res = optimize_weights(model.cluster_model(0), sweeps=10,
                           fine_tune_sweeps=5)
    Ab = np.broadcast_to(res.A.astype(np.float32), (model.C, M, M)).copy()

    # block=K: buffer exactly the K rounds consumed — the default 256-round
    # block would be a 256 x n^2 tau buffer (17 GB at n=4096) for 4 rounds
    if clustered:
        channel = ClusteredStaticChannel(model, seed=0, block=K)
        strategy = strategies.get("clustered")
        A = jnp.asarray(Ab)
    else:
        channel = StaticChannel(model.to_dense(), seed=0, block=K)
        strategy = strategies.get("colrel")
        A = jnp.asarray(block_diag_from_blocks(Ab, ClusterSpec(n, M)))
    tau_up, tau_dd = channel.trace(0, K)

    H = np.diag(np.linspace(1.0, 8.0, D)).astype(np.float32)
    Hj = jnp.asarray(H)

    def loss_fn(params, batch):
        d = params["x"] - batch["t"][0]
        return 0.5 * d @ (Hj @ d), {}

    rng = np.random.default_rng(7)
    batches = {"t": jnp.asarray(
        rng.normal(size=(K, n, 1, 1, D)).astype(np.float32))}
    rc = RoundConfig(n_clients=n, local_steps=1, mode="per_client",
                     aggregation=strategy)
    scan_fn = make_scan_round_fn(loss_fn, sgd(0.1),
                                 sgd_momentum(1.0, beta=0.9), rc)
    params = {"x": jnp.zeros((D,), jnp.float32)}
    server_state = sgd_momentum(1.0, beta=0.9).init(params)
    args = (params, server_state, (), batches,
            jnp.asarray(tau_up, jnp.float32),
            jnp.asarray(tau_dd, jnp.float32), A)
    compiled = jax.jit(scan_fn).lower(*args).compile()
    return compiled, args


def _time_one(n: int, clustered: bool) -> dict:
    compiled, args = _setup(n, clustered)
    peak = _mem_bytes(compiled)
    jax.block_until_ready(compiled(*args))  # warm (allocs, thunk caches)
    iters = _ITERS[n]
    t0 = time.perf_counter()
    for _ in range(iters):
        out = compiled(*args)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    return {
        "rounds_per_sec": round(iters * K / dt, 2),
        "peak_bytes": peak,
        "us_per_round": dt * 1e6 / (iters * K),
    }


def bench_shard() -> List[Row]:
    max_n = int(os.environ.get("SHARD_BENCH_MAX_N", str(SIZES[-1])))
    floor = float(os.environ.get("SHARD_BENCH_MIN_SPEEDUP", "3"))
    rows: List[Row] = []
    sweep = []
    gate = None
    for n in SIZES:
        if n > max_n:
            continue
        entry = {"n": n, "C": n // M, "m": M}
        c = _time_one(n, clustered=True)
        entry["clustered"] = {k: c[k] for k in ("rounds_per_sec", "peak_bytes")}
        rows.append((f"shard/clustered_n{n}", c["us_per_round"],
                     f"rounds_per_sec={c['rounds_per_sec']}"))
        if n <= DENSE_MAX:
            d = _time_one(n, clustered=False)
            entry["dense"] = {k: d[k] for k in ("rounds_per_sec", "peak_bytes")}
            entry["speedup"] = round(c["rounds_per_sec"] / d["rounds_per_sec"], 2)
            entry["mem_ratio"] = round(d["peak_bytes"] / max(c["peak_bytes"], 1), 2)
            rows.append((f"shard/dense_n{n}", d["us_per_round"],
                         f"rounds_per_sec={d['rounds_per_sec']};"
                         f"speedup={entry['speedup']}x;"
                         f"mem_ratio={entry['mem_ratio']}x"))
            if n == GATE_N:
                gate = entry
        else:
            entry["dense"] = None  # K x n^2 trace: the layout being avoided
            rows.append((f"shard/dense_n{n}", 0.0, "skipped=dense_trace_too_large"))
        sweep.append(entry)

    with open("BENCH_shard.json", "w") as f:
        json.dump({
            "cluster_size": M,
            "scan_rounds": K,
            "model_dim": D,
            "dense_max_n": DENSE_MAX,
            "sweep": sweep,
            "gate_n": GATE_N,
            "gate_floor": floor,
        }, f, indent=1)

    if gate is not None:
        assert gate["speedup"] >= floor, (
            f"clustered speedup {gate['speedup']}x < {floor}x at n={GATE_N} "
            f"(m={M}, K={K})")
        assert gate["clustered"]["peak_bytes"] < gate["dense"]["peak_bytes"], (
            f"clustered peak {gate['clustered']['peak_bytes']} not below "
            f"dense {gate['dense']['peak_bytes']} at n={GATE_N}")
    return rows
