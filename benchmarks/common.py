"""Shared harness for the paper-figure benchmarks.

Each benchmark mirrors one table/figure of the paper; budgets are sized
for a single CPU core (reduced CNN widths, fewer rounds — protocol
parameters n=10, T=8, lr, momentum are kept at the paper's values).
Rounds are configurable via BENCH_ROUNDS.
"""

from __future__ import annotations

import os
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import colrel_paper
from repro.core import Aggregation, LinkModel, fedavg_weights, optimize_weights
from repro.data import synthetic_cifar, partition_iid, partition_sort_and_partition
from repro.data.pipeline import make_federated_clients
from repro.fl import FLTrainer
from repro.models import build
from repro.optim import sgd, sgd_momentum

BENCH_ROUNDS = int(os.environ.get("BENCH_ROUNDS", "6"))
Row = Tuple[str, float, str]


def timed(f, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = f(*args, **kw)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") or isinstance(
            out, jax.Array
        ) else None
    us = (time.perf_counter() - t0) / repeat * 1e6
    return out, us


def run_cnn_fl(
    link_model: LinkModel,
    aggregation: Aggregation,
    A: np.ndarray,
    *,
    non_iid_s: int | None = None,
    rounds: int = BENCH_ROUNDS,
    seed: int = 0,
) -> Dict[str, float]:
    """One federated CNN training run; returns final loss/accuracy."""
    setup = colrel_paper.reduced(batch_size=16)
    bundle = build(setup.cnn)
    images, labels = synthetic_cifar(n=4000, seed=1)
    ev_images, ev_labels = synthetic_cifar(n=1000, seed=2)
    n = link_model.n
    if non_iid_s:
        parts = partition_sort_and_partition(labels, n, s=non_iid_s, seed=seed)
    else:
        parts = partition_iid(len(labels), n, seed=seed)
    clients = make_federated_clients(
        {"images": images, "labels": labels}, parts, setup.batch_size, seed=seed
    )

    @jax.jit
    def eval_fn(params):
        _, m = bundle.loss_fn(params, {"images": ev_images, "labels": ev_labels})
        return m

    trainer = FLTrainer(
        bundle.loss_fn,
        bundle.init(jax.random.PRNGKey(seed)),
        link_model,
        A,
        clients,
        sgd(setup.lr, weight_decay=setup.weight_decay),
        sgd_momentum(1.0, beta=setup.server_momentum),
        local_steps=setup.local_steps,
        aggregation=aggregation,
        seed=seed,
    )
    trainer.run(rounds)
    m = eval_fn(trainer.params)
    return {
        "loss": float(m["ce"]),
        "acc": float(m["acc"]),
        "train_loss": trainer.log.loss[-1],
        "mean_participation": float(np.mean(trainer.log.participation)),
    }


def strategies_for(model: LinkModel):
    """(label, aggregation, A) triples: ColRel + the paper's baselines."""
    res = optimize_weights(model, sweeps=25, fine_tune_sweeps=25)
    eye = fedavg_weights(model.n)
    return [
        ("colrel", Aggregation.COLREL, res.A),
        ("fedavg_blind", Aggregation.FEDAVG_BLIND, eye),
        ("fedavg_nonblind", Aggregation.FEDAVG_NONBLIND, eye),
        ("fedavg_perfect", Aggregation.FEDAVG_PERFECT, eye),
    ], res
