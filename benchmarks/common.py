"""Shared harness for the paper-figure benchmarks.

Each benchmark mirrors one table/figure of the paper; budgets are sized
for a single CPU core (reduced CNN widths, fewer rounds — protocol
parameters n=10, T=8, lr, momentum are kept at the paper's values).
Rounds are configurable via BENCH_ROUNDS.
"""

from __future__ import annotations

import os
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LinkModel, fedavg_weights, optimize_weights
from repro.fl import ExperimentSpec, build_experiment

BENCH_ROUNDS = int(os.environ.get("BENCH_ROUNDS", "6"))
Row = Tuple[str, float, str]


def timed(f, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = f(*args, **kw)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") or isinstance(
            out, jax.Array
        ) else None
    us = (time.perf_counter() - t0) / repeat * 1e6
    return out, us


def run_cnn_fl(
    link_model: LinkModel,
    strategy: str,
    A: np.ndarray,
    *,
    non_iid_s: int | None = None,
    rounds: int = BENCH_ROUNDS,
    seed: int = 0,
) -> Dict[str, float]:
    """One federated CNN training run; returns final loss/accuracy.

    Thin wrapper over the declarative ExperimentSpec — bench budgets
    (reduced data / eval sizes, batch 16) are the only deviations from
    the spec defaults."""
    spec = ExperimentSpec(
        model="cifar_cnn",
        topology=link_model,
        non_iid_s=non_iid_s or 0,
        data_size=4000,
        eval_size=1000,
        batch_size=16,
        strategy=strategy,
        alpha=A,
        rounds=rounds,
        seed=seed,
    )
    exp = build_experiment(spec)
    exp.run()
    m = exp.trainer.eval_fn(exp.params)
    return {
        "loss": float(m["ce"]),
        "acc": float(m["acc"]),
        "train_loss": exp.log.loss[-1],
        "mean_participation": float(np.mean(exp.log.participation)),
    }


def strategies_for(model: LinkModel):
    """(label, strategy name, A) triples: ColRel + the paper's baselines."""
    res = optimize_weights(model, sweeps=25, fine_tune_sweeps=25)
    eye = fedavg_weights(model.n)
    return [
        ("colrel", "colrel", res.A),
        ("fedavg_blind", "fedavg_blind", eye),
        ("fedavg_nonblind", "fedavg_nonblind", eye),
        ("fedavg_perfect", "fedavg_perfect", eye),
    ], res
