"""Checkpointing overhead benchmark: async saves vs a bare run.

Trains the strongly-convex quadratic task at (n=256, R=256, K=64)
through the chunked scan engine twice with identical seeds — once bare
and once with the async checkpointer committing the complete run state
every chunk (``ckpt_every=64``, four periodic saves plus the final
commit, keep-last-3 retention, sha256-checksummed atomic writes to a
real directory).

The design target (DESIGN.md §12) is that fault tolerance is cheap
enough to leave on: ``AsyncCheckpointer.save`` snapshots the state on
the caller thread (device arrays by reference — jax buffers are
immutable — host arrays by copy) and serializes/writes on a background
thread, overlapping the next chunk's device execution.  The gate
asserts the checkpointed path keeps >= 95% of the bare throughput
(``CKPT_BENCH_MAX_OVERHEAD`` overrides the 5% budget for throttled
shared CI runners).  Timing takes the best of ``REPS`` interleaved
repetitions per path, compile excluded.

Correctness rides along: both runs must produce *bitwise-identical*
loss / participation / weight-sum / uplink-bits trajectories and final
params (checkpointing only observes the run), the expected steps must
be committed, and restoring the latest checkpoint into a fresh trainer
must reproduce the final params exactly.

Emits ``BENCH_ckpt.json`` with both throughputs and the measured
overhead fraction.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import tempfile
import time
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.channel import MarkovChannel, gilbert_elliott
from repro.ckpt import CheckpointWriter
from repro.core import fedavg_weights, topology
from repro.data import quadratic_problem
from repro.data.pipeline import ClientDataset
from repro.fl import FLTrainer

from .common import Row

N, R, CHUNK = 256, 256, 64
WARM = CHUNK  # rounds consumed before timing (compile + stream warmup)
REPS = 3      # interleaved repetitions; best-of per path


def _make_trainer(*, seed: int = 0) -> FLTrainer:
    from repro.optim import sgd, sgd_momentum

    prob = quadratic_problem(N, 16, mu=1.0, L=8.0, hetero=1.0, seed=0)
    H = jnp.asarray(prob["H"], jnp.float32)

    def loss_fn(params, batch):
        x = params["x"]
        d = x - batch["center"][0]
        return 0.5 * d @ (H @ d) + 0.3 * batch["noise"][0] @ x, {}

    clients = []
    for i in range(N):
        c = prob["centers"][i].astype(np.float32)
        pool = np.random.default_rng(50 + i).normal(size=(256, 16)).astype(np.float32)
        clients.append(ClientDataset({"center": np.tile(c, (256, 1)), "noise": pool},
                                     batch_size=1, seed=seed + i))
    model = topology.fully_connected(N, 0.6, p_c=0.7, rho=0.5)
    channel = MarkovChannel(gilbert_elliott(model, memory=0.9), seed=seed,
                            block=256)
    # fedavg weights: COPT at n=256 is minutes of host work and the round
    # body is identical either way — this bench measures checkpointing
    return FLTrainer(loss_fn, {"x": jnp.zeros(16)}, model, fedavg_weights(N),
                     clients, sgd(0.02), sgd_momentum(1.0, beta=0.0),
                     local_steps=2, strategy="colrel", seed=seed,
                     channel=channel)


def bench_ckpt() -> List[Row]:
    tmp = pathlib.Path(tempfile.mkdtemp(prefix="ckpt_bench_"))
    s_off, s_on = float("inf"), float("inf")
    t_off = t_on = None
    last_dir = None
    for rep in range(REPS):
        t = _make_trainer()
        t.run(WARM, chunk=CHUNK)
        t0 = time.perf_counter()
        t.run(R, chunk=CHUNK)
        s_off = min(s_off, time.perf_counter() - t0)
        t_off = t

        last_dir = tmp / f"rep{rep}"
        t = _make_trainer()
        t.run(WARM, chunk=CHUNK)
        t0 = time.perf_counter()
        t.run(R, chunk=CHUNK, ckpt_dir=last_dir, ckpt_every=CHUNK)
        s_on = min(s_on, time.perf_counter() - t0)
        t_on = t

    # checkpointing only observes the run: bitwise-identical trajectories
    for field in ("loss", "participation", "weight_sums", "uplink_bits"):
        a, b = getattr(t_off.log, field), getattr(t_on.log, field)
        assert a == b, f"checkpointing changed the {field} trajectory"
    assert np.array_equal(np.asarray(t_off.params["x"]),
                          np.asarray(t_on.params["x"]))
    # the timed segment runs rounds 64..320; per-chunk saves land on
    # 128/192/256/320 and keep-last-3 retains the newest three
    assert CheckpointWriter(last_dir).steps() == [192, 256, 320]
    # ...and the committed state restores to the exact final params
    t_back = _make_trainer()
    assert t_back.restore(last_dir) == WARM + R
    assert np.array_equal(np.asarray(t_back.params["x"]),
                          np.asarray(t_on.params["x"]))
    shutil.rmtree(tmp, ignore_errors=True)

    rps_off = R / s_off
    rps_on = R / s_on
    overhead = max(0.0, 1.0 - rps_on / rps_off)
    budget = float(os.environ.get("CKPT_BENCH_MAX_OVERHEAD", "0.05"))
    assert overhead <= budget, (
        f"checkpoint overhead {overhead:.1%} > {budget:.0%} budget at "
        f"(n={N}, R={R}, K={CHUNK}): {rps_off:.1f} -> {rps_on:.1f} rounds/s")

    with open("BENCH_ckpt.json", "w") as f:
        json.dump({
            "n_clients": N,
            "rounds": R,
            "chunk": CHUNK,
            "ckpt_every": CHUNK,
            "rounds_per_sec_off": round(rps_off, 1),
            "rounds_per_sec_on": round(rps_on, 1),
            "overhead_frac": round(overhead, 4),
            "budget_frac": budget,
            "bitwise_identical": True,
        }, f, indent=1)

    return [
        (f"ckpt/off_n{N}_K{CHUNK}", s_off * 1e6 / R,
         f"rounds_per_sec={rps_off:.1f}"),
        (f"ckpt/on_n{N}_K{CHUNK}", s_on * 1e6 / R,
         f"rounds_per_sec={rps_on:.1f};overhead={overhead:.1%}"),
    ]
