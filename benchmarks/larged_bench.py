"""Large-d relaying engine benchmark + memory-roofline gate (DESIGN.md §14).

Measures the segmented zero-copy aggregation engine against the seed
path at transformer-scale flat dimensions.  Both paths compute the same
ColRel collapse ``(1/n) tau_up @ ((A * tau_dd^T) @ stack)``; they differ
in how the ``(n, d)`` client-update stack exists:

* **seed** — the pre-§14 pipeline: ``jnp.concatenate`` flatten (one
  extra full-stack copy), then the monolithic fused pass over the
  assembled ``(n, d)`` buffer.
* **engine** — segmented streaming: per-leaf ``(n, d_i)`` segments feed
  the collapsed weight row directly (``ravel_stacked_segments`` +
  ``row_stream``); no ``(n, d)`` buffer is ever materialized.

Two gates, both recorded in ``BENCH_largeD.json`` and enforced here:

1. **memory roofline** — the engine's peak live bytes, from the
   compiled executable's ``memory_analysis()`` (arguments + outputs +
   temps - donation aliasing), must stay within
   ``LARGED_BENCH_MAX_PEAK_RATIO`` (default 1.7) of the single-stack
   floor ``n * d * 4``.  The seed path cannot meet this — the concat
   temp alone adds a full extra stack.
2. **throughput** — the engine must aggregate at
   ``LARGED_BENCH_MIN_SPEEDUP`` (default 1.5) times the seed path's
   rounds/sec at the largest swept ``d`` (the regime is
   bandwidth-bound: dropping the concat round-trip removes two of the
   three full-stack memory passes).

A third, ungated record — ``max_abs_diff`` — pins the two paths to the
same answer (the reduction is over ``n`` per column, so per-leaf
streaming reassociates nothing).

``LARGED_BENCH_MAX_D`` caps the sweep for CI smoke runs (the full sweep
tops out at d = 10^7: a 320 MB stack at n = 8).  A donation section
additionally lowers the trainer's round function with and without
``donate_argnums`` and records the aliased bytes XLA reclaims.
"""

from __future__ import annotations

import json
import os
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flatten
from repro.kernels import ops as kernel_ops

from .common import Row

N = 8
FULL_SWEEP = (100_000, 1_000_000, 10_000_000)
FLOOR_DTYPE_BYTES = 4  # the f32 stack the memory gate is priced against


def _shapes_for(d: int) -> list:
    """Transformer-shard-shaped leaves summing to exactly ``d``: a block
    of square attention projections, a pair of 1:4 MLP rectangles, thin
    norm/bias vectors, and an odd-sized remainder leaf so the segmented
    path always sees an unaligned tail."""
    h = max(int(np.sqrt(d / 14.0)), 4)
    shapes = []
    total = 0
    for shape in [(h, h)] * 4 + [(h, 4 * h), (4 * h, h)] + [(h,)] * 2:
        size = int(np.prod(shape))
        if total + size > d - 1:
            break
        shapes.append(shape)
        total += size
    shapes.append((d - total,))  # remainder: prime-ish, never tile-aligned
    return shapes


def _make_deltas(d: int, seed: int = 0):
    """Client-stacked update tree: leaves ``(N, *shape)``, f32."""
    rng = np.random.default_rng(seed)
    return {
        f"leaf{i}": jnp.asarray(
            rng.normal(size=(N, *shape)).astype(np.float32))
        for i, shape in enumerate(_shapes_for(d))
    }


def _make_channel(seed: int = 1):
    rng = np.random.default_rng(seed)
    tau_up = jnp.asarray((rng.random(N) < 0.7).astype(np.float32))
    tau_dd = jnp.asarray((rng.random((N, N)) < 0.8).astype(np.float32))
    A = jnp.asarray(rng.dirichlet(np.ones(N), size=N).T.astype(np.float32))
    return tau_up, tau_dd, A


def _seed_fn(spec):
    """The pre-§14 pipeline: concat flatten + monolithic fused pass."""

    def fn(deltas, tau_up, tau_dd, A):
        stack = flatten.ravel_stacked_concat(deltas, dtype=jnp.float32)
        gflat = kernel_ops.fused_aggregate(A, tau_up, tau_dd, stack)
        return flatten.unravel(spec, gflat, dtype=jnp.float32)

    return fn


def _engine_fn(spec):
    """Segment streaming: per-leaf segments against the collapsed row."""

    def fn(deltas, tau_up, tau_dd, A):
        w = kernel_ops.collapsed_weight_row(A, tau_up, tau_dd)
        segments = flatten.ravel_stacked_segments(deltas, dtype=jnp.float32)
        leaves = [kernel_ops.row_stream(w, seg).reshape(shape)
                  for seg, shape in zip(segments, spec.shapes)]
        return jax.tree.unflatten(spec.treedef, leaves)

    return fn


def peak_bytes(compiled) -> int:
    """Peak live bytes of a compiled executable: arguments + outputs +
    temps, minus what donation aliasing reclaims."""
    m = compiled.memory_analysis()
    return int(m.argument_size_in_bytes + m.output_size_in_bytes
               + m.temp_size_in_bytes - m.alias_size_in_bytes)


def _time_calls(fn, args, *, min_calls: int = 3, min_s: float = 0.5) -> float:
    """Median-free steady-state rate: calls/sec over >= min_s of work."""
    jax.block_until_ready(fn(*args))  # warm (compile excluded)
    calls, t0 = 0, time.perf_counter()
    while True:
        jax.block_until_ready(fn(*args))
        calls += 1
        dt = time.perf_counter() - t0
        if calls >= min_calls and dt >= min_s:
            return calls / dt


def _donation_record(d: int = 50_000) -> dict:
    """Lower the trainer's actual round function with and without the
    carry donation and record the aliased bytes XLA reclaims."""
    from repro import strategies
    from repro.fl.round import RoundConfig, make_round_fn
    from repro.optim import sgd, sgd_momentum

    rng = np.random.default_rng(7)
    params = {"x": jnp.zeros((d,), jnp.float32)}
    targets = jnp.asarray(rng.normal(size=(N, 1, 4, d)).astype(np.float32))

    def loss_fn(p, batch):
        r = p["x"] - batch["t"]
        return jnp.mean(r * r), None

    rc = RoundConfig(n_clients=N, local_steps=1, mode="per_client",
                     aggregation=strategies.get("colrel", fused="kernel"),
                     segment_d=1)
    fn = make_round_fn(loss_fn, sgd(0.3), sgd_momentum(1.0, beta=0.9), rc)
    server_state = sgd_momentum(1.0, beta=0.9).init(params)
    agg_state = rc.aggregation.init_state(N, d)
    tau_up, tau_dd, A = _make_channel()
    args = (params, server_state, agg_state, {"t": targets},
            tau_up, tau_dd, A)
    plain = jax.jit(fn).lower(*args).compile()
    donated = jax.jit(fn, donate_argnums=(0, 1, 2)).lower(*args).compile()
    aliased = int(donated.memory_analysis().alias_size_in_bytes)
    assert aliased > 0, "donated round reclaimed no buffers"
    return {
        "d": d,
        "peak_bytes_plain": peak_bytes(plain),
        "peak_bytes_donated": peak_bytes(donated),
        "alias_bytes": aliased,
    }


def bench_larged() -> List[Row]:
    max_d = int(os.environ.get("LARGED_BENCH_MAX_D", str(FULL_SWEEP[-1])))
    ds = [d for d in FULL_SWEEP if d <= max_d] or [max_d]
    max_ratio = float(os.environ.get("LARGED_BENCH_MAX_PEAK_RATIO", "1.7"))
    min_speedup = float(os.environ.get("LARGED_BENCH_MIN_SPEEDUP", "1.5"))

    rows: List[Row] = []
    sweep = []
    for d in ds:
        deltas = _make_deltas(d)
        spec = flatten.flat_spec(deltas, stacked=True)
        assert spec.d == d, (spec.d, d)
        tau_up, tau_dd, A = _make_channel()
        args = (deltas, tau_up, tau_dd, A)

        seed_c = jax.jit(_seed_fn(spec)).lower(*args).compile()
        engine_c = jax.jit(_engine_fn(spec)).lower(*args).compile()

        a = jax.tree.leaves(seed_c(*args))
        b = jax.tree.leaves(engine_c(*args))
        diff = max(float(jnp.max(jnp.abs(x - y))) for x, y in zip(a, b))
        scale = max(float(jnp.max(jnp.abs(x))) for x in a)
        assert diff <= 1e-5 * max(scale, 1.0), (
            f"engine disagrees with seed at d={d}: {diff} vs scale {scale}")

        floor = N * d * FLOOR_DTYPE_BYTES
        peak_seed = peak_bytes(seed_c)
        peak_engine = peak_bytes(engine_c)
        rps_seed = _time_calls(seed_c, args)
        rps_engine = _time_calls(engine_c, args)
        rec = {
            "d": d,
            "floor_bytes": floor,
            "peak_bytes_seed": peak_seed,
            "peak_bytes_engine": peak_engine,
            "peak_ratio_seed": round(peak_seed / floor, 3),
            "peak_ratio_engine": round(peak_engine / floor, 3),
            "rounds_per_sec_seed": round(rps_seed, 2),
            "rounds_per_sec_engine": round(rps_engine, 2),
            "speedup": round(rps_engine / rps_seed, 2),
            "max_abs_diff": diff,
        }
        sweep.append(rec)
        rows.append((
            f"larged/d{d}", 1e6 / rps_engine,
            f"speedup={rec['speedup']}x;peak_ratio={rec['peak_ratio_engine']}"
            f";seed_ratio={rec['peak_ratio_seed']}",
        ))

    last = sweep[-1]
    assert last["peak_ratio_engine"] <= max_ratio, (
        f"engine peak {last['peak_ratio_engine']}x floor exceeds the "
        f"{max_ratio}x memory-roofline gate at d={last['d']}")
    assert last["speedup"] >= min_speedup, (
        f"engine speedup {last['speedup']}x < {min_speedup}x gate at "
        f"d={last['d']}")

    donation = _donation_record()
    rows.append((
        "larged/donation", 0.0,
        f"alias_bytes={donation['alias_bytes']};"
        f"peak={donation['peak_bytes_donated']}/{donation['peak_bytes_plain']}",
    ))

    with open("BENCH_largeD.json", "w") as f:
        json.dump({
            "n_clients": N,
            "floor_dtype_bytes": FLOOR_DTYPE_BYTES,
            "gates": {"max_peak_ratio": max_ratio,
                      "min_speedup": min_speedup},
            "gates_checked_at_d": last["d"],
            "sweep": sweep,
            "donation": donation,
        }, f, indent=1)

    return rows
