"""Scan-engine benchmark: chunked multi-round compilation vs the
per-round host loop.

Trains the strongly-convex quadratic task at (n=16, R=512) twice with
identical seeds — once through the per-round jitted loop (one host
round-trip per communication round) and once through the chunked
``lax.scan`` engine (``FLTrainer.run(chunk=K)``, one device program per
K rounds) — and measures rounds/sec for each, compile excluded.  The
loop is host-latency-bound at this scale (dispatch + per-round metric
syncs dwarf the round's arithmetic), which is exactly the regime the
paper's multi-thousand-round experiments live in; the scan removes that
bound.

Correctness is asserted alongside perf: both runs must produce
*bitwise-identical* loss / participation / weight-sum / uplink-bits
trajectories (they consume the same channel and batch streams and the
scan body is the loop's round function).

Emits ``BENCH_scan.json`` with the rounds/sec of both paths and the
speedup factor.  The gate defaults to the 5x the tentpole targets on CPU
at this shape; ``SCAN_BENCH_MIN_SPEEDUP`` lets throttled shared CI
runners lower it (the workflow pins 2) without losing the regression
signal.
"""

from __future__ import annotations

import json
import os
import time
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.channel import MarkovChannel, gilbert_elliott
from repro.core import optimize_weights, topology
from repro.data import quadratic_problem
from repro.data.pipeline import ClientDataset
from repro.fl import FLTrainer
from repro.optim import sgd, sgd_momentum

from .common import Row

N, R, CHUNK = 16, 512, 64
WARM = CHUNK  # rounds consumed before timing (compile + stream warmup)


def _make_trainer(seed: int = 0) -> FLTrainer:
    prob = quadratic_problem(N, 16, mu=1.0, L=8.0, hetero=1.0, seed=0)
    H = jnp.asarray(prob["H"], jnp.float32)

    def loss_fn(params, batch):
        x = params["x"]
        d = x - batch["center"][0]
        return 0.5 * d @ (H @ d) + 0.3 * batch["noise"][0] @ x, {}

    clients = []
    for i in range(N):
        c = prob["centers"][i].astype(np.float32)
        pool = np.random.default_rng(50 + i).normal(size=(2048, 16)).astype(np.float32)
        clients.append(ClientDataset({"center": np.tile(c, (2048, 1)), "noise": pool},
                                     batch_size=1, seed=seed + i))
    model = topology.fully_connected(N, 0.6, p_c=0.7, rho=0.5)
    res = optimize_weights(model, sweeps=10, fine_tune_sweeps=10)
    channel = MarkovChannel(gilbert_elliott(model, memory=0.9), seed=seed,
                            block=256)
    return FLTrainer(loss_fn, {"x": jnp.zeros(16)}, model, res.A, clients,
                     sgd(0.02), sgd_momentum(1.0, beta=0.0), local_steps=2,
                     strategy="colrel", seed=seed, channel=channel)


def bench_scan_engine() -> List[Row]:
    # per-round loop: warm the compile + streams, then time R rounds
    t_loop = _make_trainer()
    t_loop.run(WARM)
    t0 = time.perf_counter()
    t_loop.run(R)
    s_loop = time.perf_counter() - t0

    # chunked scan: same seeds, same streams, K rounds per device program
    t_scan = _make_trainer()
    t_scan.run(WARM, chunk=CHUNK)
    t0 = time.perf_counter()
    t_scan.run(R, chunk=CHUNK)
    s_scan = time.perf_counter() - t0

    # bitwise-identical trajectories over every round (warmup + timed)
    for field in ("loss", "participation", "weight_sums", "uplink_bits"):
        a, b = getattr(t_loop.log, field), getattr(t_scan.log, field)
        assert a == b, f"scan-vs-loop {field} trajectories diverge"
    assert np.array_equal(np.asarray(t_loop.params["x"]),
                          np.asarray(t_scan.params["x"]))

    rps_loop = R / s_loop
    rps_scan = R / s_scan
    speedup = s_loop / s_scan
    floor = float(os.environ.get("SCAN_BENCH_MIN_SPEEDUP", "5"))
    assert speedup >= floor, (
        f"scan speedup {speedup:.1f}x < {floor}x at (n={N}, R={R}, K={CHUNK})")

    with open("BENCH_scan.json", "w") as f:
        json.dump({
            "n_clients": N,
            "rounds": R,
            "chunk": CHUNK,
            "rounds_per_sec_loop": round(rps_loop, 1),
            "rounds_per_sec_scan": round(rps_scan, 1),
            "speedup": round(speedup, 2),
            "bitwise_identical": True,
        }, f, indent=1)

    return [
        (f"scan/loop_n{N}_R{R}", s_loop * 1e6 / R,
         f"rounds_per_sec={rps_loop:.1f}"),
        (f"scan/chunk{CHUNK}_n{N}_R{R}", s_scan * 1e6 / R,
         f"rounds_per_sec={rps_scan:.1f};speedup={speedup:.1f}x"),
    ]
