"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the JSON
records written by repro.launch.dryrun.

    PYTHONPATH=src python -m benchmarks.make_report [--tag TAG] > tables.md
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024 or unit == "TB":
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}TB"


def load(tag: str = ""):
    recs = []
    for f in sorted(RESULTS.glob("*.json")):
        r = json.loads(f.read_text())
        if (r.get("tag") or "") == tag:
            recs.append(r)
    return recs


def roofline_table(recs, mesh="16x16") -> str:
    lines = [
        "| arch | shape | mode | compute (s) | memory (s) | collective (s) "
        "| bottleneck | useful/HLO | temp/chip | fits 16GB |",
        "|---|---|---|---|---|---|---|---|---|---|".replace("|---|---|---|---|---|---|---|---|---|---|",
            "|---|---|---|---:|---:|---:|---|---:|---:|---|"),
    ]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        t = r["roofline"]
        temp = r["memory_analysis"].get("temp_size_in_bytes", 0)
        args_b = r["memory_analysis"].get("argument_size_in_bytes", 0)
        fits = "yes" if (temp + args_b) <= 16e9 else "**no**"
        u = r["useful_flop_ratio"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['fl_mode'] if r['shape']=='train_4k' else '-'} "
            f"| {t['compute_s']:.3f} | {t['memory_s']:.3f} | {t['collective_s']:.3f} "
            f"| {t['bottleneck'].replace('_s','')} | {u and round(u,3)} "
            f"| {fmt_bytes(temp)} | {fits} |"
        )
    return "\n".join(lines)


def dryrun_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | compile (s) | args/chip | temp/chip | "
        "collective bytes/chip | top collective |",
        "|---|---|---|---:|---:|---:|---:|---|",
    ]
    for r in recs:
        ma = r["memory_analysis"]
        top = max(r["collectives"].items(), key=lambda kv: kv[1])[0] if any(
            r["collectives"].values()) else "-"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']:.0f} "
            f"| {fmt_bytes(ma.get('argument_size_in_bytes', 0))} "
            f"| {fmt_bytes(ma.get('temp_size_in_bytes', 0))} "
            f"| {fmt_bytes(r['collective_bytes_per_chip'])} | {top} |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    recs = load(args.tag)
    single = [r for r in recs if r["mesh"] == "16x16"]
    multi = [r for r in recs if r["mesh"] == "2x16x16"]
    print(f"## §Dry-run ({len(single)} single-pod + {len(multi)} multi-pod records)\n")
    print(dryrun_table(recs))
    print(f"\n## §Roofline (single-pod 16x16, {len(single)} records)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
