"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the JSON
records written by repro.launch.dryrun — and, with ``--telemetry DIR``,
an observability report from a training run's telemetry stream
(DESIGN.md §11): a per-client participation histogram built from the
``summary.clients`` event and a rounds/sec table from the ``timing``
events of ``events.jsonl``.

    PYTHONPATH=src python -m benchmarks.make_report [--tag TAG] > tables.md
    PYTHONPATH=src python -m benchmarks.make_report \
        --telemetry /tmp/colrel_metrics > telemetry.md
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024 or unit == "TB":
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}TB"


def load(tag: str = ""):
    recs = []
    for f in sorted(RESULTS.glob("*.json")):
        r = json.loads(f.read_text())
        if (r.get("tag") or "") == tag:
            recs.append(r)
    return recs


def roofline_table(recs, mesh="16x16") -> str:
    lines = [
        "| arch | shape | mode | compute (s) | memory (s) | collective (s) "
        "| bottleneck | useful/HLO | temp/chip | fits 16GB |",
        "|---|---|---|---|---|---|---|---|---|---|".replace("|---|---|---|---|---|---|---|---|---|---|",
            "|---|---|---|---:|---:|---:|---|---:|---:|---|"),
    ]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        t = r["roofline"]
        temp = r["memory_analysis"].get("temp_size_in_bytes", 0)
        args_b = r["memory_analysis"].get("argument_size_in_bytes", 0)
        fits = "yes" if (temp + args_b) <= 16e9 else "**no**"
        u = r["useful_flop_ratio"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['fl_mode'] if r['shape']=='train_4k' else '-'} "
            f"| {t['compute_s']:.3f} | {t['memory_s']:.3f} | {t['collective_s']:.3f} "
            f"| {t['bottleneck'].replace('_s','')} | {u and round(u,3)} "
            f"| {fmt_bytes(temp)} | {fits} |"
        )
    return "\n".join(lines)


def dryrun_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | compile (s) | args/chip | temp/chip | "
        "collective bytes/chip | top collective |",
        "|---|---|---|---:|---:|---:|---:|---|",
    ]
    for r in recs:
        ma = r["memory_analysis"]
        top = max(r["collectives"].items(), key=lambda kv: kv[1])[0] if any(
            r["collectives"].values()) else "-"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']:.0f} "
            f"| {fmt_bytes(ma.get('argument_size_in_bytes', 0))} "
            f"| {fmt_bytes(ma.get('temp_size_in_bytes', 0))} "
            f"| {fmt_bytes(r['collective_bytes_per_chip'])} | {top} |"
        )
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# telemetry stream report (DESIGN.md §11)
# ---------------------------------------------------------------------------


def load_events(metrics_dir) -> list:
    """Parse a run's ``events.jsonl`` (one JSON object per line)."""
    out = []
    for line in (Path(metrics_dir) / "events.jsonl").read_text().splitlines():
        line = line.strip()
        if line:
            out.append(json.loads(line))
    return out


def participation_histogram(events, width: int = 40) -> str:
    """Per-client participation-rate bars from the end-of-run
    ``summary.clients`` event (the paper's whole subject: who got
    through, and how unevenly)."""
    summaries = [e for e in events if e["event"] == "summary.clients"]
    if not summaries:
        return "_no summary.clients event (telemetry off or run not closed)_"
    s = summaries[-1]
    rates = s["participation_rate"]
    streaks = s.get("outage_streak_max") or [0] * len(rates)
    lines = [
        f"### Per-client participation ({s['rounds']} rounds, "
        f"{len(rates)} clients)",
        "",
        "| client | rate | max outage streak | |",
        "|---:|---:|---:|---|",
    ]
    for i, (rate, streak) in enumerate(zip(rates, streaks)):
        bar = "#" * max(1, round(rate * width)) if rate > 0 else ""
        lines.append(f"| {i} | {rate:.3f} | {streak} | `{bar}` |")
    mean = sum(rates) / len(rates)
    lines.append("")
    lines.append(f"mean rate {mean:.3f}, min {min(rates):.3f} "
                 f"(client {rates.index(min(rates))}), "
                 f"max {max(rates):.3f} "
                 f"(client {rates.index(max(rates))})")
    return "\n".join(lines)


def throughput_table(events) -> str:
    """Rounds/sec per execution block from the ``timing`` events."""
    timing = [e for e in events if e["event"] == "timing"]
    if not timing:
        return "_no timing events_"
    lines = [
        "### Throughput",
        "",
        "| rounds | wall (s) | rounds/sec |",
        "|---|---:|---:|",
    ]
    for e in timing:
        r0, k = e["round0"], e["rounds"]
        lines.append(f"| {r0}-{r0 + k - 1} | {e['seconds']:.3f} "
                     f"| {e['rounds_per_sec']:.1f} |")
    total_r = sum(e["rounds"] for e in timing)
    total_s = sum(e["seconds"] for e in timing)
    lines.append(f"| **total: {total_r}** | **{total_s:.3f}** "
                 f"| **{total_r / total_s:.1f}** |" if total_s > 0 else "")
    return "\n".join(lines)


def telemetry_report(metrics_dir) -> str:
    events = load_events(metrics_dir)
    parts = [f"## Telemetry report ({metrics_dir})", ""]
    manifest = Path(metrics_dir) / "manifest.json"
    if manifest.exists():
        m = json.loads(manifest.read_text())
        parts.append(f"run: strategy `{m.get('strategy')}`, channel "
                     f"`{m.get('channel')}`, backend `{m.get('backend')}`, "
                     f"config digest `{str(m.get('config_digest'))[:12]}`")
        parts.append("")
    parts.append(participation_histogram(events))
    parts.append("")
    parts.append(throughput_table(events))
    health = [e for e in events
              if str(e.get("event", "")).startswith("health.")]
    if health:
        parts.append("")
        parts.append(f"### Health events ({len(health)})")
        parts.append("")
        for e in health[:20]:
            parts.append(f"- round {e.get('round')}: `{e['event']}` "
                         + json.dumps({k: v for k, v in e.items()
                                       if k not in ("event", "seq", "round")}))
    return "\n".join(parts)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="")
    ap.add_argument("--telemetry", default=None, metavar="DIR",
                    help="render an observability report from a run's "
                         "telemetry dir (events.jsonl [+ manifest.json]) "
                         "instead of the dry-run tables")
    args = ap.parse_args()
    if args.telemetry:
        print(telemetry_report(args.telemetry))
        return
    recs = load(args.tag)
    single = [r for r in recs if r["mesh"] == "16x16"]
    multi = [r for r in recs if r["mesh"] == "2x16x16"]
    print(f"## §Dry-run ({len(single)} single-pod + {len(multi)} multi-pod records)\n")
    print(dryrun_table(recs))
    print(f"\n## §Roofline (single-pod 16x16, {len(single)} records)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
