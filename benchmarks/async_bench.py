"""Async vs sync convergence at equal *wall-clock* under bursty blockage.

The sync engine is deadline-free: every round the PS waits out the
uplink timeout whenever at least one scheduled client is blocked, so
under a bursty Gilbert-Elliott channel nearly every round costs the
full timeout.  The async engine (DESIGN.md §13) closes every round at
the deadline — blocked clients' last updates age in the staging buffer
and arrive staleness-weighted (``gamma^age``) — so each round costs one
deadline regardless of blockage.

Wall-clock model (the container has no radio): a sync round costs
``T_TIMEOUT`` deadline units when any client's uplink is blocked that
round and 1.0 otherwise; an async round always costs 1.0.  Both engines
train the strongly-convex quadratic task over the *same* GE trace
(identical seeds), we charge each run by this clock, and compare losses
at the same spent budget: the async loss after R rounds (cost R) vs the
sync loss at the last round whose cumulative cost fits within R.  Tail
losses are median-smoothed over the last SMOOTH rounds to keep the gate
robust to the per-round noise injected by the quadratic's stochastic
linear term.

The gate asserts ``loss_async <= ASYNC_BENCH_MAX_LOSS_RATIO *
loss_sync`` (default 1.0: async must be at least as converged at equal
wall-clock).  Emits ``BENCH_async.json`` with both trajectories'
endpoints, the modeled speedup, and the blockage statistics.
"""

from __future__ import annotations

import json
import os
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.channel import MarkovChannel, gilbert_elliott
from repro.core import optimize_weights, topology
from repro.data import quadratic_problem
from repro.data.pipeline import ClientDataset
from repro.fl import FLTrainer

from .common import Row

N, D = 24, 16
R, CHUNK = 96, 32
T_TIMEOUT = 3.0   # sync deadline units burned per round with any blockage
GAMMA = 0.8       # staleness decay for the async PS
MEMORY = 0.9      # GE burstiness
P_UP, P_C = 0.35, 0.4
SMOOTH = 8        # tail rounds median-smoothed before the comparison


def _make_trainer(model, A, channel, *, mode: str, seed: int = 0) -> FLTrainer:
    from repro.optim import sgd, sgd_momentum

    prob = quadratic_problem(N, D, mu=1.0, L=8.0, hetero=1.0, seed=0)
    H = jnp.asarray(prob["H"], jnp.float32)

    def loss_fn(params, batch):
        x = params["x"]
        d = x - batch["center"][0]
        return 0.5 * d @ (H @ d) + 0.3 * batch["noise"][0] @ x, {}

    clients = []
    for i in range(N):
        c = prob["centers"][i].astype(np.float32)
        pool = np.random.default_rng(50 + i).normal(size=(256, D)).astype(np.float32)
        clients.append(ClientDataset({"center": np.tile(c, (256, 1)), "noise": pool},
                                     batch_size=1, seed=seed + i))
    kw = dict(async_options={"gamma": GAMMA}) if mode == "async" else {}
    return FLTrainer(loss_fn, {"x": jnp.zeros(D)}, model, A, clients,
                     sgd(0.05), sgd_momentum(1.0, beta=0.9), local_steps=2,
                     strategy="colrel", seed=seed, channel=channel,
                     mode=mode if mode == "async" else "per_client", **kw)


def _tail(losses, upto: int) -> float:
    """Median of the last SMOOTH entries of losses[:upto]."""
    w = np.asarray(losses[max(0, upto - SMOOTH):upto], np.float64)
    return float(np.median(w))


def bench_async() -> List[Row]:
    model = topology.fully_connected(N, P_UP, p_c=P_C, rho=0.5)
    A = jnp.asarray(optimize_weights(model, sweeps=25, fine_tune_sweeps=25).A,
                    jnp.float32)

    def channel():
        return MarkovChannel(gilbert_elliott(model, memory=MEMORY), seed=7,
                             block=R)

    # the shared GE trace prices the sync rounds: T_TIMEOUT whenever any
    # uplink is blocked that round, 1.0 otherwise
    tau_up, _ = channel().trace(0, R)
    blocked = np.asarray(tau_up, np.float32).min(axis=1) < 0.5
    sync_cost = np.where(blocked, T_TIMEOUT, 1.0)
    cum = np.cumsum(sync_cost)
    budget = float(R)  # async closes R rounds in R deadline units
    r_sync = int(np.searchsorted(cum, budget, side="right"))
    assert r_sync >= SMOOTH, (
        f"degenerate clock: sync completes only {r_sync} rounds in budget "
        f"{budget:.0f}; lower T_TIMEOUT or raise R")

    t_sync = _make_trainer(model, A, channel(), mode="per_client")
    t_sync.run(R, chunk=CHUNK)
    t_async = _make_trainer(model, A, channel(), mode="async")
    t_async.run(R, chunk=CHUNK)

    loss_sync = _tail(t_sync.log.loss, r_sync)
    loss_async = _tail(t_async.log.loss, R)
    speedup = float(R) / float(r_sync)

    ratio_budget = float(os.environ.get("ASYNC_BENCH_MAX_LOSS_RATIO", "1.0"))
    ratio = loss_async / loss_sync
    assert ratio <= ratio_budget, (
        f"async loss {loss_async:.4f} vs sync {loss_sync:.4f} at equal "
        f"wall-clock (ratio {ratio:.3f} > budget {ratio_budget}): sync got "
        f"{r_sync}/{R} rounds, blockage {blocked.mean():.0%}")

    with open("BENCH_async.json", "w") as f:
        json.dump({
            "n_clients": N,
            "rounds_async": R,
            "rounds_sync_at_budget": r_sync,
            "t_timeout": T_TIMEOUT,
            "gamma": GAMMA,
            "ge_memory": MEMORY,
            "blocked_round_frac": round(float(blocked.mean()), 4),
            "loss_async": round(loss_async, 6),
            "loss_sync": round(loss_sync, 6),
            "loss_ratio": round(ratio, 4),
            "ratio_budget": ratio_budget,
            "round_speedup": round(speedup, 3),
        }, f, indent=1)

    return [
        (f"async/sync_n{N}_r{r_sync}", 0.0,
         f"loss={loss_sync:.4f};rounds={r_sync}"),
        (f"async/async_n{N}_r{R}", 0.0,
         f"loss={loss_async:.4f};ratio={ratio:.3f};speedup={speedup:.2f}x"),
    ]
