"""Kernel micro-benchmarks.

On CPU the Pallas kernels execute in interpret mode, so the *timing*
numbers reflect the jnp oracle path (the deployable op on this host);
the kernel itself is timed at a reduced size purely to exercise the
tiling logic, and correctness vs the oracle is re-asserted here.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels.fused_aggregate import fused_aggregate_pallas
from repro.kernels.relay_mix import relay_mix_pallas
from repro.kernels.flash_attention import flash_attention_pallas

from .common import Row


def _time(f, *a, repeat=5, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(f(*a))
    t0 = time.perf_counter()
    for _ in range(repeat):
        jax.block_until_ready(f(*a))
    return (time.perf_counter() - t0) / repeat * 1e6


def bench_relay_mix() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    n, d = 16, 1 << 20
    M = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
    X = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    jnp_ref = jax.jit(lambda m, x: ref.relay_mix_ref(m, x))
    us_ref = _time(jnp_ref, M, X)
    # interpret-mode kernel at reduced d (tiling logic exercised, not speed)
    Xs = X[:, : 1 << 14]
    got = relay_mix_pallas(M, Xs, block_d=2048, interpret=True)
    err = float(jnp.abs(got - ref.relay_mix_ref(M, Xs)).max())
    assert err <= 1e-4, f"relay_mix kernel drifted from oracle: max_err={err:.1e}"
    us_k = _time(lambda m, x: relay_mix_pallas(m, x, block_d=2048, interpret=True), M, Xs)
    rows.append(("relay_mix/jnp_ref_d1M", us_ref, f"bytes={X.nbytes}"))
    rows.append(("relay_mix/pallas_interp_d16k", us_k, f"max_err={err:.1e}"))
    return rows


def bench_fused_aggregate() -> List[Row]:
    """Fused flatten-once engine vs the per-leaf tensordot round path.

    (n=16, d=2^20): the per-leaf baseline replays fl/round.py's faithful
    COLREL aggregation over a realistic ~64-leaf pytree (two tensordots per
    leaf — the stack read leaf-by-leaf, plus an (n, d) relay intermediate);
    the fused path reads the contiguous (n, d) stack from HBM once and
    emits only the (d,) PS delta (single kernel launch).  On this CPU host
    the deployable fused op is the jnp single-pass contraction; the Pallas
    kernel is timed in interpret mode at reduced d purely to exercise the
    tiling, with correctness re-asserted vs the two-stage oracle.
    """
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    n, d, n_leaves = 16, 1 << 20, 64
    A = jnp.asarray(rng.random((n, n)), jnp.float32)
    tau_up = jnp.asarray((rng.random(n) < 0.7).astype(np.float32))
    tau_dd = jnp.asarray((rng.random((n, n)) < 0.6).astype(np.float32))
    X = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    leaves = {f"leaf{i:02d}": X[:, i * (d // n_leaves):(i + 1) * (d // n_leaves)]
              for i in range(n_leaves)}

    @jax.jit
    def per_leaf(tree, A, tu, td):
        M = A * td.T
        return jax.tree.map(
            lambda D: jnp.tensordot(tu, jnp.tensordot(M, D, axes=1), axes=1) / n, tree
        )

    @jax.jit
    def fused_flat(X, A, tu, td):
        w = (tu @ (A * td.T)) / n  # collapsed weights, O(n^2)
        return w @ X  # the one pass over the (n, d) stack

    us_leaf = _time(per_leaf, leaves, A, tau_up, tau_dd)
    us_flat = _time(fused_flat, X, A, tau_up, tau_dd)
    # interpret-mode Pallas kernel at reduced d (tiling logic, not speed)
    Xs = X[:, : 1 << 14]
    got = fused_aggregate_pallas(A, tau_up, tau_dd, Xs, block_d=2048, interpret=True)
    err = float(jnp.abs(got - ref.fused_aggregate_ref(A, tau_up, tau_dd, Xs)).max())
    assert err <= 1e-5, f"fused kernel drifted from oracle: max_err={err:.1e}"
    us_k = _time(
        lambda *a: fused_aggregate_pallas(*a, block_d=2048, interpret=True),
        A, tau_up, tau_dd, Xs,
    )
    rows.append(("fused_aggregate/per_leaf_tensordot_d1M", us_leaf,
                 f"leaves={n_leaves};hbm_reads={2 * X.nbytes};out=(n*d)"))
    rows.append(("fused_aggregate/jnp_flat_d1M", us_flat,
                 f"hbm_reads={X.nbytes};hbm_passes=1;out=(d)"))
    rows.append(("fused_aggregate/pallas_interp_d16k", us_k,
                 f"max_err={err:.1e};launches=1;out=(d)"))
    return rows


def bench_flash_attention() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    BH, T, D = 4, 1024, 64
    q = jnp.asarray(rng.normal(size=(BH, T, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(BH, T, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(BH, T, D)), jnp.float32)
    jnp_ref = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    us_ref = _time(jnp_ref, q, k, v)
    qs, ks, vs = q[:, :256], k[:, :256], v[:, :256]
    got = flash_attention_pallas(qs, ks, vs, block_q=128, block_kv=128, interpret=True)
    err = float(jnp.abs(got - ref.flash_attention_ref(qs, ks, vs)).max())
    us_k = _time(
        lambda q, k, v: flash_attention_pallas(q, k, v, block_q=128, block_kv=128, interpret=True),
        qs, ks, vs,
    )
    rows.append(("flash_attn/jnp_ref_T1024", us_ref, f"flops={4*BH*T*T*D}"))
    rows.append(("flash_attn/pallas_interp_T256", us_k, f"max_err={err:.1e}"))
    return rows
