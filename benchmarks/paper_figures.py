"""Benchmarks mirroring the paper's figures (Sec. V numerical simulations).

fig2a — IID data, one well-connected client, ER D2D (p_c in {0.9, 0.5}).
fig2b — non-IID (s=3), heterogeneous uplinks, ER D2D.
fig4  — mmWave geometric topology: intermittent D2D collaboration vs
        permanent-only links vs no collaboration.
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import optimize_weights, fedavg_weights, variance_S
from repro.core import topology

from .common import BENCH_ROUNDS, Row, run_cnn_fl, strategies_for


def bench_fig2a() -> List[Row]:
    rows: List[Row] = []
    for p_c in (0.9, 0.5):
        m = topology.paper_fig2a(p_c=p_c)
        strats, _ = strategies_for(m)
        for label, agg, A in strats:
            if label != "colrel" and p_c != 0.9:
                continue  # baselines don't depend on p_c
            t0 = time.perf_counter()
            out = run_cnn_fl(m, agg, A)
            us = (time.perf_counter() - t0) * 1e6
            rows.append((
                f"fig2a/{label}_pc{p_c}",
                us / max(BENCH_ROUNDS, 1),
                f"acc={out['acc']:.3f};loss={out['loss']:.3f}",
            ))
    return rows


def bench_fig2b() -> List[Row]:
    rows: List[Row] = []
    for p_c in (0.9, 0.5):
        m = topology.paper_fig2b(p_c=p_c)
        strats, _ = strategies_for(m)
        for label, agg, A in strats:
            if label != "colrel" and p_c != 0.9:
                continue
            t0 = time.perf_counter()
            out = run_cnn_fl(m, agg, A, non_iid_s=3)
            us = (time.perf_counter() - t0) * 1e6
            rows.append((
                f"fig2b/{label}_pc{p_c}",
                us / max(BENCH_ROUNDS, 1),
                f"acc={out['acc']:.3f};loss={out['loss']:.3f}",
            ))
    return rows


def bench_fig4_mmwave() -> List[Row]:
    rows: List[Row] = []
    cases = {
        "intermittent": topology.paper_mmwave_layout(d2d_mode="intermittent"),
        "permanent": topology.paper_mmwave_layout(d2d_mode="permanent"),
        "no_collab": topology.no_collaboration(10, topology.paper_mmwave_layout().p),
    }
    for label, m in cases.items():
        res = optimize_weights(m, sweeps=25, fine_tune_sweeps=25)
        t0 = time.perf_counter()
        out = run_cnn_fl(m, "colrel", res.A, non_iid_s=3)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((
            f"fig4/colrel_{label}",
            us / max(BENCH_ROUNDS, 1),
            f"acc={out['acc']:.3f};loss={out['loss']:.3f};S={res.S:.2f}",
        ))
    # blind baseline under the same mmWave uplinks
    m = cases["no_collab"]
    t0 = time.perf_counter()
    out = run_cnn_fl(m, "fedavg_blind", fedavg_weights(10), non_iid_s=3)
    us = (time.perf_counter() - t0) * 1e6
    rows.append((
        "fig4/fedavg_blind", us / max(BENCH_ROUNDS, 1),
        f"acc={out['acc']:.3f};loss={out['loss']:.3f}",
    ))
    return rows
