"""Strategy-matrix benchmark under bursty connectivity.

Runs the registry strategies — the paper's ``colrel`` and
``fedavg_blind`` plus the two beyond-enum schemes ``multihop`` (K-hop
relaying, COPT alpha, Monte-Carlo unbiasedness correction) and
``memory`` (implicit gossip with identity alpha: no relaying, no oracle
knowledge, just replay) — over the *same* bursty Gilbert–Elliott trace
(the ``markov`` channel preset: ~10-round blockage bursts, marginals
equal to the static fig2a model), all assembled declaratively from one
:class:`ExperimentSpec` per arm.

Asserts the headline ordering the schemes exist for:

* ``memory`` beats ``fedavg_blind`` on final loss — replaying a blocked
  client's last delivered update de-biases the burst-plagued rounds that
  blind averaging loses entirely;
* ``colrel`` beats ``fedavg_blind`` (the paper's ordering, held under
  bursts).

Emits one row per (strategy, budget) for ``BENCH_strategies.json``.
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.fl import ExperimentSpec, build_experiment

from .common import Row

ROUNDS = 240
CHANNEL = "markov"  # bursty GE preset (configs/channels.py)

# (label, spec overrides) — one declarative spec per arm; all arms share
# the topology, channel preset, and channel seed (identical tau traces).
ARMS = [
    ("colrel", dict(strategy="colrel")),
    ("fedavg_blind", dict(strategy="fedavg_blind")),
    ("multihop_k2", dict(strategy="multihop", strategy_options={"hops": 2})),
    # identity alpha isolates the memory effect: no relaying, blocked
    # uplinks replay the client's last delivered raw update
    ("memory", dict(strategy="memory", alpha="fedavg")),
]


def _run_arm(label: str, overrides: dict):
    spec = ExperimentSpec(
        model="quadratic",
        topology="fig2a",
        channel=CHANNEL,
        rounds=ROUNDS,
        copt_sweeps=10,
        seed=0,
        **overrides,
    )
    t0 = time.perf_counter()
    exp = build_experiment(spec)
    log = exp.run()
    us = (time.perf_counter() - t0) * 1e6
    tail = ROUNDS // 3
    final_loss = float(np.mean(log.loss[-tail:]))
    dist2 = exp.trainer.eval_fn(exp.params)["dist2"]
    ws = np.asarray(log.weight_sums[-tail:])
    w_mse = (float(np.mean((ws - 1.0) ** 2))
             if np.isfinite(ws).all() else float("nan"))
    return us, final_loss, dist2, w_mse


def bench_strategy_matrix() -> List[Row]:
    rows: List[Row] = []
    results = {}
    for label, overrides in ARMS:
        us, final_loss, dist2, w_mse = _run_arm(label, overrides)
        results[label] = final_loss
        rows.append((
            f"strategies/{label}_{CHANNEL}_R{ROUNDS}",
            us,
            f"loss={final_loss:.4f};dist2={dist2:.4f};w_mse={w_mse:.4f}",
        ))
    assert results["memory"] < results["fedavg_blind"], (
        f"memory loss {results['memory']:.4f} not below blind "
        f"{results['fedavg_blind']:.4f} under bursty {CHANNEL}")
    assert results["colrel"] < results["fedavg_blind"], (
        f"colrel loss {results['colrel']:.4f} not below blind "
        f"{results['fedavg_blind']:.4f} under bursty {CHANNEL}")
    return rows
