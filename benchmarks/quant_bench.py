"""Quantized-relaying benchmark: variance vs bits against the Theorem 1 floor.

Theorem 1 bounds the PS-update error by a floor proportional to the
connectivity variance proxy ``S(p, P, A)`` — that floor exists even at
infinite wire precision.  A wire codec adds *quantization* noise on
top: unbiased codecs (int8 stochastic rounding, corrected rand-k) pay
only variance, biased ones (top-k) trade variance for a systematic
offset.  This benchmark traces exactly that decomposition under the
bursty Gilbert–Elliott preset (``markov``: ~10-round blockage bursts,
marginals equal to the static fig2a model):

* hold one synthetic update stack ``x (n, d)`` fixed;
* draw R rounds of (GE taus, fresh codec randomness) and aggregate
  through ``quantized(colrel)`` for each arm;
* report per-coordinate variance and the relative bias of the mean
  delta against the unbiased target ``(1/n) Σ_i x_i``.

The ``floor`` arm is unquantized colrel over the identical tau trace —
the empirical Theorem 1 connectivity floor (annotated with the
analytic ``S``).  Asserted invariants (the acceptance criteria):

* int8 variance decreases monotonically in bits and approaches the
  floor at 8 bits; int8 bias stays at the Monte-Carlo noise level
  (unbiasedness of stochastic rounding through the relay mix);
* corrected rand-k is unbiased while raw top-k is not (the descriptor
  hook doing its job);
* the fused Pallas dequant path matches the dequant-then-aggregate
  oracle within fp32 contraction-order tolerance.

Rows land in ``BENCH_quant.json`` via
``python -m benchmarks.run --only quant --json BENCH_quant.json``.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro import strategies, wire
from repro.configs import make_channel
from repro.core import optimize_weights, topology, variance_S
from repro.strategies.base import ExecutionContext

from .common import Row

ROUNDS = 320       # tau/codec draws per arm
D = 4096           # flat update dimension
CHANNEL = "markov"  # bursty GE preset (configs/channels.py)


def _setup():
    model = topology.paper_fig2a()
    res = optimize_weights(model, sweeps=15, fine_tune_sweeps=15)
    channel = make_channel(CHANNEL, model, seed=0)
    taus = [channel.tau_for_round(r) for r in range(ROUNDS)]
    rng = np.random.default_rng(42)
    x = jnp.asarray(rng.normal(size=(model.n, D)), jnp.float32)
    return model, res, taus, x


def _arm_stats(strategy, taus, x, A):
    """R aggregated deltas under the shared tau trace; one jit, state
    threaded so stochastic codecs draw fresh randomness each round."""
    n = x.shape[0]
    state = strategy.init_state(n, D)
    Aj = jnp.asarray(A, jnp.float32)
    step = jax.jit(
        lambda state, tu, td, A: strategy.aggregate(x, tu, td, A, state)
    )
    deltas = []
    t0 = time.perf_counter()
    for tu, td in taus:
        delta, state = step(state, jnp.asarray(tu, jnp.float32),
                            jnp.asarray(td, jnp.float32), Aj)
        deltas.append(np.asarray(delta))
    us = (time.perf_counter() - t0) / len(taus) * 1e6
    deltas = np.stack(deltas)  # (R, d)
    target = np.asarray(x).mean(axis=0)
    var = float(deltas.var(axis=0).mean())
    bias = float(np.linalg.norm(deltas.mean(axis=0) - target)
                 / np.linalg.norm(target))
    return us, var, bias, deltas.mean(axis=0)


def _codec_bias(mean_arm: np.ndarray, mean_floor: np.ndarray,
                x: np.ndarray) -> float:
    """Codec-attributable bias: distance between the arm's mean delta
    and the unquantized arm's mean over the *identical* tau trace, so
    the (temporally correlated) connectivity Monte-Carlo error is
    common-mode and cancels — what remains is wire bias plus the
    codec's own i.i.d. Monte-Carlo noise."""
    target_norm = float(np.linalg.norm(x.mean(axis=0)))
    return float(np.linalg.norm(mean_arm - mean_floor)) / max(target_norm, 1e-12)


def _mc_bias_tol(var_arm: float, var_floor: float, x: np.ndarray) -> float:
    """Expected relative norm of the codec-noise Monte-Carlo error for
    an unbiased arm: the codec adds ``var_arm - var_floor`` i.i.d.
    per-coordinate variance, so E||mean err|| ≈ sqrt(d · Δvar / R)."""
    dvar = max(var_arm - var_floor, 0.0)
    target_norm = float(np.linalg.norm(x.mean(axis=0)))
    return float(np.sqrt(x.shape[1] * dvar / ROUNDS)) / max(target_norm, 1e-12)


def bench_quant() -> List[Row]:
    rows: List[Row] = []
    model, res, taus, x = _setup()
    n = model.n
    S = variance_S(model, res.A)

    # -- the Theorem 1 connectivity floor: unquantized colrel ----------
    us, var_floor, bias_floor, mean_floor = _arm_stats(
        strategies.get("colrel"), taus, x, res.A)
    rows.append((f"quant/floor_colrel_R{ROUNDS}", us,
                 f"var={var_floor:.5f};bias={bias_floor:.4f};S={S:.2f}"))

    # -- int8 stochastic rounding: variance vs bits --------------------
    int8_var = {}
    xs = np.asarray(x)
    for bits in (2, 4, 6, 8):
        s = strategies.get("quantized", codec="int8",
                           codec_options={"bits": bits})
        us, var, bias, mean = _arm_stats(s, taus, x, res.A)
        int8_var[bits] = var
        bpc = s.codec.descriptor(D).bits_per_coord
        cbias = _codec_bias(mean, mean_floor, xs)
        rows.append((f"quant/int8_b{bits}_R{ROUNDS}", us,
                     f"bits={bpc:.2f};var={var:.5f};bias={bias:.4f};"
                     f"codec_bias={cbias:.4f};"
                     f"floor_ratio={var / var_floor:.3f}"))
        # unbiased ⇒ the codec-attributable mean error is pure
        # Monte-Carlo noise, E||err|| ≈ sqrt(d·Δvar/R); allow 3x
        mc = _mc_bias_tol(var, var_floor, xs)
        assert cbias < max(0.02, 3.0 * mc), (
            f"int8 b={bits} biased: {cbias:.4f} vs MC noise {mc:.4f} "
            "(stochastic rounding must stay unbiased through the relay mix)")

    # monotone variance-vs-bits, converging onto the floor
    assert int8_var[2] > int8_var[4] > int8_var[8], int8_var
    assert int8_var[8] < 1.25 * var_floor, (
        f"int8@8b variance {int8_var[8]:.5f} should sit on the floor "
        f"{var_floor:.5f}")

    # -- sparsification: biased top-k vs corrected rand-k --------------
    topk_cbias = {}
    for frac in (0.125, 0.25, 0.5):
        s = strategies.get("quantized", codec="topk",
                           codec_options={"fraction": frac})
        us, var, bias, mean = _arm_stats(s, taus, x, res.A)
        cbias = _codec_bias(mean, mean_floor, xs)
        topk_cbias[frac] = cbias
        bpc = s.codec.descriptor(D).bits_per_coord
        rows.append((f"quant/topk_f{frac}_R{ROUNDS}", us,
                     f"bits={bpc:.2f};var={var:.5f};bias={bias:.4f};"
                     f"codec_bias={cbias:.4f};"
                     f"floor_ratio={var / var_floor:.3f}"))

    s_rand = strategies.get("quantized", codec="randk",
                            codec_options={"fraction": 0.25})
    us, var_rk, bias_rk, mean_rk = _arm_stats(s_rand, taus, x, res.A)
    cbias_rk = _codec_bias(mean_rk, mean_floor, xs)
    bpc = s_rand.codec.descriptor(D).bits_per_coord
    rows.append((f"quant/randk_f0.25_R{ROUNDS}", us,
                 f"bits={bpc:.2f};var={var_rk:.5f};bias={bias_rk:.4f};"
                 f"codec_bias={cbias_rk:.4f};"
                 f"floor_ratio={var_rk / var_floor:.3f}"))
    # the descriptor hook restores unbiasedness for rand-k (gain k/d
    # divided out): its codec bias is Monte-Carlo noise, while top-k at
    # the same wire budget carries a systematic tail-loss offset
    tol = _mc_bias_tol(var_rk, var_floor, xs)
    assert cbias_rk < max(0.02, 3.0 * tol), (cbias_rk, tol)
    assert topk_cbias[0.125] > cbias_rk, (
        "deterministic top-k at 1/8 density should show the tail-loss "
        f"bias the corrected rand-k lacks: {topk_cbias[0.125]:.4f} vs "
        f"{cbias_rk:.4f}")

    # -- fused Pallas dequant path vs the dequant oracle ---------------
    tu, td = taus[0]
    tuj = jnp.asarray(tu, jnp.float32)
    tdj = jnp.asarray(td, jnp.float32)
    Aj = jnp.asarray(res.A, jnp.float32)
    ctx = ExecutionContext(n_clients=n)
    deltas_tree = {"w": x}
    s_fused = strategies.get("quantized", codec="int8", fused="kernel")
    s_oracle = strategies.get("quantized", codec="int8")
    st0 = s_fused.init_state(n, D)
    fused_fn = jax.jit(
        lambda st: s_fused.aggregate_tree(deltas_tree, tuj, tdj, Aj, st, ctx)
    )
    g_fused, _ = jax.block_until_ready(fused_fn(st0))  # warmup/compile
    t0 = time.perf_counter()
    repeat = 10
    for _ in range(repeat):
        jax.block_until_ready(fused_fn(st0))
    us_f = (time.perf_counter() - t0) / repeat * 1e6
    g_oracle, _ = s_oracle.aggregate_tree(deltas_tree, tuj, tdj, Aj, st0, ctx)
    err = float(jnp.max(jnp.abs(g_fused["w"] - g_oracle["w"])))
    scale_ref = float(jnp.max(jnp.abs(g_oracle["w"]))) + 1e-12
    rows.append((f"quant/fused_vs_oracle_d{D}", us_f,
                 f"max_err={err:.2e};rel={err / scale_ref:.2e}"))
    assert err / scale_ref < 1e-4, (
        f"fused dequant kernel drifted from the per-leaf oracle: {err:.2e}")

    return rows
