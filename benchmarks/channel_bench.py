"""Dynamic-channel benchmarks.

``channel_sampler`` — Gilbert–Elliott trace generation at (n=32,
R=2000): the host-side per-round numpy loop vs the single fused
``lax.scan`` device pass.  Asserts the scanned sampler is >= 10x faster
and that both samplers produce the same distribution (grand-mean
marginals / reciprocity joint against the analytic targets within
ESS-corrected 5-sigma bounds, plus the analytic lag-1 burst
autocorrelation — the statistic that separates Markov from i.i.d.).

``channel_adaptive`` — under a bursty GE trace whose *marginals equal
the static model's*, compares oracle-static FedAvg weights (identity
alpha, the blind baseline) against the adaptive pipeline (online link
estimation + periodic COPT-alpha re-optimization, no oracle knowledge).
Both arms see the identical tau trace (same channel seed).  Asserts the
adaptive run reaches a lower final global loss and a lower realized
PS-weight MSE (E[(sum_j w_j - 1)^2], the realized counterpart of the
paper's variance proxy S).
"""

from __future__ import annotations

import os
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.channel import (
    AdaptiveConfig,
    AdaptiveWeightSchedule,
    MarkovChannel,
    channel_key,
    gilbert_elliott,
    sample_ge_rounds,
    sample_ge_rounds_host,
)
from repro.core import fedavg_weights, topology
from repro.data import quadratic_problem
from repro.data.pipeline import ClientDataset
from repro.fl import FLTrainer
from repro.optim import sgd, sgd_momentum

from .common import Row

# ---------------------------------------------------------------------------
# channel_sampler: host loop vs fused scan
# ---------------------------------------------------------------------------


def _check_moments(ups: np.ndarray, dds: np.ndarray, params, label: str) -> None:
    """Grand-mean marginals vs analytic targets, ESS-corrected 5-sigma."""
    model, R = params.model, ups.shape[0]
    n = model.n
    lam = float(params.lam_up[0])
    ess = (1.0 - lam) / (1.0 + lam)  # effective-sample-size factor per link

    up_t = float(model.p.mean())
    sd = np.sqrt(np.mean(model.p * (1 - model.p)) / (R * ess * n))
    got = float(ups.mean())
    assert abs(got - up_t) < 5 * sd + 1e-9, (
        f"{label}: uplink grand mean {got:.4f} vs {up_t:.4f} (5sd={5*sd:.4f})")

    off = ~np.eye(n, dtype=bool)
    m_pairs = n * (n - 1) // 2
    dd_t = float(model.P[off].mean())
    sd = np.sqrt(np.mean(model.P[off] * (1 - model.P[off])) / (R * ess * m_pairs))
    got = float(dds.mean(0)[off].mean())
    assert abs(got - dd_t) < 5 * sd + 1e-9, (
        f"{label}: D2D grand mean {got:.4f} vs {dd_t:.4f} (5sd={5*sd:.4f})")

    joint = (dds * np.swapaxes(dds, 1, 2)).mean(0)[off].mean()
    e_t = float(model.E[off].mean())
    sd = np.sqrt(np.mean(model.E[off] * (1 - model.E[off])) / (R * ess * m_pairs))
    assert abs(joint - e_t) < 5 * sd + 1e-9, (
        f"{label}: joint grand mean {joint:.4f} vs {e_t:.4f} (5sd={5*sd:.4f})")


def _lag1(ups: np.ndarray) -> float:
    x0, x1 = ups[:-1], ups[1:]
    num = ((x0 - ups.mean(0)) * (x1 - ups.mean(0))).mean()
    den = ups.var(0).mean()
    return float(num / max(den, 1e-12))


def bench_channel_sampler() -> List[Row]:
    rows: List[Row] = []
    n, R = 32, 2000
    model = topology.fully_connected(n, 0.6, p_c=0.5, rho=0.5)
    params = gilbert_elliott(model, memory=0.9)

    # host loop (reference; min of 2 to damp scheduler noise)
    rng = np.random.default_rng(0)
    us_host = np.inf
    for _ in range(2):
        t0 = time.perf_counter()
        ups_h, dds_h = sample_ge_rounds_host(params, rng, R)
        us_host = min(us_host, (time.perf_counter() - t0) * 1e6)

    # fused scan (compile excluded: one warmup pass; min of 5)
    jax.block_until_ready(sample_ge_rounds(params, channel_key(0), R))
    us_scan = np.inf
    for rep in range(5):
        t0 = time.perf_counter()
        ups_s, dds_s = sample_ge_rounds(params, channel_key(1 + rep), R)
        jax.block_until_ready((ups_s, dds_s))
        us_scan = min(us_scan, (time.perf_counter() - t0) * 1e6)
    ups_s, dds_s = np.asarray(ups_s, np.float64), np.asarray(dds_s, np.float64)

    # identical distributions: both against the analytic law
    _check_moments(ups_h, dds_h, params, "host")
    _check_moments(ups_s, dds_s, params, "scan")
    # burstiness present and matching: analytic lag-1 of the uplink taus
    lag_t = float(params.lag1_uplink().mean())
    for label, ups in (("host", ups_h), ("scan", ups_s)):
        got = _lag1(ups)
        assert abs(got - lag_t) < 0.08, f"{label}: lag1 {got:.3f} vs {lag_t:.3f}"

    # ~19x on an unloaded 2-core host; CHANNEL_BENCH_MIN_SPEEDUP lets
    # oversubscribed CI runners lower the gate without losing the signal
    floor = float(os.environ.get("CHANNEL_BENCH_MIN_SPEEDUP", "10"))
    speedup = us_host / us_scan
    assert speedup >= floor, (
        f"scan speedup {speedup:.1f}x < {floor}x at (n={n}, R={R})")
    rows.append((f"channel/host_loop_n{n}_R{R}", us_host, f"rounds={R}"))
    rows.append((f"channel/scan_n{n}_R{R}", us_scan,
                 f"speedup={speedup:.1f}x;lag1={_lag1(ups_s):.3f}"))
    return rows


# ---------------------------------------------------------------------------
# channel_adaptive: oracle-static FedAvg vs estimated + re-optimized alpha
# ---------------------------------------------------------------------------


def _run_arm(model, channel, A, agg, adaptive, *, rounds, local_steps=2, seed=0):
    prob = quadratic_problem(model.n, 16, mu=1.0, L=8.0, hetero=1.0, seed=0)
    H = jnp.asarray(prob["H"], jnp.float32)

    def loss_fn(params, batch):
        x = params["x"]
        d = x - batch["center"][0]
        return 0.5 * d @ (H @ d) + 0.3 * batch["noise"][0] @ x, {}

    clients = []
    for i in range(model.n):
        c = prob["centers"][i].astype(np.float32)
        pool = np.random.default_rng(50 + i).normal(size=(2048, 16)).astype(np.float32)
        clients.append(ClientDataset({"center": np.tile(c, (2048, 1)), "noise": pool},
                                     batch_size=1, seed=seed + i))
    t = FLTrainer(loss_fn, {"x": jnp.zeros(16)}, model, A, clients,
                  sgd(0.02), sgd_momentum(1.0, beta=0.0), local_steps=local_steps,
                  strategy=agg, seed=seed, channel=channel, adaptive=adaptive)
    t.run(rounds)
    tail = rounds // 3
    final_loss = float(np.mean(t.log.loss[-tail:]))
    w_mse = float(np.mean((np.array(t.log.weight_sums[-tail:]) - 1.0) ** 2))
    return final_loss, w_mse, t


def bench_channel_adaptive() -> List[Row]:
    rows: List[Row] = []
    model = topology.paper_fig2a()
    rounds = 240

    def bursty_channel():
        # identical marginals to `model`, ~10-round blockage bursts
        return MarkovChannel(gilbert_elliott(model, memory=0.9), seed=3)

    t0 = time.perf_counter()
    loss_f, wmse_f, _ = _run_arm(
        model, bursty_channel(), fedavg_weights(model.n),
        "fedavg_blind", None, rounds=rounds)
    us_f = (time.perf_counter() - t0) * 1e6

    t0 = time.perf_counter()
    adaptive = AdaptiveWeightSchedule(
        model.n,
        AdaptiveConfig(every=40, warmup=30, sweeps=10, fine_tune_sweeps=10,
                       prune_below=0.02),
    )
    loss_a, wmse_a, tr = _run_arm(
        model, bursty_channel(), fedavg_weights(model.n),
        "colrel", adaptive, rounds=rounds)
    us_a = (time.perf_counter() - t0) * 1e6

    assert loss_a < loss_f, (
        f"adaptive loss {loss_a:.4f} not below oracle-static FedAvg {loss_f:.4f}")
    assert wmse_a < wmse_f, (
        f"adaptive weight-MSE {wmse_a:.4f} not below FedAvg {wmse_f:.4f}")
    rows.append((f"channel_adaptive/fedavg_static_R{rounds}", us_f,
                 f"loss={loss_f:.4f};w_mse={wmse_f:.4f}"))
    rows.append((f"channel_adaptive/estimated_reopt_R{rounds}", us_a,
                 f"loss={loss_a:.4f};w_mse={wmse_a:.4f};"
                 f"reopts={len(tr.log.reopt_rounds)};"
                 f"p_err_final={tr.log.est_p_err[-1]:.3f}"))
    return rows
