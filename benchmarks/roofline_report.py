"""Summarize the dry-run JSON records into the §Roofline table."""

from __future__ import annotations

import json
from pathlib import Path
from typing import List

from .common import Row

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"


def load_records(mesh: str | None = None, tag: str = "") -> list[dict]:
    recs = []
    if not RESULTS.exists():
        return recs
    for f in sorted(RESULTS.glob("*.json")):
        r = json.loads(f.read_text())
        if mesh and r["mesh"] != mesh:
            continue
        if (r.get("tag") or "") != tag:
            continue
        recs.append(r)
    return recs


def bench_dryrun_roofline() -> List[Row]:
    rows: List[Row] = []
    recs = load_records(mesh="16x16")
    if not recs:
        return [("roofline/none", 0.0, "run repro.launch.dryrun first")]
    for r in recs:
        t = r["roofline"]
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}",
            max(t["compute_s"], t["memory_s"], t["collective_s"]) * 1e6,
            f"bottleneck={t['bottleneck'].replace('_s','')};"
            f"c={t['compute_s']:.3f};m={t['memory_s']:.3f};x={t['collective_s']:.3f};"
            f"useful={r['useful_flop_ratio'] and round(r['useful_flop_ratio'],3)}",
        ))
    n_multi = len(load_records(mesh="2x16x16"))
    rows.append(("roofline/summary", 0.0,
                 f"single_pod={len(recs)};multi_pod={n_multi}"))
    return rows
