"""Summarize the dry-run JSON records into the §Roofline table.

Each row also reports the *achieved* HBM bytes/s the roofline model
implies for that step — HLO bytes over the modeled step time — as a
fraction of the v5e HBM ceiling (819 GB/s): a memory-bound step pins
the fraction at ~1.0 by construction, while compute- or
collective-bound steps show how much bandwidth headroom remains.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import List

from repro.launch.roofline import HBM_BW

from .common import Row

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"


def load_records(mesh: str | None = None, tag: str = "") -> list[dict]:
    recs = []
    if not RESULTS.exists():
        return recs
    for f in sorted(RESULTS.glob("*.json")):
        r = json.loads(f.read_text())
        if mesh and r["mesh"] != mesh:
            continue
        if (r.get("tag") or "") != tag:
            continue
        recs.append(r)
    return recs


def bench_dryrun_roofline() -> List[Row]:
    rows: List[Row] = []
    recs = load_records(mesh="16x16")
    if not recs:
        return [("roofline/none", 0.0, "run repro.launch.dryrun first")]
    for r in recs:
        t = r["roofline"]
        step_s = max(t["compute_s"], t["memory_s"], t["collective_s"])
        # achieved HBM bandwidth under the roofline step time, as a
        # fraction of the 819 GB/s ceiling
        achieved = (r["hlo_bytes_per_chip"] / step_s) if step_s else 0.0
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}",
            step_s * 1e6,
            f"bottleneck={t['bottleneck'].replace('_s','')};"
            f"c={t['compute_s']:.3f};m={t['memory_s']:.3f};x={t['collective_s']:.3f};"
            f"bw={achieved / 1e9:.0f}GBps({achieved / HBM_BW:.2f});"
            f"useful={r['useful_flop_ratio'] and round(r['useful_flop_ratio'],3)}",
        ))
    n_multi = len(load_records(mesh="2x16x16"))
    rows.append(("roofline/summary", 0.0,
                 f"single_pod={len(recs)};multi_pod={n_multi}"))
    return rows
