"""Telemetry overhead benchmark: the instrumented round vs the bare one.

Trains the strongly-convex quadratic task at (n=256, R=256, K=64)
through the chunked scan engine twice with identical seeds — once with
telemetry off and once with the full observability stack attached (the
instrumented round with its per-client vector metrics and outage-streak
carry, a real ``JsonlSink`` + ``CsvSummarySink`` writing to disk, fenced
throughput timing) — and measures rounds/sec for each.

The design target (DESIGN.md §11) is that observability is cheap enough
to leave on: the device tier adds O(n) lane-local work to an O(n·d)
round, and the host tier writes ~120 bytes/round of buffered JSONL while
vector histories accumulate as numpy.  The gate asserts the telemetry-on
path keeps >= 95% of the bare throughput (``TELEMETRY_BENCH_MAX_OVERHEAD``
overrides the 5% budget for throttled shared CI runners).  Timing takes
the best of ``REPS`` interleaved repetitions per path, compile excluded,
to damp scheduler noise.

Correctness rides along: both runs must produce *bitwise-identical*
loss / participation / weight-sum / uplink-bits trajectories and final
params (the instrumentation wrapper only reads the base round's inputs
and outputs), and the per-client vectors must reduce exactly to the
scalar streams.

Emits ``BENCH_telemetry.json`` with both throughputs and the measured
overhead fraction.
"""

from __future__ import annotations

import json
import os
import pathlib
import tempfile
import time
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.channel import MarkovChannel, gilbert_elliott
from repro.core import fedavg_weights, topology
from repro.data import quadratic_problem
from repro.data.pipeline import ClientDataset
from repro.fl import FLTrainer
from repro.telemetry import CsvSummarySink, JsonlSink, MetricsLogger

from .common import Row

N, R, CHUNK = 256, 256, 64
WARM = CHUNK  # rounds consumed before timing (compile + stream warmup)
REPS = 3      # interleaved repetitions; best-of per path


def _make_trainer(*, telemetry: bool = False, metrics=None,
                  seed: int = 0) -> FLTrainer:
    from repro.optim import sgd, sgd_momentum

    prob = quadratic_problem(N, 16, mu=1.0, L=8.0, hetero=1.0, seed=0)
    H = jnp.asarray(prob["H"], jnp.float32)

    def loss_fn(params, batch):
        x = params["x"]
        d = x - batch["center"][0]
        return 0.5 * d @ (H @ d) + 0.3 * batch["noise"][0] @ x, {}

    clients = []
    for i in range(N):
        c = prob["centers"][i].astype(np.float32)
        pool = np.random.default_rng(50 + i).normal(size=(256, 16)).astype(np.float32)
        clients.append(ClientDataset({"center": np.tile(c, (256, 1)), "noise": pool},
                                     batch_size=1, seed=seed + i))
    model = topology.fully_connected(N, 0.6, p_c=0.7, rho=0.5)
    channel = MarkovChannel(gilbert_elliott(model, memory=0.9), seed=seed,
                            block=256)
    # fedavg weights: COPT at n=256 is minutes of host work and the round
    # body is identical either way — this bench measures telemetry, not alpha
    return FLTrainer(loss_fn, {"x": jnp.zeros(16)}, model, fedavg_weights(N),
                     clients, sgd(0.02), sgd_momentum(1.0, beta=0.0),
                     local_steps=2, strategy="colrel", seed=seed,
                     channel=channel, telemetry=telemetry, metrics=metrics)


def _timed_run(telemetry: bool, out_dir: pathlib.Path) -> "tuple[float, FLTrainer]":
    metrics = None
    if telemetry:
        metrics = MetricsLogger([JsonlSink(out_dir / "events.jsonl"),
                                 CsvSummarySink(out_dir / "rounds.csv")])
    t = _make_trainer(telemetry=telemetry, metrics=metrics)
    t.run(WARM, chunk=CHUNK)
    t0 = time.perf_counter()
    t.run(R, chunk=CHUNK)
    dt = time.perf_counter() - t0
    if metrics is not None:
        metrics.flush()
    return dt, t


def bench_telemetry() -> List[Row]:
    tmp = pathlib.Path(tempfile.mkdtemp(prefix="telemetry_bench_"))
    s_off, s_on = float("inf"), float("inf")
    t_off = t_on = None
    for rep in range(REPS):
        dt, t_off = _timed_run(False, tmp)
        s_off = min(s_off, dt)
        dt, t_on = _timed_run(True, tmp / f"rep{rep}")
        s_on = min(s_on, dt)

    # the instrumented round is inert: bitwise-identical trajectories
    for field in ("loss", "participation", "weight_sums", "uplink_bits"):
        a, b = getattr(t_off.log, field), getattr(t_on.log, field)
        assert a == b, f"telemetry changed the {field} trajectory"
    assert np.array_equal(np.asarray(t_off.params["x"]),
                          np.asarray(t_on.params["x"]))
    # ...and the vectors reduce exactly to the scalar streams
    part = t_on.metrics.vector("client_participation")
    assert part.shape == (WARM + R, N)
    np.testing.assert_array_equal(
        part.sum(axis=1), np.float64(np.float32(t_off.log.participation)))

    rps_off = R / s_off
    rps_on = R / s_on
    overhead = max(0.0, 1.0 - rps_on / rps_off)
    budget = float(os.environ.get("TELEMETRY_BENCH_MAX_OVERHEAD", "0.05"))
    assert overhead <= budget, (
        f"telemetry overhead {overhead:.1%} > {budget:.0%} budget at "
        f"(n={N}, R={R}, K={CHUNK}): {rps_off:.1f} -> {rps_on:.1f} rounds/s")

    with open("BENCH_telemetry.json", "w") as f:
        json.dump({
            "n_clients": N,
            "rounds": R,
            "chunk": CHUNK,
            "rounds_per_sec_off": round(rps_off, 1),
            "rounds_per_sec_on": round(rps_on, 1),
            "overhead_frac": round(overhead, 4),
            "budget_frac": budget,
            "bitwise_identical": True,
        }, f, indent=1)

    return [
        (f"telemetry/off_n{N}_K{CHUNK}", s_off * 1e6 / R,
         f"rounds_per_sec={rps_off:.1f}"),
        (f"telemetry/on_n{N}_K{CHUNK}", s_on * 1e6 / R,
         f"rounds_per_sec={rps_on:.1f};overhead={overhead:.1%}"),
    ]
