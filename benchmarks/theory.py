"""Theorem 1 / Algorithm 3 benchmarks.

theorem1 — strongly-convex quadratic: validates (i) the O(1/r) tail of
E||x^(r) - x*||^2 under the theorem's step-size schedule, and (ii) that
the COPT-alpha-optimized A (smaller S) yields a smaller error floor than
the feasible initialization (larger S).

copt_alpha — Algorithm 3 runtime scaling (the paper's O(I(n^2 + K))).
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro import strategies
from repro.core import (
    initial_weights,
    optimize_weights,
    sample_round,
    variance_S,
)
from repro.core import topology
from repro.data import quadratic_problem
from repro.data.pipeline import ClientDataset
from repro.fl import FLTrainer
from repro.optim import sgd, sgd_momentum, inverse_round_decay

from .common import Row


def _quad_mse(model, A, *, rounds=120, local_steps=8, seeds=(0, 1, 2), sigma=0.5,
              record_tail=False):
    prob = quadratic_problem(model.n, 16, mu=1.0, L=8.0, hetero=1.0, seed=0)
    H = jnp.asarray(prob["H"], jnp.float32)
    xs = jnp.asarray(prob["x_star"], jnp.float32)

    def loss_fn(params, batch):
        x = params["x"]
        d = x - batch["center"][0]
        return 0.5 * d @ (H @ d) + sigma * batch["noise"][0] @ x, {}

    def clients(seed):
        out = []
        for i in range(model.n):
            c = prob["centers"][i].astype(np.float32)
            pool = np.random.default_rng(50 + i).normal(size=(4096, 16)).astype(np.float32)
            out.append(ClientDataset({"center": np.tile(c, (4096, 1)), "noise": pool},
                                     batch_size=1, seed=seed + i))
        return out

    # Theorem 1 schedule: eta_r = (4/mu) / (rT + 1), clipped for stability
    sched = lambda step: jnp.minimum(
        inverse_round_decay(4.0, local_steps)(step), jnp.float32(0.05)
    )
    errs, tails = [], []
    for seed in seeds:
        t = FLTrainer(loss_fn, {"x": jnp.zeros(16)}, model, A, clients(seed),
                      sgd(sched), sgd_momentum(1.0, beta=0.0),
                      local_steps=local_steps, strategy=strategies.get("colrel", fused=True),
                      seed=seed)
        tail = []
        for r in range(rounds):
            t.run(1)
            if record_tail and r >= rounds // 2:
                tail.append(float(jnp.sum((t.params["x"] - xs) ** 2)))
        errs.append(float(jnp.sum((t.params["x"] - xs) ** 2)))
        tails.append(tail)
    return float(np.mean(errs)), tails


def bench_theorem1() -> List[Row]:
    rows: List[Row] = []
    m = topology.paper_fig2a()
    res = optimize_weights(m, sweeps=25, fine_tune_sweeps=25)
    A0 = initial_weights(m)

    t0 = time.perf_counter()
    e_opt, tails = _quad_mse(m, res.A, record_tail=True)
    us = (time.perf_counter() - t0) * 1e6
    e_init, _ = _quad_mse(m, A0)

    # O(1/r) check: tail error at r and 2r should shrink ~2x (ratio in [1.2, 4])
    tail = np.mean([t for t in tails if t], axis=0)
    r_half, r_full = len(tail) // 4, len(tail) - 1
    decay_ratio = tail[r_half] / max(tail[r_full], 1e-12)
    rows.append(("theorem1/opt_A", us / 120,
                 f"mse={e_opt:.4f};S={res.S:.2f};tail_decay={decay_ratio:.2f}"))
    rows.append(("theorem1/init_A", 0.0,
                 f"mse={e_init:.4f};S={res.S_init:.2f};S_ratio={res.S_init/res.S:.2f}"))
    return rows


def bench_copt_alpha() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    for n in (10, 20, 40):
        p = rng.uniform(0.1, 0.9, n)
        m = topology.fully_connected(n, p, p_c=0.6, rho=1.0)
        t0 = time.perf_counter()
        res = optimize_weights(m, sweeps=15, fine_tune_sweeps=15)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"copt_alpha/n{n}", us,
                     f"S0={res.S_init:.2f};S={res.S:.2f};x{res.S_init/max(res.S,1e-9):.1f}"))
    return rows
