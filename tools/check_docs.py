"""Documentation checks: cross-reference links + executable snippets.

Two passes over README.md, DESIGN.md and docs/*.md (run by the CI
``docs`` job; also handy locally):

1. **link check** — every relative markdown link ``[text](path)`` must
   resolve to a file that exists (``#anchors`` stripped; ``http(s)``
   and ``mailto`` links skipped — this container is offline).
2. **doctest** — every fenced ```` ```python ```` block containing
   ``>>>`` prompts is executed with :mod:`doctest`.  Examples in the
   docs are contracts: if the registry listing or a codec bound
   changes, the docs fail CI instead of rotting.

Usage::

    PYTHONPATH=src python tools/check_docs.py [files...]

Exits non-zero on the first category of failure, printing every
offender first.
"""

from __future__ import annotations

import doctest
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

# [text](target) — excluding images and in-page anchors-only links
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# fenced python blocks
_FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def default_files():
    files = [REPO / "README.md", REPO / "DESIGN.md"]
    files += sorted((REPO / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check_links(files) -> list:
    errors = []
    for md in files:
        text = md.read_text()
        for m in _LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path = target.split("#", 1)[0]
            if not path:  # pure in-page anchor
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(REPO)}: broken link -> {target}")
    return errors


def run_doctests(files) -> list:
    errors = []
    runner_flags = doctest.ELLIPSIS | doctest.NORMALIZE_WHITESPACE
    for md in files:
        text = md.read_text()
        for i, m in enumerate(_FENCE_RE.finditer(text)):
            snippet = m.group(1)
            if ">>>" not in snippet:
                continue
            name = f"{md.relative_to(REPO)}[block {i}]"
            parser = doctest.DocTestParser()
            test = parser.get_doctest(snippet, {"__name__": "__docs__"},
                                      name, str(md), 0)
            out = []
            runner = doctest.DocTestRunner(optionflags=runner_flags)
            runner.run(test, out=out.append)
            if runner.failures:
                errors.append(f"{name}: {runner.failures} doctest failure(s)\n"
                              + "".join(out))
            else:
                print(f"ok: {name} ({runner.tries} examples)")
    return errors


def main(argv) -> int:
    files = ([pathlib.Path(a).resolve() for a in argv[1:]]
             or default_files())
    link_errors = check_links(files)
    for e in link_errors:
        print(f"LINK: {e}", file=sys.stderr)
    doc_errors = run_doctests(files)
    for e in doc_errors:
        print(f"DOCTEST: {e}", file=sys.stderr)
    if link_errors or doc_errors:
        print(f"FAILED: {len(link_errors)} link / {len(doc_errors)} doctest "
              "errors", file=sys.stderr)
        return 1
    print(f"checked {len(files)} files: links ok, doctests ok")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
