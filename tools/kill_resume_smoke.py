#!/usr/bin/env python
"""Kill-and-resume smoke: SIGTERM the launcher mid-run, resume, and
require a bitwise-identical trajectory.

Drives ``repro.launch.train`` three times (n=8, R=32, K=8, telemetry
on):

1. **reference** — uninterrupted, 32 rounds; its ``rounds.csv`` is the
   golden trajectory.
2. **victim** — same config, fresh dirs, checkpointing every chunk
   (``--ckpt-dir --ckpt-every 8``); SIGTERM is sent after the second
   chunk line appears on stdout.  The launcher's PreemptionGuard must
   latch the signal, commit a final checkpoint at the next chunk
   boundary, print the preemption notice and exit 0.
3. **resume** — ``--resume`` against the victim's dirs, running to the
   same 32-round total.

The resumed run's ``rounds.csv`` must equal the reference's byte for
byte (the CSV sink trims to the checkpoint round on resume, so the
stream is exactly-once), and the final committed checkpoint must sit at
round 32.  Exit 0 on success; any deviation is a hard failure.

Usage:  PYTHONPATH=src python tools/kill_resume_smoke.py
"""

import pathlib
import signal
import subprocess
import sys
import tempfile

REPO = pathlib.Path(__file__).resolve().parent.parent
FLAGS = ["--smoke", "--n-clients", "8", "--rounds", "32", "--chunk", "8",
         "--channel", "markov", "--seed", "0"]


def launch(extra, *, kill_after_chunks=None):
    cmd = [sys.executable, "-m", "repro.launch.train", *FLAGS, *extra]
    proc = subprocess.Popen(cmd, cwd=REPO, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True, bufsize=1)
    out, chunks = [], 0
    for line in proc.stdout:
        out.append(line)
        sys.stdout.write("  | " + line)
        if kill_after_chunks is not None and line.startswith("rounds "):
            chunks += 1
            if chunks == kill_after_chunks:
                proc.send_signal(signal.SIGTERM)
    proc.wait(timeout=600)
    return proc.returncode, "".join(out)


def fail(msg):
    print(f"FAIL: {msg}")
    sys.exit(1)


def main():
    tmp = pathlib.Path(tempfile.mkdtemp(prefix="kill_resume_smoke_"))
    ref_m, vic_m, ck = tmp / "ref_metrics", tmp / "metrics", tmp / "ckpt"

    print("== reference (uninterrupted) ==")
    rc, _ = launch(["--metrics-dir", str(ref_m)])
    if rc != 0:
        fail(f"reference run exited {rc}")

    print("== victim (SIGTERM after chunk 2) ==")
    rc, out = launch(["--metrics-dir", str(vic_m), "--ckpt-dir", str(ck),
                      "--ckpt-every", "8"], kill_after_chunks=2)
    if rc != 0:
        fail(f"victim run exited {rc}; preemption must drain and exit clean")
    if "[ckpt] preempted" not in out:
        fail("victim run never reported the latched preemption")
    committed = sorted(p.name for p in ck.glob("*.sha256"))
    if not committed:
        fail("victim run committed no checkpoint")
    print(f"  committed after kill: {committed}")

    print("== resume ==")
    rc, out = launch(["--metrics-dir", str(vic_m), "--ckpt-dir", str(ck),
                      "--ckpt-every", "8", "--resume"])
    if rc != 0:
        fail(f"resumed run exited {rc}")
    if "resuming from" not in out:
        fail("resumed run never reported its checkpoint source")
    if not (ck / "ckpt_00000032.msgpack.sha256").exists():
        fail("resumed run did not commit the final round-32 checkpoint")

    ref = (ref_m / "rounds.csv").read_bytes()
    got = (vic_m / "rounds.csv").read_bytes()
    if ref != got:
        fail("resumed rounds.csv differs from the uninterrupted run")
    n_rows = len(ref.splitlines()) - 1
    print(f"PASS: {n_rows} rounds bitwise-identical across kill/resume")


if __name__ == "__main__":
    main()
