"""The checkpoint subsystem, component by component (DESIGN.md §12).

Layers:
  1. the msgpack codec (``checkpoint/io.py``) — bf16/tuple/scalar
     round-trips and the decode-copy fix (restored arrays are mutable);
  2. PRNG key encoding — typed jax keys survive the codec, raw uint32
     key arrays pass through untouched;
  3. the writer — sharded snapshot/reassembly, the sha256 commit
     protocol (corruption refused, orphan payloads not committed),
     keep-last-k retention, and the async writer's overlap semantics
     (snapshot isolation, error surfacing, drain ordering);
  4. state hooks — every registered strategy's ``agg_state``, every
     channel family's gate state (mid-block and across-block), the link
     estimator / adaptive schedule, and the MetricsLogger cursor +
     sink resume behavior;
  5. schema-level guards — strategy/version/telemetry/client-count
     mismatches refuse to restore;
  6. preemption — the launcher guard latches SIGTERM/SIGINT and
     restores the original handlers on exit.
"""

import dataclasses
import json
import os
import pathlib
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import strategies
from repro.channel import (
    ClusteredMarkovChannel,
    ClusteredStaticChannel,
    LinkEstimator,
    MarkovChannel,
    MobilityChannel,
    StaticChannel,
    gilbert_elliott,
    gilbert_elliott_clustered,
)
from repro.channel.schedule import AdaptiveConfig, AdaptiveWeightSchedule
from repro.checkpoint import io as ckpt_io
from repro.ckpt import (
    CKPT_VERSION,
    AsyncCheckpointer,
    CheckpointWriter,
    PreemptionGuard,
    decode_prng_key,
    encode_prng_key,
    read_state,
    rng_from_json,
    rng_state_to_json,
    write_state,
)
from repro.core import topology
from repro.telemetry import CsvSummarySink, JsonlSink, MetricsLogger, RunManifest


def _trees_equal(a, b, path=""):
    la = jax.tree.leaves(a)
    lb = jax.tree.leaves(b)
    assert len(la) == len(lb), path
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# 1. the msgpack codec: round-trips + the decode-copy fix
# ---------------------------------------------------------------------------


def test_io_roundtrip_bf16():
    x = jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3) / 7
    back = ckpt_io._decode(ckpt_io._encode(np.asarray(x)))
    assert back.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(back, np.float32),
                                  np.asarray(x, np.float32))


def test_io_roundtrip_tuple_and_scalar():
    tree = {"t": (np.float32(1.5), [np.arange(4), ()]),
            "s": 3, "f": 2.25, "none": None, "b": True, "name": "adam"}
    back = ckpt_io._decode(ckpt_io._encode(tree))
    assert isinstance(back["t"], tuple)
    assert isinstance(back["t"][1], list)
    assert back["t"][1][1] == ()
    assert back["t"][0] == np.float32(1.5)
    assert back["t"][0].dtype == np.float32  # numpy scalars keep dtype
    assert back["s"] == 3 and back["f"] == 2.25
    assert back["none"] is None and back["b"] is True and back["name"] == "adam"
    np.testing.assert_array_equal(back["t"][1][0], np.arange(4))


def test_io_decoded_arrays_are_mutable():
    """Seed-era bug: ``np.frombuffer`` yields read-only arrays, so a
    restored optimizer state raised on its first in-place update."""
    for arr in (np.arange(8, dtype=np.float32),
                np.ones((2, 2), dtype=jnp.bfloat16)):
        back = ckpt_io._decode(ckpt_io._encode(arr))
        assert back.flags.writeable
        back += 1  # the actual failure mode: in-place mutation


# ---------------------------------------------------------------------------
# 2. PRNG key encoding
# ---------------------------------------------------------------------------


def test_typed_key_roundtrips():
    key = jax.random.key(42)
    enc = encode_prng_key(key)
    assert isinstance(enc, dict)
    back = decode_prng_key(enc)
    np.testing.assert_array_equal(jax.random.key_data(back),
                                  jax.random.key_data(key))
    # and the stream continues identically
    np.testing.assert_array_equal(
        np.asarray(jax.random.uniform(back, (4,))),
        np.asarray(jax.random.uniform(key, (4,))))


def test_raw_key_passes_through():
    key = jax.random.PRNGKey(7)  # raw uint32 — already codec-friendly
    assert encode_prng_key(key) is key
    tree = read_state(write_state(_tmp() / "k.msgpack", {"k": key}))
    np.testing.assert_array_equal(tree["k"], np.asarray(key))


_TMP = []


def _tmp() -> pathlib.Path:
    import tempfile
    p = pathlib.Path(tempfile.mkdtemp())
    _TMP.append(p)
    return p


# ---------------------------------------------------------------------------
# 3. the writer
# ---------------------------------------------------------------------------


def _state_tree():
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.bfloat16)},
        "agg": (jnp.zeros((4, 6)), ()),
        "host": np.arange(5.0),
        "round": 7,
        "rng": rng_state_to_json(np.random.default_rng(3)),
    }


def test_write_read_state_roundtrip():
    tree = _state_tree()
    back = read_state(write_state(_tmp() / "s.msgpack", tree))
    assert back["round"] == 7 and back["rng"] == tree["rng"]
    assert isinstance(back["agg"], tuple) and back["agg"][1] == ()
    _trees_equal(
        {k: tree[k] for k in ("params", "agg", "host")},
        {k: back[k] for k in ("params", "agg", "host")})


def test_read_state_refuses_corruption():
    path = write_state(_tmp() / "s.msgpack", _state_tree())
    data = bytearray(path.read_bytes())
    data[len(data) // 2] ^= 0xFF
    path.write_bytes(bytes(data))
    with pytest.raises(ValueError, match="corrupt"):
        read_state(path)


def test_writer_retention_and_latest():
    w = CheckpointWriter(_tmp() / "ck", keep=2)
    for step in (4, 8, 12, 16):
        w.save(step, {"step": step})
    assert w.steps() == [12, 16]
    assert w.latest_step() == 16
    assert w.load()["step"] == 16
    assert w.load(12)["step"] == 12
    # GC removed both payload and sidecar of the dropped steps
    assert not w.path_for(4).exists()
    assert not (w.path_for(4).parent / "ckpt_00000004.msgpack.sha256").exists()


def test_orphan_payload_is_not_committed():
    """Commit protocol: a checkpoint exists iff its sidecar exists, so a
    crash between payload and sidecar rename is a clean no-op."""
    w = CheckpointWriter(_tmp() / "ck", keep=0)
    w.save(4, {"step": 4})
    w.path_for(8).write_bytes(b"torn write")  # payload, no sidecar
    assert w.steps() == [4]
    assert w.latest_step() == 4


def test_snapshot_isolation_from_host_mutation():
    """The async writer snapshots host arrays on the caller thread; the
    trainer mutating them afterwards must not corrupt the checkpoint."""
    ck = AsyncCheckpointer(_tmp() / "ck", keep=0)
    host = np.arange(4.0)
    ck.save(1, {"host": host})
    host += 100.0  # trainer moves on while the writer serializes
    ck.wait()
    np.testing.assert_array_equal(ck.load(1)["host"], np.arange(4.0))
    ck.close()


def test_async_checkpointer_surfaces_writer_errors():
    ck = AsyncCheckpointer(_tmp() / "ck", keep=0)
    ck.save(1, {"bad": object()})  # not serializable -> worker-side failure
    with pytest.raises(RuntimeError, match="async checkpoint write failed"):
        ck.wait()
    ck.close()


def test_async_checkpointer_commit_order_and_drain():
    ck = AsyncCheckpointer(_tmp() / "ck", keep=3)
    for step in (2, 4, 6, 8):
        ck.save(step, {"x": jnp.full((3,), step)})
    ck.close()  # drains the queue before stopping
    w = CheckpointWriter(ck.writer.dir, keep=3)
    assert w.steps() == [4, 6, 8]
    np.testing.assert_array_equal(w.load(8)["x"], np.full((3,), 8.0))


def test_rng_json_roundtrip_continues_stream():
    rng = np.random.default_rng(11)
    rng.normal(size=100)
    back = rng_from_json(rng_state_to_json(rng))
    np.testing.assert_array_equal(back.normal(size=32), rng.normal(size=32))


def test_rng_json_refuses_foreign_bit_generator():
    s = json.dumps({"bit_generator": "MT19937", "state": {}})
    with pytest.raises(ValueError, match="MT19937"):
        rng_from_json(s)


# ---------------------------------------------------------------------------
# 4a. strategy agg_state hooks: every registered strategy round-trips
# ---------------------------------------------------------------------------

_STRATEGY_NAMES = sorted(strategies.available())


def test_strategy_registry_fully_covered():
    """The parametrized round-trip below covers every registered
    strategy — a new registration without hook coverage fails here."""
    assert set(_STRATEGY_NAMES) == {
        "async_colrel", "clustered", "colrel", "fedavg_blind",
        "fedavg_nonblind", "fedavg_perfect", "memory", "multihop",
        "quantized",
    }


@pytest.mark.parametrize("name", _STRATEGY_NAMES)
def test_strategy_agg_state_roundtrip(name):
    s = strategies.get(name)
    state = s.init_state(6, 24)
    # give carried leaves a non-init value so the trip is non-trivial
    state = jax.tree.map(
        lambda x: x + 3 if np.issubdtype(np.asarray(x).dtype, np.floating)
        else x, state)
    path = write_state(_tmp() / f"{name}.msgpack",
                       {"agg": s.checkpoint_state(state)})
    back = s.restore_state(read_state(path)["agg"])
    assert jax.tree.structure(back) == jax.tree.structure(state)
    _trees_equal(back, state)
    for x, y in zip(jax.tree.leaves(back), jax.tree.leaves(state)):
        assert x.dtype == y.dtype


def test_quantized_codec_key_continues_stream():
    """The quantized strategy's carried PRNG key must continue the
    dither stream, not restart it."""
    s = strategies.get("quantized")
    state = s.init_state(4, 16)
    key = jax.tree.leaves(state)[0]
    advanced = jax.tree.map(
        lambda x: jax.random.split(x)[0] if np.asarray(x).dtype == np.uint32
        else x, state)
    back = s.restore_state(read_state(write_state(
        _tmp() / "q.msgpack", {"agg": s.checkpoint_state(advanced)}))["agg"])
    with pytest.raises(AssertionError):
        _trees_equal(back, state)  # advanced, not the init key
    _trees_equal(back, advanced)
    del key


# ---------------------------------------------------------------------------
# 4b. channel gate state: restore regenerates the stream bitwise
# ---------------------------------------------------------------------------


def _channel_factories():
    model = topology.fully_connected(6, 0.4, p_c=0.7, rho=0.6)
    cmodel = topology.clustered_blocks(6, 0.4, 3, p_intra=0.7, rho=0.6)
    return {
        "static": lambda: StaticChannel(model, seed=5, block=4),
        "markov": lambda: MarkovChannel(gilbert_elliott(model, memory=0.8),
                                        seed=5, block=4),
        "clustered_static": lambda: ClusteredStaticChannel(
            cmodel, seed=5, block=4),
        "clustered_markov": lambda: ClusteredMarkovChannel(
            gilbert_elliott_clustered(cmodel, memory=0.8), seed=5, block=4),
        "mobility": lambda: MobilityChannel(6, epoch=3, seed=5),
    }


@pytest.mark.parametrize("kind", sorted(_channel_factories()))
@pytest.mark.parametrize("consumed", [5, 8], ids=["mid-block", "block-edge"])
def test_channel_state_roundtrip_bitwise(kind, consumed):
    """Serve some rounds, checkpoint (through the full serialization
    path), restore onto a fresh channel, continue: the stream must be
    bitwise identical to an uninterrupted one — across block refills."""
    mk = _channel_factories()[kind]
    ref = mk()
    ref_stream = [ref.tau_for_round(r) for r in range(14)]

    a = mk()
    for r in range(consumed):
        tu, td = a.tau_for_round(r)
        np.testing.assert_array_equal(tu, ref_stream[r][0])
    state = read_state(write_state(_tmp() / "ch.msgpack",
                                   a.checkpoint_state()))
    b = mk()
    b.restore_state(state)
    for r in range(consumed, 14):
        tu, td = b.tau_for_round(r)
        np.testing.assert_array_equal(tu, ref_stream[r][0], err_msg=f"r={r}")
        np.testing.assert_array_equal(td, ref_stream[r][1], err_msg=f"r={r}")


def test_channel_restore_refuses_mismatches():
    model = topology.fully_connected(6, 0.4, p_c=0.7, rho=0.6)
    a = StaticChannel(model, seed=5, block=4)
    a.tau_for_round(0)
    state = a.checkpoint_state()
    with pytest.raises(ValueError, match="block size"):
        StaticChannel(model, seed=5, block=8).restore_state(state)
    with pytest.raises(ValueError, match="StaticChannel"):
        MarkovChannel(gilbert_elliott(model, memory=0.8),
                      seed=5, block=4).restore_state(state)


def test_mobility_checkpoint_carries_current_epoch_model():
    """Mid-epoch, the served LinkModel was derived from positions that no
    longer exist; the checkpoint must ship it, not re-derive it."""
    a = MobilityChannel(6, epoch=4, seed=9)
    for r in range(6):  # into epoch 1
        a.tau_for_round(r)
    state = read_state(write_state(_tmp() / "mob.msgpack",
                                   a.checkpoint_state()))
    b = MobilityChannel(6, epoch=4, seed=9)
    b.restore_state(state)
    ref = a.model_for_round(5)
    got = b.model_for_round(5)
    np.testing.assert_array_equal(got.p, ref.p)
    np.testing.assert_array_equal(got.P, ref.P)


# ---------------------------------------------------------------------------
# 4c. estimator / adaptive schedule
# ---------------------------------------------------------------------------


def test_estimator_roundtrip():
    rng = np.random.default_rng(0)
    a = LinkEstimator(5, decay=0.99)
    for _ in range(30):
        a.update(rng.integers(0, 2, 5).astype(float),
                 rng.integers(0, 2, (5, 5)).astype(float))
    state = read_state(write_state(_tmp() / "est.msgpack",
                                   a.checkpoint_state()))
    b = LinkEstimator(5, decay=0.99)
    b.restore_state(state)
    assert b.rounds == a.rounds
    np.testing.assert_array_equal(b.p_hat, a.p_hat)
    np.testing.assert_array_equal(b.P_hat, a.P_hat)
    np.testing.assert_array_equal(b.E_hat, a.E_hat)
    # posterior continues identically
    tu = rng.integers(0, 2, 5).astype(float)
    td = rng.integers(0, 2, (5, 5)).astype(float)
    a.update(tu, td)
    b.update(tu, td)
    np.testing.assert_array_equal(b.p_hat, a.p_hat)


def test_adaptive_schedule_roundtrip_preserves_cadence():
    rng = np.random.default_rng(1)

    def feed(sched, r0, rounds):
        out = []
        for r in range(r0, r0 + rounds):
            A = sched.step(r, rng2.integers(0, 2, 4).astype(float),
                           rng2.integers(0, 2, (4, 4)).astype(float))
            out.append(None if A is None else np.asarray(A))
        return out

    cfg = AdaptiveConfig(every=6, warmup=4, sweeps=3, fine_tune_sweeps=3)
    rng2 = np.random.default_rng(2)
    ref = AdaptiveWeightSchedule(4, cfg)
    ref_out = feed(ref, 0, 18)

    rng2 = np.random.default_rng(2)
    a = AdaptiveWeightSchedule(4, cfg)
    feed(a, 0, 9)
    state = read_state(write_state(_tmp() / "sched.msgpack",
                                   a.checkpoint_state()))
    b = AdaptiveWeightSchedule(4, cfg)
    b.restore_state(state)
    assert b.events == a.events
    out = feed(b, 9, 9)
    for got, want in zip(out, ref_out[9:]):
        assert (got is None) == (want is None)
        if got is not None:
            np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# 4d. metrics logger + sinks
# ---------------------------------------------------------------------------


def test_metrics_logger_roundtrip_continues_seq_and_vectors():
    a = MetricsLogger()
    a.log_rounds(0, {"loss": np.arange(3.0), "participation": np.ones(3),
                     "client_participation": np.ones((3, 4))}, k=3)
    a.log_eval(2, {"acc": 0.5})
    state = read_state(write_state(_tmp() / "m.msgpack",
                                   a.checkpoint_state()))
    b = MetricsLogger()
    b.restore_state(state)
    assert b._seq == a._seq
    assert b.log.loss == a.log.loss
    assert b.log.rounds == a.log.rounds
    assert b.log.eval_metrics == a.log.eval_metrics
    np.testing.assert_array_equal(b.vector("client_participation"),
                                  a.vector("client_participation"))
    b.log_rounds(3, {"loss": np.zeros(1)}, k=1)
    assert b.log.rounds == [0, 1, 2, 3]


def test_jsonl_sink_resume_appends():
    path = _tmp() / "events.jsonl"
    s1 = JsonlSink(path)
    s1.emit({"event": "round", "seq": 0, "round": 0})
    s1.close()
    s2 = JsonlSink(path, resume=True)
    s2.emit({"event": "round", "seq": 1, "round": 1})
    s2.close()
    events = JsonlSink.load(path)
    assert [e["seq"] for e in events] == [0, 1]
    # without resume, the file is truncated (one run per file)
    JsonlSink(path)
    assert JsonlSink.load(path) == []


def test_csv_sink_resume_trims_post_checkpoint_rows():
    path = _tmp() / "rounds.csv"
    s1 = CsvSummarySink(path)
    for r in range(5):
        s1.emit({"event": "round", "round": r, "loss": float(r)})
    s1.close()
    s2 = CsvSummarySink(path, resume=True)
    s2.trim_rounds_after(2)  # resumed from a round-3 checkpoint
    s2.emit({"event": "round", "round": 3, "loss": 30.0})
    s2.close()
    rows = path.read_text().splitlines()
    assert [row.split(",")[0] for row in rows[1:]] == ["0", "1", "2", "3"]
    assert rows[4].split(",")[1] == "30.0"


def test_manifest_records_resumed_from():
    m = RunManifest.collect({"rounds": 8}, strategy="colrel",
                            resumed_from="/ck/ckpt_00000004.msgpack")
    assert m.resumed_from == "/ck/ckpt_00000004.msgpack"
    assert RunManifest.collect({"rounds": 8}).resumed_from is None
    p = m.write(_tmp())
    assert json.loads(p.read_text())["resumed_from"].endswith("4.msgpack")


# ---------------------------------------------------------------------------
# 6. preemption guard
# ---------------------------------------------------------------------------


def test_preemption_guard_latches_and_restores():
    before = signal.getsignal(signal.SIGTERM)
    with PreemptionGuard() as guard:
        assert not guard.triggered
        os.kill(os.getpid(), signal.SIGTERM)
        assert guard.triggered
        assert guard.signum == signal.SIGTERM
    assert signal.getsignal(signal.SIGTERM) is before


def test_preemption_guard_sigint():
    with PreemptionGuard() as guard:
        os.kill(os.getpid(), signal.SIGINT)
        assert guard.triggered and guard.signum == signal.SIGINT
