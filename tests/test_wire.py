"""Wire-format codecs and the quantized relaying strategy.

Five layers:
  1. codec round-trips — int8 stochastic rounding is unbiased (mean
     over draws converges to the input) and bounded by one grid pitch;
     top-k keeps exactly the k largest-|x| coordinates per row; rand-k
     is unbiased after the descriptor's gain correction;
  2. registry mechanics — get/register/resolve, unknown codecs fail
     loudly, custom codecs slot into the quantized strategy;
  3. the quantized strategy — identity codec is *bitwise* the inner
     strategy (the infinite-bits anchor), codec state threads through
     jax.jit without recompiles, calibration proxies to the inner
     scheme, golden-fixture entry pins the int8(colrel) trajectory;
  4. the fused Pallas kernels — dequant-mix-accumulate vs the dequant
     oracle, and the memory strategy's select-accumulate-update vs its
     staged jnp path, both at the kernel (interpret=True) and the round
     level;
  5. the example CLI option parser (typed + dotted --strategy-opt).
"""

import importlib.util
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import strategies, wire
from repro.core import topology
from repro.core.connectivity import sample_round
from repro.fl import ExperimentSpec, build_experiment
from repro.fl.round import RoundConfig, make_round_fn
from repro.kernels import ops as kernel_ops
from repro.kernels.fused_dequant import fused_dequant_aggregate_pallas
from repro.kernels.fused_memory import fused_memory_update_pallas
from repro.optim import sgd, sgd_momentum
from repro.strategies.base import ExecutionContext

_GG_PATH = pathlib.Path(__file__).parent / "golden" / "generate_golden.py"
_spec = importlib.util.spec_from_file_location("_golden_gen_wire", _GG_PATH)
gg = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(gg)

GOLDEN = np.load(pathlib.Path(__file__).parent / "golden" / "round_golden.npz")

RNG = np.random.default_rng(123)


def _stack(n=6, d=128, rng=RNG):
    return jnp.asarray(rng.normal(size=(n, d)), jnp.float32)


def _taus(n=6, rng=RNG):
    tu = jnp.asarray((rng.random(n) < 0.7).astype(np.float32))
    td = jnp.asarray((rng.random((n, n)) < 0.6).astype(np.float32))
    A = jnp.asarray(np.abs(rng.normal(size=(n, n))) + np.eye(n), jnp.float32)
    return tu, td, A


# ---------------------------------------------------------------------------
# 1. codec round-trips
# ---------------------------------------------------------------------------


def test_int8_roundtrip_bounded_by_grid_pitch():
    x = _stack()
    codec = wire.get("int8")
    (q, scale), _ = codec.encode(x, codec.init_state(*x.shape))
    assert q.dtype == jnp.int8 and scale.shape == (x.shape[0], 1)
    recon = codec.decode((q, scale))
    # stochastic rounding moves each coordinate at most one grid step
    err = np.abs(np.asarray(recon - x))
    np.testing.assert_array_less(
        err, np.broadcast_to(np.asarray(scale), err.shape) + 1e-9)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_int8_stochastic_rounding_unbiased(bits):
    """Mean reconstruction over independent draws converges to x at the
    Monte-Carlo rate — the unbiasedness the wire format is built on."""
    x = _stack(n=4, d=64)
    codec = wire.get("int8", bits=bits)
    state = codec.init_state(4, 64)
    draws = 1500
    acc = jnp.zeros_like(x)
    for _ in range(draws):
        enc, state = codec.encode(x, state)
        acc = acc + codec.decode(enc)
    scale = np.asarray(jnp.max(jnp.abs(x), axis=1, keepdims=True)) / codec.levels
    err = np.abs(np.asarray(acc / draws - x))
    # per-coordinate SR noise is at most one grid pitch; 5 sigma of the
    # mean of `draws` bounded draws
    np.testing.assert_array_less(
        err, np.broadcast_to(5.0 * scale / np.sqrt(draws), err.shape) + 1e-7)


def test_int8_encode_deterministic_given_state():
    x = _stack()
    codec = wire.get("int8")
    st = codec.init_state(*x.shape)
    (q1, s1), next1 = codec.encode(x, st)
    (q2, s2), _ = codec.encode(x, st)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    # and the state advances, so the next round draws fresh randomness
    (q3, _), _ = codec.encode(x, next1)
    assert not np.array_equal(np.asarray(q1), np.asarray(q3))


def test_topk_support_masks():
    rng = np.random.default_rng(5)
    x = _stack(n=5, d=40, rng=rng)
    codec = wire.get("topk", k=7)
    enc, _ = codec.encode(x, ())
    recon = np.asarray(codec.decode(enc))
    xs = np.asarray(x)
    for i in range(5):
        support = np.flatnonzero(recon[i])
        assert support.size == 7
        # the kept coordinates are exactly the 7 largest-|x| ones
        top = np.argsort(-np.abs(xs[i]))[:7]
        assert set(support) == set(top)
        np.testing.assert_array_equal(recon[i][support], xs[i][support])
    # descriptor is honest about the bias
    assert not codec.descriptor(40).unbiased


def test_randk_unbiased_after_gain_correction():
    x = _stack(n=3, d=32)
    codec = wire.get("randk", fraction=0.25)
    desc = codec.descriptor(32)
    assert desc.gain == pytest.approx(8 / 32)
    state = codec.init_state(3, 32)
    draws = 4000
    acc = jnp.zeros_like(x)
    for _ in range(draws):
        enc, state = codec.encode(x, state)
        acc = acc + codec.decode(enc)
    corrected = np.asarray(acc / draws) / desc.gain
    # per-coordinate variance after correction is (d/k - 1) x^2
    sigma = np.abs(np.asarray(x)) * np.sqrt(desc.rel_variance / draws)
    np.testing.assert_array_less(np.abs(corrected - np.asarray(x)),
                                 5.0 * sigma + 1e-6)
    # support size is exactly k per row
    enc, _ = codec.encode(x, state)
    assert (np.count_nonzero(np.asarray(enc), axis=1) <= 8).all()


# ---------------------------------------------------------------------------
# 2. registry mechanics
# ---------------------------------------------------------------------------


def test_wire_registry_lists_builtins():
    names = wire.available()
    assert {"identity", "int8", "topk", "randk"} <= set(names)


def test_wire_registry_unknown_fails_loudly():
    with pytest.raises(KeyError, match="unknown wire codec"):
        wire.get("does_not_exist")
    with pytest.raises(ValueError, match="already registered"):
        wire.register("int8", wire.Int8StochasticCodec)


def test_custom_codec_slots_into_quantized_strategy():
    @wire.register("negate", overwrite=True)
    class NegateCodec(wire.WireCodec):
        name = "negate"

        def descriptor(self, d):
            return wire.CodecDescriptor(name="negate", bits_per_coord=32.0,
                                        unbiased=True, gain=-1.0)

        def encode(self, x, state):
            return -x, state

        def decode(self, encoded):
            return encoded

    s = strategies.get("quantized", codec="negate", inner="fedavg_perfect")
    x = _stack()
    tu, td, A = _taus()
    # gain -1 is divided out by the correction hook: decode(-x)/-1 == x
    delta, _ = s.aggregate(x, tu, td, A, s.init_state(*x.shape))
    np.testing.assert_allclose(np.asarray(delta),
                               np.asarray(jnp.mean(x, axis=0)), rtol=1e-6)


# ---------------------------------------------------------------------------
# 3. the quantized strategy
# ---------------------------------------------------------------------------


def test_quantized_identity_is_bitwise_inner():
    """Infinite bits: the identity codec makes quantized(colrel) the
    exact colrel dense aggregation, bit for bit."""
    x = _stack(n=8, d=300)
    tu, td, A = _taus(n=8)
    qs = strategies.get("quantized", codec="identity")
    dq, _ = qs.aggregate(x, tu, td, A, qs.init_state(8, 300))
    dc, _ = strategies.get("colrel").aggregate(x, tu, td, A, ())
    np.testing.assert_array_equal(np.asarray(dq), np.asarray(dc))


def test_quantized_round_golden():
    """The int8(colrel) round trajectory is pinned in the golden fixture
    so codec/strategy refactors cannot silently drift it."""
    params, _ = gg.run_quantized()
    np.testing.assert_array_equal(np.asarray(params["x"], np.float32),
                                  GOLDEN[f"{gg.QUANT_TAG}|x"])
    np.testing.assert_array_equal(np.asarray(params["W"], np.float32),
                                  GOLDEN[f"{gg.QUANT_TAG}|W"])


def test_quantized_state_jit_roundtrip_no_recompile():
    """(codec key, inner state) threads through the compiled round;
    taus change every call, randomness is fresh, zero retraces."""
    traces = []
    H, centers, Wc, model, A = gg.PROB
    strat = strategies.get("quantized", codec="int8")
    rc = RoundConfig(n_clients=gg.N, local_steps=2, aggregation=strat)
    server_opt = sgd_momentum(1.0, beta=0.9)
    base = make_round_fn(gg.make_loss(H, Wc), sgd(0.05), server_opt, rc)

    def counted(*a):
        traces.append(1)
        return base(*a)

    fn = jax.jit(counted)
    params = {"x": jnp.zeros(gg.DX, jnp.float32),
              "W": jnp.zeros((3, 4), jnp.float32)}
    sstate = server_opt.init(params)
    st = strat.init_state(gg.N, gg.DX + 12)
    tau_rng = np.random.default_rng(3)
    bat_rng = np.random.default_rng(6)
    keys = [np.asarray(st[0])]
    for _ in range(3):
        tu, td = sample_round(model, tau_rng)
        b = gg.batches_for(bat_rng, 2)
        params, sstate, st, metrics = fn(
            params, sstate, st, jax.tree.map(jnp.asarray, b),
            jnp.asarray(tu, jnp.float32), jnp.asarray(td, jnp.float32),
            jnp.asarray(A, jnp.float32))
        keys.append(np.asarray(st[0]))
    assert len(traces) == 1, f"retraced {len(traces)} times"
    # the codec PRNG key advanced every round
    assert not np.array_equal(keys[0], keys[-1])
    # quantized has no scalar collapse -> weight_sum logs NaN by contract
    assert np.isnan(float(metrics["weight_sum"]))


def test_quantized_proxies_inner_contract():
    q_colrel = strategies.get("quantized", inner="colrel")
    assert q_colrel.needs_A and q_colrel.stateful
    q_blind = strategies.get("quantized", inner="fedavg_blind")
    assert not q_blind.needs_A
    # calibration proxies: quantized(multihop K=2) calibrates the inner
    m = topology.paper_fig2a()
    q_hop = strategies.get("quantized", inner="multihop",
                           inner_options={"hops": 2})
    calibrated = q_hop.calibrate(m, np.eye(10))
    assert calibrated.inner.correction is not None
    assert calibrated.calibration_tracks_A
    assert calibrated.codec is q_hop.codec


def test_quantized_rejects_bad_combinations():
    with pytest.raises(ValueError, match="do not nest"):
        strategies.get("quantized", inner="quantized")
    with pytest.raises(ValueError, match="supports_fused_dequant"):
        strategies.get("quantized", codec="topk", fused="kernel")
    with pytest.raises(ValueError, match="colrel"):
        strategies.get("quantized", inner="fedavg_blind", fused="kernel")
    with pytest.raises(ValueError, match="bits"):
        wire.get("int8", bits=9)


def test_quantized_experiment_spec_end_to_end():
    spec = ExperimentSpec(model="quadratic", topology="fig2a",
                          strategy="quantized",
                          strategy_options={"codec": "int8",
                                            "codec_options": {"bits": 6}},
                          channel="markov", rounds=5, seed=0)
    exp = build_experiment(spec)
    assert exp.strategy.name == "quantized"
    log = exp.run()
    assert len(log.loss) == 5 and np.isfinite(log.loss).all()


# ---------------------------------------------------------------------------
# 4. the fused Pallas kernels
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,d", [(4, 96), (10, 1000), (16, 2048)])
def test_fused_dequant_kernel_matches_oracle(n, d):
    """interpret-mode Pallas vs dequantize-then-two-stage-colrel."""
    rng = np.random.default_rng(n * d)
    x = _stack(n=n, d=d, rng=rng)
    tu, td, A = _taus(n=n, rng=rng)
    codec = wire.get("int8")
    (q, scale), _ = codec.encode(x, codec.init_state(n, d))
    got = fused_dequant_aggregate_pallas(A, tu, td, q, scale,
                                         block_d=512, interpret=True)
    recon = codec.decode((q, scale))
    want, _ = strategies.get("colrel").aggregate(recon, tu, td, A, ())
    assert got.shape == (d,) and got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
    # and the deployable CPU op agrees with the kernel's tiling
    ops_out = kernel_ops.fused_dequant_aggregate(A, tu, td, q, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ops_out),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("n,d", [(4, 96), (10, 1000)])
def test_fused_memory_kernel_matches_oracle(n, d):
    """interpret-mode Pallas select-accumulate-update vs the memory
    strategy's staged jnp aggregate."""
    rng = np.random.default_rng(n + d)
    x = _stack(n=n, d=d, rng=rng)
    tu, td, A = _taus(n=n, rng=rng)
    mem = strategies.get("memory")
    buf = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    want_delta, want_buf = mem.aggregate(x, tu, td, A, buf)
    got_delta, got_buf = fused_memory_update_pallas(
        A, tu, td, x, buf, block_d=512, interpret=True)
    np.testing.assert_allclose(np.asarray(got_delta), np.asarray(want_delta),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(got_buf), np.asarray(want_buf),
                               atol=1e-5, rtol=1e-5)


def test_memory_fused_round_matches_plain():
    """Round level: memory fused='kernel' follows the identical
    trajectory (delta and carried buffer) as the staged path."""
    x_tree = {"w": _stack(n=gg.N, d=gg.DX + 12).reshape(gg.N, 4, 5)}
    tu, td, A = _taus(n=gg.N)
    ctx = ExecutionContext(n_clients=gg.N)
    plain = strategies.get("memory")
    fused = strategies.get("memory", fused="kernel")
    buf = plain.init_state(gg.N, 20)
    g_p, buf_p = plain.aggregate_tree(x_tree, tu, td, A, buf, ctx)
    g_f, buf_f = fused.aggregate_tree(x_tree, tu, td, A, buf, ctx)
    np.testing.assert_allclose(np.asarray(g_p["w"]), np.asarray(g_f["w"]),
                               atol=1e-6, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(buf_p), np.asarray(buf_f),
                               atol=1e-6, rtol=1e-6)


def test_quantized_fused_tree_matches_dequant_oracle():
    """aggregate_tree with fused='kernel' (flatten-once + fused dequant)
    vs the dequant-oracle path, same codec draw."""
    n, d = 8, 520
    x_tree = {"a": _stack(n=n, d=512).reshape(n, 16, 32),
              "b": _stack(n=n, d=8)}
    tu, td, A = _taus(n=n)
    ctx = ExecutionContext(n_clients=n, fused_block_d=128)
    s_fused = strategies.get("quantized", codec="int8", fused="kernel")
    s_oracle = strategies.get("quantized", codec="int8")
    st = s_fused.init_state(n, d)
    g_f, st_f = s_fused.aggregate_tree(x_tree, tu, td, A, st, ctx)
    g_o, st_o = s_oracle.aggregate_tree(x_tree, tu, td, A, st, ctx)
    for a, b in zip(jax.tree.leaves(g_f), jax.tree.leaves(g_o)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)
    # both advanced the codec key identically
    np.testing.assert_array_equal(np.asarray(st_f[0]), np.asarray(st_o[0]))


# ---------------------------------------------------------------------------
# 5. the example CLI option parser
# ---------------------------------------------------------------------------


def test_cli_strategy_opt_parsing():
    spec = importlib.util.spec_from_file_location(
        "_train_cli", pathlib.Path(__file__).parent.parent / "examples"
        / "train_colrel_cifar.py")
    cli = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(cli)

    assert cli.parse_opt("hops=3") == ("hops", 3)
    assert cli.parse_opt("lr=2.5e-3") == ("lr", 2.5e-3)
    assert cli.parse_opt("fused=kernel") == ("fused", "kernel")
    assert cli.parse_opt("adaptive=true") == ("adaptive", True)
    assert cli.parse_opt("correction=none") == ("correction", None)
    # dotted keys build the nested option dicts the quantized strategy
    # takes: --strategy-opt codec_options.bits=4
    assert cli.parse_opt("codec_options.bits=4") == ("codec_options.bits", 4)
    opts = cli.build_options([("codec", "int8"),
                              ("codec_options.bits", 4),
                              ("codec_options.seed", 7)])
    assert opts == {"codec": "int8",
                    "codec_options": {"bits": 4, "seed": 7}}
    with pytest.raises(Exception):
        cli.parse_opt("no_equals_sign")
    # key conflicts fail loudly in both orders instead of silently
    # dropping options
    with pytest.raises(SystemExit, match="scalar option"):
        cli.build_options([("codec_options", "x"), ("codec_options.bits", 4)])
    with pytest.raises(SystemExit, match="nested options"):
        cli.build_options([("codec_options.bits", 4), ("codec_options", "x")])
