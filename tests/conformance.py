"""Cross-strategy conformance harness (DESIGN.md §13).

One fixture, one assertion vocabulary, a matrix derived from the *live*
strategy registry times the execution engines — so a newly registered
strategy automatically inherits kill/resume bitwise continuation,
jit-cache stability, and the weight-sum unbiasedness contract with zero
new test code.  ``tests/test_conformance.py`` parametrizes over
:func:`matrix`; this module holds the shared machinery.

Execution modes cover every engine the trainer exposes:

* ``per_round`` — the host loop (``chunk=1``);
* ``chunked``   — the compiled multi-round scan (``chunk=3``);
* ``no_trace``  — connectivity drawn inside the scan (no tau tensors on
  host);
* ``async``     — the staleness-weighted asynchronous engine wrapping
  the strategy (age vector + staging buffer riding ``agg_state``).

The weight-sum contract (paper Eq. (5)): after host-side calibration
against the fixture link statistics, a strategy with
``unbiased_weight_sum`` and a scalar collapse must satisfy
``E[sum_j weights_j] = 1`` under the channel's stationary law — checked
by Monte Carlo over a bulk trace.  Strategies without a scalar collapse
(``weights() is None``) must instead log ``weight_sum = NaN`` every
round, never a silently wrong number.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import strategies
from repro.channel import (
    ClusteredMarkovChannel,
    MarkovChannel,
    gilbert_elliott,
    gilbert_elliott_clustered,
)
from repro.core import optimize_weights, topology
from repro.core.weights import optimize_weights_clustered
from repro.data.pipeline import ClientDataset
from repro.fl import FLTrainer
from repro.optim import sgd, sgd_momentum

N, D = 6, 12

#: name -> (FLTrainer mode, run() kwargs).  ``chunk=3`` over 6 rounds
#: crosses a chunk boundary; the channel block size (4) additionally
#: crosses a buffer refill, so resume exercises mid-block regeneration.
EXECUTION_MODES = {
    "per_round": ("per_client", dict(chunk=1)),
    "chunked": ("per_client", dict(chunk=3)),
    "no_trace": ("per_client", dict(chunk=3, no_trace=True)),
    "async": ("async", dict(chunk=3)),
}


def strategy_names():
    return sorted(strategies.available())


def matrix():
    """(strategy, mode) grid — every registered strategy through every
    execution engine."""
    return [(s, m) for s in strategy_names() for m in EXECUTION_MODES]


@functools.lru_cache(maxsize=None)
def _fixture(strategy: str):
    """(link model, alpha) for a strategy: the clustered scheme gets the
    block topology with block-COPT weights; everything else the dense
    fully-connected model with COPT-alpha (unbiased by construction)."""
    if strategy == "clustered":
        model = topology.clustered_blocks(N, 0.5, 3, p_intra=0.8, rho=0.6)
        A = optimize_weights_clustered(model, sweeps=5, fine_tune_sweeps=5).Ab
    else:
        model = topology.fully_connected(N, 0.5, p_c=0.8, rho=1.0)
        A = optimize_weights(model, sweeps=5, fine_tune_sweeps=5).A
    return model, np.asarray(A)


def _channel(strategy: str, model, *, seed=5, block=4):
    if strategy == "clustered":
        return ClusteredMarkovChannel(
            gilbert_elliott_clustered(model, memory=0.8), seed=seed, block=block)
    return MarkovChannel(gilbert_elliott(model, memory=0.8), seed=seed,
                         block=block)


def make_trainer(strategy: str, mode: str = "per_round", *, telemetry=False,
                 seed=3, **trainer_kw) -> FLTrainer:
    """The tiny least-squares fixture from the resume golden tests,
    generalized over the execution-mode axis.  Extra keywords pass
    through to :class:`FLTrainer` (``donate``, ``segment_d``, ...)."""
    rng = np.random.default_rng(0)
    targets = rng.normal(size=(N, D)).astype(np.float32)
    clients = [ClientDataset({"t": np.repeat(targets[i][None], 64, 0)},
                             batch_size=4, seed=i) for i in range(N)]
    model, A = _fixture(strategy)

    def loss_fn(p, batch):
        r = p["x"] - batch["t"]
        return jnp.mean(r * r), None

    fl_mode, _ = EXECUTION_MODES[mode]
    return FLTrainer(loss_fn, {"x": jnp.zeros((D,), jnp.float32)}, model, A,
                     clients, sgd(0.3), sgd_momentum(1.0, beta=0.9),
                     local_steps=2, strategy=strategy, seed=seed,
                     channel=_channel(strategy, model), mode=fl_mode,
                     telemetry=telemetry, **trainer_kw)


def run_kwargs(mode: str) -> dict:
    return dict(EXECUTION_MODES[mode][1])


def compiled_fn(trainer: FLTrainer, mode: str):
    """The jitted entry point a given execution mode runs through, for
    cache-stability assertions."""
    if mode == "per_round":
        return trainer._round_fn
    if mode == "no_trace":
        return trainer._sampled_scan_fn
    return trainer._scan_fn


def assert_same_run(a: FLTrainer, b: FLTrainer) -> None:
    """Bitwise-identical trajectories and final state (NaN-aware for the
    weight-sum stream)."""
    for field in ("rounds", "loss", "participation", "uplink_bits",
                  "weight_sums"):
        av, bv = getattr(a.log, field), getattr(b.log, field)
        assert len(av) == len(bv), field
        for x, y in zip(av, bv):
            assert x == y or (np.isnan(x) and np.isnan(y)), (field, x, y)
    for name, ta, tb in (("params", a.params, b.params),
                        ("server_state", a.server_state, b.server_state),
                        ("agg_state", a.agg_state, b.agg_state)):
        la, lb = jax.tree.leaves(ta), jax.tree.leaves(tb)
        assert len(la) == len(lb), name
        for x, y in zip(la, lb):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=name)


def mc_weight_sum(strategy: str, *, rounds: int = 4096) -> float:
    """Monte-Carlo ``E[sum_j weights_j]`` for a calibrated strategy over
    the fixture channel's stationary law; NaN when the strategy has no
    scalar collapse."""
    model, A = _fixture(strategy)
    s = strategies.get(strategy).calibrate(model, A)
    Aj = jnp.asarray(A, jnp.float32)
    tau_up, tau_dd = _channel(strategy, model, block=rounds).trace(0, rounds)
    w0 = s.weights(jnp.asarray(tau_up[0], jnp.float32),
                   jnp.asarray(tau_dd[0], jnp.float32), Aj)
    if w0 is None:
        return float("nan")
    sums = jax.jit(jax.vmap(
        lambda tu, td: jnp.sum(s.weights(tu, td, Aj))))(
        jnp.asarray(tau_up, jnp.float32), jnp.asarray(tau_dd, jnp.float32))
    return float(jnp.mean(sums))
