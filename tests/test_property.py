"""Property tests on the system's core invariants.

Runs under real hypothesis when installed (CI loads the derandomized
``ci`` profile) and otherwise under the seeded deterministic stand-in
in ``_property_harness`` — either way the suite executes and reports,
never skips.
"""

import numpy as np

from _property_harness import given, settings, st

from repro.core import (
    LinkModel,
    effective_weights,
    initial_weights,
    is_unbiased,
    optimize_weights,
    reciprocity_matrix,
    sample_round,
    variance_S,
    variance_Sbar,
)
from repro.core.relay import colrel_round_delta

import jax.numpy as jnp


@st.composite
def link_models(draw):
    n = draw(st.integers(3, 8))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    p = rng.uniform(0.05, 1.0, n)
    P = rng.uniform(0.0, 1.0, (n, n))
    P = np.where(P < 0.3, 0.0, P)  # sparsify
    np.fill_diagonal(P, 1.0)
    rho = draw(st.sampled_from([0.0, 0.5, 1.0]))
    # rho > 0 needs symmetric-support P for a meaningful coupling; keep general
    return LinkModel(p, P, reciprocity_matrix(P, rho))


@settings(max_examples=25, deadline=None)
@given(link_models())
def test_optimizer_invariants(m):
    res = optimize_weights(m, sweeps=10, fine_tune_sweeps=10)
    assert np.all(res.A >= -1e-10)
    assert is_unbiased(m, res.A, atol=1e-6)
    assert res.S <= res.S_init + 1e-8
    assert variance_S(m, res.A) <= variance_Sbar(m, res.A) + 1e-8


@settings(max_examples=25, deadline=None)
@given(link_models(), st.integers(0, 2**31 - 1), st.integers(1, 16))
def test_fused_equals_faithful(m, seed, d):
    """The exact algebraic fusion: weighted-psum == relay + blind PS sum."""
    rng = np.random.default_rng(seed)
    A = initial_weights(m)
    tau_up, tau_dd = sample_round(m, rng)
    updates = jnp.asarray(rng.normal(size=(m.n, d)), jnp.float32)
    faithful = colrel_round_delta(
        updates, jnp.asarray(A, jnp.float32), jnp.asarray(tau_up, jnp.float32),
        jnp.asarray(tau_dd, jnp.float32), fused=False)
    fused = colrel_round_delta(
        updates, jnp.asarray(A, jnp.float32), jnp.asarray(tau_up, jnp.float32),
        jnp.asarray(tau_dd, jnp.float32), fused=True)
    np.testing.assert_allclose(np.asarray(faithful), np.asarray(fused),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([8, 16, 32]),
       st.sampled_from([1, 2, 4]))
def test_ssd_chunk_invariance(seed, chunk, heads):
    """Chunked SSD must be invariant to the chunk size (same math)."""
    from repro.models import ssm
    import jax

    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    B, T, Dk, Dv = 1, 64, 4, 6
    q = jax.random.normal(ks[0], (B, T, heads, Dk))
    k = jax.random.normal(ks[1], (B, T, heads, Dk))
    v = jax.random.normal(ks[2], (B, T, heads, Dv))
    loga = -jax.nn.softplus(jax.random.normal(ks[3], (B, T, heads)))
    y1, s1 = ssm.ssd_chunked(q, k, v, loga, chunk=chunk)
    y2, s2 = ssm.ssd_reference(q, k, v, loga)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([8, 16, 32]))
def test_gla_chunk_invariance(seed, chunk):
    from repro.models import ssm
    import jax

    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    B, T, H, Dk, Dv = 1, 64, 2, 4, 4
    r = jax.random.normal(ks[0], (B, T, H, Dk))
    k = jax.random.normal(ks[1], (B, T, H, Dk))
    v = jax.random.normal(ks[2], (B, T, H, Dv))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, Dk)) * 0.5 - 1.0)
    u = jax.random.normal(ks[4], (H, Dk)) * 0.3
    y1, s1 = ssm.gla_chunked(r, k, v, logw, u, chunk=chunk)
    y2, s2 = ssm.gla_reference(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(link_models(), st.integers(0, 2**31 - 1), st.integers(1, 300))
def test_fused_kernel_equals_faithful_oracle(m, seed, d):
    """The single-pass Pallas kernel == relay_mix + blind PS sum for any
    link realization (interpret mode; includes d far off the lane grid)."""
    from repro.kernels.fused_aggregate import fused_aggregate_pallas
    from repro.kernels.ref import fused_aggregate_ref

    rng = np.random.default_rng(seed)
    A = initial_weights(m)
    tau_up, tau_dd = sample_round(m, rng)
    updates = jnp.asarray(rng.normal(size=(m.n, d)), jnp.float32)
    args = (jnp.asarray(A, jnp.float32), jnp.asarray(tau_up, jnp.float32),
            jnp.asarray(tau_dd, jnp.float32), updates)
    got = fused_aggregate_pallas(*args, block_d=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(fused_aggregate_ref(*args)),
                               atol=1e-5, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_effective_weight_mean_is_one(seed):
    """E[w_j] = 1 under condition (5) — checked in expectation analytically:
    E[w_j] = p_j alpha_jj + sum_{i != j} p_i p_ji alpha_ij."""
    rng = np.random.default_rng(seed)
    n = 6
    p = rng.uniform(0.1, 1.0, n)
    P = rng.uniform(0.2, 1.0, (n, n))
    np.fill_diagonal(P, 1.0)
    m = LinkModel(p, P, reciprocity_matrix(P, 0.0))
    res = optimize_weights(m, sweeps=8, fine_tune_sweeps=0)
    A = res.A
    ew = np.array([
        sum(p[i] * (P[j, i] if i != j else 1.0) * A[i, j] for i in range(n))
        for j in range(n)
    ])
    np.testing.assert_allclose(ew, 1.0, atol=1e-6)


# ---------------------------------------------------------------------------
# the async carry (DESIGN.md §13): age recurrence, staleness weighting,
# bitwise reduction to the sync inner strategy under zero blockage
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 8), st.integers(1, 6),
       st.booleans(), st.integers(1, 8))
def test_async_age_recurrence(seed, n, d, opportunistic, rounds):
    """Over an arbitrary blockage trace: a delivered client's age resets
    to 0 and its staged row refreshes; a blocked client's age increments
    and its row is untouched; delivery is exactly
    ``max(tau_up, relay-rescue)`` (or bare ``tau_up`` without
    opportunistic relaying)."""
    from repro.strategies import AsyncRelayStrategy

    s = AsyncRelayStrategy(gamma=0.9, opportunistic=opportunistic)
    rng = np.random.default_rng(seed)
    age = np.zeros(n, np.int32)
    staging = np.zeros((n, d), np.float32)
    for _ in range(rounds):
        tau_up = (rng.random(n) < 0.5).astype(np.float32)
        tau_dd = (rng.random((n, n)) < 0.5).astype(np.float32)
        np.fill_diagonal(tau_dd, 1.0)
        stack = rng.normal(size=(n, d)).astype(np.float32)
        deliv, age2, staging2 = s.advance(
            jnp.asarray(age), jnp.asarray(staging), jnp.asarray(stack),
            jnp.asarray(tau_up), jnp.asarray(tau_dd))
        deliv, age2, staging2 = map(np.asarray, (deliv, age2, staging2))
        want = (np.maximum(tau_up, (tau_dd * tau_up[None, :]).max(axis=1))
                if opportunistic else tau_up)
        np.testing.assert_array_equal(deliv, want)
        np.testing.assert_array_equal(age2[deliv > 0], 0)
        np.testing.assert_array_equal(age2[deliv == 0], age[deliv == 0] + 1)
        np.testing.assert_array_equal(staging2[deliv > 0], stack[deliv > 0])
        np.testing.assert_array_equal(staging2[deliv == 0],
                                      staging[deliv == 0])
        age, staging = age2, staging2


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 16),
       st.sampled_from([0.5, 0.8, 0.9, 1.0]))
def test_staleness_weights_normalize(seed, n, gamma):
    """``staleness_weights`` sums to 1 for any age vector, and the
    effective multiplier is *exactly* 1.0f per client when all ages are
    0 (the bitwise sync-reduction precondition)."""
    from repro.strategies import AsyncRelayStrategy

    s = AsyncRelayStrategy(gamma=gamma)
    ages = jnp.asarray(np.random.default_rng(seed).integers(0, 20, n),
                       jnp.int32)
    w = np.asarray(s.staleness_weights(ages))
    assert w.shape == (n,) and (w > 0).all()
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)
    staging = jnp.asarray(
        np.random.default_rng(seed + 1).normal(size=(n, 3)), jnp.float32)
    eff = s._effective(jnp.zeros((n,), jnp.int32), staging)
    np.testing.assert_array_equal(np.asarray(eff), np.asarray(staging))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(3, 8), st.integers(1, 8),
       st.integers(1, 4))
def test_async_zero_blockage_is_bitwise_sync(seed, n, d, rounds):
    """With every uplink connected, the async aggregate is bitwise
    identical to the sync inner colrel aggregate round for round, and
    every age stays pinned at 0."""
    from repro import strategies as S

    rng = np.random.default_rng(seed)
    a = S.get("async_colrel")
    inner = a.inner
    A = jnp.asarray(rng.uniform(0.0, 1.0, (n, n)), jnp.float32)
    ones_up = jnp.ones((n,), jnp.float32)
    st_async = a.init_state(n, d)
    for _ in range(rounds):
        tau_dd = jnp.asarray((rng.random((n, n)) < 0.7), jnp.float32)
        updates = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        d_async, st_async = a.aggregate(updates, ones_up, tau_dd, A, st_async)
        d_sync, _ = inner.aggregate(updates, ones_up, tau_dd, A, ())
        np.testing.assert_array_equal(np.asarray(d_async), np.asarray(d_sync))
        np.testing.assert_array_equal(np.asarray(st_async["age"]), 0)
