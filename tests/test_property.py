"""Hypothesis property tests on the system's core invariants.

Skipped wholesale (not a collection error) when hypothesis is absent —
the fused-engine equivalences are additionally covered by the seeded
sweeps in tests/test_fused_aggregate.py, which have no extra deps.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    LinkModel,
    effective_weights,
    initial_weights,
    is_unbiased,
    optimize_weights,
    reciprocity_matrix,
    sample_round,
    variance_S,
    variance_Sbar,
)
from repro.core.relay import colrel_round_delta

import jax.numpy as jnp


@st.composite
def link_models(draw):
    n = draw(st.integers(3, 8))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    p = rng.uniform(0.05, 1.0, n)
    P = rng.uniform(0.0, 1.0, (n, n))
    P = np.where(P < 0.3, 0.0, P)  # sparsify
    np.fill_diagonal(P, 1.0)
    rho = draw(st.sampled_from([0.0, 0.5, 1.0]))
    # rho > 0 needs symmetric-support P for a meaningful coupling; keep general
    return LinkModel(p, P, reciprocity_matrix(P, rho))


@settings(max_examples=25, deadline=None)
@given(link_models())
def test_optimizer_invariants(m):
    res = optimize_weights(m, sweeps=10, fine_tune_sweeps=10)
    assert np.all(res.A >= -1e-10)
    assert is_unbiased(m, res.A, atol=1e-6)
    assert res.S <= res.S_init + 1e-8
    assert variance_S(m, res.A) <= variance_Sbar(m, res.A) + 1e-8


@settings(max_examples=25, deadline=None)
@given(link_models(), st.integers(0, 2**31 - 1), st.integers(1, 16))
def test_fused_equals_faithful(m, seed, d):
    """The exact algebraic fusion: weighted-psum == relay + blind PS sum."""
    rng = np.random.default_rng(seed)
    A = initial_weights(m)
    tau_up, tau_dd = sample_round(m, rng)
    updates = jnp.asarray(rng.normal(size=(m.n, d)), jnp.float32)
    faithful = colrel_round_delta(
        updates, jnp.asarray(A, jnp.float32), jnp.asarray(tau_up, jnp.float32),
        jnp.asarray(tau_dd, jnp.float32), fused=False)
    fused = colrel_round_delta(
        updates, jnp.asarray(A, jnp.float32), jnp.asarray(tau_up, jnp.float32),
        jnp.asarray(tau_dd, jnp.float32), fused=True)
    np.testing.assert_allclose(np.asarray(faithful), np.asarray(fused),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([8, 16, 32]),
       st.sampled_from([1, 2, 4]))
def test_ssd_chunk_invariance(seed, chunk, heads):
    """Chunked SSD must be invariant to the chunk size (same math)."""
    from repro.models import ssm
    import jax

    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    B, T, Dk, Dv = 1, 64, 4, 6
    q = jax.random.normal(ks[0], (B, T, heads, Dk))
    k = jax.random.normal(ks[1], (B, T, heads, Dk))
    v = jax.random.normal(ks[2], (B, T, heads, Dv))
    loga = -jax.nn.softplus(jax.random.normal(ks[3], (B, T, heads)))
    y1, s1 = ssm.ssd_chunked(q, k, v, loga, chunk=chunk)
    y2, s2 = ssm.ssd_reference(q, k, v, loga)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([8, 16, 32]))
def test_gla_chunk_invariance(seed, chunk):
    from repro.models import ssm
    import jax

    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    B, T, H, Dk, Dv = 1, 64, 2, 4, 4
    r = jax.random.normal(ks[0], (B, T, H, Dk))
    k = jax.random.normal(ks[1], (B, T, H, Dk))
    v = jax.random.normal(ks[2], (B, T, H, Dv))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, Dk)) * 0.5 - 1.0)
    u = jax.random.normal(ks[4], (H, Dk)) * 0.3
    y1, s1 = ssm.gla_chunked(r, k, v, logw, u, chunk=chunk)
    y2, s2 = ssm.gla_reference(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(link_models(), st.integers(0, 2**31 - 1), st.integers(1, 300))
def test_fused_kernel_equals_faithful_oracle(m, seed, d):
    """The single-pass Pallas kernel == relay_mix + blind PS sum for any
    link realization (interpret mode; includes d far off the lane grid)."""
    from repro.kernels.fused_aggregate import fused_aggregate_pallas
    from repro.kernels.ref import fused_aggregate_ref

    rng = np.random.default_rng(seed)
    A = initial_weights(m)
    tau_up, tau_dd = sample_round(m, rng)
    updates = jnp.asarray(rng.normal(size=(m.n, d)), jnp.float32)
    args = (jnp.asarray(A, jnp.float32), jnp.asarray(tau_up, jnp.float32),
            jnp.asarray(tau_dd, jnp.float32), updates)
    got = fused_aggregate_pallas(*args, block_d=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(fused_aggregate_ref(*args)),
                               atol=1e-5, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_effective_weight_mean_is_one(seed):
    """E[w_j] = 1 under condition (5) — checked in expectation analytically:
    E[w_j] = p_j alpha_jj + sum_{i != j} p_i p_ji alpha_ij."""
    rng = np.random.default_rng(seed)
    n = 6
    p = rng.uniform(0.1, 1.0, n)
    P = rng.uniform(0.2, 1.0, (n, n))
    np.fill_diagonal(P, 1.0)
    m = LinkModel(p, P, reciprocity_matrix(P, 0.0))
    res = optimize_weights(m, sweeps=8, fine_tune_sweeps=0)
    A = res.A
    ew = np.array([
        sum(p[i] * (P[j, i] if i != j else 1.0) * A[i, j] for i in range(n))
        for j in range(n)
    ])
    np.testing.assert_allclose(ew, 1.0, atol=1e-6)
