"""The resume protocol beyond the conformance matrix.

The golden kill/restore/continue-*bitwise* matrix (every registered
strategy x every execution engine, including jit-cache stability across
the restore) lives in ``test_conformance.py`` now.  This file keeps the
protocol pieces the matrix does not parametrize: directory-based
periodic checkpointing, telemetry-streak and adaptive-schedule resume,
the experiment-layer wiring (spec fields, sink append mode, manifest
provenance), config-mismatch refusal, and the launcher's flag
validation.
"""

import json
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.channel import (
    AdaptiveConfig,
    AdaptiveWeightSchedule,
    ClusteredMarkovChannel,
    MarkovChannel,
    gilbert_elliott,
    gilbert_elliott_clustered,
)
from repro.ckpt import CheckpointWriter, read_state
from repro.core import optimize_weights, topology
from repro.data.pipeline import ClientDataset
from repro.fl import FLTrainer
from repro.fl.experiment import ExperimentSpec, build_experiment
from repro.optim import sgd, sgd_momentum
from repro.telemetry import JsonlSink

N, D = 6, 12


def _make_trainer(strategy="colrel", *, telemetry=False, adaptive=None,
                  metrics=None, seed=3):
    """A tiny least-squares problem over a bursty channel with a small
    block size (4), so a 6-round run crosses a buffer refill and resume
    exercises both mid-block and cross-block regeneration."""
    rng = np.random.default_rng(0)
    targets = rng.normal(size=(N, D)).astype(np.float32)
    clients = [ClientDataset({"t": np.repeat(targets[i][None], 64, 0)},
                             batch_size=4, seed=i) for i in range(N)]
    if strategy == "clustered":
        model = topology.clustered_blocks(N, 0.5, 3, p_intra=0.8, rho=0.6)
        channel = ClusteredMarkovChannel(
            gilbert_elliott_clustered(model, memory=0.8), seed=5, block=4)
        A = np.full((2, 3, 3), 1.0, np.float64)  # (C, m, m) block weights
    else:
        model = topology.fully_connected(N, 0.5, p_c=0.8, rho=1.0)
        channel = MarkovChannel(gilbert_elliott(model, memory=0.8),
                                seed=5, block=4)
        A = optimize_weights(model, sweeps=5, fine_tune_sweeps=5).A

    def loss_fn(p, batch):
        r = p["x"] - batch["t"]
        return jnp.mean(r * r), None

    return FLTrainer(loss_fn, {"x": jnp.zeros((D,), jnp.float32)}, model, A,
                     clients, sgd(0.3), sgd_momentum(1.0, beta=0.9),
                     local_steps=2, strategy=strategy, seed=seed,
                     channel=channel, telemetry=telemetry, adaptive=adaptive,
                     metrics=metrics)


def _assert_same_run(a, b):
    for field in ("rounds", "loss", "participation", "uplink_bits",
                  "weight_sums"):
        av, bv = getattr(a.log, field), getattr(b.log, field)
        assert len(av) == len(bv), field
        for x, y in zip(av, bv):
            assert x == y or (np.isnan(x) and np.isnan(y)), (field, x, y)
    for name, ta, tb in (("params", a.params, b.params),
                         ("server_state", a.server_state, b.server_state),
                         ("agg_state", a.agg_state, b.agg_state)):
        la, lb = jax.tree.leaves(ta), jax.tree.leaves(tb)
        assert len(la) == len(lb), name
        for x, y in zip(la, lb):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=name)


# ---------------------------------------------------------------------------
# 1. directory-based periodic checkpointing + resume-from-latest
# ---------------------------------------------------------------------------


def test_periodic_ckpt_dir_and_resume_latest(tmp_path):
    ref = _make_trainer()
    ref.run(9, chunk=3)

    a = _make_trainer()
    a.run(6, chunk=3, ckpt_dir=tmp_path, ckpt_every=3, ckpt_keep=2)
    assert CheckpointWriter(tmp_path).steps() == [3, 6]

    b = _make_trainer()
    b.run(9, chunk=3, resume_from=tmp_path)  # directory -> latest step
    _assert_same_run(ref, b)


def test_ckpt_keep_gc(tmp_path):
    a = _make_trainer()
    a.run(8, chunk=2, ckpt_dir=tmp_path, ckpt_every=2, ckpt_keep=2)
    assert CheckpointWriter(tmp_path).steps() == [6, 8]


def test_final_only_checkpoint(tmp_path):
    """``ckpt_every=0`` with a ckpt_dir commits exactly one final state."""
    a = _make_trainer()
    a.run(5, chunk=1, ckpt_dir=tmp_path)
    assert CheckpointWriter(tmp_path).steps() == [5]
    assert read_state(CheckpointWriter(tmp_path).path_for(5))["round"] == 5


def test_misaligned_cadence_is_an_error(tmp_path):
    t = _make_trainer()
    with pytest.raises(ValueError, match="multiple of"):
        t.run(6, chunk=3, ckpt_dir=tmp_path, ckpt_every=2)


# ---------------------------------------------------------------------------
# 2. telemetry + adaptive state across a resume
# ---------------------------------------------------------------------------


def test_telemetry_streak_resumes_bitwise(tmp_path):
    ref = _make_trainer(telemetry=True)
    ref.run(6, chunk=3)

    t1 = _make_trainer(telemetry=True)
    t1.run(3, chunk=3)
    path = t1.save_checkpoint(tmp_path / "c.msgpack")
    t2 = _make_trainer(telemetry=True)
    t2.run(6, chunk=3, resume_from=path)
    _assert_same_run(ref, t2)
    np.testing.assert_array_equal(np.asarray(ref._streak),
                                  np.asarray(t2._streak))
    np.testing.assert_array_equal(ref.metrics.vector("client_participation"),
                                  t2.metrics.vector("client_participation"))


def test_adaptive_schedule_resumes_bitwise(tmp_path):
    cfg = AdaptiveConfig(every=4, warmup=2, sweeps=3, fine_tune_sweeps=3)

    def mk():
        return _make_trainer("colrel",
                             adaptive=AdaptiveWeightSchedule(N, cfg))

    ref = mk()
    ref.run(8, chunk=2)
    assert ref.log.reopt_rounds, "fixture must actually re-optimize"

    t1 = mk()
    t1.run(4, chunk=2)
    path = t1.save_checkpoint(tmp_path / "c.msgpack")
    t2 = mk()
    t2.run(8, chunk=2, resume_from=path)
    _assert_same_run(ref, t2)
    assert t2.log.reopt_rounds == ref.log.reopt_rounds
    assert t2.log.S_est == ref.log.S_est
    np.testing.assert_array_equal(np.asarray(ref.A), np.asarray(t2.A))


# ---------------------------------------------------------------------------
# 3. experiment-layer wiring: spec fields, sinks, manifest
# ---------------------------------------------------------------------------


def test_experiment_resume_with_metrics(tmp_path):
    def spec(mdir, **kw):
        return ExperimentSpec(model="quadratic", topology="fig2a",
                              strategy="colrel", channel="markov", chunk=3,
                              rounds=6, seed=3, metrics_dir=str(mdir),
                              ckpt_dir=str(tmp_path / "ck"), ckpt_every=3,
                              **kw)

    ref = build_experiment(ExperimentSpec(
        model="quadratic", topology="fig2a", strategy="colrel",
        channel="markov", chunk=3, rounds=6, seed=3))
    ref.run(6)

    m1 = tmp_path / "m"
    a = build_experiment(spec(m1))
    a.run(3)
    a.close()

    b = build_experiment(spec(m1, resume_from=str(tmp_path / "ck")))
    b.run(6)
    b.close()
    assert ref.log.loss == b.log.loss
    # the CSV stream is exactly-once across the resume
    rows = (m1 / "rounds.csv").read_text().splitlines()
    assert [r.split(",")[0] for r in rows[1:]] == [str(r) for r in range(6)]
    # events appended, seq monotonic at-least-once across the two runs
    seqs = [e["seq"] for e in JsonlSink.load(m1 / "events.jsonl")
            if e["event"] == "round"]
    assert seqs == sorted(seqs)
    manifest = json.loads((m1 / "manifest.json").read_text())
    assert manifest["resumed_from"].endswith("ck")


# ---------------------------------------------------------------------------
# 4. mismatched configurations refuse to restore
# ---------------------------------------------------------------------------


def test_restore_refuses_mismatches(tmp_path):
    t1 = _make_trainer("colrel")
    t1.run(2)
    path = t1.save_checkpoint(tmp_path / "c.msgpack")

    with pytest.raises(ValueError, match="strategy"):
        _make_trainer("memory").run(6, resume_from=path)
    with pytest.raises(ValueError, match="telemetry"):
        _make_trainer("colrel", telemetry=True).run(6, resume_from=path)

    from repro.ckpt import restore_run_state
    state = read_state(path)
    state["version"] = 0
    with pytest.raises(ValueError, match="version"):
        restore_run_state(_make_trainer("colrel"), state)

    state = read_state(path)
    state["clients"] = state["clients"][:-1]
    with pytest.raises(ValueError, match="client"):
        restore_run_state(_make_trainer("colrel"), state)

    state = read_state(path)
    state["adaptive"] = {"estimator": {}, "events": "[]"}
    with pytest.raises(ValueError, match="adaptive"):
        restore_run_state(_make_trainer("colrel"), state)

    with pytest.raises(ValueError, match="behind"):
        # the resumed total must not be behind the checkpointed round
        _make_trainer("colrel").run(1, resume_from=path)


# ---------------------------------------------------------------------------
# 5. launcher flag validation (clear errors, not silent fallback)
# ---------------------------------------------------------------------------


def test_launcher_flag_validation():
    repo = pathlib.Path(__file__).parent.parent

    def run(*flags):
        return subprocess.run(
            [sys.executable, "-m", "repro.launch.train", "--smoke",
             "--rounds", "8", *flags],
            cwd=repo, capture_output=True, text=True,
            env={**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"})

    r = run("--chunk", "4", "--ckpt-dir", "/tmp/x", "--ckpt-every", "6")
    assert r.returncode == 2
    assert "multiple of --chunk" in r.stderr

    r = run("--resume")
    assert r.returncode == 2
    assert "--ckpt-dir" in r.stderr

    r = run("--ckpt-every", "2")
    assert r.returncode == 2
    assert "--ckpt-dir" in r.stderr
