"""The conformance matrix: every registered strategy x every engine.

Parametrization is derived from the live registry (``conformance.py``),
so registering a new strategy automatically buys it:

1. kill at round 3 / restore / continue *bitwise* — trajectories, final
   params / server state / agg state — under every execution engine
   (per-round loop, chunked scan, no-trace in-scan sampling, async),
   with the restore landing in the warm jit cache entry (no recompile);
2. the weight-sum contract: calibrated scalar-collapsible strategies
   satisfy ``E[sum w] = 1`` (Eq. (5)) under the fixture channel unless
   they declare ``unbiased_weight_sum = False``; non-collapsible ones
   log ``weight_sum = NaN`` every round.

The historical per-strategy copies of these checks lived in
``test_resume.py`` (golden kill/resume matrix) and
``test_strategies.py`` (memory-state jit round-trip); both now live
here, once.
"""

import numpy as np
import pytest

import conformance
from repro import strategies


@pytest.mark.parametrize("mode", list(conformance.EXECUTION_MODES))
@pytest.mark.parametrize("strategy", conformance.strategy_names())
def test_kill_resume_bitwise_no_recompile(strategy, mode, tmp_path):
    kw = conformance.run_kwargs(mode)
    ref = conformance.make_trainer(strategy, mode)
    ref.run(6, **kw)

    t1 = conformance.make_trainer(strategy, mode)
    t1.run(3, **kw)
    path = t1.save_checkpoint(tmp_path / "c.msgpack")

    t2 = conformance.make_trainer(strategy, mode)
    # resume semantics: `rounds` is the TOTAL target, not an increment
    t2.run(6, **kw, resume_from=path)
    assert t2.round == 6
    conformance.assert_same_run(ref, t2)
    # jit stability: the restored agg_state (incl. the async age vector /
    # staging buffer and any strategy-carried buffers) must land in the
    # already-warm cache entry — taus change every call without retracing
    assert conformance.compiled_fn(t2, mode)._cache_size() == 1


@pytest.mark.parametrize("strategy", conformance.strategy_names())
def test_weight_sum_contract(strategy):
    s = strategies.get(strategy)
    mean = conformance.mc_weight_sum(strategy)
    if np.isnan(mean):
        # no scalar collapse -> every logged weight_sum must be NaN by
        # contract (never a silently wrong number)
        t = conformance.make_trainer(strategy)
        t.run(3, chunk=1)
        assert all(np.isnan(x) for x in t.log.weight_sums), t.log.weight_sums
    elif s.unbiased_weight_sum:
        assert abs(mean - 1.0) < 0.1, (
            f"{strategy}: E[sum w] = {mean:.4f} != 1 after calibration")
    else:
        # declared-biased schemes (blind FedAvg) must actually be biased —
        # otherwise the flag is stale
        assert mean < 0.9, (
            f"{strategy}: declared unbiased_weight_sum=False but "
            f"E[sum w] = {mean:.4f}")


def test_matrix_derives_from_registry():
    """The grid tracks the live registry: a strategy registered tomorrow
    appears in the matrix with no test edits."""
    grid = conformance.matrix()
    assert {s for s, _ in grid} == set(strategies.available())
    assert {m for _, m in grid} == set(conformance.EXECUTION_MODES)
    assert len(grid) == len(strategies.available()) * len(
        conformance.EXECUTION_MODES)
    # the async engine is part of the standing matrix
    assert "async" in conformance.EXECUTION_MODES
