"""FL round semantics on a strongly-convex quadratic: mode equivalences and
the paper's convergence ordering (ColRel ~ perfect >> blind)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Aggregation, fedavg_weights, optimize_weights, topology
from repro.data import quadratic_problem
from repro.data.pipeline import ClientDataset
from repro.fl import FLTrainer
from repro.optim import sgd, sgd_momentum

PROB = quadratic_problem(10, 16, mu=1.0, L=8.0, hetero=1.0, seed=0)
H = jnp.asarray(PROB["H"], jnp.float32)
XSTAR = jnp.asarray(PROB["x_star"], jnp.float32)
MODEL = topology.paper_fig2a()
RES = optimize_weights(MODEL, sweeps=20, fine_tune_sweeps=20)


def loss_fn(params, batch):
    x = params["x"]
    d = x - batch["center"][0]
    return 0.5 * d @ (H @ d) + 0.1 * batch["noise"][0] @ x, {}


def make_clients(seed):
    cs = []
    for i in range(10):
        c = PROB["centers"][i].astype(np.float32)
        pool = np.random.default_rng(100 + i).normal(size=(2048, 16)).astype(np.float32)
        cs.append(ClientDataset({"center": np.tile(c, (2048, 1)), "noise": pool},
                                batch_size=1, seed=seed + i))
    return cs


def run(agg, A, mode="per_client", rounds=40, local_steps=4, seed=0):
    t = FLTrainer(loss_fn, {"x": jnp.zeros(16)}, MODEL, A, make_clients(7),
                  sgd(0.02), sgd_momentum(1.0, beta=0.0), local_steps=local_steps,
                  aggregation=agg, mode=mode, seed=seed)
    t.run(rounds)
    return float(jnp.sum((t.params["x"] - XSTAR) ** 2))


def test_fused_equals_faithful():
    a = run(Aggregation.COLREL, RES.A)
    b = run(Aggregation.COLREL_FUSED, RES.A)
    assert abs(a - b) < 1e-5


def test_sequential_equals_per_client():
    a = run(Aggregation.COLREL_FUSED, RES.A)
    b = run(Aggregation.COLREL_FUSED, RES.A, mode="client_sequential")
    assert abs(a - b) < 1e-4


def test_weighted_grad_equals_per_client_at_T1():
    a = run(Aggregation.COLREL_FUSED, RES.A, local_steps=1)
    b = run(Aggregation.COLREL_FUSED, RES.A, mode="weighted_grad", local_steps=1)
    assert abs(a - b) < 1e-4


def test_paper_ordering():
    colrel = run(Aggregation.COLREL, RES.A)
    blind = run(Aggregation.FEDAVG_BLIND, fedavg_weights(10))
    perfect = run(Aggregation.FEDAVG_PERFECT, fedavg_weights(10))
    # Fig. 2 ordering: blind >> colrel, colrel within noise of perfect.
    assert colrel < 0.1 * blind, (colrel, blind)
    assert colrel < blind and perfect < blind


def test_optimized_weights_reduce_round_variance():
    """COPT-alpha's S reduction shows up as lower realized variance of the
    aggregated round delta (the quantity Theorem 1 bounds)."""
    from repro.core import initial_weights, sample_round, effective_weights

    rng = np.random.default_rng(3)
    A_opt, A_init = RES.A, initial_weights(MODEL)
    var_opt = var_init = 0.0
    R = 8000
    for _ in range(R):
        tu, td = sample_round(MODEL, rng)
        w_o = effective_weights(A_opt, tu, td)
        w_i = effective_weights(A_init, tu, td)
        var_opt += ((w_o - 1).sum() / 10) ** 2
        var_init += ((w_i - 1).sum() / 10) ** 2
    assert var_opt < 0.5 * var_init, (var_opt / R, var_init / R)


def test_trainer_with_markov_channel_and_adaptive_alpha():
    """End-to-end: bursty channel + online estimation + periodic re-opt.
    The round function's A input is traced, so swapping alpha mid-run must
    not recompile or corrupt the trajectory; all adaptive logs populate."""
    from repro.channel import (
        AdaptiveConfig,
        AdaptiveWeightSchedule,
        MarkovChannel,
        gilbert_elliott,
    )

    ch = MarkovChannel(gilbert_elliott(MODEL, memory=0.8), seed=1, block=16)
    sched = AdaptiveWeightSchedule(
        10, AdaptiveConfig(every=10, warmup=5, sweeps=3, fine_tune_sweeps=3)
    )
    t = FLTrainer(loss_fn, {"x": jnp.zeros(16)}, MODEL, fedavg_weights(10),
                  make_clients(7), sgd(0.02), sgd_momentum(1.0, beta=0.0),
                  local_steps=2, aggregation=Aggregation.COLREL, seed=0,
                  channel=ch, adaptive=sched)
    t.run(30)
    assert len(t.log.loss) == 30 and np.isfinite(t.log.loss).all()
    assert t.log.reopt_rounds == [9, 19, 29]
    assert len(t.log.S_est) == len(t.log.S_true) == len(t.log.est_p_err) == 3
    assert len(t.log.weight_sums) == 30
    # resumed run() continues the round counter and the channel stream
    t.run(5)
    assert t.log.rounds[-1] == 34


def test_weighted_flat_equals_weighted_grad():
    """The flat ColRel round (per-sequence loss weights) produces the same
    global update as the per-client-vmap weighted_grad round."""
    import jax
    from repro.configs.base import get_arch
    from repro.core import sample_round
    from repro.fl.round import RoundConfig, make_round_fn
    from repro.models import build
    from repro.optim import sgd, sgd_momentum

    cfg = get_arch("qwen3-0.6b").smoke()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    n, B, S = 4, 2, 32
    m = topology.fully_connected(n, 0.6, p_c=0.8)
    rng = np.random.default_rng(0)
    tu, td = sample_round(m, rng)
    toks = rng.integers(0, cfg.vocab_size, size=(n, B, S + 1), dtype=np.int32)
    A = jnp.asarray(np.eye(n) * 2.0, jnp.float32)

    server = sgd_momentum(1.0, beta=0.0)
    out = {}
    for mode in ("weighted_grad", "weighted_flat"):
        rc = RoundConfig(n_clients=n, local_steps=1, mode=mode,
                         aggregation=Aggregation.COLREL_FUSED)
        fn = jax.jit(make_round_fn(bundle.loss_fn, sgd(0.1), server, rc))
        if mode == "weighted_grad":
            batches = {"tokens": jnp.asarray(toks[..., :-1]),
                       "labels": jnp.asarray(toks[..., 1:])}
        else:
            batches = {"tokens": jnp.asarray(toks[..., :-1]).reshape(n * B, S),
                       "labels": jnp.asarray(toks[..., 1:]).reshape(n * B, S)}
        p2, _, _, _ = fn(params, server.init(params), (),
                         batches, jnp.asarray(tu, jnp.float32),
                         jnp.asarray(td, jnp.float32), A)
        out[mode] = p2
    for a, b in zip(jax.tree.leaves(out["weighted_grad"]),
                    jax.tree.leaves(out["weighted_flat"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-5, rtol=2e-4)
