"""Golden-fixture generator for the aggregation-strategy refactor.

Run once against the PRE-refactor round implementation (the closed
``Aggregation`` enum dispatched inside ``fl/round.py``) to freeze the
exact round outputs for every (strategy, execution-mode) pair on fixed
tau draws:

    PYTHONPATH=src python tests/golden/generate_golden.py

``tests/test_strategies.py`` replays the identical experiment through
the registry-driven round and asserts bit-identical parameters, so any
numerical drift introduced by the strategy API is a test failure, not a
silent trajectory change.  The fixture (``round_golden.npz``) is
committed; this script is provenance + the regeneration recipe.
"""

import os

import numpy as np

# Golden fixtures are CPU artifacts: force determinism.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import jax.numpy as jnp

from repro.core import topology
from repro.core.connectivity import sample_round
from repro.fl.round import RoundConfig, make_round_fn
from repro.optim import sgd, sgd_momentum

N, DX, ROUNDS = 6, 8, 2
STRATEGIES = [
    "colrel", "colrel_fused", "fedavg_perfect", "fedavg_blind", "fedavg_nonblind",
]
MODES = ["per_client", "client_sequential", "weighted_grad"]


def problem():
    rng = np.random.default_rng(1234)
    H = rng.normal(size=(DX, DX))
    H = H @ H.T / DX + np.eye(DX)
    centers = rng.normal(size=(N, DX))
    Wc = rng.normal(size=(3, 4))
    model = topology.fully_connected(N, 0.5, p_c=0.7, rho=0.5)
    A = np.abs(rng.normal(size=(N, N))) + np.eye(N)
    return H, centers, Wc, model, A


def make_loss(H, Wc):
    Hj = jnp.asarray(H, jnp.float32)
    Wcj = jnp.asarray(Wc, jnp.float32)

    def loss_fn(params, batch):
        d = params["x"] - batch["center"][0]
        quad = 0.5 * d @ (Hj @ d) + 0.1 * batch["noise"][0] @ params["x"]
        wterm = 0.5 * jnp.sum((params["W"] - Wcj) ** 2)
        wterm = wterm + 0.1 * jnp.sum(batch["noise_w"][0] * params["W"])
        return quad + wterm, {}

    return loss_fn


def batches_for(rng, T):
    """(n, T, B=1, ...) stacked local-step batches, deterministic."""
    H, centers, _, _, _ = PROB
    return {
        "center": np.tile(centers[:, None, None, :], (1, T, 1, 1)).astype(np.float32),
        "noise": rng.normal(size=(N, T, 1, DX)).astype(np.float32),
        "noise_w": rng.normal(size=(N, T, 1, 3, 4)).astype(np.float32),
    }


PROB = problem()


def run_config(strategy, mode, *, use_fused_kernel=False):
    """Replay one (strategy, mode) config through the current round
    implementation.  Originally run at the pre-refactor commit (enum
    dispatch, no agg_state) to produce the frozen fixture; now exercises
    the registry-driven round so the golden test replays it exactly."""
    H, centers, Wc, model, A = PROB
    T = 1 if mode == "weighted_grad" else 2
    rc_kwargs = dict(n_clients=N, local_steps=T, mode=mode, aggregation=strategy)
    if use_fused_kernel:
        rc_kwargs["use_fused_kernel"] = True
    rc = RoundConfig(**rc_kwargs)
    server_opt = sgd_momentum(1.0, beta=0.9)
    fn = jax.jit(make_round_fn(make_loss(H, Wc), sgd(0.05), server_opt, rc))

    params = {"x": jnp.zeros(DX, jnp.float32), "W": jnp.zeros((3, 4), jnp.float32)}
    sstate = server_opt.init(params)
    agg_state = rc.resolve_strategy().init_state(N, DX + 12)
    tau_rng = np.random.default_rng(77)
    bat_rng = np.random.default_rng(99)
    metrics = None
    for _ in range(ROUNDS):
        tau_up, tau_dd = sample_round(model, tau_rng)
        b = batches_for(bat_rng, T)
        if mode == "weighted_grad":
            b = {k: v[:, 0] for k, v in b.items()}
        out = fn(params, sstate, agg_state, jax.tree.map(jnp.asarray, b),
                 jnp.asarray(tau_up, jnp.float32), jnp.asarray(tau_dd, jnp.float32),
                 jnp.asarray(A, jnp.float32))
        params, sstate, agg_state, metrics = out[0], out[1], out[2], out[-1]
    return params, metrics


def quantized_int8_strategy():
    """The pinned quantized config: int8 stochastic rounding (seed 0)
    around colrel.  The codec PRNG key comes from ``init_state`` and jax's
    default threefry is stable across versions, so the trajectory is a
    committed fixture like the legacy enum configs."""
    from repro import strategies

    return strategies.get("quantized", codec="int8", inner="colrel")


QUANT_TAG = "quantized_int8|per_client"


def run_quantized():
    return run_config(quantized_int8_strategy(), "per_client")


def main(extend: bool = False):
    """``--extend`` loads the committed fixture and appends only missing
    tags (the quantized entry), so the frozen pre-refactor arrays are
    carried over byte-for-byte rather than recomputed."""
    path = os.path.join(os.path.dirname(__file__), "round_golden.npz")
    out = {}
    if extend:
        with np.load(path) as existing:
            out.update({k: existing[k] for k in existing.files})
    else:
        configs = [(s, m, False) for s in STRATEGIES for m in MODES]
        configs.append(("colrel", "per_client", True))
        for strategy, mode, fused_kernel in configs:
            params, metrics = run_config(strategy, mode,
                                         use_fused_kernel=fused_kernel)
            tag = f"{strategy}|{mode}" + ("|kernel" if fused_kernel else "")
            out[f"{tag}|x"] = np.asarray(params["x"], np.float32)
            out[f"{tag}|W"] = np.asarray(params["W"], np.float32)
            out[f"{tag}|weight_sum"] = np.float32(metrics["weight_sum"])
            print(f"{tag:40s} |x|={np.linalg.norm(out[f'{tag}|x']):.6f}")
    if f"{QUANT_TAG}|x" not in out:
        params, _ = run_quantized()
        out[f"{QUANT_TAG}|x"] = np.asarray(params["x"], np.float32)
        out[f"{QUANT_TAG}|W"] = np.asarray(params["W"], np.float32)
        print(f"{QUANT_TAG:40s} "
              f"|x|={np.linalg.norm(out[f'{QUANT_TAG}|x']):.6f}")
    np.savez(path, **out)
    print(f"wrote {path} ({len(out)} arrays)")


if __name__ == "__main__":
    import sys

    main(extend="--extend" in sys.argv)
