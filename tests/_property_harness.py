"""Hypothesis front-end for the property tests, with a seeded fallback.

When the real ``hypothesis`` package is importable it is used directly,
with two profiles registered here so CI is reproducible:

* ``ci``  — ``derandomize=True`` (the example stream is derived from the
  test's source, no ambient entropy), loaded when ``CI`` is set;
* ``dev`` — ``deadline=None`` (jit compilation blows any wall-clock
  deadline), loaded otherwise.

When hypothesis is absent (this container ships without it), a minimal
deterministic stand-in provides the same surface the tests use —
``given`` / ``settings`` / ``st.integers`` / ``st.floats`` /
``st.booleans`` / ``st.sampled_from`` / ``st.composite`` — drawing
``max_examples`` examples from a ``numpy`` generator seeded from the
test's qualified name, so runs are reproducible and the suite reports
the same pass/fail either way (no skips).
"""

from __future__ import annotations

import os
import zlib

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
    for _name, _kw in (("ci", dict(derandomize=True, deadline=None)),
                       ("dev", dict(deadline=None))):
        settings.register_profile(_name, **_kw)
    settings.load_profile("ci" if os.environ.get("CI") else "dev")
except ImportError:
    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw = draw_fn

        def example(self, rng):
            return self._draw(rng)

    class _St:
        """The slice of ``hypothesis.strategies`` the tests draw from."""

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value,
                                                          max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: float(rng.uniform(min_value,
                                                           max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

        @staticmethod
        def composite(fn):
            def build(*args, **kw):
                def draw_fn(rng):
                    return fn(lambda s: s.example(rng), *args, **kw)

                return _Strategy(draw_fn)

            return build

    st = _St()

    def settings(**kw):
        """Records ``max_examples`` on the (already-``given``-wrapped)
        test; every other knob is a no-op here."""

        def deco(fn):
            fn._max_examples = kw.get("max_examples", 10)
            return fn

        return deco

    def given(*strategies_):
        def deco(fn):
            # no functools.wraps: __wrapped__ would make pytest inspect
            # the original signature and demand fixtures for the params
            def wrapper():
                n = getattr(wrapper, "_max_examples", 10)
                base = zlib.crc32(fn.__qualname__.encode())
                for i in range(n):
                    rng = np.random.default_rng((base, i))
                    args = [s.example(rng) for s in strategies_]
                    try:
                        fn(*args)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example #{i} (seed ({base}, {i})): "
                            f"{args!r}") from e

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco
