"""The flatten-once fused aggregation engine vs its faithful oracles.

Three layers of equivalence, none needing extra deps:
  1. kernel level  — fused_aggregate_pallas == relay_mix + ps_aggregate
                     (the two-stage path in core/relay.py) over random tau
                     draws, f32 and bf16, n off the 8-sublane grid and d
                     off the block_d grid;
  2. flatten level — ravel_stacked/unravel round-trips real model param
                     trees bit-exactly;
  3. round level   — a per_client COLREL round with use_fused_kernel=True
                     matches the per-leaf tensordot round end to end.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flatten
from repro.core.relay import ps_aggregate, relay_mix
from repro.kernels import ref
from repro.kernels.fused_aggregate import fused_aggregate_pallas

RNG = np.random.default_rng(7)


def _random_round(n, rng):
    A = jnp.asarray(rng.random((n, n)) * 0.5 + 0.1, jnp.float32)
    tau_up = jnp.asarray((rng.random(n) < 0.7).astype(np.float32))
    tau_dd = jnp.asarray((rng.random((n, n)) < 0.5).astype(np.float32))
    return A, tau_up, tau_dd


def _two_stage_oracle(A, tau_up, tau_dd, X):
    """The faithful pipeline exactly as core/relay.py composes it, fp32."""
    tilde = relay_mix(X.astype(jnp.float32), A, tau_dd)
    return ps_aggregate(tilde, tau_up)


@pytest.mark.parametrize("n", [4, 10, 16, 33])  # 4/10/33 are off the 8-sublane grid
@pytest.mark.parametrize("d", [96, 1000, 4096])  # 96/1000 are off the block_d grid
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_kernel_matches_two_stage_oracle(n, d, dtype):
    A, tau_up, tau_dd = _random_round(n, RNG)
    X = jnp.asarray(RNG.normal(size=(n, d))).astype(dtype)
    got = fused_aggregate_pallas(A, tau_up, tau_dd, X, block_d=512, interpret=True)
    want = _two_stage_oracle(A, tau_up, tau_dd, X)
    assert got.shape == (d,) and got.dtype == jnp.float32
    tol = 1e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol, rtol=tol)


@pytest.mark.parametrize("seed", range(5))
def test_fused_kernel_random_tau_draws(seed):
    """Acceptance sweep: <=1e-5 max abs error (f32) over randomized taus."""
    rng = np.random.default_rng(seed)
    n, d = int(rng.integers(2, 24)), int(rng.integers(1, 2000))
    A, tau_up, tau_dd = _random_round(n, rng)
    X = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    got = fused_aggregate_pallas(A, tau_up, tau_dd, X, block_d=256, interpret=True)
    want = ref.fused_aggregate_ref(A, tau_up, tau_dd, X)
    assert float(jnp.abs(got - want).max()) <= 1e-5


def test_fused_kernel_block_larger_than_d():
    """block_d > d collapses to a single masked tile."""
    n, d = 8, 100
    A, tau_up, tau_dd = _random_round(n, RNG)
    X = jnp.asarray(RNG.normal(size=(n, d)), jnp.float32)
    got = fused_aggregate_pallas(A, tau_up, tau_dd, X, block_d=4096, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.fused_aggregate_ref(A, tau_up, tau_dd, X)),
        atol=1e-5, rtol=1e-5,
    )


# ---------------------------------------------------------------------------
# flatten round-trips on real model parameter trees
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "olmo-1b"])
def test_flatten_roundtrip_model_params(arch):
    from repro.configs.base import get_arch
    from repro.models import build

    cfg = get_arch(arch).smoke()
    params = build(cfg).init(jax.random.PRNGKey(0))
    spec = flatten.flat_spec(params)
    flat = flatten.ravel(params, dtype=jnp.float32)
    assert flat.shape == (spec.d,)
    back = flatten.unravel(spec, flat)
    assert jax.tree.structure(back) == jax.tree.structure(params)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(params)):
        assert a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b, np.float32))


def test_flatten_stacked_roundtrip_and_layout():
    """Stacked ravel keeps client rows independent and leaf order stable."""
    n = 3
    tree = {
        "w": jnp.asarray(RNG.normal(size=(n, 4, 5)), jnp.float32),
        "b": {"inner": jnp.asarray(RNG.normal(size=(n, 7)), jnp.float32)},
        "s": jnp.asarray(RNG.normal(size=(n,)), jnp.float32).reshape(n, *()),
    }
    # leaves (n, *shape); per-client view must equal the per-tree ravel
    spec = flatten.flat_spec(tree, stacked=True)
    stack = flatten.ravel_stacked(tree)
    assert stack.shape == (n, spec.d)
    for i in range(n):
        client_tree = jax.tree.map(lambda x: x[i], tree)
        np.testing.assert_array_equal(
            np.asarray(stack[i]), np.asarray(flatten.ravel(client_tree))
        )
        back = flatten.unravel(spec, stack[i])
        for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(client_tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_unravel_rejects_wrong_length():
    spec = flatten.flat_spec({"a": jnp.zeros((2, 3))})
    with pytest.raises(ValueError):
        flatten.unravel(spec, jnp.zeros((7,)))


def test_round_config_rejects_inert_fused_flag():
    """use_fused_kernel + non-COLREL aggregation would silently run the
    scalar-weights path; RoundConfig refuses the combination outright."""
    from repro.core import Aggregation
    from repro.fl.round import RoundConfig

    with pytest.raises(ValueError, match="use_fused_kernel"):
        RoundConfig(n_clients=4, local_steps=1,
                    aggregation=Aggregation.FEDAVG_BLIND, use_fused_kernel=True)


# ---------------------------------------------------------------------------
# round level: fused engine == per-leaf tensordot path end to end
# ---------------------------------------------------------------------------


def test_round_fused_kernel_matches_per_leaf_path():
    from repro.core import Aggregation, optimize_weights, sample_round, topology
    from repro.fl.round import RoundConfig, make_round_fn
    from repro.optim import sgd, sgd_momentum

    n, T, dim = 6, 3, 16
    H = jnp.eye(dim) * 2.0

    def loss_fn(params, batch):
        d = params["x"] - batch["center"][0]
        return 0.5 * d @ (H @ d), {}

    m = topology.fully_connected(n, 0.5, p_c=0.8)
    A = jnp.asarray(optimize_weights(m, sweeps=5, fine_tune_sweeps=5).A, jnp.float32)
    rng = np.random.default_rng(0)
    tu, td = sample_round(m, rng)
    params = {"x": jnp.zeros((dim,), jnp.float32),
              "y": {"z": jnp.ones((4, 3), jnp.float32)}}
    batches = {"center": jnp.asarray(rng.normal(size=(n, T, 1, dim)), jnp.float32)}

    def loss2(params, batch):
        l, _ = loss_fn({"x": params["x"]}, batch)
        return l + 0.05 * jnp.sum(params["y"]["z"] ** 2), {}

    server = sgd_momentum(1.0, beta=0.9)
    out = {}
    for fused in (False, True):
        rc = RoundConfig(n_clients=n, local_steps=T, mode="per_client",
                         aggregation=Aggregation.COLREL, use_fused_kernel=fused,
                         fused_block_d=128)
        fn = jax.jit(make_round_fn(loss2, sgd(0.05), server, rc))
        p2, _, _, metrics = fn(params, server.init(params), (), batches,
                               jnp.asarray(tu, jnp.float32),
                               jnp.asarray(td, jnp.float32), A)
        out[fused] = (p2, metrics)
    for a, b in zip(jax.tree.leaves(out[False][0]), jax.tree.leaves(out[True][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6, rtol=1e-6)
    assert abs(float(out[False][1]["loss"]) - float(out[True][1]["loss"])) < 1e-6


def test_round_config_flat_dtype_bf16_close_to_f32():
    """bf16 stack: same round, looser tolerance (fp32 accumulation)."""
    from repro.core import Aggregation, sample_round, topology
    from repro.fl.round import RoundConfig, make_round_fn
    from repro.optim import sgd, sgd_momentum

    n, T, dim = 4, 2, 32

    def loss_fn(params, batch):
        d = params["x"] - batch["center"][0]
        return 0.5 * jnp.sum(d * d), {}

    m = topology.fully_connected(n, 0.6, p_c=0.9)
    rng = np.random.default_rng(1)
    tu, td = sample_round(m, rng)
    A = jnp.asarray(np.eye(n), jnp.float32)
    params = {"x": jnp.zeros((dim,), jnp.float32)}
    batches = {"center": jnp.asarray(rng.normal(size=(n, T, 1, dim)), jnp.float32)}
    server = sgd_momentum(1.0, beta=0.0)
    got = {}
    for flat_dtype in ("float32", "bfloat16"):
        rc = RoundConfig(n_clients=n, local_steps=T, mode="per_client",
                         aggregation=Aggregation.COLREL, use_fused_kernel=True,
                         flat_dtype=flat_dtype, fused_block_d=128)
        fn = jax.jit(make_round_fn(loss_fn, sgd(0.1), server, rc))
        p2, _, _, _ = fn(params, server.init(params), (), batches,
                         jnp.asarray(tu, jnp.float32), jnp.asarray(td, jnp.float32), A)
        got[flat_dtype] = np.asarray(p2["x"])
    np.testing.assert_allclose(got["bfloat16"], got["float32"], atol=5e-3, rtol=5e-2)
