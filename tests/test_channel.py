"""Dynamic channel subsystem: Gilbert–Elliott statistics, scan-vs-host
distribution identity, online estimation, adaptive alpha re-optimization,
and the vectorized static sampler against the per-round loop reference."""

import numpy as np
import pytest

from repro.channel import (
    AdaptiveConfig,
    AdaptiveWeightSchedule,
    LinkEstimator,
    MarkovChannel,
    MobilityChannel,
    StaticChannel,
    channel_key,
    gilbert_elliott,
    sample_ge_rounds,
    sample_ge_rounds_host,
)
from repro.core import (
    LinkModel,
    is_unbiased,
    optimize_weights,
    sample_round,
    sample_rounds,
    topology,
    unbiasedness_residual,
)

MODEL = topology.fully_connected(6, 0.6, p_c=0.5, rho=0.5)
OFF = ~np.eye(6, dtype=bool)


# ---------------------------------------------------------------------------
# Gilbert–Elliott chains
# ---------------------------------------------------------------------------


def test_ge_feasibility_and_validation():
    ge = gilbert_elliott(MODEL, memory=0.9)
    # tightest gates: uplink occupancy equals the marginal
    assert np.allclose(ge.pi_up, MODEL.p)
    # pair occupancy obeys the Fréchet floor
    iu, ju = ge.pair_indices()
    floor = np.maximum(
        np.maximum(MODEL.P[iu, ju], MODEL.P[ju, iu]),
        MODEL.P[iu, ju] + MODEL.P[ju, iu] - MODEL.E[iu, ju],
    )
    assert np.all(ge.pi_dd >= floor - 1e-12)
    with pytest.raises(ValueError):
        gilbert_elliott(MODEL, memory=1.0)
    with pytest.raises(ValueError):
        gilbert_elliott(MODEL, memory=0.5, occupancy=0.0)


def test_ge_stationary_occupancy_matches_marginals():
    """Empirical stationary occupancy of the scanned GE trace matches the
    target (p, P, E) within ESS-corrected tolerance."""
    lam, R = 0.8, 20000
    ge = gilbert_elliott(MODEL, memory=lam)
    ups, dds = sample_ge_rounds(ge, channel_key(0), R)
    ups, dds = np.asarray(ups, np.float64), np.asarray(dds, np.float64)
    ess = (1 - lam) / (1 + lam)
    # per-link tolerance: 5 sigma of the autocorrelated mean
    tol_up = 5 * np.sqrt(MODEL.p * (1 - MODEL.p) / (R * ess))
    assert np.all(np.abs(ups.mean(0) - MODEL.p) < tol_up + 1e-9)
    tol_dd = 5 * np.sqrt(np.maximum(MODEL.P * (1 - MODEL.P), 1e-12) / (R * ess))
    assert np.all(np.abs((dds.mean(0) - MODEL.P))[OFF] < (tol_dd + 1e-9)[OFF])
    joint = (dds * np.swapaxes(dds, 1, 2)).mean(0)
    tol_e = 5 * np.sqrt(np.maximum(MODEL.E * (1 - MODEL.E), 1e-12) / (R * ess))
    assert np.all(np.abs(joint - MODEL.E)[OFF] < (tol_e + 1e-9)[OFF])
    assert np.all(dds[:, np.arange(6), np.arange(6)] == 1.0)


def test_ge_burstiness_lag1():
    """Lag-1 autocorrelation of the taus matches the analytic value, and
    memory=0 really is the i.i.d. channel (no temporal correlation)."""
    R = 20000
    for lam in (0.0, 0.9):
        ge = gilbert_elliott(MODEL, memory=lam)
        ups, _ = sample_ge_rounds(ge, channel_key(1), R)
        ups = np.asarray(ups, np.float64)
        want = ge.lag1_uplink()[0]
        got = np.mean(
            [np.corrcoef(ups[:-1, i], ups[1:, i])[0, 1] for i in range(6)]
        )
        assert abs(got - want) < 0.05, (lam, got, want)
    assert gilbert_elliott(MODEL, memory=0.0).lag1_uplink()[0] == 0.0


def test_ge_host_and_scan_same_distribution():
    """The numpy per-round loop and the fused scan draw from the same law
    (grand means within 6 sigma of each other)."""
    lam, R = 0.7, 8000
    ge = gilbert_elliott(MODEL, memory=lam)
    ups_h, dds_h = sample_ge_rounds_host(ge, np.random.default_rng(0), R)
    ups_s, dds_s = sample_ge_rounds(ge, channel_key(2), R)
    ups_s, dds_s = np.asarray(ups_s, np.float64), np.asarray(dds_s, np.float64)
    ess = (1 - lam) / (1 + lam)
    n_up = 6 * R * ess
    sd = np.sqrt(2 * 0.25 / n_up)  # two-sample, p(1-p) <= 1/4
    assert abs(ups_h.mean() - ups_s.mean()) < 6 * sd
    n_dd = 15 * R * ess  # unordered pairs
    sd = np.sqrt(2 * 0.25 / n_dd)
    assert abs(dds_h.mean(0)[OFF].mean() - dds_s.mean(0)[OFF].mean()) < 6 * sd
    jh = (dds_h * np.swapaxes(dds_h, 1, 2)).mean(0)[OFF].mean()
    js = (dds_s * np.swapaxes(dds_s, 1, 2)).mean(0)[OFF].mean()
    assert abs(jh - js) < 6 * sd


def test_markov_channel_blocks_are_consistent():
    """Block-wise service equals one continuous trace (state carried)."""
    ge = gilbert_elliott(MODEL, memory=0.9)
    ch = MarkovChannel(ge, seed=0, block=32)
    taus = [ch.tau_for_round(r) for r in range(100)]
    assert all(t[0].shape == (6,) and t[1].shape == (6, 6) for t in taus)
    with pytest.raises(ValueError):
        ch.tau_for_round(3)  # cannot rewind
    # burstiness survives block boundaries: long-run mean is still p
    ups = np.array([t[0] for t in taus])
    assert abs(ups.mean() - 0.6) < 0.15


# ---------------------------------------------------------------------------
# Static + mobility channels
# ---------------------------------------------------------------------------


def test_static_channel_matches_paper_law(rng):
    ch = StaticChannel(MODEL, seed=0)
    R = 4000
    ups = np.array([ch.tau_for_round(r)[0] for r in range(R)])
    assert np.all(np.abs(ups.mean(0) - MODEL.p) < 5 * np.sqrt(0.25 / R) + 1e-9)
    assert ch.model_for_round(7) is MODEL


def test_mobility_channel_drifts():
    ch = MobilityChannel(8, area=250.0, speed=20.0, epoch=5, seed=0)
    for r in range(20):
        tu, td = ch.tau_for_round(r)
        assert tu.shape == (8,) and td.shape == (8, 8)
    m0, m3 = ch.model_for_round(0), ch.model_for_round(19)
    assert isinstance(m0, LinkModel) and isinstance(m3, LinkModel)
    # fast movement must actually change the uplink marginals
    assert np.abs(m0.p - m3.p).max() > 1e-3
    with pytest.raises(ValueError):
        ch.model_for_round(500)  # future epoch


# ---------------------------------------------------------------------------
# Estimation + adaptive re-optimization
# ---------------------------------------------------------------------------


def test_estimator_converges_on_long_trace(rng):
    est = LinkEstimator(6)
    for _ in range(6000):
        est.update(*sample_round(MODEL, rng))
    assert np.abs(est.p_hat - MODEL.p).max() < 0.04
    assert np.abs((est.P_hat - MODEL.P)[OFF]).max() < 0.04
    assert np.abs((est.E_hat - MODEL.E)[OFF]).max() < 0.04
    em = est.estimated_model()  # projection must be LinkModel-feasible
    assert isinstance(em, LinkModel)
    errs = est.errors(MODEL)
    assert max(errs.values()) < 0.05


def test_estimator_converges_on_bursty_trace():
    """Same marginals under bursty GE: the estimator must still find them."""
    ge = gilbert_elliott(MODEL, memory=0.9)
    est = LinkEstimator(6)
    ups, dds = sample_ge_rounds(ge, channel_key(3), 20000)
    ups, dds = np.asarray(ups, np.float64), np.asarray(dds, np.float64)
    for r in range(ups.shape[0]):
        est.update(ups[r], dds[r])
    assert np.abs(est.p_hat - MODEL.p).max() < 0.06
    assert np.abs((est.P_hat - MODEL.P)[OFF]).max() < 0.06


def test_estimator_decay_tracks_drift(rng):
    """EWMA estimator follows a mid-stream change of the true model."""
    m2 = topology.fully_connected(6, 0.2, p_c=0.5, rho=0.5)
    est = LinkEstimator(6, decay=0.98)
    for _ in range(2000):
        est.update(*sample_round(MODEL, rng))
    for _ in range(2000):
        est.update(*sample_round(m2, rng))
    assert np.abs(est.p_hat - m2.p).max() < 0.1  # forgot the old p=0.6


def test_adaptive_alpha_unbiased_after_reopt(rng):
    """Alpha re-optimized from estimated stats satisfies the unbiasedness
    condition exactly under the estimated model and approximately under
    the true one (shrinking with estimation error)."""
    sched = AdaptiveWeightSchedule(6, AdaptiveConfig(every=500, warmup=100))
    A = None
    for r in range(2000):
        out = sched.step(r, *sample_round(MODEL, rng))
        if out is not None:
            A = out
    assert A is not None and len(sched.events) == 4
    assert is_unbiased(sched.estimator.estimated_model(), A, atol=1e-6)
    resid = np.abs(unbiasedness_residual(MODEL, A)).max()
    assert resid < 0.1, resid
    # and the adaptive S is in the same ballpark as the oracle optimum
    oracle = optimize_weights(MODEL, sweeps=10, fine_tune_sweeps=10)
    assert sched.events[-1]["S_est"] < 2.0 * oracle.S + 1.0


def test_adaptive_schedule_cadence(rng):
    sched = AdaptiveWeightSchedule(6, AdaptiveConfig(every=10, warmup=25))
    fired = [r for r in range(60) if sched.step(r, *sample_round(MODEL, rng)) is not None]
    assert fired == [29, 39, 49, 59]  # warmup respected, then every K


# ---------------------------------------------------------------------------
# Satellite: vectorized static sample_rounds vs the per-round loop
# ---------------------------------------------------------------------------


def _sample_rounds_loop(model, rng, rounds):
    """The old host-side per-round reference implementation."""
    ups = np.empty((rounds, model.n))
    dds = np.empty((rounds, model.n, model.n))
    for r in range(rounds):
        ups[r], dds[r] = sample_round(model, rng)
    return ups, dds


def test_sample_rounds_matches_loop_reference(rng):
    R = 4000
    ups_v, dds_v = sample_rounds(MODEL, np.random.default_rng(1), R)
    ups_l, dds_l = _sample_rounds_loop(MODEL, np.random.default_rng(2), R)
    assert ups_v.shape == ups_l.shape and dds_v.shape == dds_l.shape
    sd = np.sqrt(2 * 0.25 / (6 * R))
    assert abs(ups_v.mean() - ups_l.mean()) < 6 * sd
    sd = np.sqrt(2 * 0.25 / (15 * R))
    assert abs(dds_v.mean(0)[OFF].mean() - dds_l.mean(0)[OFF].mean()) < 6 * sd
    jv = (dds_v * np.swapaxes(dds_v, 1, 2)).mean(0)[OFF].mean()
    jl = (dds_l * np.swapaxes(dds_l, 1, 2)).mean(0)[OFF].mean()
    assert abs(jv - jl) < 6 * sd
    assert np.all(dds_v[:, np.arange(6), np.arange(6)] == 1.0)


def test_effective_weights_numpy_jax_agree(rng):
    """Satellite: the canonical numpy effective_weights and its device twin
    evaluate the identical contraction."""
    import jax.numpy as jnp

    from repro.core import effective_weights
    from repro.core.relay import effective_weights as effective_weights_jax

    for _ in range(10):
        A = rng.random((6, 6))
        tu, td = sample_round(MODEL, rng)
        w_np = effective_weights(A, tu, td)
        w_jx = np.asarray(
            effective_weights_jax(
                jnp.asarray(A, jnp.float32),
                jnp.asarray(tu, jnp.float32),
                jnp.asarray(td, jnp.float32),
            )
        )
        np.testing.assert_allclose(w_np, w_jx, rtol=1e-5, atol=1e-5)
