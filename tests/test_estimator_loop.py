"""Estimator-in-the-loop Theorem 1 check.

Theorem 1's error floor scales with the variance proxy ``S`` of the
relaying weights in use.  When alpha is re-optimized from *estimated*
link statistics (Algorithm 3 fed by the in-loop ``LinkEstimator``
instead of oracle probabilities), the achieved floor can only be worse
than the oracle COPT-alpha floor by however wrong the estimate is — so
the empirical chain to pin is:

1. the estimator is consistent: the re-opt gap ``|S_est - S_true|``
   from ``TrainLog`` shrinks as rounds accumulate;
2. the excess variance of the adaptive alpha over the oracle optimum
   (``S_true - S_opt``, both measured on the *true* model) shrinks with
   it, and ends bounded by the remaining estimation gap;
3. training with the adaptively-found alpha reaches an error floor
   comparable to the oracle's (Theorem 1 with estimated stats), far
   below the unoptimized initialization's.
"""

import jax.numpy as jnp
import numpy as np

from repro.channel import (
    AdaptiveConfig,
    AdaptiveWeightSchedule,
    MarkovChannel,
    gilbert_elliott,
)
from repro.core import initial_weights, optimize_weights, topology, variance_S
from repro.data import quadratic_problem
from repro.data.pipeline import ClientDataset
from repro.fl import FLTrainer
from repro.optim import inverse_round_decay, sgd, sgd_momentum

N, DX = 10, 16


def _quad_trainer(model, A, *, adaptive=None, channel=None, seed=0,
                  local_steps=8):
    prob = quadratic_problem(N, DX, mu=1.0, L=8.0, hetero=1.0, seed=0)
    H = jnp.asarray(prob["H"], jnp.float32)

    def loss_fn(params, batch):
        x = params["x"]
        d = x - batch["center"][0]
        return 0.5 * d @ (H @ d) + 0.5 * batch["noise"][0] @ x, {}

    clients = []
    for i in range(N):
        c = prob["centers"][i].astype(np.float32)
        pool = np.random.default_rng(50 + i).normal(
            size=(4096, DX)).astype(np.float32)
        clients.append(ClientDataset({"center": np.tile(c, (4096, 1)),
                                      "noise": pool}, batch_size=1,
                                     seed=seed + i))
    # Theorem 1 schedule: eta_r = (4/mu) / (rT + 1), clipped for stability
    sched = lambda step: jnp.minimum(
        inverse_round_decay(4.0, local_steps)(step), jnp.float32(0.05))
    return FLTrainer(loss_fn, {"x": jnp.zeros(DX)}, model, A, clients,
                     sgd(sched), sgd_momentum(1.0, beta=0.0),
                     local_steps=local_steps, strategy="colrel", seed=seed,
                     channel=channel, adaptive=adaptive), prob


def _final_mse(trainer, prob, rounds=96, chunk=16):
    trainer.run(rounds, chunk=chunk)
    xs = np.asarray(prob["x_star"])
    return float(np.sum((np.asarray(trainer.params["x"]) - xs) ** 2))


def test_estimator_floor_tracks_estimation_error():
    model = topology.paper_fig2b()
    channel = MarkovChannel(gilbert_elliott(model, memory=0.5), seed=11,
                            block=16)
    # the oracle optimizes against the channel's *effective* stationary
    # model (what `S_true` is measured on), not the raw link model
    true_m = channel.model_for_round(0)
    oracle = optimize_weights(true_m, sweeps=100, fine_tune_sweeps=100)
    A0 = initial_weights(model)

    # phase 1: adaptive run from the feasible initialization; the
    # schedule re-optimizes alpha from estimated stats every 16 rounds
    cfg = AdaptiveConfig(every=16, warmup=8, sweeps=15, fine_tune_sweeps=15)
    t, prob = _quad_trainer(model, A0,
                            adaptive=AdaptiveWeightSchedule(N, cfg),
                            channel=channel, seed=1)
    mse_adaptive = _final_mse(t, prob)
    log = t.log
    assert len(log.S_est) >= 3, "fixture must re-optimize several times"

    # 1. estimator consistency: both the S gap and the marginal-p error
    #    at the last re-opt sit well below the first (more observed
    #    rounds -> better stats)
    gaps = [abs(e - s) for e, s in zip(log.S_est, log.S_true)]
    assert gaps[-1] <= 0.8 * gaps[0] + 1e-3, gaps
    assert log.est_p_err[-1] < 0.6 * log.est_p_err[0], log.est_p_err
    # ...and S_est is honest by the end: within 30% of the truth
    assert gaps[-1] <= 0.3 * log.S_true[-1], gaps

    # 2. the achieved variance tracks the oracle optimum to within the
    #    remaining estimation error (no sign constraint: an alpha that
    #    is unbiased only under *estimated* stats may undercut the
    #    oracle's constrained minimum by violating true unbiasedness),
    #    and lands far below the unoptimized initialization
    dev = [abs(s - oracle.S) for s in log.S_true]
    assert dev[-1] <= 2.0 * gaps[-1] + 0.05 * oracle.S, (dev, gaps)
    S0 = variance_S(true_m, A0)
    assert oracle.S < S0, "fixture must leave COPT room to optimize"
    assert log.S_true[-1] < 0.25 * S0, (log.S_true[-1], S0)

    # 3. Theorem 1 with estimated stats: the error floor reached from
    #    estimated statistics is within a small factor of the floor the
    #    oracle alpha reaches under the same schedule — not the ~S0/S_opt
    #    (6.5x) variance blow-up a non-adapting run would predict
    t_or, prob = _quad_trainer(model, oracle.A, seed=1)
    mse_oracle = _final_mse(t_or, prob)
    assert mse_adaptive <= 4.0 * mse_oracle, (mse_adaptive, mse_oracle)
