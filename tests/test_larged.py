"""Large-d engine tests (DESIGN.md §14): segmented flatten vs the
concat oracle, unaligned-d segment streaming, carry donation, and the
donation-vs-async-checkpoint race.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import conformance
from repro import strategies
from repro.ckpt import writer
from repro.core import flatten
from repro.kernels import ops as kernel_ops
from repro.strategies.async_relay import AsyncRelayStrategy, delivered_mask
from repro.strategies.base import ExecutionContext

N = 5


def _tree(shapes, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return {f"l{i}": jnp.asarray(rng.normal(size=(N, *s)).astype(dtype))
            for i, s in enumerate(shapes)}


# awkward layouts: prime sizes, 1-element leaves, a single-leaf tree
AWKWARD = [
    [(7, 3), (1,), (13,), (2, 5, 3)],
    [(1,), (1,), (1,)],
    [(37,)],
]


@pytest.mark.parametrize("shapes", AWKWARD)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ravel_stacked_dus_bitwise_matches_concat(shapes, dtype):
    """The segmented DUS-fill flatten is bitwise the concatenate oracle,
    including the per-leaf cast (no full-size third copy)."""
    tree = _tree(shapes)
    a = flatten.ravel_stacked(tree, dtype=dtype)
    b = flatten.ravel_stacked_concat(tree, dtype=dtype)
    assert a.dtype == b.dtype == dtype
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("shapes", AWKWARD)
def test_segments_concat_is_the_stack(shapes):
    tree = _tree(shapes)
    segs = flatten.ravel_stacked_segments(tree, dtype=jnp.float32)
    spec = flatten.flat_spec(tree, stacked=True)
    assert [s.shape for s in segs] == [(N, sz) for sz in spec.sizes]
    np.testing.assert_array_equal(
        np.asarray(jnp.concatenate(segs, axis=1)),
        np.asarray(flatten.ravel_stacked(tree, dtype=jnp.float32)))


def _ctx(segment_d=0):
    return ExecutionContext(n_clients=N, segment_d=segment_d)


def _channel(seed=1):
    rng = np.random.default_rng(seed)
    tau_up = jnp.asarray((rng.random(N) < 0.6).astype(np.float32))
    tau_dd = jnp.asarray((rng.random((N, N)) < 0.7).astype(np.float32))
    A = jnp.asarray(rng.dirichlet(np.ones(N), size=N).T.astype(np.float32))
    return tau_up, tau_dd, A


@pytest.mark.parametrize("shapes", AWKWARD)
def test_colrel_segment_stream_unaligned_d(shapes):
    """Segmented colrel == monolithic kernel path at prime/unaligned d.
    The reduction is over n per column, so nothing reassociates — but
    op-by-op (unjitted) the two matmul shapes may vectorize the 5-term
    dot differently, so the eager contract is 1-ulp, not bitwise (the
    jitted trainer-level comparison below is the bitwise pin)."""
    s = strategies.get("colrel", fused="kernel")
    tree = _tree(shapes)
    tau_up, tau_dd, A = _channel()
    d_mono, st_mono = s.aggregate_tree(tree, tau_up, tau_dd, A,
                                       s.init_state(N, 1), _ctx(0))
    d_seg, st_seg = s.aggregate_tree(tree, tau_up, tau_dd, A,
                                     s.init_state(N, 1), _ctx(1))
    for a, b in zip(jax.tree.leaves(d_mono), jax.tree.leaves(d_seg)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-8, rtol=1e-6)


def test_use_segments_gate():
    """segment_d is opt-in (0 = off), engages at d >= segment_d, and
    never under pjit axes (GSPMD owns the partitioning there)."""
    assert not ExecutionContext(n_clients=N, segment_d=0).use_segments(10)
    assert ExecutionContext(n_clients=N, segment_d=10).use_segments(10)
    assert not ExecutionContext(n_clients=N, segment_d=11).use_segments(10)
    assert not ExecutionContext(n_clients=N, segment_d=1,
                                spmd_axes=("c",)).use_segments(10)


def test_async_age_where_free_bitwise():
    """The where-free age recurrence is bitwise the select form for the
    exact {0., 1.} delivery indicator."""
    rng = np.random.default_rng(3)
    age = jnp.asarray(rng.integers(0, 9, size=64), jnp.int32)
    deliv = jnp.asarray((rng.random(64) < 0.5).astype(np.float32))
    got = AsyncRelayStrategy._advance_age(age, deliv)
    want = jnp.where(deliv > 0, 0, age + 1).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_async_segmented_age_staging_and_metrics_bitwise():
    """Segmented async round: age / staging (hence mean_age / max_age /
    stale_frac, which are pure functions of age) stay bitwise the
    monolithic path; the delta agrees to fp32 contraction tolerance
    (the staleness fold reassociates one multiply)."""
    shapes = AWKWARD[0]
    s = strategies.AsyncRelayStrategy(
        inner=strategies.get("colrel", fused="kernel"), gamma=0.8)
    tree = _tree(shapes)
    d = flatten.flat_spec(tree, stacked=True).d
    tau_up, tau_dd, A = _channel()
    st0 = s.init_state(N, d)
    # pre-age the carry so the staleness weights are non-trivial
    st0["age"] = jnp.asarray([0, 2, 1, 0, 3], jnp.int32)
    st0["staging"] = flatten.ravel_stacked(_tree(shapes, seed=9))
    d_mono, st_mono = s.aggregate_tree(tree, tau_up, tau_dd, A,
                                       dict(st0), _ctx(0))
    d_seg, st_seg = s.aggregate_tree(tree, tau_up, tau_dd, A,
                                     dict(st0), _ctx(1))
    np.testing.assert_array_equal(np.asarray(st_mono["age"]),
                                  np.asarray(st_seg["age"]))
    np.testing.assert_array_equal(np.asarray(st_mono["staging"]),
                                  np.asarray(st_seg["staging"]))
    for a, b in zip(jax.tree.leaves(d_mono), jax.tree.leaves(d_seg)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-6, rtol=2e-6)
    # the staleness metrics the async round emits are functions of age
    for f in (lambda a: jnp.mean(a.astype(jnp.float32)), jnp.max,
              lambda a: jnp.mean((a > 0).astype(jnp.float32))):
        assert float(f(st_mono["age"])) == float(f(st_seg["age"]))


def test_delivered_mask_matches_oracle():
    tau_up, tau_dd, _ = _channel(4)
    got = delivered_mask(tau_up, tau_dd)
    tu, td = np.asarray(tau_up), np.asarray(tau_dd)
    want = np.maximum(tu, (td * tu[None, :]).max(axis=1))
    np.testing.assert_array_equal(np.asarray(got), want.astype(np.float32))


# -- donation ----------------------------------------------------------


def test_round_donation_aliases_carry_buffers():
    """donate_argnums on the compiled round aliases the carry into the
    outputs: XLA reports reclaimed bytes and the peak drops."""
    from repro.fl.round import RoundConfig, make_round_fn
    from repro.optim import sgd, sgd_momentum

    D = 4096
    params = {"x": jnp.zeros((D,), jnp.float32)}
    batches = {"t": jnp.zeros((N, 1, 2, D), jnp.float32)}

    def loss_fn(p, batch):
        r = p["x"] - batch["t"]
        return jnp.mean(r * r), None

    rc = RoundConfig(n_clients=N, local_steps=1, mode="per_client",
                     aggregation=strategies.get("colrel", fused="kernel"),
                     segment_d=1)
    fn = make_round_fn(loss_fn, sgd(0.3), sgd_momentum(1.0, beta=0.9), rc)
    sstate = sgd_momentum(1.0, beta=0.9).init(params)
    agg = rc.aggregation.init_state(N, D)
    tau_up, tau_dd, A = _channel()
    args = (params, sstate, agg, batches, tau_up, tau_dd, A)

    def peak(c):
        m = c.memory_analysis()
        return (m.argument_size_in_bytes + m.output_size_in_bytes
                + m.temp_size_in_bytes - m.alias_size_in_bytes)

    plain = jax.jit(fn).lower(*args).compile()
    donated = jax.jit(fn, donate_argnums=(0, 1, 2)).lower(*args).compile()
    assert donated.memory_analysis().alias_size_in_bytes > 0
    assert peak(donated) < peak(plain)


@pytest.mark.parametrize("mode", ["per_round", "chunked", "no_trace",
                                  "async"])
def test_donated_run_bitwise_matches_undonated(mode):
    """Donation is a memory optimization, not a numeric one: every
    engine produces bitwise-identical trajectories and final state with
    and without it."""
    kw = conformance.run_kwargs(mode)
    a = conformance.make_trainer("colrel", mode, donate=True)
    a.run(6, **kw)
    b = conformance.make_trainer("colrel", mode, donate=False)
    b.run(6, **kw)
    conformance.assert_same_run(a, b)


def test_segmented_trainer_bitwise_matches_monolithic():
    """The conformance fixture through the chunked engine with segment
    streaming engaged == the monolithic kernel path, bitwise."""
    s = strategies.get("colrel", fused="kernel")
    a = conformance.make_trainer(s, "chunked", segment_d=1)
    a.run(6, chunk=3)
    b = conformance.make_trainer(s, "chunked", segment_d=0)
    b.run(6, chunk=3)
    conformance.assert_same_run(a, b)


def test_snapshot_copy_arrays_survives_donation(tmp_path):
    """The async-checkpoint / donation race: a copy_arrays snapshot owns
    its storage, so the writer thread survives the caller donating (and
    XLA deleting) the original carry buffers before serialization."""
    tree = {"x": jnp.arange(8, dtype=jnp.float32),
            "y": {"z": jnp.ones((3, 4), jnp.float32)}}
    snap = writer.snapshot(tree, copy_arrays=True)
    for leaf in jax.tree.leaves(tree):
        leaf.delete()  # what donating into the next step does
    path = writer.write_state(tmp_path / "c.msgpack", snap, snapshotted=True)
    out = writer.read_state(path)
    np.testing.assert_array_equal(out["x"], np.arange(8, dtype=np.float32))
    np.testing.assert_array_equal(out["y"]["z"], np.ones((3, 4), np.float32))


def test_ckpt_resume_with_donation_enabled(tmp_path):
    """Periodic async checkpoints under the donating trainer: the
    committed snapshot restores bitwise (the copy was taken before the
    buffers were donated away)."""
    ref = conformance.make_trainer("colrel", "chunked")
    ref.run(6, chunk=3)

    t1 = conformance.make_trainer("colrel", "chunked")
    t1.run(3, chunk=3, ckpt_dir=tmp_path, ckpt_every=3)
    t2 = conformance.make_trainer("colrel", "chunked")
    t2.run(6, chunk=3, resume_from=tmp_path)
    conformance.assert_same_run(ref, t2)
