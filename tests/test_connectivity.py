"""Connectivity model: validation, sampling laws, reciprocity coupling."""

import numpy as np
import pytest

from repro.core import (
    LinkModel,
    effective_weights,
    reciprocity_matrix,
    sample_round,
)
from repro.core import topology


def test_linkmodel_validation():
    p = np.array([0.5, 0.5])
    P = np.array([[1.0, 0.3], [0.4, 1.0]])
    LinkModel(p, P, reciprocity_matrix(P, 0.0))
    with pytest.raises(ValueError):
        LinkModel(p, P * 2, reciprocity_matrix(P, 0.0))  # probs > 1
    with pytest.raises(ValueError):
        LinkModel(p, P - np.eye(2) * 0.5, reciprocity_matrix(P, 0.0))  # diag != 1
    with pytest.raises(ValueError):
        # E below independence violates the paper's assumption
        E = P * P.T - 0.05
        np.fill_diagonal(E, 1.0)
        LinkModel(p, P, E)


def test_reciprocity_matrix_bounds():
    P = np.array([[1.0, 0.6], [0.8, 1.0]])
    for rho in (0.0, 0.3, 1.0):
        E = reciprocity_matrix(P, rho)
        assert np.all(E >= P * P.T - 1e-12)
        assert np.all(E <= np.minimum(P, P.T) + 1e-12)
    assert np.allclose(reciprocity_matrix(P, 0.0), np.where(np.eye(2), 1, P * P.T))


@pytest.mark.parametrize("rho", [0.0, 1.0])
def test_sampling_marginals_and_correlation(rho, rng):
    m = topology.fully_connected(4, 0.7, p_c=0.5, rho=rho)
    R = 6000
    ups = np.zeros(4)
    dd11 = 0.0
    dds = np.zeros((4, 4))
    for _ in range(R):
        tu, td = sample_round(m, rng)
        ups += tu
        dds += td
        dd11 += td[0, 1] * td[1, 0]
    assert np.allclose(ups / R, 0.7, atol=0.03)
    off = ~np.eye(4, dtype=bool)
    assert np.allclose((dds / R)[off], 0.5, atol=0.03)
    expected_joint = m.E[0, 1]
    assert abs(dd11 / R - expected_joint) < 0.03


def test_full_reciprocity_is_symmetric(rng):
    m = topology.fully_connected(5, 0.5, p_c=0.6, rho=1.0)
    for _ in range(50):
        _, td = sample_round(m, rng)
        assert np.array_equal(td, td.T)  # tau_ij = 0 <=> tau_ji = 0


def test_effective_weights_identity(rng):
    m = topology.paper_fig2b()
    A = rng.random((10, 10))
    tu, td = sample_round(m, rng)
    w = effective_weights(A, tu, td)
    # brute-force the double sum
    want = np.zeros(10)
    for j in range(10):
        want[j] = sum(tu[i] * td[j, i] * A[i, j] for i in range(10))
    assert np.allclose(w, want)


def test_mmwave_prob():
    assert topology.mmwave_prob(np.array([0.0])) == 1.0
    d99 = 30 * (5.2 - np.log(0.99))
    assert abs(topology.mmwave_prob(np.array([d99]))[0] - 0.99) < 1e-9


def test_topologies_shapes():
    for m in [
        topology.no_collaboration(6, 0.3),
        topology.ring(6, 0.3, 0.9),
        topology.star_relay(6, 0.3, hub=2),
        topology.clustered(6, 0.3, cluster_size=3),
        topology.erdos_renyi(6, 0.3, 0.5, structural=True),
        topology.paper_fig2a(),
        topology.paper_fig2b(),
        topology.paper_mmwave_layout(d2d_mode="intermittent"),
        topology.paper_mmwave_layout(d2d_mode="permanent"),
    ]:
        assert m.P.shape == (m.n, m.n)
        assert np.allclose(np.diag(m.P), 1.0)
