"""Optimizers, schedules, data pipeline, checkpointing."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data import (
    partition_iid,
    partition_sort_and_partition,
    synthetic_cifar,
    synthetic_tokens,
)
from repro.data.pipeline import ClientDataset, federated_batches, make_federated_clients


def test_sgd_momentum_matches_manual():
    opt = optim.sgd_momentum(0.1, beta=0.9)
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -1.0])}
    s = opt.init(p)
    m = np.zeros(2)
    x = np.array([1.0, 2.0])
    for _ in range(3):
        upd, s = opt.update(g, s, p)
        p = optim.apply_updates(p, upd)
        m = 0.9 * m + np.array([0.5, -1.0])
        x = x - 0.1 * m
    np.testing.assert_allclose(np.asarray(p["w"]), x, rtol=1e-6)


def test_adamw_direction():
    opt = optim.adamw(1e-2)
    p = {"w": jnp.zeros(3)}
    s = opt.init(p)
    g = {"w": jnp.array([1.0, -1.0, 0.0])}
    upd, s = opt.update(g, s, p)
    assert upd["w"][0] < 0 and upd["w"][1] > 0 and abs(upd["w"][2]) < 1e-8


def test_inverse_round_decay_matches_theorem():
    mu, T = 2.0, 8
    sched = optim.inverse_round_decay(4.0 / mu, T)
    for r in [0, 1, 10]:
        assert abs(float(sched(jnp.int32(r))) - (4 / mu) / (r * T + 1)) < 1e-7


def test_partition_iid_covers_everything():
    parts = partition_iid(103, 10, seed=0)
    allidx = np.concatenate(parts)
    assert len(allidx) == 103
    assert len(np.unique(allidx)) == 103


def test_sort_and_partition_skew():
    _, labels = synthetic_cifar(n=2000, seed=1)
    for s in (1, 2, 3):
        parts = partition_sort_and_partition(labels, 10, s=s, seed=0)
        assert len(np.unique(np.concatenate(parts))) == 2000
        # each shard can straddle one label boundary, so the hard cap is 2s;
        # the typical client has ~s distinct labels
        counts = [len(np.unique(labels[pt])) for pt in parts]
        assert max(counts) <= 2 * s
        assert np.mean(counts) <= s + 1.0


def test_client_dataset_and_stacking():
    imgs, labels = synthetic_cifar(n=200, seed=0)
    parts = partition_iid(200, 4, seed=0)
    clients = make_federated_clients({"images": imgs, "labels": labels}, parts, 8)
    fb = federated_batches(clients)
    assert fb["images"].shape == (4, 8, 32, 32, 3)
    assert fb["labels"].shape == (4, 8)
    # per-client rngs are independent and reproducible
    c2 = make_federated_clients({"images": imgs, "labels": labels}, parts, 8)
    fb2 = federated_batches(c2)
    np.testing.assert_array_equal(fb["labels"], fb2["labels"])


def test_synthetic_tokens_learnable_structure():
    toks, styles = synthetic_tokens(16, 64, vocab=97, seed=0)
    assert toks.shape == (16, 64) and toks.min() >= 0 and toks.max() < 97
    assert styles.shape == (16,)


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": [jnp.ones((2,), jnp.bfloat16), {"c": jnp.int32(3)}],
        "scalar": 1.5,
        "name": "x",
    }
    path = os.path.join(tmp_path, "ckpt.msgpack")
    save_checkpoint(path, tree)
    back = load_checkpoint(path)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    assert back["b"][0].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(back["b"][0], np.float32), np.ones(2, np.float32)
    )
    assert back["b"][1]["c"] == 3
    assert back["scalar"] == 1.5 and back["name"] == "x"


def test_quadratic_problem_conditioning():
    from repro.data import quadratic_problem

    prob = quadratic_problem(4, 8, mu=0.5, L=4.0, seed=0)
    eig = np.linalg.eigvalsh(prob["H"])
    assert eig.min() >= 0.5 - 1e-9 and eig.max() <= 4.0 + 1e-9
    np.testing.assert_allclose(prob["x_star"], prob["centers"].mean(0))
