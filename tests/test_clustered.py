"""Block-sparse clustered relaying (DESIGN.md §10).

Five layers:
  1. golden equivalence — the ``clustered`` strategy with C = 1 (the
     cluster *is* the population) replays the committed ``colrel``
     golden trajectories bitwise through the scan engine, for every
     execution mode x fused option: the block einsums lower to the same
     XLA contractions as their dense twins;
  2. the block substrate — ``clustered_blocks`` round-trips through its
     dense form exactly, and per-cluster COPT-alpha
     (``optimize_weights_clustered``) matches the dense Gauss-Seidel
     block for block while preserving unbiasedness;
  3. the blocked Pallas kernels against the ``core.blocks`` reference
     contractions at tile-unaligned cluster sizes;
  4. the clustered channels — loop/trace stream identity and the in-scan
     samplers' layouts;
  5. the client-axis sharding rules (``launch/sharding``) — axis
     placement on a multi-axis mesh (spec level) and 1-device
     degeneration — plus the trainer's ``no_trace`` mode.
"""

import importlib.util
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import strategies
from repro.channel import (
    ClusteredMarkovChannel,
    ClusteredStaticChannel,
    MarkovChannel,
    StaticChannel,
    clustered_ge_scan_sampler,
    clustered_static_scan_sampler,
    gilbert_elliott,
    gilbert_elliott_clustered,
)
from repro.core import blocks, optimize_weights, topology
from repro.core.connectivity import reciprocity_matrix, sample_round
from repro.core.weights import (
    is_unbiased,
    is_unbiased_clustered,
    optimize_weights_clustered,
    unbiasedness_residual_clustered,
)
from repro.data.pipeline import ClientDataset
from repro.fl import FLTrainer
from repro.fl.round import RoundConfig, make_scan_round_fn
from repro.kernels.relay_block import (
    block_fused_aggregate_pallas,
    block_relay_mix_pallas,
)
from repro.optim import sgd, sgd_momentum

_GG_PATH = pathlib.Path(__file__).parent / "golden" / "generate_golden.py"
_spec = importlib.util.spec_from_file_location("_golden_gen_clustered", _GG_PATH)
gg = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(gg)

GOLDEN = np.load(pathlib.Path(__file__).parent / "golden" / "round_golden.npz")


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _random_clustered(C=3, m=5, seed=0, rho=0.6):
    """A clustered link model with *distinct* random blocks (stronger than
    ``clustered_blocks``' identical ones)."""
    rng = np.random.default_rng(seed)
    Pb = rng.uniform(0.4, 0.95, size=(C, m, m))
    for c in range(C):
        np.fill_diagonal(Pb[c], 1.0)
    Eb = np.stack([reciprocity_matrix(Pb[c], rho) for c in range(C)])
    p = rng.uniform(0.3, 0.9, size=C * m)
    return blocks.ClusteredLinkModel(p, Pb, Eb)


def _golden_inputs(mode, rounds):
    """The golden problem's tau/batch streams stacked for a K-round scan
    (identical draws to gg.run_config's per-round loop)."""
    T = 1 if mode == "weighted_grad" else 2
    tau_rng = np.random.default_rng(77)
    bat_rng = np.random.default_rng(99)
    taus = [sample_round(gg.PROB[3], tau_rng) for _ in range(rounds)]
    bs = [gg.batches_for(bat_rng, T) for _ in range(rounds)]
    if mode == "weighted_grad":
        bs = [{k: v[:, 0] for k, v in b.items()} for b in bs]
    batches = {k: jnp.asarray(np.stack([b[k] for b in bs])) for k in bs[0]}
    tau_up = jnp.asarray(np.stack([t[0] for t in taus]), jnp.float32)
    tau_dd = jnp.asarray(np.stack([t[1] for t in taus]), jnp.float32)
    return batches, tau_up, tau_dd


def _run_clustered_scan(fused, mode, rounds=gg.ROUNDS):
    """gg.run_config's experiment through the scan engine with the
    ``clustered`` strategy at C = 1: the (n, n) operands reshape to
    (1, n, n) blocks and flow through the round as opaque traced slots."""
    H, centers, Wc, model, A = gg.PROB
    n = gg.N
    T = 1 if mode == "weighted_grad" else 2
    rc = RoundConfig(n_clients=n, local_steps=T, mode=mode,
                     aggregation=strategies.get("clustered", fused=fused))
    server_opt = sgd_momentum(1.0, beta=0.9)
    fn = jax.jit(make_scan_round_fn(gg.make_loss(H, Wc), sgd(0.05),
                                    server_opt, rc))
    params = {"x": jnp.zeros(gg.DX, jnp.float32),
              "W": jnp.zeros((3, 4), jnp.float32)}
    batches, tau_up, tau_dd = _golden_inputs(mode, rounds)
    tau_b = tau_dd.reshape(rounds, 1, n, n)
    Ab = jnp.asarray(A, jnp.float32).reshape(1, n, n)
    params, _, _, metrics = fn(params, server_opt.init(params), (),
                               batches, tau_up, tau_b, Ab)
    return params, metrics


# ---------------------------------------------------------------------------
# 1. golden: clustered C=1 == colrel, bitwise, through the scan engine
# ---------------------------------------------------------------------------

_C1_CONFIGS = [(f, m, t)
               for f, t in ((False, "colrel"), ("collapse", "colrel_fused"))
               for m in gg.MODES]
_C1_CONFIGS.append(("kernel", "per_client", "colrel|per_client|kernel"))


@pytest.mark.parametrize("fused,mode,ref", [
    (f, m, t if "|" in t else f"{t}|{m}") for f, m, t in _C1_CONFIGS
], ids=[f"{m}-{f}" for f, m, _ in _C1_CONFIGS])
def test_clustered_c1_matches_colrel_golden(fused, mode, ref):
    """C = 1 block execution replays the committed dense colrel fixture
    bit for bit — params and the realized weight-sum metric."""
    params, metrics = _run_clustered_scan(fused, mode)
    np.testing.assert_array_equal(np.asarray(params["x"], np.float32),
                                  GOLDEN[f"{ref}|x"])
    np.testing.assert_array_equal(np.asarray(params["W"], np.float32),
                                  GOLDEN[f"{ref}|W"])
    np.testing.assert_array_equal(
        np.float32(np.asarray(metrics["weight_sum"])[-1]),
        GOLDEN[f"{ref}|weight_sum"])


# ---------------------------------------------------------------------------
# 2. block substrate: dense round-trip + per-cluster COPT-alpha
# ---------------------------------------------------------------------------


def test_clustered_blocks_dense_roundtrip():
    model = topology.clustered_blocks(24, 0.5, 6, p_intra=0.8, rho=0.7)
    dense = model.to_dense()
    # cross-cluster support is exactly zero (E inherits it from P)
    mask = np.kron(np.eye(4), np.ones((6, 6)))
    assert np.array_equal(dense.P * (1 - mask), np.zeros((24, 24)))
    assert np.array_equal(dense.E * (1 - mask), np.zeros((24, 24)))
    back = blocks.ClusteredLinkModel.from_dense(dense, 6)
    np.testing.assert_array_equal(back.Pb, model.Pb)
    np.testing.assert_array_equal(back.Eb, model.Eb)
    # strict converter refuses cross-cluster mass
    bad = dense.P.copy()
    bad[0, 7] = 0.5
    with pytest.raises(ValueError):
        blocks.blocks_from_dense(bad, blocks.ClusterSpec(24, 6), strict=True)


def test_block_copt_matches_dense_per_cluster():
    """COPT-alpha decomposes exactly over clusters: cross-cluster
    constraint coefficients and E-couplings vanish, so the block solver
    reproduces the dense Gauss-Seidel block for block."""
    model = _random_clustered(C=3, m=5, seed=2)
    res_d = optimize_weights(model.to_dense(), sweeps=30, fine_tune_sweeps=10)
    res_b = optimize_weights_clustered(model, sweeps=30, fine_tune_sweeps=10)
    Ab_from_dense = blocks.blocks_from_dense(
        res_d.A, blocks.ClusterSpec(15, 5), strict=False)
    np.testing.assert_allclose(res_b.Ab, Ab_from_dense, atol=1e-9)
    np.testing.assert_allclose(res_b.S, res_d.S, rtol=1e-9)
    assert is_unbiased_clustered(model, res_b.Ab)
    assert is_unbiased(model.to_dense(),
                       blocks.block_diag_from_blocks(
                           res_b.Ab, blocks.ClusterSpec(15, 5)))
    assert np.max(np.abs(unbiasedness_residual_clustered(
        model, res_b.Ab))) < 1e-8


# ---------------------------------------------------------------------------
# 3. blocked kernels vs the core.blocks reference, tile-unaligned shapes
# ---------------------------------------------------------------------------

_KSHAPES = [(3, 5, 37, 16), (2, 8, 64, 64), (4, 3, 10, 4), (1, 6, 33, 8)]


@pytest.mark.parametrize("C,m,d,bd", _KSHAPES,
                         ids=[f"C{c}m{m}d{d}bd{b}" for c, m, d, b in _KSHAPES])
def test_block_kernels_match_reference(C, m, d, bd):
    rng = np.random.default_rng(C * 100 + m)
    Ab = jnp.asarray(rng.uniform(0.1, 2.0, size=(C, m, m)), jnp.float32)
    tau_b = jnp.asarray(rng.integers(0, 2, size=(C, m, m)), jnp.float32)
    tau_up = jnp.asarray(rng.integers(0, 2, size=(C * m,)), jnp.float32)
    upd = jnp.asarray(rng.normal(size=(C * m, d)), jnp.float32)

    mix_k = block_relay_mix_pallas(Ab, tau_b, upd, block_d=bd, interpret=True)
    mix_ref = blocks.block_relay_mix(upd, Ab, tau_b)
    np.testing.assert_allclose(np.asarray(mix_k), np.asarray(mix_ref),
                               atol=2e-6, rtol=1e-5)

    agg_k = block_fused_aggregate_pallas(Ab, tau_up, tau_b, upd, block_d=bd,
                                         interpret=True)
    agg_ref = blocks.block_colrel_round_delta(upd, Ab, tau_up, tau_b,
                                              fused=True)
    np.testing.assert_allclose(np.asarray(agg_k), np.asarray(agg_ref),
                               atol=2e-6, rtol=1e-5)


# ---------------------------------------------------------------------------
# 4. clustered channels: stream identity + in-scan samplers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cls,args", [
    (ClusteredStaticChannel, {}),
    (ClusteredMarkovChannel, {"memory": 0.8}),
], ids=["static", "markov"])
def test_clustered_channel_loop_equals_trace(cls, args):
    model = topology.clustered_blocks(12, 0.5, 4, p_intra=0.8, rho=0.6)
    if cls is ClusteredMarkovChannel:
        mk = lambda: cls(gilbert_elliott_clustered(model, **args), seed=5)
    else:
        mk = lambda: cls(model, seed=5)
    a, b = mk(), mk()
    ups_t, dds_t = a.trace(0, 30)
    assert ups_t.shape == (30, 12) and dds_t.shape == (30, 3, 4, 4)
    for r in range(30):
        tu, td = b.tau_for_round(r)
        np.testing.assert_array_equal(np.asarray(tu), np.asarray(ups_t[r]))
        np.testing.assert_array_equal(np.asarray(td), np.asarray(dds_t[r]))


def test_clustered_scan_samplers_shapes_and_marginals():
    model = topology.clustered_blocks(12, 0.4, 4, p_intra=0.7, rho=1.0)
    for sampler in (clustered_static_scan_sampler(model),
                    clustered_ge_scan_sampler(
                        gilbert_elliott_clustered(model, memory=0.8))):
        init_fn, sample_fn = sampler
        state = init_fn(jax.random.PRNGKey(0))

        def body(carry, key):
            tu, td, st = sample_fn(carry, key)
            return st, (tu, td)

        keys = jax.random.split(jax.random.PRNGKey(1), 600)
        _, (ups, dds) = jax.lax.scan(body, state, keys)
        assert ups.shape == (600, 12) and dds.shape == (600, 3, 4, 4)
        # marginals of the in-scan draw match the model law
        np.testing.assert_allclose(np.asarray(ups).mean(), 0.4, atol=0.05)
        off = ~np.eye(4, dtype=bool)
        np.testing.assert_allclose(
            np.asarray(dds).mean(axis=0)[:, off].mean(), 0.7, atol=0.05)
        # reciprocity rho=1: tau_ij == tau_ji within every cluster
        np.testing.assert_array_equal(np.asarray(dds),
                                      np.swapaxes(np.asarray(dds), -1, -2))


# ---------------------------------------------------------------------------
# 5. client-axis sharding rules + the trainer's no-trace mode
# ---------------------------------------------------------------------------


class _FakeMesh:
    """Duck-typed mesh for spec-level rule checks (tier-1 runs 1-device)."""
    axis_names = ("data", "model")
    shape = {"data": 4, "model": 2}


def test_fl_round_rule_axis_placement():
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import fl_round_rule

    mesh = _FakeMesh()
    r, rs = fl_round_rule(), fl_round_rule(scan=True)
    assert r.spec("tau_up", (16,), mesh) == P("data")
    assert r.spec("tau_dd", (16, 16), mesh) == P("data", None)
    assert r.spec("A", (8, 4, 4), mesh) == P("data", None, None)
    # scan: the leading K axis stays unsharded
    assert rs.spec("tau_up", (5, 16), mesh) == P(None, "data")
    assert rs.spec("tau_dd", (5, 16, 16), mesh) == P(None, "data", None)
    assert rs.spec("tau_dd", (5, 8, 4, 4), mesh) == P(None, "data", None, None)
    # non-divisible cluster/client counts replicate instead of erroring
    assert r.spec("A", (3, 4, 4), mesh) == P(None, None, None)
    assert r.spec("tau_up", (6,), mesh) == P(None)


def test_client_rules_degenerate_on_one_device():
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import (
        channel_state_sharding,
        client_state_shardings,
        fl_round_rule,
    )

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    SDS = jax.ShapeDtypeStruct
    sh = fl_round_rule().shardings(
        mesh, {"tau_dd": SDS((16, 16), np.float32)})["tau_dd"]
    assert sh.spec == P(None, None)
    st = client_state_shardings(mesh, {"buf": SDS((16, 8), np.float32)}, 16)
    assert st["buf"].spec == P(None, None)
    assert channel_state_sharding(mesh, (136,)).spec == P(None)


def _tiny_trainer(channel, n=8, d=12, seed=3):
    rng = np.random.default_rng(0)
    targets = rng.normal(size=(n, d)).astype(np.float32)
    clients = [ClientDataset({"t": np.repeat(targets[i][None], 64, 0)},
                             batch_size=4, seed=i) for i in range(n)]
    model = topology.fully_connected(n, 0.5, p_c=0.8, rho=1.0)
    A = optimize_weights(model, sweeps=5, fine_tune_sweeps=5).A

    def loss_fn(p, batch):
        r = p["x"] - batch["t"]
        return jnp.mean(r * r), None

    return FLTrainer(loss_fn, {"x": jnp.zeros((d,), jnp.float32)}, model, A,
                     clients, sgd(0.3), sgd_momentum(1.0, beta=0.9),
                     local_steps=2, channel=channel, seed=seed)


def test_trainer_no_trace_runs_all_rounds():
    model = topology.fully_connected(8, 0.5, p_c=0.8, rho=1.0)
    for channel in (StaticChannel(model, seed=3),
                    MarkovChannel(gilbert_elliott(model, memory=0.8), seed=3)):
        t = _tiny_trainer(channel)
        log = t.run(10, chunk=4, no_trace=True)  # 2 full chunks + tail of 2
        assert log.rounds == list(range(10))
        assert np.all(np.isfinite(log.loss))
        assert np.all(np.isfinite(log.weight_sums))


def test_trainer_no_trace_rejects_unsupported():
    from repro.channel import AdaptiveConfig, AdaptiveWeightSchedule

    class NoSampler:
        n = 8
        def tau_for_round(self, r):  # pragma: no cover
            raise AssertionError("no_trace must not call tau_for_round")
        def model_for_round(self, r):
            return topology.fully_connected(8, 0.5, p_c=0.8, rho=1.0)

    model = topology.fully_connected(8, 0.5, p_c=0.8, rho=1.0)
    t = _tiny_trainer(StaticChannel(model, seed=3))
    t.channel = NoSampler()
    with pytest.raises(ValueError, match="scan_sampler"):
        t.run(2, chunk=2, no_trace=True)

    t2 = _tiny_trainer(StaticChannel(model, seed=3))
    t2.adaptive = AdaptiveWeightSchedule(8, AdaptiveConfig(every=4))
    with pytest.raises(ValueError, match="adaptive"):
        t2.run(4, chunk=4, no_trace=True)
