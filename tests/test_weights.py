"""COPT-alpha (Algorithm 3): unbiasedness, variance reduction, edge cases."""

import numpy as np
import pytest

from repro.core import (
    fedavg_weights,
    importance_weights,
    initial_weights,
    is_unbiased,
    optimize_weights,
    unbiasedness_residual,
    variance_S,
    variance_Sbar,
)
from repro.core import topology
from repro.core.connectivity import sample_round


TOPOLOGIES = {
    "fig2a": topology.paper_fig2a(),
    "fig2b": topology.paper_fig2b(),
    "mmwave_int": topology.paper_mmwave_layout(d2d_mode="intermittent"),
    "mmwave_perm": topology.paper_mmwave_layout(d2d_mode="permanent"),
    "ring": topology.ring(8, 0.4, 0.8),
}


@pytest.mark.parametrize("name", list(TOPOLOGIES))
def test_copt_alpha(name):
    m = TOPOLOGIES[name]
    A0 = initial_weights(m)
    assert is_unbiased(m, A0, atol=1e-8), "init must satisfy condition (5)"
    res = optimize_weights(m, sweeps=25, fine_tune_sweeps=25)
    assert is_unbiased(m, res.A, atol=1e-6)
    assert np.all(res.A >= -1e-12), "Assumption 4: nonnegative weights"
    assert res.S <= res.S_init + 1e-9, "optimizer must not increase S"
    assert res.S <= variance_Sbar(m, res.A) + 1e-9, "Lemma 2: S <= Sbar"


def test_monotone_history():
    m = TOPOLOGIES["fig2b"]
    res = optimize_weights(m, sweeps=15, fine_tune_sweeps=15)
    relax = [v for tag, _, v in res.history if tag == "relax"]
    assert all(b <= a + 1e-9 for a, b in zip(relax, relax[1:])), \
        "Gauss-Seidel on the convex relaxation must be monotone"


def test_no_collaboration_recovers_importance_weights():
    # With P = I the only feasible unbiased weights are alpha_ii = 1/p_i.
    m = topology.no_collaboration(6, [0.2, 0.4, 0.5, 0.8, 1.0, 0.3])
    res = optimize_weights(m, sweeps=5, fine_tune_sweeps=5)
    assert np.allclose(res.A, importance_weights(m), atol=1e-8)


def test_perfect_connectivity_uniform():
    # All links perfect: optimum splits weight equally (case 2 of Eq. (11)).
    m = topology.fully_connected(5, 1.0, p_c=1.0, rho=1.0)
    res = optimize_weights(m, sweeps=3, fine_tune_sweeps=3)
    assert np.allclose(res.A, np.full((5, 5), 1 / 5), atol=1e-9)
    assert res.S < 1e-12


def test_fedavg_blind_weights_biased_under_dropouts():
    m = topology.no_collaboration(4, 0.5)
    resid = unbiasedness_residual(m, fedavg_weights(4))
    assert np.all(resid < -1e-6), "blind FedAvg underweights dropped clients"


def test_variance_matches_monte_carlo(rng):
    """Appendix C: with identical unit updates, E[((1/n) sum_j (w_j - 1))^2]
    equals S / n^2."""
    m = topology.paper_fig2a()
    res = optimize_weights(m, sweeps=20, fine_tune_sweeps=20)
    n = m.n
    R = 20000
    acc = 0.0
    from repro.core import effective_weights

    for _ in range(R):
        tu, td = sample_round(m, rng)
        w = effective_weights(res.A, tu, td)
        acc += ((w - 1.0).sum() / n) ** 2
    mc = acc / R
    analytic = variance_S(m, res.A) / n**2
    assert abs(mc - analytic) / analytic < 0.1, (mc, analytic)


def test_colrel_lower_variance_than_no_relaying():
    m = topology.paper_fig2b()
    res = optimize_weights(m, sweeps=25, fine_tune_sweeps=25)
    s_imp = variance_S(m, importance_weights(m))
    assert res.S < 0.5 * s_imp, "relaying should cut variance substantially"
