"""The pluggable AggregationStrategy API.

Four layers:
  1. golden equivalence — every registry strategy that adapts an old
     ``Aggregation`` enum value produces bit-identical round outputs to
     the pre-refactor implementation (frozen fixture in
     ``tests/golden/round_golden.npz``) on fixed tau draws, across
     per_client / client_sequential / weighted_grad modes;
  2. registry mechanics — deprecated aliases warn and forward, custom
     strategies register and run, invalid combinations fail loudly;
  3. the two beyond-enum strategies — multihop K=1 reduces exactly to
     colrel, memory with no blockages reduces exactly to colrel, memory
     state round-trips through jax.jit without recompiles as taus
     change;
  4. the declarative ExperimentSpec assembly.
"""

import importlib.util
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import strategies
from repro.core import Aggregation, aggregate, fedavg_weights, optimize_weights, topology
from repro.core.connectivity import sample_round
from repro.fl import ExperimentSpec, build_experiment
from repro.fl.round import RoundConfig, make_round_fn
from repro.optim import sgd, sgd_momentum

# the golden generator doubles as the replay harness (same problem, same
# seeds, same round loop — see its docstring for provenance)
_GG_PATH = pathlib.Path(__file__).parent / "golden" / "generate_golden.py"
_spec = importlib.util.spec_from_file_location("_golden_gen", _GG_PATH)
gg = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(gg)

GOLDEN = np.load(pathlib.Path(__file__).parent / "golden" / "round_golden.npz")

LEGACY_CONFIGS = [(s, m, False) for s in gg.STRATEGIES for m in gg.MODES]
LEGACY_CONFIGS.append(("colrel", "per_client", True))


# ---------------------------------------------------------------------------
# 1. golden equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy,mode,fused_kernel", LEGACY_CONFIGS,
                         ids=[f"{s}-{m}{'-kernel' if k else ''}"
                              for s, m, k in LEGACY_CONFIGS])
@pytest.mark.filterwarnings("ignore::DeprecationWarning")
def test_registry_round_bit_identical_to_legacy(strategy, mode, fused_kernel):
    params, metrics = gg.run_config(strategy, mode, use_fused_kernel=fused_kernel)
    tag = f"{strategy}|{mode}" + ("|kernel" if fused_kernel else "")
    np.testing.assert_array_equal(np.asarray(params["x"], np.float32),
                                  GOLDEN[f"{tag}|x"])
    np.testing.assert_array_equal(np.asarray(params["W"], np.float32),
                                  GOLDEN[f"{tag}|W"])
    np.testing.assert_array_equal(np.float32(metrics["weight_sum"]),
                                  GOLDEN[f"{tag}|weight_sum"])


def test_all_legacy_enum_values_resolve():
    import warnings

    for agg in Aggregation:
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            s = strategies.resolve(agg)
        assert isinstance(s, strategies.AggregationStrategy)
        assert s.name in strategies.available()
        deprecated = [w for w in caught if w.category is DeprecationWarning]
        assert bool(deprecated) == (agg == Aggregation.COLREL_FUSED)


# ---------------------------------------------------------------------------
# 2. registry mechanics
# ---------------------------------------------------------------------------


def test_available_lists_builtins_without_deprecated():
    names = strategies.available()
    assert {"colrel", "fedavg_perfect", "fedavg_blind", "fedavg_nonblind",
            "multihop", "memory"} <= set(names)
    assert "colrel_fused" not in names
    assert "colrel_fused" in strategies.available(include_deprecated=True)


def test_deprecated_alias_warns_and_forwards():
    with pytest.warns(DeprecationWarning, match="COLREL_FUSED"):
        s = strategies.get("colrel_fused")
    assert isinstance(s, strategies.ColRelStrategy) and s.fused == "collapse"


def test_use_fused_kernel_warns_and_forwards():
    with pytest.warns(DeprecationWarning, match="use_fused_kernel"):
        s = strategies.resolve("colrel", fused_kernel=True)
    assert isinstance(s, strategies.ColRelStrategy) and s.fused == "kernel"
    with pytest.raises(ValueError, match="use_fused_kernel"):
        strategies.resolve("fedavg_blind", fused_kernel=True)


def test_unknown_strategy_fails_loudly():
    with pytest.raises(KeyError, match="unknown aggregation strategy"):
        strategies.get("does_not_exist")
    with pytest.raises(KeyError):
        RoundConfig(n_clients=2, local_steps=1, aggregation="does_not_exist")


def test_custom_registered_strategy_runs_in_round():
    """Openness proof at the unit level: a never-seen scheme registered
    from outside the package runs through the round machinery."""

    @strategies.register("half_arrivals", overwrite=True)
    class HalfArrivals(strategies.AggregationStrategy):
        name = "half_arrivals"
        scalar_collapsible = True

        def weights(self, tau_up, tau_dd, A):
            return tau_up.astype(jnp.float32) / (2.0 * tau_up.shape[0])

    assert "half_arrivals" in strategies.available()
    params, _ = gg.run_config("half_arrivals", "per_client")
    assert np.isfinite(np.asarray(params["x"])).all()


def test_stateful_strategy_rejected_outside_per_client():
    rc = RoundConfig(n_clients=4, local_steps=1, mode="client_sequential",
                     aggregation="memory")
    with pytest.raises(ValueError, match="per_client mode"):
        make_round_fn(lambda p, b: (0.0, {}), sgd(0.1), sgd_momentum(1.0), rc)

    # stateful-but-collapsible is rejected too: the scalar-only modes
    # would silently freeze the carried state at init_state
    class StatefulCollapsible(strategies.AggregationStrategy):
        name = "stateful_collapsible"
        scalar_collapsible = True
        stateful = True

        def weights(self, tau_up, tau_dd, A):
            return tau_up.astype(jnp.float32) / tau_up.shape[0]

    rc2 = RoundConfig(n_clients=4, local_steps=1, mode="weighted_grad",
                      aggregation=StatefulCollapsible())
    with pytest.raises(ValueError, match="per_client mode"):
        make_round_fn(lambda p, b: (0.0, {}), sgd(0.1), sgd_momentum(1.0), rc2)


def test_core_aggregate_delegates_through_registry():
    rng = np.random.default_rng(0)
    n, d = 6, 11
    upd = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    m = topology.fully_connected(n, 0.5, p_c=0.7)
    tu, td = sample_round(m, rng)
    tu, td = jnp.asarray(tu, jnp.float32), jnp.asarray(td, jnp.float32)
    A = jnp.asarray(np.abs(rng.normal(size=(n, n))), jnp.float32)

    from repro.core import relay

    np.testing.assert_array_equal(
        np.asarray(aggregate("colrel", upd, tau_up=tu, tau_dd=td, A=A)),
        np.asarray(relay.colrel_round_delta(upd, A, tu, td)))
    np.testing.assert_array_equal(
        np.asarray(aggregate("fedavg_blind", upd, tau_up=tu)),
        np.asarray((tu @ upd) / n))
    with pytest.raises(ValueError, match="needs A and tau_dd"):
        aggregate("colrel", upd, tau_up=tu)


# ---------------------------------------------------------------------------
# 3a. multihop
# ---------------------------------------------------------------------------


def _round_harness(strategy, taus, *, rounds=3):
    """Run ``rounds`` rounds of the golden problem under explicit taus."""
    H, centers, Wc, model, A = gg.PROB
    rc = RoundConfig(n_clients=gg.N, local_steps=2, mode="per_client",
                     aggregation=strategy)
    server_opt = sgd_momentum(1.0, beta=0.9)
    fn = jax.jit(make_round_fn(gg.make_loss(H, Wc), sgd(0.05), server_opt, rc))
    params = {"x": jnp.zeros(gg.DX, jnp.float32),
              "W": jnp.zeros((3, 4), jnp.float32)}
    sstate = server_opt.init(params)
    st = rc.resolve_strategy().init_state(gg.N, gg.DX + 12)
    bat_rng = np.random.default_rng(5)
    for r in range(rounds):
        tu, td = taus(r)
        b = gg.batches_for(bat_rng, 2)
        params, sstate, st, _ = fn(params, sstate, st,
                                   jax.tree.map(jnp.asarray, b),
                                   jnp.asarray(tu, jnp.float32),
                                   jnp.asarray(td, jnp.float32),
                                   jnp.asarray(gg.PROB[4], jnp.float32))
    return params, st


def _sampled_taus(seed=3):
    model = gg.PROB[3]
    rng = np.random.default_rng(seed)
    draws = [sample_round(model, rng) for _ in range(8)]
    return lambda r: draws[r]


def test_multihop_k1_reduces_exactly_to_colrel():
    taus = _sampled_taus()
    p_hop, _ = _round_harness(strategies.get("multihop", hops=1), taus)
    # identical scalar collapse -> bit-identical to colrel's fused path
    p_col, _ = _round_harness(strategies.get("colrel", fused=True), taus)
    for a, b in zip(jax.tree.leaves(p_hop), jax.tree.leaves(p_col)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and numerically equal to the faithful two-stage execution
    p_faith, _ = _round_harness(strategies.get("colrel"), taus)
    for a, b in zip(jax.tree.leaves(p_hop), jax.tree.leaves(p_faith)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-6)


def test_multihop_weights_match_matrix_power():
    n = 8
    m = topology.fully_connected(n, 0.5, p_c=0.6, rho=0.3)
    rng = np.random.default_rng(2)
    tu, td = sample_round(m, rng)
    A = np.abs(rng.normal(size=(n, n))) * 0.3 + np.eye(n)
    for K in (1, 2, 3):
        s = strategies.get("multihop", hops=K)
        got = np.asarray(s.weights(jnp.asarray(tu, jnp.float32),
                                   jnp.asarray(td, jnp.float32),
                                   jnp.asarray(A, jnp.float32)))
        M = A * td.T
        want = tu @ np.linalg.matrix_power(M, K) / n
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)
        # the multi-stage dense path agrees with the scalar collapse
        upd = jnp.asarray(rng.normal(size=(n, 5)), jnp.float32)
        delta, _ = s.aggregate(upd, jnp.asarray(tu, jnp.float32),
                               jnp.asarray(td, jnp.float32),
                               jnp.asarray(A, jnp.float32))
        np.testing.assert_allclose(np.asarray(delta), got @ np.asarray(upd),
                                   atol=1e-4, rtol=1e-4)


def test_multihop_calibration_restores_unbiasedness():
    """COPT-alpha satisfies condition (5), so at K=1 the Monte-Carlo
    correction is ~1; at K=2 it deviates, and dividing by it restores
    E[sum w] = 1."""
    m = topology.paper_fig2a()
    res = optimize_weights(m, sweeps=15, fine_tune_sweeps=15)
    c1 = strategies.multihop_correction(m, res.A, 1, draws=4096, seed=0)
    np.testing.assert_allclose(c1, np.ones(m.n), atol=0.12)

    s2 = strategies.get("multihop", hops=2).calibrate(m, res.A)
    assert s2.correction is not None
    # realized E[sum_j w_j] over fresh draws ~ 1 after correction
    rng = np.random.default_rng(9)
    tot = 0.0
    R = 2000
    for _ in range(R):
        tu, td = sample_round(m, rng)
        w = s2.weights(jnp.asarray(tu, jnp.float32), jnp.asarray(td, jnp.float32),
                       jnp.asarray(res.A, jnp.float32))
        tot += float(jnp.sum(w))
    assert abs(tot / R - 1.0) < 0.1, tot / R


# ---------------------------------------------------------------------------
# 3b. memory
# ---------------------------------------------------------------------------


def test_memory_no_blockage_reduces_exactly_to_colrel():
    n = gg.N
    all_up = lambda r: (np.ones(n), np.ones((n, n)))
    p_mem, buf = _round_harness(strategies.get("memory"), all_up)
    p_col, _ = _round_harness(strategies.get("colrel"), all_up)
    for a, b in zip(jax.tree.leaves(p_mem), jax.tree.leaves(p_col)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-7, rtol=1e-7)
    assert np.isfinite(np.asarray(buf)).all()


def test_memory_replays_last_received_update():
    """Dense-level semantics: a blocked uplink contributes the client's
    last successfully delivered consensus, not zero."""
    s = strategies.get("memory")
    n, d = 3, 2
    A = jnp.eye(n)
    ones_dd = jnp.ones((n, n))
    buf = s.init_state(n, d)
    u1 = jnp.asarray([[1.0, 0.0], [0.0, 2.0], [4.0, 4.0]])
    # round 1: client 2 blocked -> contributes its zero-initialized slot
    d1, buf = s.aggregate(u1, jnp.asarray([1.0, 1.0, 0.0]), ones_dd, A, buf)
    np.testing.assert_allclose(np.asarray(d1), np.asarray((u1[0] + u1[1]) / n))
    u2 = jnp.asarray([[10.0, 10.0], [0.5, 0.5], [7.0, 7.0]])
    # round 2: client 0 blocked -> replays u1[0]; client 2 now arrives
    d2, buf = s.aggregate(u2, jnp.asarray([0.0, 1.0, 1.0]), ones_dd, A, buf)
    np.testing.assert_allclose(np.asarray(d2),
                               np.asarray((u1[0] + u2[1] + u2[2]) / n))
    np.testing.assert_allclose(np.asarray(buf),
                               np.asarray(jnp.stack([u1[0], u2[1], u2[2]])))


# the (n, d) buffer's jit round-trip / no-recompile / NaN-weight-sum
# contract is covered for every stateful strategy by the conformance
# matrix (tests/test_conformance.py)


# ---------------------------------------------------------------------------
# 4. ExperimentSpec / build_experiment
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy,options", [
    ("multihop", {"hops": 2}),
    ("memory", {}),
])
def test_experiment_spec_runs_new_strategies_end_to_end(strategy, options):
    spec = ExperimentSpec(model="quadratic", topology="fig2a",
                          strategy=strategy, strategy_options=options,
                          channel="markov", rounds=6, seed=0)
    exp = build_experiment(spec)
    assert exp.strategy.name == strategy
    log = exp.run()
    assert len(log.loss) == 6 and np.isfinite(log.loss).all()
    if strategy == "multihop":
        assert exp.strategy.correction is not None  # auto-calibrated
        assert np.isfinite(np.asarray(log.weight_sums)).all()
    if strategy == "memory":
        assert exp.trainer.agg_state.shape[0] == exp.link_model.n


def test_register_overwrite_clears_deprecated_alias():
    strategies.register_deprecated_alias(
        "tmp_alias_xyz", "fedavg_blind", "tmp_alias_xyz is deprecated")
    with pytest.warns(DeprecationWarning):
        assert isinstance(strategies.get("tmp_alias_xyz"),
                          strategies.FedAvgBlind)

    @strategies.register("tmp_alias_xyz", overwrite=True)
    class TmpStrategy(strategies.AggregationStrategy):
        name = "tmp_alias_xyz"
        scalar_collapsible = True

        def weights(self, tau_up, tau_dd, A):
            return tau_up.astype(jnp.float32)

    # the overwrite wins: no alias forwarding, no warning
    assert isinstance(strategies.get("tmp_alias_xyz"), TmpStrategy)


def test_adaptive_rejects_calibrated_multihop_and_skips_calibration():
    # a calibrated multihop holds a correction baked against one alpha;
    # the adaptive schedule swapping alpha mid-run must be refused
    m = topology.paper_fig2a()
    calibrated = strategies.get("multihop", hops=2).calibrate(m, np.eye(10))
    assert calibrated.calibration_tracks_A
    from repro.channel import AdaptiveConfig, AdaptiveWeightSchedule
    from repro.fl import FLTrainer

    sched = AdaptiveWeightSchedule(10, AdaptiveConfig(every=10, warmup=5))
    with pytest.raises(ValueError, match="calibrated against a fixed alpha"):
        FLTrainer(lambda p, b: (0.0, {}), {"x": jnp.zeros(2)}, m, np.eye(10),
                  [None] * 10, sgd(0.1), sgd_momentum(1.0),
                  strategy=calibrated, adaptive=sched)
    # build_experiment therefore leaves multihop uncalibrated under
    # adaptive (blind start alpha -> nothing meaningful to calibrate to)
    spec = ExperimentSpec(model="quadratic", topology="fig2a",
                          strategy="multihop", strategy_options={"hops": 2},
                          adaptive=True, reopt_every=10, rounds=3)
    exp = build_experiment(spec)
    assert exp.strategy.correction is None
    log = exp.run()
    assert np.isfinite(log.loss).all()


def test_experiment_spec_adaptive_guard_from_registry():
    spec = ExperimentSpec(model="quadratic", topology="fig2a",
                          strategy="fedavg_blind", adaptive=True)
    with pytest.raises(ValueError, match="needs_A|ignores"):
        build_experiment(spec)


def test_experiment_spec_alpha_modes():
    spec = ExperimentSpec(model="quadratic", topology="fig2a",
                          strategy="colrel", copt_sweeps=5, rounds=2)
    exp = build_experiment(spec)
    assert exp.copt_result is not None  # auto -> copt for A-reading strategy
    spec2 = spec.replace(strategy="fedavg_blind")
    exp2 = build_experiment(spec2)
    assert exp2.copt_result is None
    np.testing.assert_array_equal(exp2.A, fedavg_weights(exp2.link_model.n))
    # explicit array passes through
    exp3 = build_experiment(spec.replace(alpha=np.eye(10)))
    np.testing.assert_array_equal(exp3.A, np.eye(10))


def test_trainer_rejects_both_strategy_spellings():
    from repro.fl import FLTrainer

    with pytest.raises(ValueError, match="not both"):
        FLTrainer(lambda p, b: (0.0, {}), {"x": jnp.zeros(2)},
                  topology.paper_fig2a(), np.eye(10), [None] * 10,
                  sgd(0.1), sgd_momentum(1.0),
                  strategy="colrel", aggregation="colrel")
