"""Decode-path correctness: sequential one-token decoding with caches must
reproduce teacher-forced forward logits (the KV cache / recurrent-state
bookkeeping is exactly consistent)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models import build
from repro.models import transformer as tr
from repro.models import hybrid as hy

KEY = jax.random.PRNGKey(7)
B, T = 2, 16

ARCHS = ["qwen3-0.6b", "gemma3-1b", "dbrx-132b", "rwkv6-1.6b", "jamba-1.5-large-398b"]


@pytest.mark.parametrize("arch_id", ARCHS)
def test_decode_matches_forward(arch_id):
    cfg = get_arch(arch_id).smoke().replace(frontend_tokens=0)
    bundle = build(cfg)
    params = bundle.init(KEY)
    tokens = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)

    if cfg.arch_type == "hybrid":
        ref_logits, _ = hy.forward(cfg, params, tokens)
    else:
        ref_logits, _ = tr.forward(cfg, params, tokens)

    cache = bundle.init_cache(B, T)
    got = []
    for t in range(T):
        logits, cache = bundle.decode_step(params, cache, tokens[:, t : t + 1], jnp.int32(t))
        got.append(logits)
    got = jnp.stack(got, axis=1)  # (B, T, V)

    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref_logits, np.float32),
        atol=2e-2, rtol=2e-2,
    )


def test_encdec_decode_matches_forward():
    cfg = get_arch("seamless-m4t-large-v2").smoke()
    from repro.models import encdec as ed

    bundle = build(cfg)
    params = bundle.init(KEY)
    frames = jax.random.normal(KEY, (B, cfg.frontend_tokens, cfg.d_model))
    tokens = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    memory = ed.encode(cfg, params, frames)
    ref = ed.decode_train(cfg, params, tokens, memory)

    cache = bundle.init_cache(B, T, cfg.frontend_tokens)
    cache = {**cache, "memory": memory}
    got = []
    for t in range(T):
        logits, cache = bundle.decode_step(params, cache, tokens[:, t : t + 1], jnp.int32(t))
        got.append(logits)
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-2, rtol=2e-2)


def test_chunked_attention_matches_dense():
    """The query-blocked streaming attention path == dense path."""
    cfg = get_arch("qwen3-0.6b").smoke()
    bundle = build(cfg)
    params = bundle.init(KEY)
    tokens = jax.random.randint(KEY, (B, 64), 0, cfg.vocab_size)
    dense, _ = tr.forward(cfg.replace(attn_chunk=4096), params, tokens)
    chunked, _ = tr.forward(cfg.replace(attn_chunk=16), params, tokens)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked), atol=2e-3, rtol=2e-3)


def test_sliding_window_decode_matches_forward():
    """gemma3-style local/global pattern must agree between the traced
    per-layer window array in forward and the decode mask."""
    cfg = get_arch("gemma3-1b").smoke().replace(sliding_window=8, local_global_ratio=1)
    bundle = build(cfg)
    params = bundle.init(KEY)
    tokens = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    ref, _ = tr.forward(cfg, params, tokens)
    cache = bundle.init_cache(B, T)
    got = []
    for t in range(T):
        logits, cache = bundle.decode_step(params, cache, tokens[:, t : t + 1], jnp.int32(t))
        got.append(logits)
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-2, rtol=2e-2)


def test_banded_window_attention_matches_dense():
    """Static banded sliding-window path == dense masked attention."""
    from repro.models.attention import attention, init_attention
    from repro.models.common import ModelConfig

    cfg = ModelConfig(d_model=64, n_heads=2, n_kv_heads=1, head_dim=32,
                      vocab_size=64, sliding_window=16, attn_chunk=32)
    p = init_attention(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 64))
    dense = attention(cfg, p, x, window=jnp.int32(16))
    banded = attention(cfg, p, x, static_window=16)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(banded),
                               atol=2e-4, rtol=2e-4)


def test_static_window_pattern_forward_matches_scan():
    cfg = get_arch("gemma3-1b").smoke().replace(attn_chunk=16)
    bundle = build(cfg)
    params = bundle.init(KEY)
    tokens = jax.random.randint(KEY, (B, 64), 0, cfg.vocab_size)
    a, _ = tr.forward(cfg, params, tokens)
    b, _ = tr.forward(cfg.replace(static_window_pattern=True), params, tokens)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-3, rtol=3e-3)
