"""Distribution-layer EXECUTION test: run a real ColRel round on an
8-device host mesh (subprocess with forced device count) and check it
matches the single-device reference bit-for-bit (up to float tolerance).

This goes beyond the dry-run (which only lowers+compiles at 512 devices):
the sharding rules, spmd-pinned client vmap, and fused aggregation
actually execute here.
"""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import get_arch
    from repro.core import sample_round, topology, optimize_weights
    from repro.core.aggregation import Aggregation
    from repro.fl.round import RoundConfig, make_round_fn
    from repro.models import build
    from repro.optim import sgd, sgd_momentum
    from repro.launch import sharding as shard_rules

    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((4, 2), ("data", "model"))

    cfg = get_arch("qwen3-0.6b").smoke()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))

    n, B, S, T = 4, 2, 32, 2
    m = topology.fully_connected(n, 0.5, p_c=0.8)
    A = jnp.asarray(optimize_weights(m, sweeps=5, fine_tune_sweeps=5).A, jnp.float32)
    rng = np.random.default_rng(0)
    tu, td = sample_round(m, rng)
    toks = rng.integers(0, cfg.vocab_size, size=(n, T, B, S + 1), dtype=np.int32)
    batches = {"tokens": jnp.asarray(toks[..., :-1]), "labels": jnp.asarray(toks[..., 1:])}
    args = (jnp.asarray(tu, jnp.float32), jnp.asarray(td, jnp.float32), A)

    server = sgd_momentum(1.0, beta=0.9)

    def run(sharded, aggregation, fused_kernel=False):
        rc = RoundConfig(n_clients=n, local_steps=T, mode="per_client",
                         aggregation=aggregation, use_fused_kernel=fused_kernel,
                         spmd_axes=("data",) if sharded else None)
        fn = make_round_fn(bundle.loss_fn, sgd(0.1), server, rc)
        if sharded:
            with mesh:
                psh = shard_rules.param_shardings(cfg, jax.eval_shape(lambda: params), mesh)
                bsh = shard_rules.train_batch_shardings(mesh, "per_client",
                                                        jax.eval_shape(lambda: batches))
                rep = NamedSharding(mesh, P())
                fn = jax.jit(fn, in_shardings=(psh, psh_state(psh), (), bsh, rep, rep, rep))
                return fn(params, server.init(params), (), batches, *args)
        return jax.jit(fn)(params, server.init(params), (), batches, *args)

    def psh_state(psh):
        # server momentum state mirrors params + a replicated step counter
        return {"step": NamedSharding(mesh, P()), "m": psh}

    p_ref, _, _, met_ref = run(False, Aggregation.COLREL)
    p_dist, _, _, met_dist = run(True, Aggregation.COLREL)
    p_fused, _, _, _ = run(True, Aggregation.COLREL_FUSED)
    p_flat, _, _, _ = run(True, Aggregation.COLREL, fused_kernel=True)

    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_dist)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   atol=3e-5, rtol=3e-4)
    for a, b in zip(jax.tree.leaves(p_dist), jax.tree.leaves(p_fused)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   atol=3e-5, rtol=3e-4)
    # flatten-once fused engine under pjit (sharded deltas -> GSPMD-
    # partitioned single-pass contraction) == the per-leaf reference
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_flat)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   atol=3e-5, rtol=3e-4)
    assert abs(float(met_ref["loss"]) - float(met_dist["loss"])) < 1e-4
    print("DISTRIBUTED_EXEC_OK")
    """
)


@pytest.mark.slow
def test_distributed_round_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    assert "DISTRIBUTED_EXEC_OK" in out.stdout
