"""Pallas kernels vs their pure-jnp oracles: shape/dtype sweeps in
interpret mode (kernel bodies execute step-by-step on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.relay_mix import relay_mix_pallas

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("n", [4, 10, 16, 33])
@pytest.mark.parametrize("d", [128, 1000, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_relay_mix_sweep(n, d, dtype):
    M = jnp.asarray(RNG.normal(size=(n, n)), jnp.float32)
    X = jnp.asarray(RNG.normal(size=(n, d))).astype(dtype)
    got = relay_mix_pallas(M, X, block_d=512, interpret=True)
    want = ref.relay_mix_ref(M, X)
    tol = 1e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_relay_mix_masked_semantics():
    """Kernel applied to (A * tau^T) reproduces Eq. (3) with dropped links."""
    n, d = 8, 256
    A = jnp.asarray(RNG.random((n, n)), jnp.float32)
    tau = jnp.asarray((RNG.random((n, n)) < 0.5).astype(np.float32))
    X = jnp.asarray(RNG.normal(size=(n, d)), jnp.float32)
    M = A * tau.T
    got = ops.relay_mix(M, X, block_d=128)
    want = M @ X
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("shape", [(1, 128, 2, 64), (2, 256, 4, 32), (1, 192, 1, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(shape, dtype):
    B, T, H, D = shape
    q = jnp.asarray(RNG.normal(size=(B * H, T, D))).astype(dtype)
    k = jnp.asarray(RNG.normal(size=(B * H, T, D))).astype(dtype)
    v = jnp.asarray(RNG.normal(size=(B * H, T, D))).astype(dtype)
    got = flash_attention_pallas(q, k, v, block_q=64, block_kv=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_flash_gqa_wrapper():
    B, T, H, KV, D = 2, 128, 4, 2, 32
    q = jnp.asarray(RNG.normal(size=(B, T, H, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, T, KV, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, T, KV, D)), jnp.float32)
    got = ops.flash_attention(q, k, v, block_q=64, block_kv=64)
    G = H // KV
    kr = jnp.repeat(k, G, 2).transpose(0, 2, 1, 3).reshape(B * H, T, D)
    vr = jnp.repeat(v, G, 2).transpose(0, 2, 1, 3).reshape(B * H, T, D)
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    want = ref.flash_attention_ref(qr, kr, vr).reshape(B, H, T, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_flash_attention_in_model_attention():
    """models/attention.py use_flash path == jnp path."""
    from repro.models.attention import attention, init_attention
    from repro.models.common import ModelConfig

    cfg = ModelConfig(d_model=64, n_heads=2, n_kv_heads=2, head_dim=32, vocab_size=64)
    p = init_attention(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 64))
    a = attention(cfg, p, x, use_flash=False)
    b = attention(cfg, p, x, use_flash=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("shape", [(2, 128, 8, 16), (1, 64, 4, 4), (3, 96, 16, 32)])
@pytest.mark.parametrize("chunk", [16, 32])
def test_ssd_scan_sweep(shape, chunk):
    from repro.kernels.ssd_scan import ssd_scan_pallas

    BH, T, Dk, Dv = shape
    q = jnp.asarray(RNG.normal(size=(BH, T, Dk)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(BH, T, Dk)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(BH, T, Dv)), jnp.float32)
    logd = jnp.asarray(-np.abs(RNG.normal(size=(BH, T))), jnp.float32)
    got = ssd_scan_pallas(q, k, v, logd, chunk=chunk, interpret=True)
    want = ref.ssd_scan_ref(q, k, v, logd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-4, rtol=5e-4)


def test_ssd_scan_matches_jnp_chunked():
    """Kernel == the jnp production path (models.ssm.ssd_chunked)."""
    from repro.kernels.ssd_scan import ssd_scan_pallas
    from repro.models import ssm

    B, T, H, Dk, Dv = 2, 64, 3, 8, 8
    q = jnp.asarray(RNG.normal(size=(B, T, H, Dk)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, T, H, Dk)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, T, H, Dv)), jnp.float32)
    loga = jnp.asarray(-np.abs(RNG.normal(size=(B, T, H))), jnp.float32)
    y_jnp, _ = ssm.ssd_chunked(q, k, v, loga, chunk=16)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, T, Dk)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, T, Dk)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, T, Dv)
    lf = loga.transpose(0, 2, 1).reshape(B * H, T)
    y_k = ssd_scan_pallas(qf, kf, vf, lf, chunk=16, interpret=True)
    y_k = y_k.reshape(B, H, T, Dv).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_jnp), atol=5e-4, rtol=5e-4)
