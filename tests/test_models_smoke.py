"""Per-architecture smoke tests: every assigned arch's REDUCED config runs
one forward/train step and one decode step on CPU — shapes + no NaNs."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import CLI_ALIASES, get_arch
from repro.models import build

KEY = jax.random.PRNGKey(0)
B, T = 2, 64


def _batch(cfg):
    V = cfg.vocab_size
    batch = {
        "tokens": jax.random.randint(KEY, (B, T), 0, V),
        "labels": jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, V),
    }
    if cfg.frontend_tokens:
        batch["prefix"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.frontend_tokens, cfg.d_model), cfg.jdtype
        )
    return batch


@pytest.mark.parametrize("arch_id", sorted(CLI_ALIASES))
def test_smoke_train_step(arch_id):
    cfg = get_arch(arch_id).smoke()
    bundle = build(cfg)
    params = bundle.init(KEY)
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(bundle.loss_fn, has_aux=True)(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch_id}: non-finite loss"
    leaves = jax.tree.leaves(grads)
    assert leaves, arch_id
    for g in leaves:
        assert jnp.isfinite(g.astype(jnp.float32)).all(), f"{arch_id}: NaN grads"
    # one SGD step changes the params
    newp = jax.tree.map(lambda p, g: p - 0.01 * g.astype(p.dtype), params, grads)
    loss2, _ = bundle.loss_fn(newp, batch)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch_id", sorted(CLI_ALIASES))
def test_smoke_decode_step(arch_id):
    cfg = get_arch(arch_id).smoke()
    bundle = build(cfg)
    params = bundle.init(KEY)
    max_len = 128
    if cfg.arch_type in ("encdec", "audio"):
        cache = bundle.init_cache(B, max_len, cfg.frontend_tokens)
    else:
        cache = bundle.init_cache(B, max_len)
    token = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = bundle.decode_step(params, cache, token, jnp.int32(3))
    assert logits.shape == (B, cfg.vocab_size), arch_id
    assert jnp.isfinite(logits).all(), f"{arch_id}: NaN decode logits"
    # caches keep their structure
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch_id", sorted(CLI_ALIASES))
def test_full_config_matches_assignment(arch_id):
    """The full() configs must carry the exact assigned dimensions."""
    expected = {
        "seamless-m4t-large-v2": dict(n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192, vocab_size=256206),
        "dbrx-132b": dict(n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752, vocab_size=100352, n_experts=16, top_k=4),
        "olmo-1b": dict(n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=8192, vocab_size=50304, norm="nonparametric_ln"),
        "qwen3-0.6b": dict(n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=3072, vocab_size=151936, qk_norm=True),
        "granite-moe-3b-a800m": dict(n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512, vocab_size=49155, n_experts=40, top_k=8),
        "jamba-1.5-large-398b": dict(n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576, vocab_size=65536, n_experts=16, top_k=2),
        "deepseek-coder-33b": dict(n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=19200, vocab_size=32256),
        "rwkv6-1.6b": dict(n_layers=24, d_model=2048, d_ff=7168, vocab_size=65536, arch_type="ssm"),
        "internvl2-2b": dict(n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192, vocab_size=92553, arch_type="vlm"),
        "gemma3-1b": dict(n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, d_ff=6912, vocab_size=262144, sliding_window=512, local_global_ratio=5),
    }[arch_id]
    cfg = get_arch(arch_id).full()
    for k, v in expected.items():
        assert getattr(cfg, k) == v, f"{arch_id}.{k}: {getattr(cfg, k)} != {v}"


@pytest.mark.parametrize("arch_id", sorted(CLI_ALIASES))
def test_smoke_config_is_reduced(arch_id):
    cfg = get_arch(arch_id).smoke()
    assert cfg.d_model <= 512
    assert cfg.n_layers <= 4
    assert cfg.n_experts <= 4
