"""The chunked multi-round scan engine (DESIGN.md §9).

Five layers:
  1. round-level equivalence — ``make_scan_round_fn`` over K rounds is
     *bitwise identical* to K sequential ``make_round_fn`` calls for
     every registered strategy, including stateful ones (memory's replay
     buffer, quantized int8's threaded PRNG key), pinned against the
     frozen pre-refactor fixture ``tests/golden/round_golden.npz``;
  2. stream equivalence — the vectorized batch gather and the channel
     ``trace`` service produce the exact streams their per-round
     counterparts do, for any chunking of the consumption;
  3. trainer-level equivalence — ``FLTrainer.run(chunk=K)`` reproduces
     the per-round loop bitwise (loss/participation/weight-sum/uplink-
     bits trajectories and final params), including resumed runs, tail
     remainders, and adaptive re-optimization at chunk boundaries (with
     the misaligned-cadence fallback);
  4. the in-scan channel samplers — marginals match the process law and
     the sampled-tau scan variant runs end to end;
  5. the wire-format-aware uplink accounting and the production
     ``build_step(scan_rounds=K)`` lowering.
"""

import importlib.util
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import strategies
from repro.channel import (
    AdaptiveConfig,
    AdaptiveWeightSchedule,
    MarkovChannel,
    MobilityChannel,
    StaticChannel,
    channel_key,
    ge_scan_sampler,
    gilbert_elliott,
    static_scan_sampler,
)
from repro.core import fedavg_weights, optimize_weights, topology
from repro.core.connectivity import sample_round
from repro.data import quadratic_problem
from repro.data.pipeline import ClientDataset, stack_chunk_batches
from repro.fl import FLTrainer
from repro.fl.round import RoundConfig, make_round_fn, make_scan_round_fn
from repro.optim import sgd, sgd_momentum

_GG_PATH = pathlib.Path(__file__).parent / "golden" / "generate_golden.py"
_spec = importlib.util.spec_from_file_location("_golden_gen_scan", _GG_PATH)
gg = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(gg)

GOLDEN = np.load(pathlib.Path(__file__).parent / "golden" / "round_golden.npz")


# ---------------------------------------------------------------------------
# harnesses
# ---------------------------------------------------------------------------


def _golden_inputs(mode: str, rounds: int):
    """The golden problem's tau/batch streams, stacked for a K-round scan
    (identical draws to gg.run_config's per-round loop)."""
    T = 1 if mode == "weighted_grad" else 2
    tau_rng = np.random.default_rng(77)
    bat_rng = np.random.default_rng(99)
    taus = [sample_round(gg.PROB[3], tau_rng) for _ in range(rounds)]
    bs = [gg.batches_for(bat_rng, T) for _ in range(rounds)]
    if mode == "weighted_grad":
        bs = [{k: v[:, 0] for k, v in b.items()} for b in bs]
    batches = {k: jnp.asarray(np.stack([b[k] for b in bs])) for k in bs[0]}
    tau_up = jnp.asarray(np.stack([t[0] for t in taus]), jnp.float32)
    tau_dd = jnp.asarray(np.stack([t[1] for t in taus]), jnp.float32)
    return batches, tau_up, tau_dd


def run_config_scan(strategy, mode, *, rounds=gg.ROUNDS, use_fused_kernel=False):
    """gg.run_config's experiment executed as ONE scan chunk of K rounds."""
    H, centers, Wc, model, A = gg.PROB
    T = 1 if mode == "weighted_grad" else 2
    rc_kwargs = dict(n_clients=gg.N, local_steps=T, mode=mode,
                     aggregation=strategy)
    if use_fused_kernel:
        rc_kwargs["use_fused_kernel"] = True
    rc = RoundConfig(**rc_kwargs)
    server_opt = sgd_momentum(1.0, beta=0.9)
    fn = jax.jit(make_scan_round_fn(gg.make_loss(H, Wc), sgd(0.05),
                                    server_opt, rc))
    params = {"x": jnp.zeros(gg.DX, jnp.float32),
              "W": jnp.zeros((3, 4), jnp.float32)}
    batches, tau_up, tau_dd = _golden_inputs(mode, rounds)
    params, _, agg_state, metrics = fn(
        params, server_opt.init(params),
        rc.resolve_strategy().init_state(gg.N, gg.DX + 12),
        batches, tau_up, tau_dd, jnp.asarray(A, jnp.float32))
    return params, metrics, agg_state


def _quadratic_trainer(*, channel=None, adaptive=None, strategy="colrel",
                       A=None, local_steps=4, seed=0):
    prob = quadratic_problem(10, 16, mu=1.0, L=8.0, hetero=1.0, seed=0)
    H = jnp.asarray(prob["H"], jnp.float32)
    model = topology.paper_fig2a()

    def loss_fn(params, batch):
        x = params["x"]
        d = x - batch["center"][0]
        return 0.5 * d @ (H @ d) + 0.1 * batch["noise"][0] @ x, {}

    clients = []
    for i in range(10):
        c = prob["centers"][i].astype(np.float32)
        pool = np.random.default_rng(100 + i).normal(size=(2048, 16)).astype(np.float32)
        clients.append(ClientDataset({"center": np.tile(c, (2048, 1)),
                                      "noise": pool}, batch_size=1, seed=7 + i))
    if A is None:
        A = optimize_weights(model, sweeps=10, fine_tune_sweeps=10).A
    return FLTrainer(loss_fn, {"x": jnp.zeros(16)}, model, A, clients,
                     sgd(0.02), sgd_momentum(1.0, beta=0.0),
                     local_steps=local_steps, strategy=strategy, seed=seed,
                     channel=channel, adaptive=adaptive)


def _assert_logs_bitwise(a, b):
    for field in ("rounds", "loss", "participation", "uplink_bits",
                  "weight_sums"):
        av, bv = getattr(a.log, field), getattr(b.log, field)
        # list equality is bitwise for floats (and treats NaN != NaN, so
        # compare NaN-bearing weight_sums positionally)
        assert len(av) == len(bv), field
        for x, y in zip(av, bv):
            assert x == y or (np.isnan(x) and np.isnan(y)), (field, x, y)
    np.testing.assert_array_equal(np.asarray(a.params["x"]),
                                  np.asarray(b.params["x"]))


# ---------------------------------------------------------------------------
# 1. round-level scan == loop, pinned against the golden fixture
# ---------------------------------------------------------------------------

GOLDEN_CONFIGS = [(s, m, False) for s in gg.STRATEGIES for m in gg.MODES]
GOLDEN_CONFIGS.append(("colrel", "per_client", True))


@pytest.mark.parametrize("strategy,mode,fused_kernel", GOLDEN_CONFIGS,
                         ids=[f"{s}-{m}{'-kernel' if k else ''}"
                              for s, m, k in GOLDEN_CONFIGS])
@pytest.mark.filterwarnings("ignore::DeprecationWarning")
def test_scan_matches_golden_fixture(strategy, mode, fused_kernel):
    """One K-round scan reproduces the frozen pre-refactor trajectory
    bitwise — the same fixture the per-round loop is pinned against."""
    params, metrics, _ = run_config_scan(strategy, mode,
                                         use_fused_kernel=fused_kernel)
    tag = f"{strategy}|{mode}" + ("|kernel" if fused_kernel else "")
    np.testing.assert_array_equal(np.asarray(params["x"], np.float32),
                                  GOLDEN[f"{tag}|x"])
    np.testing.assert_array_equal(np.asarray(params["W"], np.float32),
                                  GOLDEN[f"{tag}|W"])
    # stacked (K,) metrics: the last round's weight_sum is the frozen one
    np.testing.assert_array_equal(
        np.float32(np.asarray(metrics["weight_sum"])[-1]),
        GOLDEN[f"{tag}|weight_sum"])


def test_scan_matches_golden_quantized_int8():
    """Stateful codec PRNG key threads through the scan carry: the pinned
    quantized-int8 trajectory replays bitwise."""
    params, _, (codec_state, _) = run_config_scan(
        gg.quantized_int8_strategy(), "per_client")
    np.testing.assert_array_equal(np.asarray(params["x"], np.float32),
                                  GOLDEN[f"{gg.QUANT_TAG}|x"])
    np.testing.assert_array_equal(np.asarray(params["W"], np.float32),
                                  GOLDEN[f"{gg.QUANT_TAG}|W"])
    # the key advanced (fresh quantization noise every scanned round)
    init_key = gg.quantized_int8_strategy().init_state(gg.N, gg.DX + 12)[0]
    assert not np.array_equal(np.asarray(codec_state), np.asarray(init_key))


@pytest.mark.parametrize("name,options", [
    ("colrel", {}),
    ("fedavg_perfect", {}),
    ("fedavg_blind", {}),
    ("fedavg_nonblind", {}),
    ("multihop", {"hops": 2}),
    ("memory", {}),
    ("quantized", {"codec": "int8"}),
])
def test_scan_bitwise_matches_sequential_rounds(name, options):
    """Every registered strategy: scanned K rounds == K sequential
    ``round_fn`` calls, bit for bit (params, metrics and carried state)."""
    strategy = strategies.get(name, **options)
    p_loop, m_loop = gg.run_config(strategy, "per_client")
    p_scan, m_scan, _ = run_config_scan(strategies.get(name, **options),
                                        "per_client")
    for a, b in zip(jax.tree.leaves(p_loop), jax.tree.leaves(p_scan)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for k in ("loss", "participation", "uplink_bits"):
        np.testing.assert_array_equal(np.float32(m_loop[k]),
                                      np.asarray(m_scan[k])[-1])


def test_every_registered_strategy_is_scan_covered():
    """Fail when a new strategy lands without scan-equivalence coverage."""
    covered = {"colrel", "fedavg_perfect", "fedavg_blind", "fedavg_nonblind",
               "multihop", "memory", "quantized",
               # clustered: C=1 scan trajectories pinned bitwise against
               # colrel's golden fixture in tests/test_clustered.py
               "clustered",
               # async_colrel: the async scan's chunked/no-trace/resume
               # trajectories are pinned for every mode by the conformance
               # matrix (tests/test_conformance.py), and zero-blockage
               # bitwise sync reduction by tests/test_property.py
               "async_colrel"}
    assert set(strategies.available()) <= covered


# ---------------------------------------------------------------------------
# 2. stream equivalence: batches and channel traces
# ---------------------------------------------------------------------------


def test_next_batches_stream_equivalent():
    mk = lambda: ClientDataset(
        {"a": np.arange(500, dtype=np.float32).reshape(100, 5)},
        batch_size=3, seed=11)
    c1, c2 = mk(), mk()
    bulk = c1.next_batches(6)["a"]
    seq = np.stack([c2.next_batch()["a"] for _ in range(6)])
    np.testing.assert_array_equal(bulk, seq)
    # and the stream continues identically after a bulk draw
    np.testing.assert_array_equal(c1.next_batch()["a"], c2.next_batch()["a"])


def test_stack_chunk_batches_layout_and_stream():
    clients = [ClientDataset({"a": np.arange(40, dtype=np.float32).reshape(20, 2)},
                             batch_size=2, seed=3 + i) for i in range(4)]
    chunk = stack_chunk_batches(clients, local_steps=3, rounds=5)
    assert chunk["a"].shape == (5, 4, 3, 2, 2)
    clients2 = [ClientDataset({"a": np.arange(40, dtype=np.float32).reshape(20, 2)},
                              batch_size=2, seed=3 + i) for i in range(4)]
    for r in range(5):
        per_round = stack_chunk_batches(clients2, local_steps=3, rounds=1)
        np.testing.assert_array_equal(chunk["a"][r], per_round["a"][0])


@pytest.mark.parametrize("make", [
    lambda m: StaticChannel(m, seed=5, block=16),
    lambda m: MarkovChannel(gilbert_elliott(m, memory=0.8), seed=5, block=16),
])
def test_trace_matches_per_round_service(make):
    m = topology.fully_connected(6, 0.6, p_c=0.5, rho=0.5)
    ch_a, ch_b = make(m), make(m)
    ups, dds = ch_a.trace(0, 40)  # spans multiple 16-round blocks
    assert np.asarray(ups).shape == (40, 6) and np.asarray(dds).shape == (40, 6, 6)
    for r in range(40):
        tu, td = ch_b.tau_for_round(r)
        np.testing.assert_array_equal(np.asarray(ups[r], np.float64), tu)
        np.testing.assert_array_equal(np.asarray(dds[r], np.float64), td)
    # interleaved consumption reads the same stream
    tu, td = ch_a.tau_for_round(40)
    np.testing.assert_array_equal(tu, ch_b.tau_for_round(40)[0])
    u2, _ = ch_a.trace(41, 5)
    for i in range(5):
        np.testing.assert_array_equal(np.asarray(u2[i], np.float64),
                                      ch_b.tau_for_round(41 + i)[0])
    with pytest.raises(ValueError, match="rewind"):
        ch_a.trace(0, 4)


def test_mobility_trace_matches_per_round_service():
    ch_a = MobilityChannel(8, area=250.0, speed=10.0, epoch=5, seed=0)
    ch_b = MobilityChannel(8, area=250.0, speed=10.0, epoch=5, seed=0)
    ups, dds = ch_a.trace(0, 12)
    assert ups.shape == (12, 8) and dds.shape == (12, 8, 8)
    for r in range(12):
        tu, td = ch_b.tau_for_round(r)
        np.testing.assert_array_equal(ups[r], tu)
        np.testing.assert_array_equal(dds[r], td)


# ---------------------------------------------------------------------------
# 3. trainer-level chunked == loop
# ---------------------------------------------------------------------------


def test_trainer_chunked_matches_loop_static():
    t1 = _quadratic_trainer()
    t1.run(14)
    t2 = _quadratic_trainer()
    t2.run(14, chunk=4)  # 3 full chunks + a 2-round per-round tail
    _assert_logs_bitwise(t1, t2)


def test_trainer_chunked_matches_loop_markov_and_resume():
    mk_ch = lambda: MarkovChannel(gilbert_elliott(topology.paper_fig2a(),
                                                  memory=0.8), seed=1, block=16)
    t1 = _quadratic_trainer(channel=mk_ch())
    t1.run(20)
    t2 = _quadratic_trainer(channel=mk_ch())
    t2.run(7)           # per-round prefix ...
    t2.run(13, chunk=5)  # ... resumed chunked: aligns at round 10
    _assert_logs_bitwise(t1, t2)


def test_trainer_chunked_adaptive_matches_loop_at_boundaries():
    """Re-opt cadence a multiple of the chunk: estimator state, re-opt
    rounds and the refreshed alphas replay exactly."""
    mk = lambda: _quadratic_trainer(
        channel=MarkovChannel(gilbert_elliott(topology.paper_fig2a(),
                                              memory=0.8), seed=1, block=16),
        adaptive=AdaptiveWeightSchedule(10, AdaptiveConfig(
            every=10, warmup=5, sweeps=3, fine_tune_sweeps=3)),
        A=fedavg_weights(10), local_steps=2)
    t1 = mk()
    t1.run(30)
    t2 = mk()
    t2.run(30, chunk=5)
    _assert_logs_bitwise(t1, t2)
    assert t2.log.reopt_rounds == [9, 19, 29]
    assert t1.log.S_est == t2.log.S_est
    np.testing.assert_array_equal(np.asarray(t1.A), np.asarray(t2.A))


def test_trainer_misaligned_chunk_falls_back_to_per_round():
    adaptive = AdaptiveWeightSchedule(10, AdaptiveConfig(every=10, warmup=5))
    t = _quadratic_trainer(adaptive=adaptive, A=fedavg_weights(10))
    assert t._effective_chunk(7, 0) == 1   # 10 % 7 != 0
    assert t._effective_chunk(5, 0) == 5
    assert t._effective_chunk(5, 8) == 1   # eval cadence misaligned
    assert t._effective_chunk(5, 10) == 5


def test_trainer_chunked_eval_at_boundaries():
    t = _quadratic_trainer()
    t.eval_fn = lambda p: {"d": float(jnp.sum(p["x"] ** 2))}
    t.run(12, chunk=4, eval_every=4)
    assert t.log.eval_rounds == [3, 7, 11]
    t2 = _quadratic_trainer()
    t2.eval_fn = t.eval_fn
    t2.run(12, eval_every=4)
    assert t.log.eval_metrics == t2.log.eval_metrics


# ---------------------------------------------------------------------------
# 4. in-scan channel samplers
# ---------------------------------------------------------------------------


def _scan_sample(init_fn, sample_fn, rounds, seed=0):
    key = channel_key(seed)
    key, k_init = jax.random.split(key)
    state = init_fn(k_init)

    def body(carry, _):
        st, k = carry
        k, sub = jax.random.split(k)
        tu, td, st = sample_fn(st, sub)
        return (st, k), (tu, td)

    (_, _), (ups, dds) = jax.lax.scan(body, (state, key), None, length=rounds)
    return np.asarray(ups), np.asarray(dds)


def test_ge_scan_sampler_matches_marginals():
    m = topology.fully_connected(8, 0.6, p_c=0.5, rho=0.5)
    params = gilbert_elliott(m, memory=0.8)
    ups, dds = _scan_sample(*ge_scan_sampler(params), rounds=4000)
    ess = (1 - 0.8) / (1 + 0.8)
    sd_up = np.sqrt(0.25 / (4000 * ess * 8))
    assert abs(ups.mean() - m.p.mean()) < 6 * sd_up
    off = ~np.eye(8, dtype=bool)
    sd_dd = np.sqrt(0.25 / (4000 * ess * 28))
    assert abs(dds.mean(0)[off].mean() - m.P[off].mean()) < 6 * sd_dd
    np.testing.assert_array_equal(dds[:, np.arange(8), np.arange(8)], 1.0)


def test_static_scan_sampler_matches_marginals():
    m = topology.fully_connected(8, 0.6, p_c=0.5, rho=0.5)
    ups, dds = _scan_sample(*static_scan_sampler(m), rounds=2000)
    assert abs(ups.mean() - m.p.mean()) < 6 * np.sqrt(0.25 / (2000 * 8))
    off = ~np.eye(8, dtype=bool)
    assert abs(dds.mean(0)[off].mean() - m.P[off].mean()) < 6 * np.sqrt(0.25 / (2000 * 28))
    # reciprocity joint survives the in-scan coupling
    joint = (dds * np.swapaxes(dds, 1, 2)).mean(0)[off].mean()
    assert abs(joint - m.E[off].mean()) < 6 * np.sqrt(0.25 / (2000 * 28))


def test_scan_round_fn_with_in_scan_sampler_runs():
    """The sampled-tau variant: carry = (params, server_state, agg_state,
    channel_state, rng); taus never materialize outside the program."""
    H, centers, Wc, model, A = gg.PROB
    params_ge = gilbert_elliott(model, memory=0.8)
    init_fn, sample_fn = ge_scan_sampler(params_ge)
    rc = RoundConfig(n_clients=gg.N, local_steps=2, mode="per_client",
                     aggregation="colrel")
    server_opt = sgd_momentum(1.0, beta=0.9)
    fn = jax.jit(make_scan_round_fn(gg.make_loss(H, Wc), sgd(0.05), server_opt,
                                    rc, channel_sampler=sample_fn))
    K = 8
    bat_rng = np.random.default_rng(5)
    bs = [gg.batches_for(bat_rng, 2) for _ in range(K)]
    batches = {k: jnp.asarray(np.stack([b[k] for b in bs])) for k in bs[0]}
    params = {"x": jnp.zeros(gg.DX, jnp.float32),
              "W": jnp.zeros((3, 4), jnp.float32)}
    key = channel_key(3)
    key, k_init = jax.random.split(key)
    state = init_fn(k_init)
    p2, _, _, state2, key2, metrics = fn(
        params, server_opt.init(params), (), batches, state, key,
        jnp.asarray(A, jnp.float32))
    assert np.isfinite(np.asarray(metrics["loss"])).all()
    assert np.asarray(metrics["participation"]).shape == (K,)
    assert not np.array_equal(np.asarray(jax.random.key_data(key2)),
                              np.asarray(jax.random.key_data(key)))
    assert np.asarray(state2).shape == np.asarray(state).shape
    # rerunning from the returned state continues the chain (shape-stable
    # carry: no retrace needed)
    fn(p2, server_opt.init(p2), (), batches, state2, key2,
       jnp.asarray(A, jnp.float32))


# ---------------------------------------------------------------------------
# 5. uplink accounting + production lowering
# ---------------------------------------------------------------------------


def test_uplink_bits_metric_uncoded_and_quantized():
    d = gg.DX + 12
    _, m_col, _ = run_config_scan(strategies.get("colrel"), "per_client")
    part = np.asarray(m_col["participation"])
    np.testing.assert_allclose(np.asarray(m_col["uplink_bits"]),
                               part * d * 32.0, rtol=1e-6)
    quant = strategies.get("quantized", codec="int8", codec_options={"bits": 4})
    assert quant.wire_bits_per_coord(d) == pytest.approx(4 + 32.0 / d)
    _, m_q, _ = run_config_scan(
        strategies.get("quantized", codec="int8", codec_options={"bits": 4}),
        "per_client")
    np.testing.assert_allclose(np.asarray(m_q["uplink_bits"]),
                               np.asarray(m_q["participation"]) * d * (4 + 32.0 / d),
                               rtol=1e-6)


def test_trainer_logs_uplink_bits_both_paths():
    t = _quadratic_trainer()
    t.run(6, chunk=3)
    assert len(t.log.uplink_bits) == 6
    want = np.asarray(t.log.participation) * 16 * 32.0
    np.testing.assert_allclose(np.asarray(t.log.uplink_bits), want, rtol=1e-6)


def test_build_scan_step_lowers():
    from repro.configs.base import get_arch
    from repro.launch.steps import build_step

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_arch("qwen3-0.6b").smoke()
    step, lower_args, in_sh, out_sh = build_step(
        "qwen3-0.6b", "train_4k", mesh, scan_rounds=2, cfg_override=cfg)
    K = 2
    assert all(v.shape[0] == K for v in lower_args[3].values())
    assert lower_args[4].shape[0] == K and lower_args[5].shape[:1] == (K,)
    with mesh:
        jax.jit(step, in_shardings=in_sh, out_shardings=out_sh).lower(*lower_args)
