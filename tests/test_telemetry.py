"""The observability subsystem (DESIGN.md §11).

Five layers:
  1. device tier — the outage-streak recurrence, the instrumented
     round's vector metrics, and the guarantee that instrumentation
     changes *nothing*: trajectories and scalar metric streams are
     bitwise identical with telemetry on or off, in the per-round loop,
     the compiled scan, and no-trace mode, for static and Markov
     channels;
  2. per-client metric agreement — the ``(K, n)`` vectors from the
     compiled scan match the per-round loop exactly, and the no-trace
     in-scan sampler's vectors match an exact host-side replication of
     its PRNG stream;
  3. host tier — the one deduped ``log_rounds`` append path keeps the
     TrainLog facade bitwise-compatible with the pre-telemetry casts,
     sinks receive well-formed event streams (JSONL round-trip, CSV,
     NaN health events, monotonic ``seq``), and the run manifest digest
     is stable;
  4. timing tier — fenced throughput, recompile detection, and the
     profiler window state machine;
  5. the production lowering — ``build_step(telemetry=True)`` lowers
     with the streak operand and client-axis vector shardings on the
     1-device mesh (where every rule degenerates to replication).
"""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.channel import MarkovChannel, StaticChannel, gilbert_elliott
from repro.core import optimize_weights, topology
from repro.data import quadratic_problem
from repro.data.pipeline import ClientDataset
from repro.fl import FLTrainer
from repro.telemetry import (
    SCALAR_STREAMS,
    VECTOR_METRICS,
    CompileTracker,
    CsvSummarySink,
    JsonlSink,
    MemorySink,
    MetricsLogger,
    ProfileWindow,
    RunManifest,
    ThroughputMeter,
    config_digest,
    git_sha,
    init_streak,
    update_streak,
)

N = 10


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------

_PROB = quadratic_problem(N, 16, mu=1.0, L=8.0, hetero=1.0, seed=0)
_H = jnp.asarray(_PROB["H"], jnp.float32)
_MODEL = topology.paper_fig2a()
_A = optimize_weights(_MODEL, sweeps=10, fine_tune_sweeps=10).A


def _loss_fn(params, batch):
    x = params["x"]
    d = x - batch["center"][0]
    return 0.5 * d @ (_H @ d) + 0.1 * batch["noise"][0] @ x, {}


def _clients():
    out = []
    for i in range(N):
        c = _PROB["centers"][i].astype(np.float32)
        pool = np.random.default_rng(100 + i).normal(
            size=(2048, 16)).astype(np.float32)
        out.append(ClientDataset({"center": np.tile(c, (2048, 1)),
                                  "noise": pool}, batch_size=1, seed=7 + i))
    return out


def _trainer(*, telemetry=False, metrics=None, channel=None, profile=None,
             strategy="colrel"):
    from repro.optim import sgd, sgd_momentum

    return FLTrainer(_loss_fn, {"x": jnp.zeros(16)}, _MODEL, _A, _clients(),
                     sgd(0.02), sgd_momentum(1.0, beta=0.0), local_steps=4,
                     strategy=strategy, seed=0, telemetry=telemetry,
                     metrics=metrics, channel=channel, profile=profile)


def _markov():
    return MarkovChannel(gilbert_elliott(_MODEL, memory=0.8), seed=3)


def _assert_scalars_bitwise(a, b):
    for field in ("rounds", "loss", "participation", "uplink_bits",
                  "weight_sums"):
        av, bv = getattr(a.log, field), getattr(b.log, field)
        assert len(av) == len(bv), field
        for x, y in zip(av, bv):
            assert x == y or (np.isnan(x) and np.isnan(y)), (field, x, y)
    np.testing.assert_array_equal(np.asarray(a.params["x"]),
                                  np.asarray(b.params["x"]))


def _expected_streak(part: np.ndarray) -> np.ndarray:
    """Roll the outage-streak recurrence over a (R, n) participation
    history on host (the reference the device carry must match)."""
    out = np.zeros_like(part, dtype=np.int64)
    age = np.zeros(part.shape[1], np.int64)
    for r in range(part.shape[0]):
        age = np.where(part[r] > 0, 0, age + 1)
        out[r] = age
    return out


# ---------------------------------------------------------------------------
# 1. device tier
# ---------------------------------------------------------------------------


def test_streak_recurrence():
    s = init_streak(4)
    assert s.dtype == jnp.int32 and s.shape == (4,)
    s = update_streak(s, jnp.asarray([1.0, 0.0, 0.0, 1.0]))
    np.testing.assert_array_equal(np.asarray(s), [0, 1, 1, 0])
    s = update_streak(s, jnp.asarray([0.0, 0.0, 1.0, 1.0]))
    np.testing.assert_array_equal(np.asarray(s), [1, 2, 0, 0])
    assert s.dtype == jnp.int32  # carry stays shape/dtype-stable


def test_instrumented_round_is_inert():
    """Telemetry on vs off: identical params and scalar streams, plus
    correct vector metrics (per-round loop)."""
    base = _trainer()
    base.run(6)
    tel = _trainer(telemetry=True)
    tel.run(6)
    _assert_scalars_bitwise(base, tel)
    part = tel.metrics.vector("client_participation")
    bits = tel.metrics.vector("client_uplink_bits")
    streak = tel.metrics.vector("outage_streak")
    assert part.shape == bits.shape == streak.shape == (6, N)
    # scalar streams are exact reductions of the vector streams
    np.testing.assert_array_equal(
        part.sum(axis=1), np.float64(np.float32(base.log.participation)))
    np.testing.assert_allclose(
        bits.sum(axis=1), np.asarray(base.log.uplink_bits), rtol=1e-6)
    np.testing.assert_array_equal(streak, _expected_streak(part))
    # participation vectors are 0/1 realizations
    assert set(np.unique(part)) <= {0.0, 1.0}


@pytest.mark.parametrize("channel_fn", [None, _markov],
                         ids=["static", "markov"])
def test_loop_vs_scan_telemetry_bitwise(channel_fn):
    """chunk=K with telemetry reproduces the per-round loop bitwise —
    scalars AND per-client vectors — under static and Markov channels."""
    ch = channel_fn() if channel_fn else None
    loop = _trainer(telemetry=True, channel=channel_fn() if channel_fn else None)
    loop.run(8)
    chunked = _trainer(telemetry=True, channel=ch)
    chunked.run(8, chunk=4)
    _assert_scalars_bitwise(loop, chunked)
    for name in VECTOR_METRICS:
        np.testing.assert_array_equal(
            loop.metrics.vector(name), chunked.metrics.vector(name), err_msg=name)


def test_chunked_telemetry_off_matches_pre_telemetry_golden():
    """The telemetry-off chunked path is still bitwise-identical to the
    per-round loop (the satellite-1 dedupe changed the append code)."""
    a = _trainer()
    a.run(7)  # odd round count: chunk path + tail remainder
    b = _trainer()
    b.run(7, chunk=3)
    _assert_scalars_bitwise(a, b)


def test_no_trace_matches_host_replication_of_sampler():
    """No-trace telemetry vectors equal an exact host-side replay of the
    in-scan sampler's PRNG stream (same splits the trainer performs)."""
    ch = _markov()
    t = _trainer(telemetry=True, channel=ch)
    t.run(8, chunk=4, no_trace=True)
    part = t.metrics.vector("client_participation")
    streak = t.metrics.vector("outage_streak")

    init_fn, sample_fn = _markov().scan_sampler()
    key = jax.random.PRNGKey(0)  # trainer seed
    key, sub = jax.random.split(key)
    state = init_fn(sub)
    expect = []
    for _ in range(8):
        key, sub = jax.random.split(key)
        tu, td, state = sample_fn(state, sub)
        expect.append(np.asarray(tu, np.float32))
    expect = np.stack(expect)
    np.testing.assert_array_equal(part, expect)
    np.testing.assert_array_equal(streak, _expected_streak(expect))


def test_streak_carries_across_chunk_and_mode_boundaries():
    """The streak age survives host syncs: a run split across run()
    calls and chunk boundaries equals one uninterrupted run."""
    whole = _trainer(telemetry=True)
    whole.run(8, chunk=4)
    split = _trainer(telemetry=True)
    split.run(4)           # per-round loop...
    split.run(4, chunk=4)  # ...hands the streak to the compiled scan
    np.testing.assert_array_equal(whole.metrics.vector("outage_streak"),
                                  split.metrics.vector("outage_streak"))
    _assert_scalars_bitwise(whole, split)


# ---------------------------------------------------------------------------
# 3. host tier
# ---------------------------------------------------------------------------


def test_log_rounds_cast_matches_legacy_paths():
    """The deduped cast equals both pre-telemetry casts: per-round
    ``float(x)`` and chunked ``np.asarray(x, np.float64).tolist()``."""
    vals = np.asarray([0.1, 2.5, np.float32(1) / 3], np.float32)
    logger = MetricsLogger()
    logger.log_rounds(0, {"loss": vals[0]})          # per-round shape ()
    logger.log_rounds(1, {"loss": vals[1:]}, k=2)    # chunk shape (2,)
    assert logger.log.loss == [float(v) for v in vals]
    assert logger.log.loss == np.asarray(vals, np.float64).tolist()
    assert logger.log.rounds == [0, 1, 2]


def test_round_events_and_seq_monotonic():
    sink = MemorySink()
    logger = MetricsLogger([sink])
    logger.log_rounds(0, {"loss": np.float32(1.0),
                          "participation": np.float32(3.0)})
    logger.log_eval(0, {"acc": 0.5})
    logger.log_timing(0, 4, 2.0)
    seqs = [e["seq"] for e in sink.events]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    ev = sink.of_kind("round")[0]
    assert ev["round"] == 0 and ev["loss"] == 1.0 and ev["participation"] == 3.0
    assert sink.of_kind("timing")[0]["rounds_per_sec"] == 2.0


def test_nan_loss_emits_health_event():
    sink = MemorySink()
    logger = MetricsLogger([sink])
    logger.log_rounds(4, {"loss": np.asarray([1.0, np.nan], np.float32)}, k=2)
    nan_ev = sink.of_kind("health.nan")
    assert len(nan_ev) == 1 and nan_ev[0]["round"] == 5
    # the value still lands in the facade (bitwise compatibility)
    assert len(logger.log.loss) == 2 and np.isnan(logger.log.loss[1])


def test_jsonl_sink_round_trip(tmp_path):
    path = tmp_path / "events.jsonl"
    sink = JsonlSink(path, buffer=2)
    logger = MetricsLogger([sink])
    for r in range(5):
        logger.log_rounds(r, {"loss": np.float32(r)})
    logger.close()
    events = JsonlSink.load(path)
    rounds = [e for e in events if e["event"] == "round"]
    assert [e["round"] for e in rounds] == list(range(5))
    assert all(json.dumps(e) for e in events)  # every line valid JSON


def test_csv_summary_sink(tmp_path):
    path = tmp_path / "rounds.csv"
    logger = MetricsLogger([CsvSummarySink(path)])
    logger.log_rounds(0, {"loss": np.float32(1.5),
                          "participation": np.float32(2.0),
                          "uplink_bits": np.float32(8.0),
                          "weight_sum": np.float32(1.0),
                          "weight_drift": np.float32(0.0)})
    logger.close()
    lines = path.read_text().strip().splitlines()
    assert lines[0].startswith("round,loss,participation")
    assert lines[1].split(",")[0] == "0" and float(lines[1].split(",")[1]) == 1.5


def test_client_summary_and_vectors_npz(tmp_path):
    sink = MemorySink()
    logger = MetricsLogger([sink])
    part = np.asarray([[1, 0], [0, 0], [1, 1]], np.float32)
    for r in range(3):
        logger.log_rounds(r, {
            "loss": np.float32(0.0),
            "client_participation": part[r],
            "client_uplink_bits": part[r] * 32.0,
            "outage_streak": _expected_streak(part)[r],
        })
    p = logger.save_vectors(tmp_path / "vectors.npz")
    logger.close()
    summ = sink.of_kind("summary.clients")[0]
    assert summ["participation_count"] == [2, 1]
    assert summ["outage_streak_max"] == [1, 2]
    loaded = np.load(p)
    np.testing.assert_array_equal(loaded["client_participation"], part)


def test_manifest_digest_and_write(tmp_path):
    cfg = {"b": 1, "a": [1, 2], "arr": np.arange(3), "f": np.float32(0.5)}
    d1 = config_digest(cfg)
    d2 = config_digest({"a": [1, 2], "f": np.float32(0.5),
                        "arr": np.arange(3), "b": 1})
    assert d1 == d2  # key order independent
    assert d1 != config_digest({**cfg, "b": 2})
    m = RunManifest.collect(cfg, strategy="colrel", channel="markov",
                            codec="int8", mesh_shape={"data": 1},
                            n_clients=4)
    assert m.backend == jax.default_backend()
    assert m.jax_version == jax.__version__
    assert m.config_digest == d1
    p = m.write(tmp_path)
    loaded = json.loads(p.read_text())
    assert loaded["strategy"] == "colrel" and loaded["codec"] == "int8"
    assert loaded["extra"]["n_clients"] == 4
    # this repo is a git checkout, so the SHA resolves here
    assert git_sha(str(pathlib.Path(__file__).parent)) is not None


# ---------------------------------------------------------------------------
# 4. timing tier
# ---------------------------------------------------------------------------


def test_throughput_meter_fences():
    meter = ThroughputMeter()
    meter.start()
    x = jnp.ones((256, 256)) @ jnp.ones((256, 256))
    dt = meter.stop(4, fence=x)
    assert dt > 0 and meter.total_rounds == 4
    assert meter.rounds_per_sec() == pytest.approx(4 / dt)
    with pytest.raises(RuntimeError):
        meter.stop(1)


def test_compile_tracker_detects_retrace():
    calls = jax.jit(lambda x: x * 2)
    tracker = CompileTracker()
    tracker.register("f", calls)
    calls(jnp.zeros(3))
    assert tracker.check() == {"f": 1}  # first (expected) compile
    calls(jnp.zeros(3))
    assert tracker.check() == {}       # steady state: cache hit
    calls(jnp.zeros(5))                # new shape: retrace
    assert tracker.check() == {"f": 1}
    assert tracker.compile_counts()["f"] == 2


def test_profile_window_state_machine(monkeypatch):
    calls = []
    monkeypatch.setattr(jax.profiler, "start_trace",
                        lambda d: calls.append(("start", d)))
    monkeypatch.setattr(jax.profiler, "stop_trace",
                        lambda: calls.append(("stop", None)))
    w = ProfileWindow("/tmp/prof", start=4, rounds=4)
    assert not w.maybe_start(0) and calls == []
    assert w.maybe_start(4) and calls == [("start", "/tmp/prof")]
    assert w.maybe_start(6)            # still capturing, no double-start
    assert not w.maybe_stop(6)         # window not yet past r=8
    assert w.maybe_stop(8) and calls[-1] == ("stop", None)
    assert not w.maybe_start(12)       # one-shot: never restarts
    w2 = ProfileWindow("/tmp/prof", start=0, rounds=2)
    w2.maybe_start(0)
    w2.close()                         # force-stop a dangling window
    assert calls[-1] == ("stop", None) and w2.done
    with pytest.raises(ValueError):
        ProfileWindow("/tmp/prof", rounds=0)


def test_trainer_emits_timing_and_registers_compiles():
    sink = MemorySink()
    t = _trainer(telemetry=True, metrics=MetricsLogger([sink]))
    t.run(4, chunk=2)
    timing = sink.of_kind("timing")
    assert [e["round0"] for e in timing] == [0, 2]
    assert all(e["rounds"] == 2 and e["seconds"] > 0 for e in timing)
    assert t.meter.total_rounds == 4
    # the scan fn compiled exactly once; its expected first compile is
    # filtered, so no recompile health events
    assert t.compiles.compile_counts()["scan_fn"] == 1
    assert sink.of_kind("health.recompile") == []


# ---------------------------------------------------------------------------
# 5. production lowering (1-device mesh; rules degenerate to replication)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scan_rounds", [None, 2], ids=["per_round", "scan"])
def test_build_step_telemetry_lowers(scan_rounds):
    from repro.configs.base import get_arch
    from repro.launch.steps import build_step

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_arch("qwen3-0.6b").smoke()
    step, lower_args, in_sh, out_sh = build_step(
        "qwen3-0.6b", "train_4k", mesh, scan_rounds=scan_rounds,
        cfg_override=cfg, telemetry=True)
    C = lower_args[4].shape[-1]
    assert lower_args[-1].shape == (C,) and lower_args[-1].dtype == jnp.int32
    # out tree: (params, server_state, agg_state, streak, metrics)
    assert len(out_sh) == 5
    metrics_sh = out_sh[4]
    for name in VECTOR_METRICS:
        assert name in metrics_sh, name
    assert "weight_drift" in metrics_sh
    with mesh:
        jax.jit(step, in_shardings=in_sh, out_shardings=out_sh).lower(*lower_args)


def test_telemetry_rule_shards_client_axis():
    """On a mesh with a real client axis the (n,) streak shards over it;
    the scan variant skips the leading K axis."""
    from repro.launch.sharding import telemetry_rule

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rule = telemetry_rule()
    spec = rule.spec("streak", (8,), mesh)
    assert spec == jax.sharding.PartitionSpec(None)  # 1-device: replicated
    scan_rule = telemetry_rule(scan=True)
    spec = scan_rule.spec("outage_streak", (4, 8), mesh)
    assert spec == jax.sharding.PartitionSpec(None, None)


# ---------------------------------------------------------------------------
# experiment-level wiring
# ---------------------------------------------------------------------------


def test_experiment_spec_telemetry_wiring(tmp_path):
    from repro.fl import ExperimentSpec, build_experiment

    spec = ExperimentSpec(model="quadratic", topology="fig2a", rounds=4,
                          chunk=2, metrics_dir=str(tmp_path / "m"))
    exp = build_experiment(spec)
    assert exp.trainer.telemetry  # metrics_dir implies the device tier
    assert exp.manifest is not None
    assert (tmp_path / "m" / "manifest.json").exists()
    exp.run()
    exp.close()
    assert (tmp_path / "m" / "vectors.npz").exists()
    events = JsonlSink.load(tmp_path / "m" / "events.jsonl")
    kinds = {e["event"] for e in events}
    assert {"round", "timing", "summary.clients"} <= kinds
    assert len([e for e in events if e["event"] == "round"]) == 4
    man = json.loads((tmp_path / "m" / "manifest.json").read_text())
    assert man["config"]["model"] == "quadratic"
    assert man["config_digest"] == config_digest(man["config"])
