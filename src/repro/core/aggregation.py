"""PS aggregation strategies: ColRel and the paper's three FedAvg baselines.

All strategies consume *stacked per-client updates* ``(n, d)`` plus the
round's sampled connectivity, and return the global delta the PS applies.
They are pure JAX functions (jit/vmap/pjit friendly); the tau masks enter
as traced arrays so one compiled step serves every round.
"""

from __future__ import annotations

import enum
from typing import Optional

import jax
import jax.numpy as jnp

from . import relay as _relay

__all__ = ["Aggregation", "aggregate"]


class Aggregation(str, enum.Enum):
    """Paper Sec. V strategies."""

    COLREL = "colrel"                  # the paper's scheme (faithful path)
    COLREL_FUSED = "colrel_fused"      # exact fused weighted-reduction path
    FEDAVG_PERFECT = "fedavg_perfect"  # upper bound: everyone always arrives
    FEDAVG_BLIND = "fedavg_blind"      # sum of arrivals / n (OAC-style)
    FEDAVG_NONBLIND = "fedavg_nonblind"  # sum of arrivals / #arrivals


def aggregate(
    strategy: Aggregation | str,
    updates: jax.Array,
    *,
    tau_up: jax.Array,
    tau_dd: Optional[jax.Array] = None,
    A: Optional[jax.Array] = None,
) -> jax.Array:
    """Global delta ``(d,)`` from stacked client updates ``(n, d)``."""
    strategy = Aggregation(strategy)
    n = updates.shape[0]
    t = tau_up.astype(updates.dtype)

    if strategy == Aggregation.FEDAVG_PERFECT:
        return jnp.mean(updates, axis=0)
    if strategy == Aggregation.FEDAVG_BLIND:
        return (t @ updates) / n
    if strategy == Aggregation.FEDAVG_NONBLIND:
        k = jnp.maximum(jnp.sum(t), 1.0)
        return (t @ updates) / k
    if A is None or tau_dd is None:
        raise ValueError(f"{strategy} needs A and tau_dd")
    return _relay.colrel_round_delta(
        updates, A, tau_up, tau_dd, fused=strategy == Aggregation.COLREL_FUSED
    )
