"""Legacy aggregation entry points, now thin shims over ``repro.strategies``.

``Aggregation`` survives as the enum of the paper's five original
schemes; every value resolves through the open strategy registry
(``repro.strategies``), which is where new schemes (multi-hop relaying,
memory gossip, ...) register without touching this module.  The
``COLREL_FUSED`` value is deprecated — it was one of two spellings of
the same choice (the other being ``RoundConfig.use_fused_kernel``); both
forward, with a warning, to the ``fused`` execution option of the single
``colrel`` strategy.
"""

from __future__ import annotations

import enum
from typing import Optional

import jax

__all__ = ["Aggregation", "aggregate"]


class Aggregation(str, enum.Enum):
    """Paper Sec. V strategies (registry names; open set lives in
    ``repro.strategies.available()``)."""

    COLREL = "colrel"                  # the paper's scheme (faithful path)
    COLREL_FUSED = "colrel_fused"      # DEPRECATED: colrel with fused=True
    FEDAVG_PERFECT = "fedavg_perfect"  # upper bound: everyone always arrives
    FEDAVG_BLIND = "fedavg_blind"      # sum of arrivals / n (OAC-style)
    FEDAVG_NONBLIND = "fedavg_nonblind"  # sum of arrivals / #arrivals


def aggregate(
    strategy,
    updates: jax.Array,
    *,
    tau_up: jax.Array,
    tau_dd: Optional[jax.Array] = None,
    A: Optional[jax.Array] = None,
) -> jax.Array:
    """Global delta ``(d,)`` from stacked client updates ``(n, d)``.

    ``strategy`` is a registry name, ``Aggregation`` value, or an
    :class:`~repro.strategies.AggregationStrategy` instance (stateless
    call: carried state is initialized and discarded — use the strategy
    object directly to thread state across rounds).
    """
    from repro import strategies as _strategies  # deferred: core loads first

    s = _strategies.resolve(strategy)
    if s.needs_A and (A is None or tau_dd is None):
        raise ValueError(f"{s.name} needs A and tau_dd")
    state = s.init_state(updates.shape[0], updates.shape[-1])
    delta, _ = s.aggregate(updates, tau_up, tau_dd, A, state)
    return delta
