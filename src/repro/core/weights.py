"""Consensus-weight optimization (COPT-alpha, Algorithm 3 of the paper).

The PS update variance is controlled (Theorem 1) by

    S(p, P, A) =   sum_{i,j,l} p_j (1-p_j) p_ij p_lj  alpha_ji alpha_jl
                 + sum_{i,j}   p_ij p_j (1-p_ij)      alpha_ji^2
                 + sum_{i,l}   p_i p_l (E_il - p_il p_li) alpha_il alpha_li

subject to the unbiasedness condition (Eq. (5))

    sum_j p_j p_ij alpha_ji = 1            for every i,     alpha >= 0.

``S`` is non-convex due to the reciprocity cross terms; the paper first
minimizes the convex upper bound ``Sbar`` (cross terms alpha_il alpha_li
replaced by alpha_li^2), then fine-tunes ``S`` from that warm start.  Both
phases are Gauss–Seidel sweeps over the *columns* of A (column i = the
weights everyone assigns to client i's update); each column subproblem has a
closed-form KKT solution parameterized by a Lagrange multiplier found by
bisection (Appendix E).

Index conventions (see ``connectivity.py``): ``A[j, i] = alpha_ji`` is the
weight client j gives to client i's update; ``P[i, j] = p_ij`` is the i->j
link probability.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from .blocks import ClusteredLinkModel
from .connectivity import LinkModel

__all__ = [
    "variance_S",
    "variance_Sbar",
    "unbiasedness_residual",
    "is_unbiased",
    "initial_weights",
    "fedavg_weights",
    "optimize_weights",
    "optimize_weights_clustered",
    "unbiasedness_residual_clustered",
    "is_unbiased_clustered",
    "OptResult",
    "ClusteredOptResult",
]

# ---------------------------------------------------------------------------
# The variance functionals and the unbiasedness condition
# ---------------------------------------------------------------------------


def _terms(model: LinkModel, A: np.ndarray):
    p, P, E = model.p, model.P, model.E
    A = np.asarray(A, dtype=np.float64)
    # q_j = sum_i p_ij alpha_ji  = row j of A dotted with column j of P
    q = np.einsum("ij,ji->j", P, A)
    term1 = float(np.sum(p * (1.0 - p) * q * q))
    # sum_{i,j} p_ij p_j (1 - p_ij) alpha_ji^2
    term2 = float(np.einsum("ij,j,ij,ji->", P, p, 1.0 - P, A * A))
    # reciprocity coupling, E_il - p_il p_li
    D = E - P * P.T
    return term1, term2, D, A, p


def variance_S(model: LinkModel, A: np.ndarray) -> float:
    """The exact (possibly non-convex) variance proxy S(p, P, A)."""
    term1, term2, D, A, p = _terms(model, A)
    term3 = float(np.einsum("i,l,il,il,li->", p, p, D, A, A))
    return term1 + term2 + term3


def variance_Sbar(model: LinkModel, A: np.ndarray) -> float:
    """The convex upper bound Sbar >= S (Lemma 2)."""
    term1, term2, D, A, p = _terms(model, A)
    term3 = float(np.einsum("i,l,il,li->", p, p, D, A * A))
    return term1 + term2 + term3


def unbiasedness_residual(model: LinkModel, A: np.ndarray) -> np.ndarray:
    """Per-client residual of condition (5): sum_j p_j p_ij alpha_ji - 1."""
    A = np.asarray(A, dtype=np.float64)
    # c_i = sum_j p_j * P[i, j] * A[j, i]
    return np.einsum("j,ij,ji->i", model.p, model.P, A) - 1.0


def is_unbiased(model: LinkModel, A: np.ndarray, atol: float = 1e-8) -> bool:
    return bool(np.max(np.abs(unbiasedness_residual(model, A))) <= atol)


# ---------------------------------------------------------------------------
# Baseline weight matrices
# ---------------------------------------------------------------------------


def initial_weights(model: LinkModel) -> np.ndarray:
    """Algorithm 3 line 1 initialization (feasible for (5) by construction):

        alpha_ji^(0) = 1 / (|{k : p_k p_ik > 0}| * p_j * p_ij)
                       if p_j > 0 and p_ij > 0 else 0.
    """
    p, P = model.p, model.P
    n = model.n
    mask = (p[None, :] > 0) & (P > 0)  # mask[i, j]: j can relay for i
    counts = mask.sum(axis=1).astype(np.float64)  # per column-owner i
    A = np.zeros((n, n))
    for i in range(n):
        if counts[i] == 0:
            continue  # client i is unreachable; no feasible weights exist
        js = np.nonzero(mask[i])[0]
        A[js, i] = 1.0 / (counts[i] * p[js] * P[i, js])
    return A


def fedavg_weights(n: int) -> np.ndarray:
    """No relaying: alpha_ii = 1, alpha_ij = 0 (i != j).

    Note this equals the paper's *blind FedAvg* baseline and is biased
    whenever p_i < 1 (it violates (5) unless scaled by 1/p_i)."""
    return np.eye(n)


def importance_weights(model: LinkModel) -> np.ndarray:
    """No relaying but unbiased: alpha_ii = 1 / p_i (importance sampling)."""
    with np.errstate(divide="ignore"):
        d = np.where(model.p > 0, 1.0 / np.maximum(model.p, 1e-300), 0.0)
    return np.diag(d)


# ---------------------------------------------------------------------------
# Column subproblem: closed form + bisection on lambda (Appendix E)
# ---------------------------------------------------------------------------


def _solve_column(
    model: LinkModel,
    A: np.ndarray,
    i: int,
    *,
    fine_tune: bool,
    tol: float = 1e-12,
    max_bisect: int = 200,
) -> np.ndarray:
    """Minimize over column i (variables x_j = alpha_ji) with others fixed.

    Implements Eq. (11) (convex relaxation of Sbar) when ``fine_tune`` is
    False and Eq. (14) (the S objective) when True.
    """
    p, P, E = model.p, model.P, model.E
    n = model.n
    x = np.zeros(n)

    w = p * P[i, :]  # w_j = p_j * p_ij, the constraint coefficients
    if np.max(w) <= 0.0:
        return x  # client i unreachable: infeasible column, leave zero

    # Perfect links shortcut (second case of (11)/(14)).
    perfect = np.isclose(w, 1.0)
    if perfect.any():
        x[perfect] = 1.0 / perfect.sum()
        return x

    active = w > 0.0  # j's that can carry weight for i
    ja = np.nonzero(active)[0]

    # c_j = sum_{l != i} p_lj alpha_jl  (current values of other columns)
    c = np.einsum("lj,jl->j", P, A) - P[i, :] * A[:, i]

    if not fine_tune:
        # denominators 2[(1 - p_j p_ij) + p_i (E_ij / p_ij - p_ji)]
        recip = np.zeros(n)
        recip[ja] = model.p[i] * (E[i, ja] / P[i, ja] - P[ja, i])
        denom = 2.0 * ((1.0 - w) + recip)
        shift = 2.0 * (1.0 - p) * c
    else:
        recip = np.zeros(n)
        recip[ja] = model.p[i] * (E[i, ja] / P[i, ja] - P[ja, i])
        denom = 2.0 * (1.0 - w)
        # extra cross term with the (fixed) reverse weights alpha_ij = A[i, j]
        shift = 2.0 * (1.0 - p) * c + 2.0 * recip * A[i, :]

    denom = np.where(active, denom, np.inf)

    def x_of(lam: float) -> np.ndarray:
        v = np.where(active, np.maximum(lam - shift, 0.0) / denom, 0.0)
        return v

    def g(lam: float) -> float:
        return float(np.sum(w * x_of(lam)))

    # Bisection for g(lam) = 1.  g is nondecreasing, g(0) may be 0.
    lo = 0.0
    hi = float(np.max(shift[ja]) + np.max(denom[ja]) / np.min(w[ja])) + 1.0
    while g(hi) < 1.0:
        hi *= 2.0
        if hi > 1e18:
            raise RuntimeError("bisection failed to bracket lambda")
    for _ in range(max_bisect):
        mid = 0.5 * (lo + hi)
        if g(mid) < 1.0:
            lo = mid
        else:
            hi = mid
        if hi - lo <= tol * max(1.0, hi):
            break
    x = x_of(hi)
    s = float(np.sum(w * x))
    if s > 0:
        x = x / s  # exact feasibility (removes residual bisection error)
    return x


# ---------------------------------------------------------------------------
# Algorithm 3 (COPT-alpha)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class OptResult:
    A: np.ndarray
    S: float
    Sbar: float
    S_init: float
    history: list  # (phase, sweep, S value) tuples
    converged: bool


def optimize_weights(
    model: LinkModel,
    *,
    sweeps: int = 50,
    fine_tune_sweeps: int = 50,
    tol: float = 1e-10,
    init: Optional[np.ndarray] = None,
    callback: Optional[Callable[[str, int, float], None]] = None,
) -> OptResult:
    """COPT-alpha: Gauss–Seidel on Sbar, then fine-tune S (Algorithm 3).

    One "sweep" updates every column once (the paper's iteration counter
    ``ell`` advances one column at a time; ``sweeps`` = ell / n).
    """
    A = initial_weights(model) if init is None else np.asarray(init, float).copy()
    S_init = variance_S(model, A)
    history: list = []
    converged = False

    def _phase(n_sweeps: int, fine_tune: bool, tag: str, A: np.ndarray):
        nonlocal converged
        f = variance_S if fine_tune else variance_Sbar
        prev = f(model, A)
        for s in range(n_sweeps):
            for i in range(model.n):
                A[:, i] = _solve_column(model, A, i, fine_tune=fine_tune)
            cur = f(model, A)
            history.append((tag, s, cur))
            if callback is not None:
                callback(tag, s, cur)
            if abs(prev - cur) <= tol * max(1.0, abs(prev)):
                converged = True
                return A
            prev = cur
        return A

    A = _phase(sweeps, fine_tune=False, tag="relax", A=A)
    A = _phase(fine_tune_sweeps, fine_tune=True, tag="fine", A=A)
    return OptResult(
        A=A,
        S=variance_S(model, A),
        Sbar=variance_Sbar(model, A),
        S_init=S_init,
        history=history,
        converged=converged,
    )


# ---------------------------------------------------------------------------
# Block-clustered COPT-alpha: the O(n²) -> O(C·m²) decomposition
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ClusteredOptResult:
    Ab: np.ndarray            # (C, m, m) optimized per-cluster weights
    S: float                  # total variance proxy, sum over clusters
    Sbar: float
    S_init: float
    per_cluster: list         # the C individual OptResults
    converged: bool           # all clusters converged


def optimize_weights_clustered(
    model: ClusteredLinkModel,
    *,
    sweeps: int = 50,
    fine_tune_sweeps: int = 50,
    tol: float = 1e-10,
    init: Optional[np.ndarray] = None,
    callback: Optional[Callable[[int, str, int, float], None]] = None,
) -> ClusteredOptResult:
    """COPT-alpha on a block-diagonal model: one independent per-cluster
    Gauss–Seidel per block, O(C·m²) column solves instead of O(n²).

    The decomposition is *exact*, not an approximation: with p_ij = 0
    across clusters, the unbiasedness constraint for column i only has
    support inside i's cluster (its coefficients are ``p_j p_ij``), and
    every coupling term of S / Sbar carries a factor of ``p_ij`` or
    ``E_il`` that vanishes across clusters — so the dense objective is a
    sum of per-cluster objectives and Gauss–Seidel never mixes blocks.
    ``tests/test_clustered.py`` pins block-vs-dense equality per column.

    ``init`` may be a (C, m, m) block warm start; ``callback`` receives
    ``(cluster, phase, sweep, value)``.  S / Sbar / S_init are the
    dense-equivalent totals (sums over clusters).
    """
    C, m = model.C, model.m
    Ab = np.zeros((C, m, m))
    per_cluster: list = []
    if init is not None:
        init = np.asarray(init, dtype=np.float64)
        if init.shape != (C, m, m):
            raise ValueError(f"init must be ({C}, {m}, {m}), got {init.shape}")
    for c in range(C):
        sub = model.cluster_model(c)
        cb = None
        if callback is not None:
            cb = lambda tag, s, v, _c=c: callback(_c, tag, s, v)
        res = optimize_weights(
            sub,
            sweeps=sweeps,
            fine_tune_sweeps=fine_tune_sweeps,
            tol=tol,
            init=None if init is None else init[c],
            callback=cb,
        )
        Ab[c] = res.A
        per_cluster.append(res)
    return ClusteredOptResult(
        Ab=Ab,
        S=float(sum(r.S for r in per_cluster)),
        Sbar=float(sum(r.Sbar for r in per_cluster)),
        S_init=float(sum(r.S_init for r in per_cluster)),
        per_cluster=per_cluster,
        converged=all(r.converged for r in per_cluster),
    )


def unbiasedness_residual_clustered(
    model: ClusteredLinkModel, Ab: np.ndarray
) -> np.ndarray:
    """Per-client residual of condition (5) on the block form: the dense
    sum over j collapses to j in i's cluster (p_ij = 0 elsewhere)."""
    Ab = np.asarray(Ab, dtype=np.float64)
    C, m = model.C, model.m
    pb = model.p.reshape(C, m)
    # c_i = sum_j p_j * Pb[c, i, j] * Ab[c, j, i]
    return np.einsum("cj,cij,cji->ci", pb, model.Pb, Ab).reshape(C * m) - 1.0


def is_unbiased_clustered(
    model: ClusteredLinkModel, Ab: np.ndarray, atol: float = 1e-8
) -> bool:
    return bool(
        np.max(np.abs(unbiasedness_residual_clustered(model, Ab))) <= atol
    )
