"""The collaborative-relaying consensus operation (paper Eq. (3)) in JAX.

Two mathematically equivalent execution paths:

* **Faithful** (Alg. 1 lines 8-11 + Alg. 2 line 5): materialize each
  client's relayed consensus ``Dx~_i = sum_j tau_ji alpha_ij Dx_j`` (a
  masked-weighted mixing across the client axis — an all-gather in the
  distributed setting), then the PS adds ``(1/n) sum_i tau_i Dx~_i``.
* **Fused** (beyond-paper, exact): collapse both stages into the effective
  per-client scalar weights ``w_j = sum_i tau_i tau_ji alpha_ij`` and a
  single weighted reduction.  Identical output for identical tau draws.

Everything here operates on *stacked dense updates* ``(n, d)``; pytree
plumbing lives in ``repro/fl`` and sharded execution in ``repro/dist``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import connectivity

__all__ = [
    "mixing_matrix",
    "relay_mix",
    "ps_aggregate",
    "effective_weights",
    "fused_round_delta",
    "colrel_round_delta",
]


def mixing_matrix(A: jax.Array, tau_dd: jax.Array) -> jax.Array:
    """M[i, j] = alpha_ij * tau_ji — the realized consensus matrix.

    ``Dx~ = M @ Dx`` reproduces Eq. (3):  Dx~_i = sum_j tau_ji alpha_ij Dx_j.
    ``tau_dd[j, i]`` is the indicator that j's broadcast reached i.
    """
    return A * tau_dd.T


def relay_mix(updates: jax.Array, A: jax.Array, tau_dd: jax.Array) -> jax.Array:
    """Faithful local consensus: (n, d) -> (n, d), Dx~ = (A * tau_dd^T) Dx."""
    M = mixing_matrix(A.astype(updates.dtype), tau_dd.astype(updates.dtype))
    return M @ updates


def ps_aggregate(updates_tilde: jax.Array, tau_up: jax.Array) -> jax.Array:
    """Blind PS sum (Alg. 2 line 5, without the +x^(r)):
    (1/n) sum_i tau_i Dx~_i."""
    n = updates_tilde.shape[0]
    return (tau_up.astype(updates_tilde.dtype) @ updates_tilde) / n


def effective_weights(A: jax.Array, tau_up: jax.Array, tau_dd: jax.Array) -> jax.Array:
    """w_j = sum_i tau_i tau_ji alpha_ij — device twin of the canonical
    ``repro.core.effective_weights`` (numpy), delegating to the single
    shared contraction spec so the two can never drift."""
    return jnp.einsum(connectivity.EFFECTIVE_WEIGHTS_EINSUM, tau_up, A, tau_dd)


def fused_round_delta(updates: jax.Array, w: jax.Array) -> jax.Array:
    """(1/n) sum_j w_j Dx_j — the fused relay+aggregate reduction."""
    n = updates.shape[0]
    return (w.astype(updates.dtype) @ updates) / n


def colrel_round_delta(
    updates: jax.Array,
    A: jax.Array,
    tau_up: jax.Array,
    tau_dd: jax.Array,
    *,
    fused: bool = False,
) -> jax.Array:
    """End-to-end ColRel round delta applied by the PS: (d,) from (n, d)."""
    if fused:
        w = effective_weights(A.astype(jnp.float32), tau_up, tau_dd)
        return fused_round_delta(updates, w)
    tilde = relay_mix(updates, A, tau_dd)
    return ps_aggregate(tilde, tau_up)
