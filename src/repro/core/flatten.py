"""Flatten-once plumbing between pytree model updates and the (n, d) stack.

The ColRel hot path (relay mix + blind PS sum) is pure memory-bound
streaming over the stacked client updates.  Executing it per-leaf costs
one XLA op pair *per pytree leaf* (hundreds for the production archs) and
re-reads the (n, d) stack from HBM leaf by leaf.  Instead, the round
ravels the whole per-client update pytree into a single contiguous
``(n_clients, d)`` buffer **once per round**, streams that buffer through
the fused aggregation kernel exactly once, and unravels the resulting
``(d,)`` PS delta back to the model pytree.

Two ravel executions (DESIGN.md §14):

* **Segmented fill** (:func:`ravel` / :func:`ravel_stacked`) — the
  ``(n, d)`` buffer is pre-allocated once and filled leaf-by-leaf with
  ``dynamic_update_slice``.  Each write is the single consumer of the
  previous buffer value, so XLA updates it in place: the stack is
  materialized exactly once, and any dtype cast happens *per leaf inside
  the fill* (fused into the slice write) instead of materializing a
  second full-size casted copy first.
* **Segment streaming** (:func:`ravel_stacked_segments`) — at large d
  the stack itself is the memory bottleneck; this returns the per-leaf
  ``(n, d_i)`` column segments (reshape + cast only, no buffer at all)
  so the fused kernels can consume leaf buffers directly and the
  monolithic stack never exists.

:func:`ravel_stacked_concat` keeps the pre-segmentation ``concatenate``
implementation as the oracle/baseline (bitwise-identical values) for
``benchmarks/larged_bench.py`` and the segmented-path tests.

``FlatSpec`` is hashable static metadata (leaf shapes + treedef), so the
same spec can key jit caches and be rebuilt for free under tracing.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Any

__all__ = [
    "FlatSpec",
    "flat_spec",
    "ravel",
    "ravel_stacked",
    "ravel_stacked_concat",
    "ravel_stacked_segments",
    "unravel",
    "unravel_stacked",
]


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Static layout of a flattened pytree: where each leaf lives in (d,)."""

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]

    @property
    def sizes(self) -> Tuple[int, ...]:
        return tuple(int(np.prod(s, dtype=np.int64)) for s in self.shapes)

    @property
    def offsets(self) -> Tuple[int, ...]:
        return tuple(int(o) for o in np.cumsum((0,) + self.sizes[:-1]))

    @property
    def d(self) -> int:
        return sum(self.sizes)


def flat_spec(tree: Params, *, stacked: bool = False) -> FlatSpec:
    """Layout spec for ``tree``.  With ``stacked=True`` the leaves carry a
    leading client axis ``(n, *shape)`` that is excluded from the layout."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(
        tuple(leaf.shape[1:] if stacked else leaf.shape) for leaf in leaves
    )
    return FlatSpec(treedef, shapes)


def _cast(part: jax.Array, dtype) -> jax.Array:
    # per-leaf cast, fused into the segment write by XLA — never a full
    # (n, d) casted intermediate
    return part if dtype is None else part.astype(dtype)


def ravel(tree: Params, *, dtype=None) -> jax.Array:
    """Pytree -> contiguous (d,) buffer (leaf order = jax.tree.flatten).

    Segmented fill: the output buffer is allocated once and each leaf is
    written into its column range with ``dynamic_update_slice`` (cast
    folded per leaf), so the flat buffer is materialized exactly once.
    """
    leaves = jax.tree.leaves(tree)
    if len(leaves) == 1:
        return _cast(leaves[0].reshape(-1), dtype)
    parts = [_cast(leaf.reshape(-1), dtype) for leaf in leaves]
    out_dtype = parts[0].dtype
    d = sum(p.shape[0] for p in parts)
    out = jnp.zeros((d,), out_dtype)
    offset = 0
    for p in parts:
        out = jax.lax.dynamic_update_slice(out, p, (offset,))
        offset += p.shape[0]
    return out


def ravel_stacked(tree: Params, *, dtype=None) -> jax.Array:
    """Stacked pytree (leaves ``(n, *shape)``) -> contiguous ``(n, d)``.

    This is the flatten-*once* step of the fused aggregation engine: the
    only materialization of the round's update stack — a segmented
    ``dynamic_update_slice`` fill of one pre-allocated buffer, with any
    dtype cast folded into each leaf's write.
    """
    leaves = jax.tree.leaves(tree)
    n = leaves[0].shape[0]
    if len(leaves) == 1:
        return _cast(leaves[0].reshape(n, -1), dtype)
    parts = [_cast(leaf.reshape(n, -1), dtype) for leaf in leaves]
    out_dtype = parts[0].dtype
    d = sum(p.shape[1] for p in parts)
    out = jnp.zeros((n, d), out_dtype)
    offset = 0
    for p in parts:
        out = jax.lax.dynamic_update_slice(out, p, (0, offset))
        offset += p.shape[1]
    return out


def ravel_stacked_concat(tree: Params, *, dtype=None) -> jax.Array:
    """The pre-segmentation ``concatenate`` ravel (seed path), kept as the
    oracle/baseline: same values bit-for-bit as :func:`ravel_stacked`, but
    the full-size casted parts materialize before the concat — the extra
    copy ``benchmarks/larged_bench.py`` measures against."""
    leaves = jax.tree.leaves(tree)
    n = leaves[0].shape[0]
    parts = [leaf.reshape(n, -1) for leaf in leaves]
    if dtype is not None:
        parts = [p.astype(dtype) for p in parts]
    return jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]


def ravel_stacked_segments(tree: Params, *, dtype=None) -> List[jax.Array]:
    """Stacked pytree -> per-leaf ``(n, d_i)`` column segments, in spec
    order.  Layout-only (reshape + per-leaf cast); the monolithic stack is
    never built — ``jnp.concatenate(segments, axis=1)`` would reproduce
    :func:`ravel_stacked` bitwise.  This is what the segment-streaming
    kernel paths (DESIGN.md §14) consume."""
    leaves = jax.tree.leaves(tree)
    n = leaves[0].shape[0]
    return [_cast(leaf.reshape(n, -1), dtype) for leaf in leaves]


def unravel(spec: FlatSpec, flat: jax.Array, *, dtype: Optional[Any] = None) -> Params:
    """(d,) buffer -> pytree with ``spec``'s structure and leaf shapes."""
    if flat.shape != (spec.d,):
        raise ValueError(f"flat buffer {flat.shape} != spec total ({spec.d},)")
    if dtype is not None:
        flat = flat.astype(dtype)
    leaves = [
        jax.lax.slice(flat, (o,), (o + s,)).reshape(shape)
        for o, s, shape in zip(spec.offsets, spec.sizes, spec.shapes)
    ]
    return jax.tree.unflatten(spec.treedef, leaves)


def unravel_stacked(
    spec: FlatSpec, stack: jax.Array, *, dtype: Optional[Any] = None
) -> Params:
    """``(n, d)`` stack -> stacked pytree (leaves ``(n, *shape)``).

    Exact inverse of :func:`ravel_stacked` for a spec built with
    ``stacked=True`` — column slices are layout-only, so a ravel/unravel
    round trip at matching dtype is bitwise."""
    if stack.ndim != 2 or stack.shape[1] != spec.d:
        raise ValueError(f"stack {stack.shape} != (n, {spec.d})")
    n = stack.shape[0]
    if dtype is not None:
        stack = stack.astype(dtype)
    leaves = [
        jax.lax.slice(stack, (0, o), (n, o + s)).reshape((n,) + shape)
        for o, s, shape in zip(spec.offsets, spec.sizes, spec.shapes)
    ]
    return jax.tree.unflatten(spec.treedef, leaves)
