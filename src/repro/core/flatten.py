"""Flatten-once plumbing between pytree model updates and the (n, d) stack.

The ColRel hot path (relay mix + blind PS sum) is pure memory-bound
streaming over the stacked client updates.  Executing it per-leaf costs
one XLA op pair *per pytree leaf* (hundreds for the production archs) and
re-reads the (n, d) stack from HBM leaf by leaf.  Instead, the round
ravels the whole per-client update pytree into a single contiguous
``(n_clients, d)`` buffer **once per round**, streams that buffer through
the fused aggregation kernel exactly once, and unravels the resulting
``(d,)`` PS delta back to the model pytree.

The ravel is layout-only work (reshape + one concatenate into the
contiguous buffer); the unravel is ``d`` slices.  Both are O(n*d) bytes —
the same traffic a single leaf-wise pass would pay — and everything in
between touches the stack once.

``FlatSpec`` is hashable static metadata (leaf shapes + treedef), so the
same spec can key jit caches and be rebuilt for free under tracing.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Any

__all__ = [
    "FlatSpec",
    "flat_spec",
    "ravel",
    "ravel_stacked",
    "unravel",
    "unravel_stacked",
]


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Static layout of a flattened pytree: where each leaf lives in (d,)."""

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]

    @property
    def sizes(self) -> Tuple[int, ...]:
        return tuple(int(np.prod(s, dtype=np.int64)) for s in self.shapes)

    @property
    def offsets(self) -> Tuple[int, ...]:
        return tuple(int(o) for o in np.cumsum((0,) + self.sizes[:-1]))

    @property
    def d(self) -> int:
        return sum(self.sizes)


def flat_spec(tree: Params, *, stacked: bool = False) -> FlatSpec:
    """Layout spec for ``tree``.  With ``stacked=True`` the leaves carry a
    leading client axis ``(n, *shape)`` that is excluded from the layout."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(
        tuple(leaf.shape[1:] if stacked else leaf.shape) for leaf in leaves
    )
    return FlatSpec(treedef, shapes)


def ravel(tree: Params, *, dtype=None) -> jax.Array:
    """Pytree -> contiguous (d,) buffer (leaf order = jax.tree.flatten)."""
    leaves = jax.tree.leaves(tree)
    parts = [leaf.reshape(-1) for leaf in leaves]
    if dtype is not None:
        parts = [p.astype(dtype) for p in parts]
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def ravel_stacked(tree: Params, *, dtype=None) -> jax.Array:
    """Stacked pytree (leaves ``(n, *shape)``) -> contiguous ``(n, d)``.

    This is the flatten-*once* step of the fused aggregation engine: the
    only materialization of the round's update stack.
    """
    leaves = jax.tree.leaves(tree)
    n = leaves[0].shape[0]
    parts = [leaf.reshape(n, -1) for leaf in leaves]
    if dtype is not None:
        parts = [p.astype(dtype) for p in parts]
    return jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]


def unravel(spec: FlatSpec, flat: jax.Array, *, dtype: Optional[Any] = None) -> Params:
    """(d,) buffer -> pytree with ``spec``'s structure and leaf shapes."""
    if flat.shape != (spec.d,):
        raise ValueError(f"flat buffer {flat.shape} != spec total ({spec.d},)")
    if dtype is not None:
        flat = flat.astype(dtype)
    leaves = [
        jax.lax.slice(flat, (o,), (o + s,)).reshape(shape)
        for o, s, shape in zip(spec.offsets, spec.sizes, spec.shapes)
    ]
    return jax.tree.unflatten(spec.treedef, leaves)


def unravel_stacked(
    spec: FlatSpec, stack: jax.Array, *, dtype: Optional[Any] = None
) -> Params:
    """``(n, d)`` stack -> stacked pytree (leaves ``(n, *shape)``).

    Exact inverse of :func:`ravel_stacked` for a spec built with
    ``stacked=True`` — column slices are layout-only, so a ravel/unravel
    round trip at matching dtype is bitwise."""
    if stack.ndim != 2 or stack.shape[1] != spec.d:
        raise ValueError(f"stack {stack.shape} != (n, {spec.d})")
    n = stack.shape[0]
    if dtype is not None:
        stack = stack.astype(dtype)
    leaves = [
        jax.lax.slice(stack, (0, o), (n, o + s)).reshape((n,) + shape)
        for o, s, shape in zip(spec.offsets, spec.sizes, spec.shapes)
    ]
    return jax.tree.unflatten(spec.treedef, leaves)
