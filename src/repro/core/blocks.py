"""Block-sparse clustered connectivity: the population-scale representation.

The paper's machinery is dense in the client axis — COPT-alpha is O(n²),
the relay mix is an (n, n)×(n, d) contraction, and every tau_dd draw is
an (n, n) tensor.  But relaying is inherently *local*: a client only
mixes with a small neighborhood, so under clustering (C clusters of m
clients, relaying within a cluster, nothing across) the mixing matrix A
is block-diagonal.  This module stores exactly the C diagonal blocks —
``(C, m, m)`` instead of ``(n, n)`` — for the link statistics, the relay
weights, and the per-round tau_dd realizations, which is what makes
n = 2^14+ reachable: memory and flops drop from O(n²) to O(C·m²) =
O(n·m), and every block tensor shards along its leading cluster axis
(the same ``clients`` mesh axis the (n, d) update stack partitions on —
``repro.launch.sharding.client_stack_rule``).

Index conventions match ``connectivity.py`` restricted to a cluster:
``Pb[c, i, j]`` is the D2D success probability from the cluster's i-th
to its j-th client (global ids ``c*m + i`` -> ``c*m + j``), ``Ab[c, i,
j] = alpha_{c*m+i, c*m+j}``, and ``tau_b[c, i, j]`` realizes the
intra-cluster link i -> j.  Cross-cluster links are structurally absent
(p = 0), so the block form is lossless for clustered topologies.

Host-side classes (numpy): :class:`ClusterSpec`, :class:`ClusteredLinkModel`
with dense round-trips for the small-n oracle tests.  Device-side ops
(jnp): the blocked twins of ``core/relay.py`` — per-cluster mixing,
relay mix, effective weights and the end-to-end round delta.  At C = 1
every blocked op is *bitwise identical* to its dense twin (the block
einsum and the dense einsum lower to the same contraction), which is the
correctness anchor ``tests/test_clustered.py`` pins.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .connectivity import LinkModel

__all__ = [
    "ClusterSpec",
    "ClusteredLinkModel",
    "block_diag_from_blocks",
    "blocks_from_dense",
    "block_mixing_matrix",
    "block_relay_mix",
    "block_effective_weights",
    "block_ps_aggregate",
    "block_colrel_round_delta",
]


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """C clusters of m clients each; client i lives in cluster i // m."""

    n: int
    m: int  # cluster size

    def __post_init__(self) -> None:
        if self.m <= 0 or self.n <= 0:
            raise ValueError(f"need positive n, m (got n={self.n}, m={self.m})")
        if self.n % self.m != 0:
            raise ValueError(
                f"cluster size m={self.m} must divide n={self.n} "
                "(pad the population or pick a divisor)"
            )

    @property
    def C(self) -> int:
        return self.n // self.m

    def cluster_of(self, i) -> np.ndarray:
        return np.asarray(i) // self.m

    def pair_indices(self) -> tuple[np.ndarray, np.ndarray]:
        """Within-cluster unordered pair index (iu, ju), both (m(m-1)/2,)."""
        return np.triu_indices(self.m, k=1)


# ---------------------------------------------------------------------------
# dense <-> block conversions (host-side, numpy)
# ---------------------------------------------------------------------------


def blocks_from_dense(X: np.ndarray, spec: ClusterSpec, *,
                      strict: bool = True, atol: float = 0.0) -> np.ndarray:
    """Extract the C diagonal (m, m) blocks of an (n, n) matrix.

    ``strict=True`` refuses matrices with mass outside the diagonal
    blocks (the block form would silently drop it).
    """
    X = np.asarray(X)
    n, m, C = spec.n, spec.m, spec.C
    if X.shape != (n, n):
        raise ValueError(f"expected ({n}, {n}), got {X.shape}")
    Xb = X.reshape(C, m, C, m)
    blocks = np.ascontiguousarray(np.einsum("cidj->cdij", Xb).diagonal(
        axis1=0, axis2=1).transpose(2, 0, 1))
    if strict:
        off = X - block_diag_from_blocks(blocks, spec)
        if np.max(np.abs(off)) > atol:
            raise ValueError(
                "matrix has mass outside the diagonal blocks; the "
                f"(C={C}, m={m}) block form would drop it"
            )
    return blocks


def block_diag_from_blocks(blocks: np.ndarray, spec: ClusterSpec) -> np.ndarray:
    """Scatter (C, m, m) blocks onto a dense block-diagonal (n, n)."""
    blocks = np.asarray(blocks)
    n, m, C = spec.n, spec.m, spec.C
    if blocks.shape != (C, m, m):
        raise ValueError(f"expected ({C}, {m}, {m}), got {blocks.shape}")
    out = np.zeros((n, n), blocks.dtype)
    for c in range(C):
        out[c * m:(c + 1) * m, c * m:(c + 1) * m] = blocks[c]
    return out


@dataclasses.dataclass(frozen=True)
class ClusteredLinkModel:
    """Block-diagonal :class:`LinkModel`: only the C diagonal (m, m)
    blocks of P / E are stored; cross-cluster links are structurally
    zero.  At n = 2^14 this is ~500x less memory than the dense model
    (and the dense form is never materialized on the way in)."""

    p: np.ndarray   # (n,)     uplink success probabilities
    Pb: np.ndarray  # (C, m, m) intra-cluster D2D probabilities, diag == 1
    Eb: np.ndarray  # (C, m, m) intra-cluster reciprocity correlations

    def __post_init__(self) -> None:
        p = np.asarray(self.p, dtype=np.float64)
        Pb = np.asarray(self.Pb, dtype=np.float64)
        Eb = np.asarray(self.Eb, dtype=np.float64)
        if p.ndim != 1 or Pb.ndim != 3 or Pb.shape[1] != Pb.shape[2]:
            raise ValueError(f"bad shapes p{p.shape} Pb{Pb.shape}")
        if Eb.shape != Pb.shape:
            raise ValueError(f"Eb {Eb.shape} != Pb {Pb.shape}")
        C, m, _ = Pb.shape
        if p.shape[0] != C * m:
            raise ValueError(f"p has {p.shape[0]} clients, blocks give {C * m}")
        if np.any((p < 0) | (p > 1)) or np.any((Pb < 0) | (Pb > 1)):
            raise ValueError("probabilities must lie in [0, 1]")
        eye = np.broadcast_to(np.eye(m), (C, m, m))
        if not np.allclose(Pb[:, np.arange(m), np.arange(m)], 1.0):
            raise ValueError("Pb must have unit diagonals (p_ii = 1)")
        if not np.allclose(Eb, np.swapaxes(Eb, 1, 2)):
            raise ValueError("Eb blocks must be symmetric")
        PbT = np.swapaxes(Pb, 1, 2)
        lo = np.maximum(0.0, Pb + PbT - 1.0)
        hi = np.minimum(Pb, PbT)
        if np.any(Eb < lo - 1e-9) or np.any(Eb > hi + 1e-9):
            raise ValueError("Eb violates the Frechet bounds for (Pb, Pb^T)")
        if np.any(Eb + 1e-9 < Pb * PbT):
            raise ValueError(
                "paper assumes E_{i,j} >= p_ij * p_ji (nonneg. reciprocity)"
            )
        del eye
        object.__setattr__(self, "p", p)
        object.__setattr__(self, "Pb", Pb)
        object.__setattr__(self, "Eb", Eb)

    # -- geometry -------------------------------------------------------
    @property
    def n(self) -> int:
        return int(self.p.shape[0])

    @property
    def C(self) -> int:
        return int(self.Pb.shape[0])

    @property
    def m(self) -> int:
        return int(self.Pb.shape[1])

    @property
    def spec(self) -> ClusterSpec:
        return ClusterSpec(self.n, self.m)

    # -- views ----------------------------------------------------------
    def cluster_model(self, c: int) -> LinkModel:
        """Cluster c as a standalone (m,)-client :class:`LinkModel` —
        the view per-cluster COPT-alpha optimizes over."""
        m = self.m
        return LinkModel(self.p[c * m:(c + 1) * m], self.Pb[c], self.Eb[c])

    def to_dense(self) -> LinkModel:
        """The equivalent dense model (small-n oracle tests only —
        materializes (n, n))."""
        spec = self.spec
        P = block_diag_from_blocks(self.Pb, spec)
        E = block_diag_from_blocks(self.Eb, spec)
        return LinkModel(self.p, P, E)

    @classmethod
    def from_dense(cls, model: LinkModel, cluster_size: int,
                   *, atol: float = 0.0) -> "ClusteredLinkModel":
        """Block a dense model; refuses cross-cluster mass (strict)."""
        spec = ClusterSpec(model.n, cluster_size)
        return cls(
            model.p,
            blocks_from_dense(model.P, spec, strict=True, atol=atol),
            blocks_from_dense(model.E, spec, strict=True, atol=atol),
        )


# ---------------------------------------------------------------------------
# device-side blocked relay ops (the jnp twins of core/relay.py)
# ---------------------------------------------------------------------------


def block_mixing_matrix(Ab: jax.Array, tau_b: jax.Array) -> jax.Array:
    """Per-cluster realized mixing mask: Mb[c] = Ab[c] * tau_b[c]^T."""
    return Ab * jnp.swapaxes(tau_b, 1, 2)


def block_relay_mix(updates: jax.Array, Ab: jax.Array,
                    tau_b: jax.Array) -> jax.Array:
    """Faithful blocked consensus: (n, d) -> (n, d), per-cluster
    ``Dx~_c = (Ab[c] * tau_b[c]^T) @ Dx_c`` — C independent (m, m)x(m, d)
    contractions, never the dense (n, n) matmul."""
    C, m, _ = Ab.shape
    d = updates.shape[-1]
    Mb = block_mixing_matrix(Ab.astype(updates.dtype),
                             tau_b.astype(updates.dtype))
    tilde = jnp.einsum("cij,cjk->cik", Mb, updates.reshape(C, m, d))
    return tilde.reshape(C * m, d)


def block_effective_weights(Ab: jax.Array, tau_up: jax.Array,
                            tau_b: jax.Array) -> jax.Array:
    """Blocked twin of :func:`repro.core.relay.effective_weights`: the
    cluster-batched form of the canonical contraction
    ``w_j = sum_i tau_i tau_ji alpha_ij`` (clusters are independent, so
    the sum over i only runs within j's cluster).  Returns (n,)."""
    C, m, _ = Ab.shape
    w = jnp.einsum("ci,cij,cji->cj", tau_up.reshape(C, m), Ab, tau_b)
    return w.reshape(C * m)


def block_ps_aggregate(tilde_b: jax.Array, tau_up: jax.Array) -> jax.Array:
    """Blind PS sum over the blocked consensus: (C, m, d) -> (d,)."""
    C, m, _ = tilde_b.shape
    n = C * m
    return jnp.einsum("ci,cik->k",
                      tau_up.reshape(C, m).astype(tilde_b.dtype), tilde_b) / n


def block_colrel_round_delta(
    updates: jax.Array,
    Ab: jax.Array,
    tau_up: jax.Array,
    tau_b: jax.Array,
    *,
    fused: bool = False,
) -> jax.Array:
    """End-to-end blocked ColRel PS delta: (d,) from (n, d) updates with
    ``(C, m, m)`` relay weights / D2D realizations."""
    C, m, _ = Ab.shape
    n = C * m
    if fused:
        w = block_effective_weights(Ab.astype(jnp.float32), tau_up, tau_b)
        return (w.astype(updates.dtype) @ updates) / n
    Mb = block_mixing_matrix(Ab.astype(updates.dtype),
                             tau_b.astype(updates.dtype))
    tilde = jnp.einsum("cij,cjk->cik", Mb,
                       updates.reshape(C, m, updates.shape[-1]))
    return block_ps_aggregate(tilde, tau_up)
