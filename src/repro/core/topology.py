"""Network-topology builders reproducing the paper's experimental setups.

Every builder returns a :class:`repro.core.connectivity.LinkModel`.  The
paper's Section V uses three families:

* Erdős–Rényi D2D graphs with uniform per-round link probability ``p_c``
  and fully reciprocal sampling (``tau_ij = 0 <=> tau_ji = 0``), combined
  with either a single well-connected client (Fig. 2a) or heterogeneous
  uplinks (Fig. 2b).
* mmWave geometric topologies (Fig. 3/4):
  ``p = min(1, exp(-d/30 + 5.2))`` as in Akdeniz et al. [4], with either
  *permanent* thresholded D2D links ([1]'s setting) or *intermittent* D2D
  links pruned below 0.5.
* Degenerate topologies (no collaboration) recovering classical FedAvg:
  ``P = I``.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from .blocks import ClusteredLinkModel, ClusterSpec
from .connectivity import LinkModel, reciprocity_matrix

__all__ = [
    "no_collaboration",
    "fully_connected",
    "erdos_renyi",
    "ring",
    "star_relay",
    "clustered",
    "clustered_blocks",
    "mmwave_prob",
    "mmwave_geometric",
    "paper_fig2a",
    "paper_fig2b",
    "paper_mmwave_layout",
]

# ---------------------------------------------------------------------------
# Generic graphs
# ---------------------------------------------------------------------------


def _uniform_uplinks(n: int, p_up) -> np.ndarray:
    p = np.asarray(p_up, dtype=np.float64)
    if p.ndim == 0:
        p = np.full(n, float(p))
    if p.shape != (n,):
        raise ValueError(f"p_up must broadcast to ({n},)")
    return p


def no_collaboration(n: int, p_up) -> LinkModel:
    """Classical intermittent FedAvg: no D2D links at all (P = I)."""
    P = np.eye(n)
    return LinkModel(_uniform_uplinks(n, p_up), P, reciprocity_matrix(P, 0.0))


def fully_connected(n: int, p_up, p_c: float = 1.0, rho: float = 1.0) -> LinkModel:
    """All-pairs D2D links with per-round success ``p_c``."""
    P = np.full((n, n), float(p_c))
    np.fill_diagonal(P, 1.0)
    return LinkModel(_uniform_uplinks(n, p_up), P, reciprocity_matrix(P, rho))


def erdos_renyi(
    n: int,
    p_up,
    p_c: float,
    *,
    rho: float = 1.0,
    structural: bool = False,
    rng: Optional[np.random.Generator] = None,
) -> LinkModel:
    """Erdős–Rényi collaboration, as in the paper's Fig. 2 experiments.

    With ``structural=False`` (paper's reading): every pair is connected by
    an *intermittent* link that is up with probability ``p_c`` each round,
    with fully reciprocal sampling (rho=1) so tau_ij = tau_ji.

    With ``structural=True``: a fixed ER graph is drawn once with edge
    probability ``p_c`` and present edges are permanent (p_ij = 1).
    """
    if structural:
        if rng is None:
            rng = np.random.default_rng(0)
        upper = rng.random((n, n)) < p_c
        adj = np.triu(upper, k=1)
        P = (adj | adj.T).astype(np.float64)
        np.fill_diagonal(P, 1.0)
        return LinkModel(_uniform_uplinks(n, p_up), P, reciprocity_matrix(P, 0.0))
    return fully_connected(n, p_up, p_c=p_c, rho=rho)


def ring(n: int, p_up, p_c: float = 1.0, rho: float = 1.0) -> LinkModel:
    P = np.eye(n)
    idx = np.arange(n)
    P[idx, (idx + 1) % n] = p_c
    P[idx, (idx - 1) % n] = p_c
    return LinkModel(_uniform_uplinks(n, p_up), P, reciprocity_matrix(P, rho))


def star_relay(n: int, p_up, hub: int = 0, p_c: float = 1.0, rho: float = 1.0) -> LinkModel:
    """All clients can reach one hub client (and vice versa)."""
    P = np.eye(n)
    P[:, hub] = p_c
    P[hub, :] = p_c
    P[hub, hub] = 1.0
    return LinkModel(_uniform_uplinks(n, p_up), P, reciprocity_matrix(P, rho))


def clustered(
    n: int,
    p_up,
    cluster_size: int,
    p_intra: float = 1.0,
    p_inter: float = 0.0,
    rho: float = 1.0,
) -> LinkModel:
    """Block-diagonal clusters — the semi-decentralized HFL-like layout."""
    cid = np.arange(n) // cluster_size
    same = cid[:, None] == cid[None, :]
    P = np.where(same, p_intra, p_inter).astype(np.float64)
    np.fill_diagonal(P, 1.0)
    return LinkModel(_uniform_uplinks(n, p_up), P, reciprocity_matrix(P, rho))


def clustered_blocks(
    n: int,
    p_up,
    cluster_size: int,
    p_intra: float = 1.0,
    rho: float = 1.0,
) -> ClusteredLinkModel:
    """Block form of :func:`clustered` with ``p_inter = 0``: only the C
    diagonal ``(m, m)`` blocks are built, so the dense (n, n) statistics
    never exist — the population-scale entry point (n = 2^14 costs
    ``n * m`` floats per tensor, not ``n**2``).

    Identical statistics to ``clustered(n, p_up, cluster_size, p_intra,
    p_inter=0.0, rho)``; ``tests/test_clustered.py`` pins the round trip.
    """
    spec = ClusterSpec(n, cluster_size)
    m = cluster_size
    Pblk = np.full((m, m), float(p_intra))
    np.fill_diagonal(Pblk, 1.0)
    Eblk = reciprocity_matrix(Pblk, rho)
    return ClusteredLinkModel(
        _uniform_uplinks(n, p_up),
        np.broadcast_to(Pblk, (spec.C, m, m)).copy(),
        np.broadcast_to(Eblk, (spec.C, m, m)).copy(),
    )


# ---------------------------------------------------------------------------
# mmWave geometric model (paper Sec. V-3, after Akdeniz et al.)
# ---------------------------------------------------------------------------


def mmwave_prob(d: np.ndarray) -> np.ndarray:
    """p = min(1, exp(-d/30 + 5.2)) with d in meters."""
    return np.minimum(1.0, np.exp(-np.asarray(d, dtype=np.float64) / 30.0 + 5.2))


def mmwave_geometric(
    positions: np.ndarray,
    ps_position: Sequence[float] = (0.0, 0.0),
    *,
    d2d_mode: str = "intermittent",
    prune_below: float = 0.5,
    permanent_threshold: float = 0.99,
    rho: float = 0.0,
) -> LinkModel:
    """Geometric mmWave topology.

    Parameters
    ----------
    positions: (n, 2) client coordinates in meters.
    d2d_mode:
        ``"intermittent"`` — Fig. 3b: keep p_ij, but drop links with
        p_ij < ``prune_below`` (too unreliable to collaborate).
        ``"permanent"``    — Fig. 3a / ISIT'22: p_ij = 1 iff
        p_ij >= ``permanent_threshold`` else 0.
    """
    pos = np.asarray(positions, dtype=np.float64)
    n = pos.shape[0]
    ps = np.asarray(ps_position, dtype=np.float64)
    d_up = np.linalg.norm(pos - ps[None, :], axis=1)
    p = mmwave_prob(d_up)
    d_dd = np.linalg.norm(pos[:, None, :] - pos[None, :, :], axis=2)
    P = mmwave_prob(d_dd)
    if d2d_mode == "permanent":
        P = (P >= permanent_threshold).astype(np.float64)
    elif d2d_mode == "intermittent":
        P = np.where(P >= prune_below, P, 0.0)
    else:
        raise ValueError(f"unknown d2d_mode {d2d_mode!r}")
    np.fill_diagonal(P, 1.0)
    return LinkModel(p, P, reciprocity_matrix(P, rho))


# ---------------------------------------------------------------------------
# The paper's concrete experimental layouts
# ---------------------------------------------------------------------------


def paper_fig2a(n: int = 10, p_good: float = 0.9, p_bad: float = 0.1, p_c: float = 0.9) -> LinkModel:
    """Fig. 2a: exactly one client with good PS connectivity, ER D2D."""
    p_up = np.full(n, p_bad)
    p_up[0] = p_good
    return fully_connected(n, p_up, p_c=p_c, rho=1.0)


def paper_fig2b(p_c: float = 0.9) -> LinkModel:
    """Fig. 2b: heterogeneous uplinks (p1=p4=p5=p8=0.1, p7=0.8, p10=0.9,
    the rest 'moderate' — we use 0.4), ER D2D with probability ``p_c``."""
    p_up = np.array([0.1, 0.4, 0.4, 0.1, 0.1, 0.4, 0.8, 0.1, 0.4, 0.9])
    return fully_connected(10, p_up, p_c=p_c, rho=1.0)


def paper_mmwave_layout(
    n: int = 10,
    seed: int = 1,
    spread: float = 220.0,
    n_near: int = 3,
    **kwargs,
) -> LinkModel:
    """A layout in the spirit of Fig. 3: PS at the origin, ``n_near`` clients
    within uplink coverage, the rest spread beyond it in loose groups so that
    only D2D relaying can reach the PS."""
    rng = np.random.default_rng(seed)
    pos = np.empty((n, 2))
    # d <= 156m -> p_i = 1 at d = 156; coverage decays after ~156 m.
    near_r = 120.0 + 40.0 * rng.random(n_near)
    near_th = 2 * np.pi * rng.random(n_near)
    pos[:n_near] = np.c_[near_r * np.cos(near_th), near_r * np.sin(near_th)]
    far = n - n_near
    far_r = spread + 60.0 * rng.random(far)
    far_th = 2 * np.pi * rng.random(far)
    pos[n_near:] = np.c_[far_r * np.cos(far_th), far_r * np.sin(far_th)]
    return mmwave_geometric(pos, (0.0, 0.0), **kwargs)
