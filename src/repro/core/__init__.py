"""ColRel core: the paper's contribution as a composable library.

Host-side (numpy): connectivity models, topologies, the variance functional
S / Sbar, and the COPT-alpha weight optimizer (Algorithm 3).

Device-side (JAX): the relay consensus (Eq. (3)), PS aggregation (Alg. 2),
and the FedAvg baselines — all jit/pjit-compatible.
"""

from .connectivity import (
    LinkModel,
    effective_weights,
    reciprocity_matrix,
    sample_round,
    sample_rounds,
)
from .blocks import (
    ClusteredLinkModel,
    ClusterSpec,
    block_colrel_round_delta,
    block_effective_weights,
    block_relay_mix,
)
from .weights import (
    ClusteredOptResult,
    OptResult,
    fedavg_weights,
    importance_weights,
    initial_weights,
    is_unbiased,
    is_unbiased_clustered,
    optimize_weights,
    optimize_weights_clustered,
    unbiasedness_residual,
    unbiasedness_residual_clustered,
    variance_S,
    variance_Sbar,
)
from .aggregation import Aggregation, aggregate
from . import blocks, flatten, relay, topology

__all__ = [
    "LinkModel",
    "ClusterSpec",
    "ClusteredLinkModel",
    "block_relay_mix",
    "block_effective_weights",
    "block_colrel_round_delta",
    "reciprocity_matrix",
    "sample_round",
    "sample_rounds",
    "effective_weights",
    "variance_S",
    "variance_Sbar",
    "unbiasedness_residual",
    "is_unbiased",
    "initial_weights",
    "fedavg_weights",
    "importance_weights",
    "optimize_weights",
    "optimize_weights_clustered",
    "unbiasedness_residual_clustered",
    "is_unbiased_clustered",
    "OptResult",
    "ClusteredOptResult",
    "Aggregation",
    "aggregate",
    "blocks",
    "flatten",
    "relay",
    "topology",
]
