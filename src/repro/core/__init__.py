"""ColRel core: the paper's contribution as a composable library.

Host-side (numpy): connectivity models, topologies, the variance functional
S / Sbar, and the COPT-alpha weight optimizer (Algorithm 3).

Device-side (JAX): the relay consensus (Eq. (3)), PS aggregation (Alg. 2),
and the FedAvg baselines — all jit/pjit-compatible.
"""

from .connectivity import (
    LinkModel,
    effective_weights,
    reciprocity_matrix,
    sample_round,
    sample_rounds,
)
from .weights import (
    OptResult,
    fedavg_weights,
    importance_weights,
    initial_weights,
    is_unbiased,
    optimize_weights,
    unbiasedness_residual,
    variance_S,
    variance_Sbar,
)
from .aggregation import Aggregation, aggregate
from . import flatten, relay, topology

__all__ = [
    "LinkModel",
    "reciprocity_matrix",
    "sample_round",
    "sample_rounds",
    "effective_weights",
    "variance_S",
    "variance_Sbar",
    "unbiasedness_residual",
    "is_unbiased",
    "initial_weights",
    "fedavg_weights",
    "importance_weights",
    "optimize_weights",
    "OptResult",
    "Aggregation",
    "aggregate",
    "flatten",
    "relay",
    "topology",
]
