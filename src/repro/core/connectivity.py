"""Intermittent-connectivity model of the ColRel paper (Sec. II-B).

Client *i*'s uplink to the parameter server succeeds in round r with
probability ``p_i`` (``tau_i(r) ~ Bernoulli(p_i)``), and the D2D link from
client i to client j succeeds with probability ``p_ij``
(``tau_ij(r) ~ Bernoulli(p_ij)``, ``p_ii = 1``).  Links are independent
across rounds; within a round the only admitted correlation is *channel
reciprocity* between ``tau_ij`` and ``tau_ji``, captured by
``E_{i,j} = E[tau_ij * tau_ji] >= p_ij * p_ji``.

Index conventions used throughout the code base (matching the paper):

* ``p[i]``       — uplink success probability of client i.
* ``P[i, j]``    — success probability of the D2D link i -> j
                   (client i transmitting, client j receiving).
* ``E[i, j]``    — reciprocity correlation E[tau_ij * tau_ji]  (symmetric).
* ``A[i, j]``    — alpha_ij, the weight client i applies to the update it
                   received from client j (Sec. II-C, Eq. (3)).

Sampled per-round indicators:

* ``tau_up[i]``     — realization of tau_i(r).
* ``tau_dd[i, j]``  — realization of tau_ij(r), i.e. "j successfully heard
                      i's broadcast"; the diagonal is always 1.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "LinkModel",
    "reciprocity_matrix",
    "sample_round",
    "sample_rounds",
    "effective_weights",
]


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Static description of the intermittent network for one experiment."""

    p: np.ndarray  # (n,)   uplink success probabilities
    P: np.ndarray  # (n, n) D2D success probabilities, diag == 1
    E: np.ndarray  # (n, n) reciprocity correlations E[tau_ij tau_ji]

    def __post_init__(self) -> None:
        p = np.asarray(self.p, dtype=np.float64)
        P = np.asarray(self.P, dtype=np.float64)
        E = np.asarray(self.E, dtype=np.float64)
        n = p.shape[0]
        if p.ndim != 1:
            raise ValueError(f"p must be a vector, got shape {p.shape}")
        if P.shape != (n, n) or E.shape != (n, n):
            raise ValueError(
                f"P/E must be ({n},{n}); got {P.shape} and {E.shape}"
            )
        if np.any((p < 0) | (p > 1)) or np.any((P < 0) | (P > 1)):
            raise ValueError("probabilities must lie in [0, 1]")
        if not np.allclose(np.diag(P), 1.0):
            raise ValueError("P must have a unit diagonal (p_ii = 1)")
        if not np.allclose(E, E.T):
            raise ValueError("E must be symmetric")
        # Frechet bounds for a coupled Bernoulli pair.
        lo = np.maximum(0.0, P + P.T - 1.0)
        hi = np.minimum(P, P.T)
        if np.any(E < lo - 1e-9) or np.any(E > hi + 1e-9):
            raise ValueError("E violates the Frechet bounds for (P, P^T)")
        if np.any(E + 1e-9 < P * P.T):
            raise ValueError(
                "paper assumes E_{i,j} >= p_ij * p_ji (nonneg. reciprocity)"
            )
        object.__setattr__(self, "p", p)
        object.__setattr__(self, "P", P)
        object.__setattr__(self, "E", E)

    @property
    def n(self) -> int:
        return int(self.p.shape[0])

    def with_reciprocity(self, rho: float) -> "LinkModel":
        return LinkModel(self.p, self.P, reciprocity_matrix(self.P, rho))

    def neighbor_counts(self) -> np.ndarray:
        """Number of clients that can ever hear client i (p_ij > 0, j != i)."""
        off = self.P - np.eye(self.n)
        return (off > 0).sum(axis=1)


def reciprocity_matrix(P: np.ndarray, rho: float) -> np.ndarray:
    """Interpolate E between independence (rho=0) and max coupling (rho=1).

    ``E = (1-rho) * p_ij p_ji + rho * min(p_ij, p_ji)`` — always inside the
    Frechet bounds and >= p_ij p_ji as the paper assumes.
    """
    if not 0.0 <= rho <= 1.0:
        raise ValueError("rho must be in [0, 1]")
    P = np.asarray(P, dtype=np.float64)
    ind = P * P.T
    full = np.minimum(P, P.T)
    E = (1.0 - rho) * ind + rho * full
    np.fill_diagonal(E, 1.0)
    return E


def sample_round(
    model: LinkModel, rng: np.random.Generator
) -> tuple[np.ndarray, np.ndarray]:
    """Draw one round's connectivity realization.

    Returns ``(tau_up, tau_dd)``: tau_up (n,) float64 in {0,1};
    tau_dd (n,n) with tau_dd[i, j] = tau_ij(r) and unit diagonal.  The pair
    (tau_ij, tau_ji) is drawn from the joint law with marginals
    (p_ij, p_ji) and correlation E[i, j]:

        P(1,1) = E, P(1,0) = p_ij - E, P(0,1) = p_ji - E,
        P(0,0) = 1 - p_ij - p_ji + E.
    """
    n = model.n
    tau_up = (rng.random(n) < model.p).astype(np.float64)

    u = rng.random((n, n))
    u = np.triu(u, k=1)  # one uniform per unordered pair {i<j}
    tau_dd = np.eye(n)
    iu, ju = np.triu_indices(n, k=1)
    pij = model.P[iu, ju]
    pji = model.P[ju, iu]
    e = model.E[iu, ju]
    uu = u[iu, ju]
    both = uu < e
    only_ij = (uu >= e) & (uu < pij)
    only_ji = (uu >= pij) & (uu < pij + pji - e)
    tau_dd[iu, ju] = (both | only_ij).astype(np.float64)
    tau_dd[ju, iu] = (both | only_ji).astype(np.float64)
    return tau_up, tau_dd


def sample_rounds(
    model: LinkModel, rng: np.random.Generator, rounds: int
) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized multi-round sampling: (R, n) uplinks and (R, n, n) D2D.

    Batched RNG — every uniform for the whole experiment is drawn in one
    call, no per-round host loop.  Distribution-identical to stacking
    :func:`sample_round` ``rounds`` times (the per-round law is the same
    coupling); the draw *order* differs, so sequences from the two APIs
    are not bit-equal for the same generator state (cross-checked
    statistically in ``tests/test_channel.py``).
    """
    n = model.n
    ups = (rng.random((rounds, n)) < model.p).astype(np.float64)
    iu, ju = np.triu_indices(n, k=1)
    u = rng.random((rounds, iu.shape[0]))  # one uniform per pair per round
    pij, pji, e = model.P[iu, ju], model.P[ju, iu], model.E[iu, ju]
    both = u < e
    only_ij = (u >= e) & (u < pij)
    only_ji = (u >= pij) & (u < pij + pji - e)
    dds = np.zeros((rounds, n, n))
    dds[:, iu, ju] = both | only_ij
    dds[:, ju, iu] = both | only_ji
    dds += np.eye(n)[None]
    return ups, dds


# The one canonical contraction behind every "effective weights" variant:
# w_j = sum_i tau_up[i] * A[i, j] * tau_dd[j, i].  The numpy function below
# and its device twin ``repro.core.relay.effective_weights`` both evaluate
# exactly this spec (property-tested against each other); ``repro.core``
# exports this one as the canonical name.
EFFECTIVE_WEIGHTS_EINSUM = "i,ij,ji->j"


def effective_weights(
    A: np.ndarray, tau_up: np.ndarray, tau_dd: np.ndarray
) -> np.ndarray:
    """Per-client effective aggregation weight for one round (exact fusion).

    The PS update (Alg. 2, line 5) is
        x^{r+1} = x^r + (1/n) sum_i tau_i * sum_j tau_ji alpha_ij Dx_j
                = x^r + (1/n) sum_j w_j Dx_j,
    with  ``w_j = sum_i tau_i * tau_ji * alpha_ij``
                = sum_i tau_up[i] * tau_dd[j, i] * A[i, j].

    This identity is what the fused "weighted-psum" execution path uses; it
    reproduces the paper-faithful PS trajectory exactly for the same draws.
    """
    # w_j = sum_i tau_up[i] * A[i, j] * tau_dd[j, i]
    return np.einsum(EFFECTIVE_WEIGHTS_EINSUM, tau_up, np.asarray(A), tau_dd)
