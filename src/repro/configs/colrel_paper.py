"""The paper's own experimental configuration (Sec. V): ResNet-20-family
CNN on CIFAR-10-shaped data, n = 10 clients, T = 8 local steps, SGD
lr = 0.05 + weight decay 1e-4, batch 64, PS momentum 0.9.

``reduced()`` shrinks widths/batch so a few hundred rounds run on one CPU
core in the benchmark harness while keeping every protocol parameter
(n, T, lr, momentum, topologies) at the paper's values.
"""

import dataclasses

from repro.models.cnn import CNNConfig


@dataclasses.dataclass(frozen=True)
class PaperSetup:
    cnn: CNNConfig
    n_clients: int = 10
    local_steps: int = 8  # the paper's T
    lr: float = 0.05
    weight_decay: float = 1e-4
    server_momentum: float = 0.9
    batch_size: int = 64
    non_iid_s: int = 3


def full() -> PaperSetup:
    return PaperSetup(cnn=CNNConfig(name="resnet20", widths=(16, 32, 64), blocks_per_stage=3))


def reduced(batch_size: int = 32) -> PaperSetup:
    return PaperSetup(
        cnn=CNNConfig(name="resnet20-thin", widths=(8, 16, 32), blocks_per_stage=1),
        batch_size=batch_size,
    )
