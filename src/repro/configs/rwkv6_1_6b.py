"""rwkv6-1.6b [ssm] — "Finch", data-dependent per-channel decay.

[arXiv:2404.05892]  24L d_model=2048 (attention-free) d_ff=7168
vocab=65536; 32 heads of 64 for the wkv state.

long_500k RUNS: decode state is O(1) in sequence (per-layer (H, 64, 64)
wkv state + token-shift vectors) — the flagship sub-quadratic arch.
"""

from repro.models import ModelConfig

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def full(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b",
        arch_type="ssm",
        n_layers=24,
        d_model=2048,
        n_heads=32,  # wkv heads of dim 64
        n_kv_heads=32,
        d_ff=7168,
        vocab_size=65536,
        norm="layernorm",
        ssm_chunk=32,
        max_seq_len=524288,
        dtype=dtype,
        fl_mode="per_client",
    )


def smoke() -> ModelConfig:
    return full(dtype="float32").replace(
        n_layers=2, d_model=128, n_heads=2, n_kv_heads=2, d_ff=256,
        vocab_size=512, ssm_chunk=16, max_seq_len=256,
    )
