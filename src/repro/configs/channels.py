"""Scenario presets for the dynamic channel subsystem.

Each preset names a reproducible channel dynamic; :func:`make_channel`
instantiates it for a given :class:`LinkModel` (static / markov — the
model supplies the per-round marginals) or client count (mobility — the
geometry *is* the model and drifts).  Used by
``examples/train_colrel_cifar.py --channel`` and
``benchmarks/channel_bench.py``; grep-able single source of truth for
what "bursty" means across the repo.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.channel import (
    ChannelProcess,
    MarkovChannel,
    MobilityChannel,
    StaticChannel,
    gilbert_elliott,
)
from repro.core.connectivity import LinkModel

__all__ = ["ChannelPreset", "CHANNEL_PRESETS", "make_channel"]


@dataclasses.dataclass(frozen=True)
class ChannelPreset:
    kind: str  # static | markov | mobility
    # markov: gate memory (lag-1 autocorrelation); 0 = i.i.d. paper model
    memory: float = 0.9
    occupancy: Optional[float] = None
    block: int = 256  # scan-generation block (rounds per device pass)
    # mobility: geometry refresh cadence / client speed / roam half-width
    epoch: int = 20
    speed: float = 4.0
    area: float = 300.0
    d2d_mode: str = "intermittent"


CHANNEL_PRESETS = {
    # the paper's i.i.d. channel, as a ChannelProcess
    "static": ChannelPreset(kind="static"),
    # GE chains fitted to the model's marginals, i.i.d. gates — sanity
    # preset: distribution-identical to "static"
    "markov_iid": ChannelPreset(kind="markov", memory=0.0),
    # mmWave-style bursty blockage: ~10-round expected blockage bursts
    "markov": ChannelPreset(kind="markov", memory=0.9),
    # heavy blockage: ~30-round bursts, same marginals
    "markov_heavy": ChannelPreset(kind="markov", memory=0.97),
    # pedestrian-speed waypoint mobility, geometry refresh every 20 rounds
    "mobility": ChannelPreset(kind="mobility", epoch=20, speed=4.0),
    # vehicular-speed drift: topology turnover within ~a re-opt window
    "mobility_fast": ChannelPreset(kind="mobility", epoch=10, speed=15.0),
}


def make_channel(
    preset: "str | ChannelPreset",
    model: Optional[LinkModel] = None,
    *,
    n: Optional[int] = None,
    seed: int = 0,
) -> ChannelProcess:
    """Instantiate a preset.

    ``static`` / ``markov*`` need ``model`` (the marginals to preserve);
    ``mobility*`` needs ``n`` (or infers it from ``model``).
    """
    if isinstance(preset, str):
        try:
            preset = CHANNEL_PRESETS[preset]
        except KeyError:
            raise KeyError(
                f"unknown channel preset {preset!r}; have {sorted(CHANNEL_PRESETS)}"
            ) from None
    if preset.kind == "static":
        if model is None:
            raise ValueError("static channel needs a LinkModel")
        return StaticChannel(model, seed=seed)
    if preset.kind == "markov":
        if model is None:
            raise ValueError("markov channel needs a LinkModel")
        params = gilbert_elliott(model, memory=preset.memory, occupancy=preset.occupancy)
        return MarkovChannel(params, seed=seed, block=preset.block)
    if preset.kind == "mobility":
        if n is None:
            if model is None:
                raise ValueError("mobility channel needs n (or a model for its n)")
            n = model.n
        return MobilityChannel(
            n,
            area=preset.area,
            speed=preset.speed,
            epoch=preset.epoch,
            seed=seed,
            d2d_mode=preset.d2d_mode,
        )
    raise ValueError(f"unknown channel kind {preset.kind!r}")
