"""granite-moe-3b-a800m [moe] — many small experts.

[hf:ibm-granite/granite-3.0 family]  32L d_model=1536 24H (GQA kv=8)
d_ff=512 per expert, vocab=49155, MoE 40e top-8.

Expert count (40) does not divide the 16-wide model mesh axis, so expert
weights shard over d_ff (tensor-parallel inside experts) instead of the
expert axis — see launch/sharding.py.  long_500k skipped: full attention.
"""

from repro.models import ModelConfig

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


def full(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        arch_type="moe",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        head_dim=64,
        d_ff=512,
        d_ff_expert=512,
        vocab_size=49155,
        n_experts=40,
        top_k=8,
        norm="rmsnorm",
        mlp="swiglu",
        max_seq_len=32768,
        dtype=dtype,
        fl_mode="per_client",
    )


def smoke() -> ModelConfig:
    return full(dtype="float32").replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=64, d_ff_expert=64, vocab_size=512, n_experts=4, top_k=2,
        max_seq_len=256,
    )
