"""Config registry: one module per assigned architecture.

Each arch module defines ``full()`` (the exact assigned configuration) and
``smoke()`` (a reduced same-family variant: <=2 layers-ish, d_model<=512,
<=4 experts) plus ``SHAPES`` — which of the four assigned input shapes the
arch supports (decode skips / long-context rules are explained per file
and in DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

ARCH_IDS: List[str] = [
    "seamless_m4t_large_v2",
    "dbrx_132b",
    "olmo_1b",
    "qwen3_0_6b",
    "granite_moe_3b_a800m",
    "jamba_1_5_large_398b",
    "deepseek_coder_33b",
    "rwkv6_1_6b",
    "internvl2_2b",
    "gemma3_1b",
]

# canonical CLI ids (--arch <id>) -> module name
CLI_ALIASES: Dict[str, str] = {
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "dbrx-132b": "dbrx_132b",
    "olmo-1b": "olmo_1b",
    "qwen3-0.6b": "qwen3_0_6b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "internvl2-2b": "internvl2_2b",
    "gemma3-1b": "gemma3_1b",
}


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def get_arch(arch_id: str):
    """Resolve an arch id (CLI or module form) to its config module."""
    mod = CLI_ALIASES.get(arch_id, arch_id).replace("-", "_").replace(".", "_")
    if mod not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(CLI_ALIASES)}")
    return importlib.import_module(f"repro.configs.{mod}")


def supported_shapes(arch_id: str) -> List[str]:
    return list(get_arch(arch_id).SHAPES)
