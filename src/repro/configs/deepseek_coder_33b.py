"""deepseek-coder-33b [dense] — llama-arch.

[arXiv:2401.14196]  62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
long_500k skipped: full attention only.  FL mode: weighted_grad (T=1
fused round; 33B per-client copies are borderline — DESIGN.md §3).
"""

from repro.models import ModelConfig

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


def full(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b",
        arch_type="dense",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=19200,
        vocab_size=32256,
        norm="rmsnorm",
        mlp="swiglu",
        rope_theta=1e5,
        max_seq_len=32768,
        dtype=dtype,
        fl_mode="weighted_grad",
    )


def smoke() -> ModelConfig:
    return full(dtype="float32").replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, max_seq_len=256, fl_mode="per_client",
    )
