"""qwen3-0.6b [dense] — qk_norm, GQA.

[hf:Qwen/Qwen3-8B family]  28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936, head_dim=128 (decoupled from d_model/n_heads), tied
embeddings.  long_500k skipped: full attention only (DESIGN.md §5).
"""

from repro.models import ModelConfig

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


def full(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b",
        arch_type="dense",
        n_layers=28,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=3072,
        vocab_size=151936,
        qk_norm=True,
        tie_embeddings=True,
        norm="rmsnorm",
        mlp="swiglu",
        rope_theta=1e6,
        max_seq_len=32768,
        dtype=dtype,
        fl_mode="per_client",
    )


def smoke() -> ModelConfig:
    return full(dtype="float32").replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, max_seq_len=256,
    )
