"""jamba-1.5-large-398b [hybrid] — Mamba + attention 1:7, MoE.

[arXiv:2403.19887]  72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2; one attention layer per 8 (9 periods of
[attn, 7x mamba]), MoE MLP every other layer.

long_500k RUNS: the hybrid's decode state is O(1) in sequence for the 63
Mamba layers; only the 9 attention layers keep a (sharded) 500k KV cache.
FL mode: weighted_grad (T=1 fused round; 398B per-client copies are
infeasible — DESIGN.md §3; client_sequential remains available).
"""

from repro.models import ModelConfig

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def full(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        arch_type="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        d_ff_expert=24576,
        vocab_size=65536,
        n_experts=16,
        top_k=2,
        attn_every=8,
        moe_every=2,
        ssm_d_state=16,
        ssm_expand=2,
        ssm_chunk=64,
        norm="rmsnorm",
        mlp="swiglu",
        max_seq_len=524288,
        dtype=dtype,
        fl_mode="weighted_grad",
    )


def smoke() -> ModelConfig:
    return full(dtype="float32").replace(
        n_layers=4,
        attn_every=4,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        d_ff_expert=256,
        vocab_size=512,
        n_experts=4,
        top_k=2,
        ssm_chunk=16,
        max_seq_len=256,
        fl_mode="per_client",
    )
