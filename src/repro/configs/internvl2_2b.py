"""internvl2-2b [vlm] — InternViT + InternLM2 backbone.

[arXiv:2404.16821]  24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
The InternViT-300M vision tower is a stub per the assignment carve-out:
``input_specs`` provides (B, patches, d_model) patch embeddings that the
(real) projector + LM consume.  long_500k skipped: full attention only.
"""

from repro.models import ModelConfig

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]
VISION_PATCHES = 1024  # 4 tiles x 256 patches after pixel-shuffle


def full(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b",
        arch_type="vlm",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=92553,
        norm="rmsnorm",
        mlp="swiglu",
        frontend_tokens=VISION_PATCHES,
        max_seq_len=32768,
        dtype=dtype,
        fl_mode="per_client",
    )


def smoke() -> ModelConfig:
    return full(dtype="float32").replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, frontend_tokens=16, max_seq_len=256,
    )
