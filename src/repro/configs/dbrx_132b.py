"""dbrx-132b [moe] — 16 experts top-4, fine-grained.

[hf:databricks/dbrx-base]  40L d_model=6144 48H (GQA kv=8) d_ff=10752
(per expert) vocab=100352, MoE 16e top-4.

long_500k skipped: pure full-attention dense-attend arch (DESIGN.md §5).
FL mode: weighted_grad (T=1 fused round) — 132B per-client copies do not
fit the per-client layout on a 16-GB/chip pod, and the client_sequential
nested scan is compile-prohibitive at 512-way SPMD on this container's
single-core XLA (DESIGN.md §3; client_sequential remains available).
"""

from repro.models import ModelConfig

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


def full(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b",
        arch_type="moe",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=10752,
        d_ff_expert=10752,
        vocab_size=100352,
        n_experts=16,
        top_k=4,
        norm="rmsnorm",
        mlp="swiglu",
        max_seq_len=32768,
        dtype=dtype,
        fl_mode="weighted_grad",
    )


def smoke() -> ModelConfig:
    return full(dtype="float32").replace(
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        d_ff_expert=256,
        vocab_size=512,
        n_experts=4,
        top_k=2,
        max_seq_len=256,
        fl_mode="per_client",
    )
