"""gemma3-1b [dense] — 5:1 local:global sliding-window attention, 128k.

[hf:google/gemma-3-1b-pt]  26L d_model=1152 4H (GQA kv=1 = MQA)
d_ff=6912 vocab=262144, head_dim=256, window=512 on local layers, one
global layer per 6.

long_500k RUNS: 25/26 layers keep only a 512-token window cache; the
global layers keep the full (sharded) cache — the dense-arch exception
allowed by the assignment because the sliding-window variant is native
to the model card.
"""

from repro.models import ModelConfig

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def full(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b",
        arch_type="dense",
        n_layers=26,
        d_model=1152,
        n_heads=4,
        n_kv_heads=1,
        head_dim=256,
        d_ff=6912,
        vocab_size=262144,
        sliding_window=512,
        local_global_ratio=5,
        tie_embeddings=True,
        qk_norm=True,
        norm="rmsnorm",
        mlp="swiglu",
        rope_theta=1e6,
        max_seq_len=524288,
        dtype=dtype,
        fl_mode="per_client",
    )


def smoke() -> ModelConfig:
    return full(dtype="float32").replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32,
        d_ff=256, vocab_size=512, sliding_window=32, local_global_ratio=1,
        max_seq_len=256,
    )
