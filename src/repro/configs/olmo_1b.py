"""olmo-1b [dense] — non-parametric LayerNorm.

[arXiv:2402.00838]  16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304.
long_500k skipped: full attention only (DESIGN.md §5).
"""

from repro.models import ModelConfig

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


def full(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name="olmo-1b",
        arch_type="dense",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=8192,
        vocab_size=50304,
        norm="nonparametric_ln",
        mlp="swiglu",
        max_seq_len=32768,
        dtype=dtype,
        fl_mode="per_client",
    )


def smoke() -> ModelConfig:
    return full(dtype="float32").replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512, max_seq_len=256,
    )
