"""seamless-m4t-large-v2 [audio backbone] — enc-dec, multimodal.

[arXiv:2308.11596]  24L d_model=1024 16H (GQA kv=16) d_ff=8192
vocab=256206.  The assigned "24L" instantiates the T2TT backbone as
24 encoder + 24 decoder layers; the speech frontend (mel + conv
w2v-BERT feature extractor) is a stub per the assignment carve-out —
``input_specs`` feeds precomputed frame embeddings of shape
(B, frames, d_model).

long_500k skipped: pure full-attention enc-dec, no sub-quadratic variant
in the model card (DESIGN.md §5).
"""

from repro.models import ModelConfig

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]
FRONTEND_FRAMES = 960  # ~30 s of speech at 32 Hz after conv stack


def full(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        arch_type="audio",
        n_layers=24,
        n_encoder_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=8192,
        vocab_size=256206,
        norm="layernorm",
        mlp="gelu",
        frontend_tokens=FRONTEND_FRAMES,
        max_seq_len=32768,
        dtype=dtype,
        fl_mode="per_client",
    )


def smoke() -> ModelConfig:
    return full(dtype="float32").replace(
        n_layers=2,
        n_encoder_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        frontend_tokens=16,
        max_seq_len=256,
    )
