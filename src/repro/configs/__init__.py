from .base import ARCH_IDS, CLI_ALIASES, INPUT_SHAPES, InputShape, get_arch, supported_shapes

__all__ = [
    "ARCH_IDS",
    "CLI_ALIASES",
    "INPUT_SHAPES",
    "InputShape",
    "get_arch",
    "supported_shapes",
]
