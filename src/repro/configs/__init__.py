from .base import ARCH_IDS, CLI_ALIASES, INPUT_SHAPES, InputShape, get_arch, supported_shapes
from .channels import CHANNEL_PRESETS, ChannelPreset, make_channel

__all__ = [
    "ARCH_IDS",
    "CLI_ALIASES",
    "INPUT_SHAPES",
    "InputShape",
    "get_arch",
    "supported_shapes",
    "CHANNEL_PRESETS",
    "ChannelPreset",
    "make_channel",
]
