"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose vs these)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def relay_mix_ref(mixing: jax.Array, updates: jax.Array) -> jax.Array:
    """(n, n) @ (n, d) in fp32 accumulation."""
    return (
        mixing.astype(jnp.float32) @ updates.astype(jnp.float32)
    ).astype(updates.dtype)


def fused_aggregate_ref(A: jax.Array, tau_up: jax.Array, tau_dd: jax.Array,
                        updates: jax.Array) -> jax.Array:
    """Faithful two-stage oracle for the fused aggregation kernel:
    relay mix (Eq. (3)) then the blind PS sum (Alg. 2 line 5), fp32."""
    n = updates.shape[0]
    m = A.astype(jnp.float32) * tau_dd.astype(jnp.float32).T
    tilde = m @ updates.astype(jnp.float32)
    return (tau_up.astype(jnp.float32) @ tilde) / n


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True) -> jax.Array:
    """q (BH, T, D), k/v (BH, S, D) — dense softmax attention in fp32."""
    BH, T, D = q.shape
    S = k.shape[1]
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / (D ** 0.5)
    if causal:
        mask = jnp.arange(T)[:, None] >= jnp.arange(S)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bts,bsd->btd", w, v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(q, k, v, log_decay):
    """Sequential SSD recurrence oracle: q/k (BH,T,Dk), v (BH,T,Dv)."""
    import numpy as np

    BH, T, Dk = q.shape
    Dv = v.shape[-1]
    qf, kf, vf = (np.asarray(x, np.float32) for x in (q, k, v))
    a = np.exp(np.asarray(log_decay, np.float32))
    S = np.zeros((BH, Dk, Dv), np.float32)
    out = np.zeros((BH, T, Dv), np.float32)
    for t in range(T):
        S = a[:, t, None, None] * S + np.einsum("bk,bv->bkv", kf[:, t], vf[:, t])
        out[:, t] = np.einsum("bk,bkv->bv", qf[:, t], S)
    return jnp.asarray(out, q.dtype)
