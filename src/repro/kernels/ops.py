"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs step-by-step in Python/XLA-CPU, validating the exact
TPU tiling logic.  On a real TPU backend the same call sites compile to
Mosaic.  ``_interpret()`` makes that switch automatic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_pallas
from .fused_aggregate import fused_aggregate_pallas, row_stream_pallas
from .fused_dequant import fused_dequant_aggregate_pallas
from .fused_memory import fused_memory_update_pallas, memory_stream_pallas
from .relay_block import (
    block_fused_aggregate_pallas,
    block_relay_mix_pallas,
    block_row_stream_pallas,
)
from .relay_mix import relay_mix_pallas
from .ssd_scan import ssd_scan_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def relay_mix(mixing: jax.Array, updates: jax.Array, *, block_d: int = 2048) -> jax.Array:
    """ColRel consensus Dx~ = mixing @ updates; (n, d) streams through VMEM."""
    return relay_mix_pallas(mixing, updates, block_d=block_d, interpret=_interpret())


def fused_aggregate(A: jax.Array, tau_up: jax.Array, tau_dd: jax.Array,
                    updates: jax.Array, *, block_d: int = 2048) -> jax.Array:
    """One-pass ColRel PS delta (1/n) tau_up @ ((A * tau_dd^T) @ updates):
    the (n, d) stack crosses HBM once; output is the (d,) fp32 delta."""
    if _interpret():
        # Non-TPU deployable op: the same collapsed contraction in jnp (one
        # pass over the stack, identical order/accumulation to the kernel).
        # This is wired into every training round, so — unlike the oracle
        # ops above — it must not emulate the tile grid in the interpreter;
        # the kernel's tiling is validated in tests at reduced d.
        n = updates.shape[0]
        w = (tau_up.astype(jnp.float32) @
             (A.astype(jnp.float32) * tau_dd.astype(jnp.float32).T)) / n
        return w @ updates.astype(jnp.float32)
    return fused_aggregate_pallas(A, tau_up, tau_dd, updates, block_d=block_d)


def block_relay_mix(Ab: jax.Array, tau_b: jax.Array, updates: jax.Array,
                    *, block_d: int = 2048) -> jax.Array:
    """Blocked consensus Dx~_c = (A_c * tau_c^T) @ Dx_c over (C, m, m)
    cluster blocks; the dense (n, n) mask is never materialized."""
    return block_relay_mix_pallas(Ab, tau_b, updates, block_d=block_d,
                                  interpret=_interpret())


def block_fused_aggregate(Ab: jax.Array, tau_up: jax.Array, tau_b: jax.Array,
                          updates: jax.Array, *,
                          block_d: int = 2048) -> jax.Array:
    """One-pass blocked ColRel PS delta over (C, m, m) cluster blocks:
    (1/n) sum_c tau_c @ ((A_c * tau_c^T) @ Dx_c); output is (d,) fp32."""
    if _interpret():
        # Non-TPU deployable op: the same per-cluster collapse in jnp
        # (identical contraction order to the kernel) — this is the hot
        # path of every clustered training round and the shard benchmark,
        # so it must not emulate the tile grid in the interpreter; the
        # kernel's tiling is validated in tests at reduced d.
        C, m, _ = Ab.shape
        n = C * m
        w = jnp.einsum(
            "ci,cij->cj",
            tau_up.astype(jnp.float32).reshape(C, m),
            Ab.astype(jnp.float32) * jnp.swapaxes(tau_b, 1, 2).astype(jnp.float32),
        ) / n
        return jnp.einsum("cj,cjk->k", w,
                          updates.astype(jnp.float32).reshape(C, m, -1))
    return block_fused_aggregate_pallas(Ab, tau_up, tau_b, updates,
                                        block_d=block_d)


def fused_dequant_aggregate(A: jax.Array, tau_up: jax.Array, tau_dd: jax.Array,
                            q: jax.Array, scale: jax.Array, *,
                            block_d: int = 2048) -> jax.Array:
    """One-pass quantized ColRel PS delta over the int8 affine wire form:
    the per-client dequant scales fold into the collapsed weight row
    ((1/n) tau_up @ (A * tau_dd^T) * scale^T) @ q, so the int8 stack
    crosses HBM once and the f32 stack is never materialized."""
    if _interpret():
        # Non-TPU deployable op: the identical folded contraction in jnp
        # (same collapse order as the kernel); the kernel's tiling is
        # validated in tests at reduced d.
        n = q.shape[0]
        w = (tau_up.astype(jnp.float32) @
             (A.astype(jnp.float32) * tau_dd.astype(jnp.float32).T)) / n
        return (w * scale.reshape(-1)) @ q.astype(jnp.float32)
    return fused_dequant_aggregate_pallas(A, tau_up, tau_dd, q, scale,
                                          block_d=block_d)


def fused_memory_update(A: jax.Array, tau_up: jax.Array, tau_dd: jax.Array,
                        updates: jax.Array, buffer: jax.Array, *,
                        block_d: int = 2048):
    """One-pass memory-strategy round (select-accumulate-update):
    tilde = (A * tau_dd^T) @ updates; contrib = tau*tilde + (1-tau)*buffer;
    returns (delta (d,), contrib (n, d)) with the (n, d) tilde intermediate
    kept in VMEM (never written to HBM) on the kernel path."""
    if _interpret():
        # Non-TPU deployable op: same math and accumulation order as
        # MemoryStrategy.aggregate (the oracle).
        n = updates.shape[0]
        m = A.astype(jnp.float32) * tau_dd.astype(jnp.float32).T
        tilde = m @ updates.astype(jnp.float32)
        t = tau_up.astype(jnp.float32)[:, None]
        contrib = t * tilde + (1.0 - t) * buffer
        delta = jnp.ones((n,), jnp.float32) @ contrib / n
        return delta, contrib
    return fused_memory_update_pallas(A, tau_up, tau_dd, updates, buffer,
                                      block_d=block_d)


# -- segment streaming (DESIGN.md §14) -----------------------------------
#
# At large d the (n, d) stack itself is the memory bottleneck, so the
# collapsed per-round operands (weight row / realized mask) are computed
# once here and each per-leaf (n, d_i) segment streams through its own
# kernel pass — the monolithic stack never materializes.  The interpret
# paths mirror the monolithic interpret expressions exactly: every output
# column is a function of its own input column only, so per-segment
# outputs equal the corresponding columns of the monolithic pass bitwise.


def mixing_mask(A: jax.Array, tau_dd: jax.Array) -> jax.Array:
    """Realized mixing mask ``A * tau_dd^T`` (n, n) f32 — the monolithic
    kernels recompute it in VMEM per tile; the segment-streaming paths
    hoist it to once per round (O(n^2), free next to the stream)."""
    return A.astype(jnp.float32) * tau_dd.astype(jnp.float32).T


def collapsed_weight_row(A: jax.Array, tau_up: jax.Array,
                         tau_dd: jax.Array) -> jax.Array:
    """The ColRel collapse ``(1/n) tau_up @ (A * tau_dd^T)`` as an (n,)
    f32 row — the carried accumulator of the segment-streaming path,
    identical expression (and accumulation) to the ``fused_aggregate``
    interpret path."""
    n = tau_up.shape[0]
    return (tau_up.astype(jnp.float32) @ mixing_mask(A, tau_dd)) / n


def block_collapsed_weight_row(Ab: jax.Array, tau_up: jax.Array,
                               tau_b: jax.Array) -> jax.Array:
    """Per-cluster collapse ``w_c = (1/n) tau_c @ (A_c * tau_c^T)`` as a
    (C, m) f32 tensor — identical einsum to the ``block_fused_aggregate``
    interpret path."""
    C, m, _ = Ab.shape
    n = C * m
    return jnp.einsum(
        "ci,cij->cj",
        tau_up.astype(jnp.float32).reshape(C, m),
        Ab.astype(jnp.float32) * jnp.swapaxes(tau_b, 1, 2).astype(jnp.float32),
    ) / n


def row_stream(w: jax.Array, segment: jax.Array, *,
               block_d: int = 2048) -> jax.Array:
    """One segment's PS-delta columns ``w @ segment`` ((n,) x (n, d_i) ->
    (d_i,) f32); consumes f32/bf16/int8 segments directly."""
    if _interpret():
        # Same contraction as the fused_aggregate interpret path restricted
        # to this segment's columns — bitwise-equal to the monolithic pass.
        return w @ segment.astype(jnp.float32)
    return row_stream_pallas(w, segment, block_d=block_d)


def block_row_stream(w: jax.Array, segment: jax.Array, *,
                     block_d: int = 2048) -> jax.Array:
    """One segment's blocked PS-delta columns
    ``sum_c w_c @ segment_c`` ((C, m) x (n, d_i) -> (d_i,) f32)."""
    if _interpret():
        # Identical einsum form to the block_fused_aggregate interpret path
        # so per-segment outputs match the monolithic pass bitwise.
        C, m = w.shape
        return jnp.einsum("cj,cjk->k", w,
                          segment.astype(jnp.float32).reshape(C, m, -1))
    return block_row_stream_pallas(w, segment, block_d=block_d)


def memory_stream(mix: jax.Array, tau_up: jax.Array, segment: jax.Array,
                  buf_seg: jax.Array, *, block_d: int = 2048):
    """One segment of the memory-strategy recursion against the
    caller-computed realized mask: returns ``(delta_seg (d_i,),
    contrib_seg (n, d_i))`` — the columns ``fused_memory_update`` would
    produce, without the monolithic stack."""
    if _interpret():
        # Same math and accumulation order as the fused_memory_update
        # interpret path (and hence MemoryStrategy.aggregate, the oracle),
        # restricted to this segment's columns.
        n = segment.shape[0]
        tilde = mix @ segment.astype(jnp.float32)
        t = tau_up.astype(jnp.float32)[:, None]
        contrib = t * tilde + (1.0 - t) * buf_seg
        delta = jnp.ones((n,), jnp.float32) @ contrib / n
        return delta, contrib
    return memory_stream_pallas(mix, tau_up, segment, buf_seg,
                                block_d=block_d)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
                    block_q: int = 128, block_kv: int = 128) -> jax.Array:
    """q/k/v (B, T, H, D) -> (B, T, H, D) causal flash attention.

    GQA is handled by the caller (kv heads already broadcast); here H == KV.
    """
    assert causal, "only causal self-attention is kernelized"
    B, T, H, D = q.shape
    KV = k.shape[2]
    if KV != H:  # broadcast grouped kv heads
        G = H // KV
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    bq = min(block_q, T)
    bkv = min(block_kv, T)
    out = flash_attention_pallas(qf, kf, vf, block_q=bq, block_kv=bkv, interpret=_interpret())
    return out.reshape(B, H, T, D).transpose(0, 2, 1, 3)


def ssd_scan(q, k, v, log_decay, *, chunk: int = 64):
    """Chunked SSD recurrence (Mamba2 hot loop), (BH, T, D) layout."""
    return ssd_scan_pallas(q, k, v, log_decay, chunk=chunk, interpret=_interpret())
