"""Pallas TPU kernel for the chunked SSD (Mamba2-style) recurrence.

One (batch*head) slice per grid row; the chunk axis is the innermost,
sequential grid dimension, with the (Dk x Dv) recurrent state living in
VMEM scratch across chunk iterations — the same carry pattern as the
flash-attention kernel's online-softmax state.

Per chunk (Q tokens):
    intra  = (q k^T ⊙ causal-decay) v
    inter  = exp(c_t) * q_t @ S
    S'     = exp(c_last) * S + sum_s exp(c_last - c_s) k_s v_s^T

All decay exponents are differences of cumulative log-decays and are
<= 0 by construction — no overflow, no rescaling passes.

The jnp twin is ``repro.models.ssm.ssd_chunked`` (used by jamba); the
oracle for tests is ``ssm.ssd_reference``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(q_ref, k_ref, v_ref, lc_ref, o_ref, s_scr, *, chunk):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    q = q_ref[0].astype(jnp.float32)  # (Q, Dk)
    k = k_ref[0].astype(jnp.float32)  # (Q, Dk)
    v = v_ref[0].astype(jnp.float32)  # (Q, Dv)
    ld = lc_ref[0].astype(jnp.float32)  # (Q, 1) per-step log decay
    # chunk-LOCAL inclusive cumulative decay, as a tril matmul (MXU-friendly)
    t_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tril = (t_i >= s_i).astype(jnp.float32)
    c = jax.lax.dot(tril, ld, preferred_element_type=jnp.float32)  # (Q, 1)

    # intra-chunk: scores[t, s] = (q_t . k_s) * exp(c_t - c_s), s <= t
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    dec = c - c.reshape(1, chunk)  # (Q, Q): c_t - c_s
    dec = jnp.where(t_i >= s_i, jnp.minimum(dec, 0.0), NEG_INF)
    y = jax.lax.dot(
        (scores * jnp.exp(dec)).astype(v.dtype), v, preferred_element_type=jnp.float32
    )

    # inter-chunk: exp(c_t) * q_t @ S_carry
    y += jnp.exp(c) * jax.lax.dot(q, s_scr[...], preferred_element_type=jnp.float32)

    # state update: S' = exp(c_last) S + sum_s exp(c_last - c_s) k_s v_s^T
    c_last = c[chunk - 1, 0]
    kdec = k * jnp.exp(jnp.minimum(c_last - c, 0.0))
    s_scr[...] = jnp.exp(c_last) * s_scr[...] + jax.lax.dot_general(
        kdec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    q: jax.Array,  # (BH, T, Dk)
    k: jax.Array,  # (BH, T, Dk)
    v: jax.Array,  # (BH, T, Dv)
    log_decay: jax.Array,  # (BH, T) non-positive
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> jax.Array:
    BH, T, Dk = q.shape
    Dv = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    ld = log_decay.astype(jnp.float32)[..., None]  # (BH, T, 1)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, T // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, Dk), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, Dk), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, Dv), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, Dv), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, Dv), q.dtype),
        scratch_shapes=[pltpu.VMEM((Dk, Dv), jnp.float32)],
        interpret=interpret,
    )(q, k, v, ld)
