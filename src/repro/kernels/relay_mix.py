"""Pallas TPU kernel for the ColRel relay consensus (Eq. (3)).

``Dx~ = M @ Dx`` where ``M = A * tau_dd^T`` is the realized (n x n) mixing
matrix and ``Dx`` is the (n, d) stack of flattened client updates with d up
to ~10^11.  The operation is totally memory-bound (arithmetic intensity
~n flops/byte with n = 16..64), so the kernel's job is to stream the
update matrix through VMEM exactly once at full HBM bandwidth with the tiny
mixing matrix pinned in VMEM, instead of letting XLA materialize masked
intermediates (A * tau^T, broadcasts) in HBM.

Tiling: grid of ``cdiv(d, block_d)`` over the d axis; block = (n, block_d)
with block_d a multiple of the 128-lane boundary.  Each grid step does an
(n x n) @ (n x block_d) MXU matmul — fully independent tiles.

The update stack is **never copied or padded on the host**: a partial
final tile reads garbage in its out-of-range lanes, but every output
column depends only on its own input column and Pallas masks out-of-range
writes, so the garbage never lands.  (The previous version materialized a
zero-padded (n_pad, d_pad) copy of the whole stack — a full second HBM
write+read for a kernel whose entire point is single-pass streaming.)
Sub-tile client counts (n not a multiple of the 8-sublane boundary) are
handled by Mosaic's internal masking; n is tiny so the cost is nil.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _relay_mix_kernel(m_ref, x_ref, o_ref):
    m = m_ref[...]
    x = x_ref[...]
    o_ref[...] = jax.lax.dot(
        m, x, precision=jax.lax.Precision.HIGHEST, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def relay_mix_pallas(
    mixing: jax.Array,  # (n, n) float32  — A * tau_dd^T, precomputed
    updates: jax.Array,  # (n, d)
    *,
    block_d: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    n, d = updates.shape
    m = mixing.astype(jnp.float32)
    bd = min(block_d, d)

    return pl.pallas_call(
        _relay_mix_kernel,
        grid=(pl.cdiv(d, bd),),
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),  # mixing pinned in VMEM
            pl.BlockSpec((n, bd), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n, bd), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, d), updates.dtype),
        interpret=interpret,
    )(m, updates)
