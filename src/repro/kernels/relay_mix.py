"""Pallas TPU kernel for the ColRel relay consensus (Eq. (3)).

``Dx~ = M @ Dx`` where ``M = A * tau_dd^T`` is the realized (n x n) mixing
matrix and ``Dx`` is the (n, d) stack of flattened client updates with d up
to ~10^11.  The operation is totally memory-bound (arithmetic intensity
~n flops/byte with n = 16..64), so the kernel's job is to stream the
update matrix through VMEM exactly once at full HBM bandwidth with the tiny
mixing matrix pinned in VMEM, instead of letting XLA materialize masked
intermediates (A * tau^T, broadcasts) in HBM.

Tiling: grid over the d axis; block = (n_pad, block_d) where n_pad rounds
the client count up to the 8-sublane boundary and block_d is a multiple of
the 128-lane boundary.  Each grid step does an (n_pad x n_pad) @
(n_pad x block_d) MXU matmul — d/block_d fully independent tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _relay_mix_kernel(m_ref, x_ref, o_ref):
    m = m_ref[...]
    x = x_ref[...]
    o_ref[...] = jax.lax.dot(
        m, x, precision=jax.lax.Precision.HIGHEST, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def relay_mix_pallas(
    mixing: jax.Array,  # (n, n) float32  — A * tau_dd^T, precomputed
    updates: jax.Array,  # (n, d)
    *,
    block_d: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    n, d = updates.shape
    n_pad = _round_up(max(n, 8), 8)
    d_pad = _round_up(d, block_d)
    m = jnp.zeros((n_pad, n_pad), jnp.float32).at[:n, :n].set(mixing.astype(jnp.float32))
    x = jnp.zeros((n_pad, d_pad), updates.dtype).at[:n, :d].set(updates)

    out = pl.pallas_call(
        _relay_mix_kernel,
        grid=(d_pad // block_d,),
        in_specs=[
            pl.BlockSpec((n_pad, n_pad), lambda i: (0, 0)),  # mixing pinned
            pl.BlockSpec((n_pad, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n_pad, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d_pad), updates.dtype),
        interpret=interpret,
    )(m, x)
    return out[:n, :d]
