"""Pallas TPU kernel fusing dequantize + relay mix + PS accumulate.

The ``quantized`` strategy receives the update stack in the int8 affine
wire format ``(q int8 (n, d), s f32 (n, 1))`` with ``x = q · s`` per
client row.  The naive PS pipeline dequantizes to a full f32 ``(n, d)``
stack (4x the HBM traffic of the wire payload, plus an (n, d) write)
and then runs the ColRel aggregation over it.  But the whole ColRel
collapse is linear in the per-client rows:

    delta = (1/n) tau_up @ ((A * tau_dd^T) @ (q · s))
          = ((1/n) tau_up @ (A * tau_dd^T) · s^T) @ q

so the per-client dequant scales fold straight into the collapsed
weight row, and the kernel streams the **int8** stack through HBM
exactly once — a 4x traffic saving over the dequantize-then-aggregate
oracle on top of the flatten-once wins of ``fused_aggregate``
(DESIGN.md §4/§8).  The dequantized f32 stack is never materialized
anywhere.

Grid layout matches ``fused_aggregate``: the tiny (n, n) / (1, n)
connectivity and scale operands stay pinned in VMEM across the
``cdiv(d, block_d)`` grid; each step reduces its ``(n, block_d)`` int8
tile straight to ``(1, block_d)`` f32.  Tail tiles rely on the same
no-padding argument: every output column is a function of its own
input column only, and Pallas masks out-of-range writes.

The per-leaf / dense dequant path (``codec.decode`` then the inner
strategy's aggregation) is the correctness oracle —
``tests/test_wire.py`` and ``benchmarks/quant_bench.py`` assert
agreement within fp32 contraction-order tolerance.

``dequant_row_stream_pallas`` is the segment-streaming twin
(DESIGN.md §14): the caller folds the per-client scales (and bias
correction) into the collapsed weight row once with
:func:`fold_dequant_scales`, then streams each per-leaf int8 segment
independently — neither the monolithic int8 stack nor any f32 stack
ever materializes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_dequant_kernel(a_ref, tau_dd_t_ref, tau_up_ref, scale_ref, q_ref,
                          o_ref, *, inv_n):
    # Realized mixing mask + scalar collapse, recomputed in VMEM each step.
    m = a_ref[...] * tau_dd_t_ref[...]  # (n, n) = A * tau_dd^T
    w = jax.lax.dot(
        tau_up_ref[...], m,
        precision=jax.lax.Precision.HIGHEST, preferred_element_type=jnp.float32,
    ) * inv_n
    # Fold the per-client dequant scales into the weight row: the int8
    # tile is consumed directly, no f32 stack ever exists.
    ws = w * scale_ref[...]  # (1, n)
    o_ref[...] = jax.lax.dot(
        ws, q_ref[...].astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST, preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fused_dequant_aggregate_pallas(
    A: jax.Array,        # (n, n) float32 relay weights alpha
    tau_up: jax.Array,   # (n,)  uplink arrival indicators
    tau_dd: jax.Array,   # (n, n) D2D arrival indicators (tau_dd[j, i]: j -> i)
    q: jax.Array,        # (n, d) int8 quantized update stack
    scale: jax.Array,    # (n,) or (n, 1) per-client dequant scales
    *,
    block_d: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    """One-pass quantized ColRel PS delta:
    ``(1/n) tau_up @ ((A * tau_dd^T) @ (q * scale))`` computed as
    ``((1/n) tau_up @ (A * tau_dd^T) * scale^T) @ q``.

    Returns the ``(d,)`` fp32 global delta.
    """
    n, d = q.shape
    a = A.astype(jnp.float32)
    tdt = tau_dd.astype(jnp.float32).T  # (n, n), tiny — layout for the mask
    tu = tau_up.astype(jnp.float32).reshape(1, n)
    s = scale.astype(jnp.float32).reshape(1, n)
    bd = min(block_d, d)

    out = pl.pallas_call(
        functools.partial(_fused_dequant_kernel, inv_n=1.0 / n),
        grid=(pl.cdiv(d, bd),),
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),   # A pinned in VMEM
            pl.BlockSpec((n, n), lambda i: (0, 0)),   # tau_dd^T pinned
            pl.BlockSpec((1, n), lambda i: (0, 0)),   # tau_up pinned
            pl.BlockSpec((1, n), lambda i: (0, 0)),   # dequant scales pinned
            pl.BlockSpec((n, bd), lambda i: (0, i)),  # the streamed int8 stack
        ],
        out_specs=pl.BlockSpec((1, bd), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(a, tdt, tu, s, q)
    return out.reshape(d)


def fold_dequant_scales(w: jax.Array, scale: jax.Array) -> jax.Array:
    """Fold the per-client dequant scales into a collapsed weight row:
    ``(w * scale)`` with everything flattened to ``(n,)`` f32.  The same
    fold the monolithic kernel performs in VMEM, hoisted out so the
    segment-streaming path pays it once per round instead of per tile."""
    return w.astype(jnp.float32).reshape(-1) * scale.astype(jnp.float32).reshape(-1)


def dequant_row_stream_pallas(ws: jax.Array, q_segment: jax.Array, *,
                              block_d: int = 2048,
                              interpret: bool = False) -> jax.Array:
    """Stream one int8 segment against the scale-folded weight row.

    ``ws @ q_segment`` with fp32 accumulation — the int8 columns cross
    HBM once and the dequantized f32 form never exists.  Delegates to
    ``row_stream_pallas`` (the kernel upcasts the tile in VMEM)."""
    from repro.kernels.fused_aggregate import row_stream_pallas

    return row_stream_pallas(ws, q_segment, block_d=block_d,
                             interpret=interpret)
