"""Pallas TPU kernel fusing the memory strategy's select-accumulate-update.

The ``memory`` strategy's PS recursion (implicit gossip, arXiv:2404.10091)
is three streaming stages over two ``(n, d)`` buffers — the round's
update stack ``x`` and the replay buffer ``B``:

    tilde    = (A * tau_dd^T) @ x            # ColRel D2D consensus
    contrib  = tau_up ⊙ tilde + (1 - tau_up) ⊙ B     # select
    delta    = (1/n) Σ_i contrib_i                   # accumulate
    B'       = contrib                               # update

Executed separately that is two full reads (x, B) plus an (n, d)
``tilde`` intermediate written and re-read, plus the contrib write —
five (n, d) HBM crossings.  Fused, each ``(n, block_d)`` grid step
reads its x and B tiles once, keeps ``tilde``/``contrib`` in VMEM, and
writes exactly the two outputs the recursion needs: the ``(1, block_d)``
delta tile and the ``(n, block_d)`` new-buffer tile — three crossings,
and no ``tilde`` ever touches HBM (the same flatten-once treatment
``fused_aggregate`` gives colrel; ROADMAP "Per-strategy Pallas
kernels").

The (n, n) connectivity operands and the (n, 1) uplink selector stay
pinned in VMEM across the ``cdiv(d, block_d)`` grid.  Tail tiles need
no host-side padding: every output column depends only on its own
input column and Pallas masks out-of-range writes.

``MemoryStrategy.aggregate`` (pure jnp, same contraction order) is the
correctness oracle — asserted in ``tests/test_wire.py``.

``memory_stream_pallas`` is the segment-streaming twin (DESIGN.md §14):
the realized mixing mask ``A * tau_dd^T`` is computed **once per round**
by the caller and each per-leaf ``(n, d_i)`` segment of the update stack
and the replay buffer streams through independently — the monolithic
``(n, d)`` stack never materializes, and the caller writes each
``contrib`` segment back into the (donated) replay buffer in place.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_memory_kernel(a_ref, tau_dd_t_ref, tau_col_ref, x_ref, buf_ref,
                         delta_ref, contrib_ref, *, inv_n):
    # Realized mixing mask, recomputed in VMEM each grid step.
    m = a_ref[...] * tau_dd_t_ref[...]  # (n, n) = A * tau_dd^T
    tilde = jax.lax.dot(
        m, x_ref[...].astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST, preferred_element_type=jnp.float32,
    )
    t = tau_col_ref[...]  # (n, 1) uplink selector
    contrib = t * tilde + (1.0 - t) * buf_ref[...].astype(jnp.float32)
    contrib_ref[...] = contrib
    delta_ref[...] = jnp.sum(contrib, axis=0, keepdims=True) * inv_n


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fused_memory_update_pallas(
    A: jax.Array,        # (n, n) float32 relay weights alpha
    tau_up: jax.Array,   # (n,)  uplink arrival indicators
    tau_dd: jax.Array,   # (n, n) D2D arrival indicators (tau_dd[j, i]: j -> i)
    updates: jax.Array,  # (n, d) flattened client update stack, f32 or bf16
    buffer: jax.Array,   # (n, d) f32 replay buffer (last delivered contribs)
    *,
    block_d: int = 2048,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """One-pass memory-strategy round: returns ``(delta (d,), buffer' (n, d))``
    with fp32 accumulation throughout."""
    n, d = updates.shape
    a = A.astype(jnp.float32)
    tdt = tau_dd.astype(jnp.float32).T  # (n, n), tiny — layout for the mask
    tcol = tau_up.astype(jnp.float32).reshape(n, 1)
    bd = min(block_d, d)

    delta, contrib = pl.pallas_call(
        functools.partial(_fused_memory_kernel, inv_n=1.0 / n),
        grid=(pl.cdiv(d, bd),),
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),   # A pinned in VMEM
            pl.BlockSpec((n, n), lambda i: (0, 0)),   # tau_dd^T pinned
            pl.BlockSpec((n, 1), lambda i: (0, 0)),   # uplink selector pinned
            pl.BlockSpec((n, bd), lambda i: (0, i)),  # streamed update stack
            pl.BlockSpec((n, bd), lambda i: (0, i)),  # streamed replay buffer
        ],
        out_specs=(
            pl.BlockSpec((1, bd), lambda i: (0, i)),
            pl.BlockSpec((n, bd), lambda i: (0, i)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((1, d), jnp.float32),
            jax.ShapeDtypeStruct((n, d), jnp.float32),
        ),
        interpret=interpret,
    )(a, tdt, tcol, updates, buffer)
    return delta.reshape(d), contrib


def _memory_stream_kernel(mix_ref, tau_col_ref, x_ref, buf_ref,
                          delta_ref, contrib_ref, *, inv_n):
    # The realized mask arrives precomputed (carried across segments).
    tilde = jax.lax.dot(
        mix_ref[...], x_ref[...].astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST, preferred_element_type=jnp.float32,
    )
    t = tau_col_ref[...]  # (n, 1) uplink selector
    contrib = t * tilde + (1.0 - t) * buf_ref[...].astype(jnp.float32)
    contrib_ref[...] = contrib
    delta_ref[...] = jnp.sum(contrib, axis=0, keepdims=True) * inv_n


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def memory_stream_pallas(
    mix: jax.Array,      # (n, n) f32 realized mask A * tau_dd^T (caller-computed)
    tau_up: jax.Array,   # (n,)  uplink arrival indicators
    segment: jax.Array,  # (n, d_i) one leaf's update segment, f32 or bf16
    buf_seg: jax.Array,  # (n, d_i) matching replay-buffer columns, f32
    *,
    block_d: int = 2048,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Segment-streaming memory round: ``(delta_seg (d_i,), contrib_seg
    (n, d_i))`` — the columns :func:`fused_memory_update_pallas` would
    produce for this leaf, without the monolithic stack."""
    n, d = segment.shape
    tcol = tau_up.astype(jnp.float32).reshape(n, 1)
    bd = min(block_d, d)

    delta, contrib = pl.pallas_call(
        functools.partial(_memory_stream_kernel, inv_n=1.0 / n),
        grid=(pl.cdiv(d, bd),),
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),   # realized mask pinned
            pl.BlockSpec((n, 1), lambda i: (0, 0)),   # uplink selector pinned
            pl.BlockSpec((n, bd), lambda i: (0, i)),  # streamed segment
            pl.BlockSpec((n, bd), lambda i: (0, i)),  # streamed buffer columns
        ],
        out_specs=(
            pl.BlockSpec((1, bd), lambda i: (0, i)),
            pl.BlockSpec((n, bd), lambda i: (0, i)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((1, d), jnp.float32),
            jax.ShapeDtypeStruct((n, d), jnp.float32),
        ),
        interpret=interpret,
    )(mix.astype(jnp.float32), tcol, segment, buf_seg)
    return delta.reshape(d), contrib
