"""Pallas TPU kernels for the perf-critical hot spots:

* ``relay_mix``       — the paper's relay consensus over flattened updates
                        (bandwidth-bound (n x n) @ (n x d) streaming matmul).
* ``fused_aggregate`` — the full ColRel aggregation (mixing mask + relay
                        mix + tau-weighted blind PS sum) collapsed into one
                        grid pass: the (n, d) stack crosses HBM exactly
                        once and the output shrinks to the (d,) PS delta.
* ``flash_attention`` — causal online-softmax attention for 32k prefill.
* ``ssd_scan``        — chunked SSD recurrence (Mamba2-style scalar decay,
                        jamba's sequence mixer) with the state carried in
                        VMEM scratch across the sequential chunk grid.

Each kernel ships with a pure-jnp oracle in ``ref.py``; tests sweep
shapes/dtypes in interpret mode and assert_allclose against the oracle.
"""

from . import ops, ref
from .flash_attention import flash_attention_pallas
from .fused_aggregate import fused_aggregate_pallas
from .relay_mix import relay_mix_pallas
from .ssd_scan import ssd_scan_pallas

__all__ = [
    "ops",
    "ref",
    "flash_attention_pallas",
    "fused_aggregate_pallas",
    "relay_mix_pallas",
    "ssd_scan_pallas",
]
