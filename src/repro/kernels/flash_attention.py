"""Pallas TPU flash attention (causal, online softmax) for long prefill.

Canonical TPU tiling: grid = (batch*heads, q_blocks, kv_blocks) with the
kv axis innermost; running (max, sum, acc) state lives in VMEM scratch and
is re-initialized whenever a new q block starts.  Causally dead kv blocks
are skipped with ``pl.when`` so the kernel does the ~T^2/2 work flash
attention is supposed to do.  Block shapes are (block_q x head_dim) and
(block_kv x head_dim) — multiples of (8, 128) for MXU alignment at the
production head dims (64/128 pad to lanes transparently).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale, block_q, block_kv):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal block skip: the first key of this block beyond the last query
    @pl.when(kj * block_kv <= qi * block_q + block_q - 1)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        q_idx = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        k_idx = kj * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        s = jnp.where(q_idx >= k_idx, s, NEG_INF)

        m_prev = m_scr[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = corr * l_scr[...] + p.sum(axis=1, keepdims=True)
        acc_scr[...] = corr * acc_scr[...] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_kv", "interpret"))
def flash_attention_pallas(
    q: jax.Array,  # (BH, T, D)
    k: jax.Array,  # (BH, S, D)
    v: jax.Array,  # (BH, S, D)
    *,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    BH, T, D = q.shape
    S = k.shape[1]
    assert T % block_q == 0 and S % block_kv == 0, (T, S, block_q, block_kv)
    scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_kv=block_kv
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, T // block_q, S // block_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
