"""Pallas TPU kernel fusing the whole ColRel aggregation into one HBM pass.

Fuses three stages that the faithful path executes as separate ops —

  1. mixing-matrix mask       ``M = A * tau_dd^T``          (Eq. (3) mask)
  2. relay mix                ``Dx~ = M @ Dx``              (Eq. (3))
  3. tau-weighted blind PS sum ``(1/n) tau_up @ Dx~``       (Alg. 2 line 5)

— into a single grid pass over the flattened update stack ``Dx (n, d)``.
Because stages 2+3 compose to ``((1/n) tau_up @ M) @ Dx``, each grid step
reduces its ``(n, block_d)`` tile straight to ``(1, block_d)`` with fp32
accumulation: the update stack crosses HBM **exactly once** and the
kernel's output is the ``(d,)`` PS delta instead of a second (n, d)
intermediate (an n-fold write saving over relay_mix + a separate sum).

The tiny (n, n) / (1, n) connectivity operands stay pinned in VMEM across
the grid; the mask and the collapsed weight row are recomputed per step
(O(n^2) flops — free next to the (n x block_d) stream).

Tail handling: the d grid is ``cdiv(d, block_d)`` with **no host-side
padding of the update stack** — out-of-range lanes of the last tile read
garbage, but every output column is a function of its own input column
only, and Pallas masks out-of-range writes, so the garbage never lands.
bf16 updates are supported (fp32 accumulation via preferred_element_type);
the output is always fp32.

``row_stream_pallas`` is the segment-streaming twin (DESIGN.md §14): the
collapsed weight row is computed **once per round** by the caller and
each per-leaf ``(n, d_i)`` segment streams through independently — the
monolithic ``(n, d)`` stack never materializes.  Every output column is
a function of its own input column only, so the per-segment outputs are
exactly the corresponding column ranges of the monolithic pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_aggregate_kernel(a_ref, tau_dd_t_ref, tau_up_ref, x_ref, o_ref, *, inv_n):
    # Stage 1: realized mixing mask, recomputed in VMEM each grid step.
    m = a_ref[...] * tau_dd_t_ref[...]  # (n, n) = A * tau_dd^T
    # Stages 2+3 collapsed: w = (1/n) tau_up @ M, one (1, n) row vector.
    w = jax.lax.dot(
        tau_up_ref[...], m,
        precision=jax.lax.Precision.HIGHEST, preferred_element_type=jnp.float32,
    ) * inv_n
    # Stream the (n, block_d) tile once; reduce straight to (1, block_d).
    o_ref[...] = jax.lax.dot(
        w, x_ref[...].astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST, preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fused_aggregate_pallas(
    A: jax.Array,        # (n, n) float32 relay weights alpha
    tau_up: jax.Array,   # (n,)  uplink arrival indicators
    tau_dd: jax.Array,   # (n, n) D2D arrival indicators (tau_dd[j, i]: j -> i)
    updates: jax.Array,  # (n, d) flattened client update stack, f32 or bf16
    *,
    block_d: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    """One-pass ColRel PS delta: ``(1/n) tau_up @ ((A * tau_dd^T) @ updates)``.

    Returns the ``(d,)`` fp32 global delta.
    """
    n, d = updates.shape
    a = A.astype(jnp.float32)
    tdt = tau_dd.astype(jnp.float32).T  # (n, n), tiny — layout for the mask
    tu = tau_up.astype(jnp.float32).reshape(1, n)
    bd = min(block_d, d)

    out = pl.pallas_call(
        functools.partial(_fused_aggregate_kernel, inv_n=1.0 / n),
        grid=(pl.cdiv(d, bd),),
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),   # A pinned in VMEM
            pl.BlockSpec((n, n), lambda i: (0, 0)),   # tau_dd^T pinned
            pl.BlockSpec((1, n), lambda i: (0, 0)),   # tau_up pinned
            pl.BlockSpec((n, bd), lambda i: (0, i)),  # the streamed stack
        ],
        out_specs=pl.BlockSpec((1, bd), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(a, tdt, tu, updates)
    return out.reshape(d)


def _row_stream_kernel(w_ref, x_ref, o_ref):
    # The weight row arrives precomputed (carried across segments); each
    # grid step streams its (n, block_d) tile straight to (1, block_d).
    o_ref[...] = jax.lax.dot(
        w_ref[...], x_ref[...].astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST, preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def row_stream_pallas(
    w: jax.Array,        # (n,) f32 collapsed weight row (caller computes once)
    segment: jax.Array,  # (n, d_i) one leaf's update segment, f32/bf16/int8
    *,
    block_d: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    """Segment-streaming delta: ``w @ segment`` with fp32 accumulation.

    Returns the ``(d_i,)`` fp32 partial delta for this segment — the
    columns the monolithic :func:`fused_aggregate_pallas` would have
    produced for the same leaf, without ever building the (n, d) stack.
    """
    n, d = segment.shape
    wr = w.astype(jnp.float32).reshape(1, n)
    bd = min(block_d, d)

    out = pl.pallas_call(
        _row_stream_kernel,
        grid=(pl.cdiv(d, bd),),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),   # weight row pinned
            pl.BlockSpec((n, bd), lambda i: (0, i)),  # the streamed segment
        ],
        out_specs=pl.BlockSpec((1, bd), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(wr, segment)
    return out.reshape(d)
