"""Pallas TPU kernels for block-sparse clustered relaying.

Under clustering the (n, n) mixing matrix is block-diagonal: only the C
diagonal ``(m, m)`` blocks carry weight (``core/blocks.py``).  The dense
kernels (``relay_mix.py`` / ``fused_aggregate.py``) would stream an
(n, n) operand that is ``1/C`` nonzero — at n = 2^14, C = 256 that is a
2 GiB mask of which 8 MiB matters.  These kernels index the ``(C, m, m)``
block tensor directly, so per grid step only one cluster's ``(m, m)``
weights and its ``(m, block_d)`` update slab touch VMEM; the dense mask
never exists anywhere, and flops drop from O(n²·d) to O(n·m·d).

Grid layout: ``(cdiv(d, block_d), C)`` with the cluster axis innermost.
For ``block_relay_mix`` every (c, d-tile) pair is independent.  For
``block_fused_aggregate`` the output tile ``(1, block_d)`` is *shared*
across the C cluster steps of one d-tile: cluster partials accumulate
into it in place, which is why the cluster axis must be minormost —
revisits to the same output block are then consecutive, so on TPU the
accumulator stays resident in VMEM across the whole cluster sweep and is
written back to HBM once per d-tile.

Alignment: ``m`` need not be a multiple of the 8-sublane / 128-lane
boundary — Mosaic masks sub-tile operands internally, and the per-column
argument from the dense kernels (each output column depends only on its
own input column; out-of-range writes are masked) carries over
unchanged, so tile-unaligned cluster sizes (m = 5, 48, ...) are exact,
just marginally less efficient.  ``tests/test_clustered.py`` pins them
against the dense oracle.

Like the dense kernels: small operands pinned in VMEM, fp32 accumulation
via ``preferred_element_type``, no host-side padding of the stack.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block_relay_mix_kernel(a_ref, tau_t_ref, x_ref, o_ref):
    # One cluster's realized mixing block, recomputed in VMEM: M_c = A_c * tau_c^T
    m = a_ref[0] * tau_t_ref[0]  # (m, m)
    o_ref[...] = jax.lax.dot(
        m, x_ref[...],
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def block_relay_mix_pallas(
    Ab: jax.Array,     # (C, m, m) float32 per-cluster relay weights
    tau_b: jax.Array,  # (C, m, m) per-cluster D2D indicators
    updates: jax.Array,  # (n, d) = (C*m, d) flattened update stack
    *,
    block_d: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    """Blocked consensus ``Dx~_c = (A_c * tau_c^T) @ Dx_c``: (n, d) ->
    (n, d) without materializing the dense (n, n) mask."""
    C, m, _ = Ab.shape
    n, d = updates.shape
    if n != C * m:
        raise ValueError(f"updates rows {n} != C*m = {C * m}")
    a = Ab.astype(jnp.float32)
    tbt = jnp.swapaxes(tau_b, 1, 2).astype(jnp.float32)
    bd = min(block_d, d)

    return pl.pallas_call(
        _block_relay_mix_kernel,
        grid=(pl.cdiv(d, bd), C),
        in_specs=[
            pl.BlockSpec((1, m, m), lambda i, c: (c, 0, 0)),  # cluster weights
            pl.BlockSpec((1, m, m), lambda i, c: (c, 0, 0)),  # cluster tau^T
            pl.BlockSpec((m, bd), lambda i, c: (c, i)),       # cluster slab
        ],
        out_specs=pl.BlockSpec((m, bd), lambda i, c: (c, i)),
        out_shape=jax.ShapeDtypeStruct((n, d), updates.dtype),
        interpret=interpret,
    )(a, tbt, updates)


def _block_fused_aggregate_kernel(a_ref, tau_t_ref, tau_up_ref, x_ref, o_ref,
                                  *, inv_n):
    c = pl.program_id(1)  # cluster axis is innermost
    m = a_ref[0] * tau_t_ref[0]
    # collapsed cluster weight row: w_c = (1/n) tau_up_c @ M_c, (1, m)
    w = jax.lax.dot(
        tau_up_ref[0], m,
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32,
    ) * inv_n
    partial = jax.lax.dot(
        w, x_ref[...].astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32,
    )

    # The (1, bd) output tile is shared by this d-tile's C cluster steps:
    # initialize on the first cluster, accumulate on the rest.
    @pl.when(c == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(c > 0)
    def _accum():
        o_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def block_fused_aggregate_pallas(
    Ab: jax.Array,      # (C, m, m) float32 per-cluster relay weights
    tau_up: jax.Array,  # (n,) uplink arrival indicators
    tau_b: jax.Array,   # (C, m, m) per-cluster D2D indicators
    updates: jax.Array,  # (n, d) flattened update stack, f32 or bf16
    *,
    block_d: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    """One-pass blocked ColRel PS delta ``(1/n) sum_c tau_c @ (M_c @ Dx_c)``.

    Returns the (d,) fp32 global delta; the stack crosses HBM once and
    neither the dense mask nor a second (n, d) intermediate is ever
    written.
    """
    C, m, _ = Ab.shape
    n, d = updates.shape
    if n != C * m:
        raise ValueError(f"updates rows {n} != C*m = {C * m}")
    a = Ab.astype(jnp.float32)
    tbt = jnp.swapaxes(tau_b, 1, 2).astype(jnp.float32)
    tu = tau_up.astype(jnp.float32).reshape(C, 1, m)
    bd = min(block_d, d)

    out = pl.pallas_call(
        functools.partial(_block_fused_aggregate_kernel, inv_n=1.0 / n),
        grid=(pl.cdiv(d, bd), C),
        in_specs=[
            pl.BlockSpec((1, m, m), lambda i, c: (c, 0, 0)),
            pl.BlockSpec((1, m, m), lambda i, c: (c, 0, 0)),
            pl.BlockSpec((1, 1, m), lambda i, c: (c, 0, 0)),
            pl.BlockSpec((m, bd), lambda i, c: (c, i)),
        ],
        out_specs=pl.BlockSpec((1, bd), lambda i, c: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(a, tbt, tu, updates)
    return out.reshape(d)


def _block_row_stream_kernel(w_ref, x_ref, o_ref):
    c = pl.program_id(1)  # cluster axis is innermost
    partial = jax.lax.dot(
        w_ref[0], x_ref[...].astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32,
    )

    @pl.when(c == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(c > 0)
    def _accum():
        o_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def block_row_stream_pallas(
    w: jax.Array,        # (C, m) f32 collapsed cluster weight rows
    segment: jax.Array,  # (n, d_i) = (C*m, d_i) one leaf's update segment
    *,
    block_d: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    """Segment-streaming blocked delta (DESIGN.md §14): the per-cluster
    collapsed rows arrive precomputed (carried across segments) and each
    cluster's slab of this segment accumulates into the shared output
    tile — the columns ``block_fused_aggregate_pallas`` would produce
    for the same leaf, without the monolithic stack."""
    C, m = w.shape
    n, d = segment.shape
    if n != C * m:
        raise ValueError(f"segment rows {n} != C*m = {C * m}")
    wr = w.astype(jnp.float32).reshape(C, 1, m)
    bd = min(block_d, d)

    out = pl.pallas_call(
        _block_row_stream_kernel,
        grid=(pl.cdiv(d, bd), C),
        in_specs=[
            pl.BlockSpec((1, 1, m), lambda i, c: (c, 0, 0)),  # cluster row
            pl.BlockSpec((m, bd), lambda i, c: (c, i)),       # cluster slab
        ],
        out_specs=pl.BlockSpec((1, bd), lambda i, c: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(wr, segment)
    return out.reshape(d)
