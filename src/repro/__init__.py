"""repro: ColRel (collaborative-relaying federated learning) in JAX.

Subpackages: core (the paper), strategies (the open aggregation-strategy
registry), channel (dynamic link processes + online estimation +
adaptive alpha), fl (federated runtime + declarative ExperimentSpec),
models (the zoo), optim, data, dist, kernels (Pallas), checkpoint,
configs, launch.
"""

__version__ = "1.0.0"
