"""Decoder-only language model assembly (dense / MoE / RWKV / VLM-backbone).

Layers are stored stacked along a leading axis and executed with
``jax.lax.scan`` so HLO size and compile time are O(1) in depth.  Per-layer
heterogeneity that varies *numerically* (gemma3's 5 local : 1 global
sliding-window pattern) is threaded through the scan as a traced per-layer
window array — global layers simply get a window larger than any sequence.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import mlp as mlp_mod
from . import moe as moe_mod
from . import rwkv as rwkv_mod
from .common import (
    ModelConfig,
    Params,
    apply_norm,
    embed_init,
    dense_init,
    init_norm,
    softmax_cross_entropy,
    split_keys,
)

Array = jax.Array

GLOBAL_WINDOW = 1 << 30  # "window" given to non-sliding layers


def window_array(cfg: ModelConfig) -> Array:
    """Per-layer attention window (traced into the layer scan)."""
    if cfg.sliding_window is None:
        return jnp.full((cfg.n_layers,), GLOBAL_WINDOW, jnp.int32)
    if not cfg.local_global_ratio:
        return jnp.full((cfg.n_layers,), cfg.sliding_window, jnp.int32)
    l = jnp.arange(cfg.n_layers)
    period = cfg.local_global_ratio + 1
    is_global = (l % period) == cfg.local_global_ratio
    return jnp.where(is_global, GLOBAL_WINDOW, cfg.sliding_window).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block(cfg: ModelConfig, key) -> Params:
    ks = split_keys(key, ["attn", "ffn", "n1", "n2"])
    if cfg.arch_type == "ssm":  # rwkv6
        return {
            "ln1": init_norm(cfg, cfg.d_model),
            "tm": rwkv_mod.init_rwkv_time_mix(cfg, ks["attn"]),
            "ln2": init_norm(cfg, cfg.d_model),
            "cm": rwkv_mod.init_rwkv_channel_mix(cfg, ks["ffn"]),
        }
    ffn = (
        moe_mod.init_moe(cfg, ks["ffn"])
        if cfg.n_experts > 0
        else mlp_mod.init_mlp(cfg, ks["ffn"])
    )
    return {
        "ln1": init_norm(cfg, cfg.d_model),
        "attn": attn_mod.init_attention(cfg, ks["attn"]),
        "ln2": init_norm(cfg, cfg.d_model),
        "ffn": ffn,
    }


def init_lm(cfg: ModelConfig, key) -> Params:
    ks = split_keys(key, ["embed", "layers", "head", "proj"])
    layer_keys = jax.random.split(ks["layers"], cfg.n_layers)
    layers = jax.vmap(lambda k: _init_block(cfg, k))(layer_keys)
    params = {
        "embed": embed_init(ks["embed"], (cfg.vocab_size, cfg.d_model), cfg.jdtype),
        "layers": layers,
        "final_norm": init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks["head"], (cfg.d_model, cfg.vocab_size), cfg.jdtype)
    if cfg.arch_type == "vlm" or cfg.frontend_tokens:
        params["proj"] = dense_init(ks["proj"], (cfg.d_model, cfg.d_model), cfg.jdtype)
    return params


# ---------------------------------------------------------------------------
# Forward (teacher-forced / prefill)
# ---------------------------------------------------------------------------


def _block(cfg, lp, x, window, use_flash, static_window=None):
    from repro.dist.constraints import constrain_act

    x = constrain_act(cfg, x)
    if cfg.arch_type == "ssm":
        h, _ = rwkv_mod.time_mix(cfg, lp["tm"], apply_norm(cfg, lp["ln1"], x))
        x = x + h
        h, _ = rwkv_mod.channel_mix(cfg, lp["cm"], apply_norm(cfg, lp["ln2"], x))
        return x + h, jnp.float32(0.0)
    h = attn_mod.attention(
        cfg, lp["attn"], apply_norm(cfg, lp["ln1"], x), window=window,
        static_window=static_window, use_flash=use_flash,
    )
    x = x + h
    hn = apply_norm(cfg, lp["ln2"], x)
    if cfg.n_experts > 0:
        h, aux = moe_mod.apply_moe(cfg, lp["ffn"], hn)
    else:
        h, aux = mlp_mod.apply_mlp(cfg, lp["ffn"], hn), jnp.float32(0.0)
    return x + h, aux


def forward(
    cfg: ModelConfig,
    params: Params,
    tokens: Array,
    *,
    prefix_embeds: Optional[Array] = None,
    use_flash: bool = False,
) -> Tuple[Array, Array]:
    """tokens (B, T) -> (logits (B, T_total, V), moe_aux scalar)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if prefix_embeds is not None:
        pref = prefix_embeds.astype(x.dtype) @ params["proj"]
        x = jnp.concatenate([pref, x], axis=1)
    wins = window_array(cfg)

    if cfg.static_window_pattern and cfg.sliding_window is not None:
        # §Perf: unrolled stack with per-layer static windows — local layers
        # use the banded O(T*window) path, global layers the dense path.
        period = (cfg.local_global_ratio or 0) + 1
        aux = jnp.float32(0.0)
        for l in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[l], params["layers"])
            is_global = cfg.local_global_ratio and (
                l % period == cfg.local_global_ratio
            )
            sw = None if is_global else cfg.sliding_window
            blk = lambda lp, x: _block(cfg, lp, x, wins[l], use_flash, static_window=sw)
            if cfg.remat:
                blk = jax.checkpoint(blk)
            x, a = blk(lp, x)
            aux = aux + a
    else:
        block = lambda lp, x, win: _block(cfg, lp, x, win, use_flash)
        if cfg.remat:
            block = jax.checkpoint(block)

        def body(carry, xs):
            x, aux = carry
            lp, win = xs
            x, a = block(lp, x, win)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), (params["layers"], wins), unroll=cfg.n_layers if cfg.scan_unroll else 1)
    x = apply_norm(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logits, aux


def loss_fn(cfg: ModelConfig, params: Params, batch: dict, *, use_flash: bool = False):
    """batch: tokens (B,T) int32, labels (B,T) int32 (-1 = masked),
    optional 'prefix' (B, P, d) frontend embeddings (vlm/audio)."""
    logits, aux = forward(
        cfg, params, batch["tokens"], prefix_embeds=batch.get("prefix"), use_flash=use_flash
    )
    labels = batch["labels"]
    T = labels.shape[1]
    logits = logits[:, -T:]  # drop prefix positions
    mask = (labels >= 0).astype(jnp.float32)
    ce = softmax_cross_entropy(logits, jnp.maximum(labels, 0))
    if "ce_weight" in batch:
        # per-sequence weights (the flat ColRel round: w_{client(seq)}/B)
        seq_loss = jnp.sum(ce * mask, axis=-1) / jnp.maximum(jnp.sum(mask, -1), 1.0)
        loss = jnp.sum(batch["ce_weight"].astype(jnp.float32) * seq_loss)
    else:
        loss = jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + cfg.router_aux_coef * aux
    return total, {"ce": loss, "moe_aux": aux}


# ---------------------------------------------------------------------------
# Decode (single-token serve step against a preallocated KV cache)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    if cfg.arch_type == "ssm":
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_layers, *x.shape)),
            rwkv_mod.init_rwkv_state(cfg, batch),
        )
    return attn_mod.init_kv_cache(cfg, batch, max_len, layers_shape=(cfg.n_layers,))


def decode_step(
    cfg: ModelConfig, params: Params, cache: Params, token: Array, pos: Array
) -> Tuple[Array, Params]:
    """token (B, 1) int32; pos scalar int32 — position being generated.
    Returns (logits (B, V), new cache)."""
    x = jnp.take(params["embed"], token, axis=0)
    wins = window_array(cfg)

    if cfg.arch_type == "ssm":

        def body(x, xs):
            lp, st = xs
            h, tm_state = rwkv_mod.time_mix(cfg, lp["tm"], apply_norm(cfg, lp["ln1"], x), state=st["tm"])
            x = x + h
            h, cm_state = rwkv_mod.channel_mix(cfg, lp["cm"], apply_norm(cfg, lp["ln2"], x), state=st["cm"])
            return x + h, {"tm": tm_state, "cm": cm_state}

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache), unroll=cfg.n_layers if cfg.scan_unroll else 1)
    else:

        def body(x, xs):
            lp, c, win = xs
            h, c = attn_mod.decode_attention(
                cfg, lp["attn"], apply_norm(cfg, lp["ln1"], x), c, pos, window=win
            )
            x = x + h
            hn = apply_norm(cfg, lp["ln2"], x)
            if cfg.n_experts > 0:
                h, _ = moe_mod.apply_moe(cfg, lp["ffn"], hn)
            else:
                h = mlp_mod.apply_mlp(cfg, lp["ffn"], hn)
            return x + h, c

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache, wins), unroll=cfg.n_layers if cfg.scan_unroll else 1)

    x = apply_norm(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head)[:, 0]
    return logits, new_cache
