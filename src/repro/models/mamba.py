"""Mamba block (Mamba2/SSD-style, the TPU-native selective SSM).

Used by the jamba hybrid config.  Per-layer parameters follow the Mamba2
structure: fused in-projection -> (gate z, conv channels xBC, dt), causal
depthwise conv, scalar-per-head decay ``a_t = exp(-exp(A_log) * dt_t)``,
chunked SSD mixer (see ``ssm.py``), gated RMS norm, out-projection.

Decode carries two states per layer: the conv window (last ``d_conv - 1``
inputs) and the SSD state (B, H, d_state, head_dim).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ssm
from .common import ModelConfig, Params, dense_init, split_keys

Array = jax.Array


def _dims(cfg: ModelConfig):
    d_in = cfg.d_inner
    H = cfg.n_ssm_heads
    P = d_in // H  # head dim (the "value" dim of SSD)
    N = cfg.ssm_d_state  # the "key" dim of SSD
    conv_ch = d_in + 2 * N  # x, B, C all pass through the conv
    return d_in, H, P, N, conv_ch


def init_mamba(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    d_in, H, P, N, conv_ch = _dims(cfg)
    ks = split_keys(key, ["in", "conv", "out", "dt", "A"])
    dt_floor = 1e-3
    return {
        "w_in": dense_init(ks["in"], (d, 2 * d_in + 2 * N + H), cfg.jdtype),
        "conv_w": dense_init(ks["conv"], (cfg.ssm_conv, conv_ch), cfg.jdtype, scale=0.5),
        "conv_b": jnp.zeros((conv_ch,), cfg.jdtype),
        "dt_bias": jnp.log(
            jnp.exp(
                jnp.exp(
                    jax.random.uniform(ks["dt"], (H,), jnp.float32, jnp.log(dt_floor), 0.0)
                )
            )
            - 1.0
        ).astype(jnp.float32),
        "A_log": jnp.log(1.0 + jnp.arange(1, H + 1, dtype=jnp.float32) % 16),
        "D": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), cfg.jdtype),
        "w_out": dense_init(ks["out"], (d_in, d), cfg.jdtype),
    }


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv: x (B, T, C), w (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):  # K == 4: tiny unrolled window sum
        out = out + pad[:, i : i + x.shape[1], :] * w[i]
    return out + b


def _split(cfg: ModelConfig, h: Array):
    d_in, H, P, N, _ = _dims(cfg)
    z, xBC, dt = jnp.split(h, [d_in, 2 * d_in + 2 * N], axis=-1)
    return z, xBC, dt


def _gated_norm(x: Array, z: Array, scale: Array, eps: float = 1e-6) -> Array:
    x = x * jax.nn.silu(z)
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * inv).astype(x.dtype) * scale


def mamba_forward(
    cfg: ModelConfig, p: Params, x: Array, state: Optional[Params] = None
) -> Tuple[Array, Optional[Params]]:
    """x (B, T, d) -> (y (B, T, d), final state or None)."""
    B, T, d = x.shape
    d_in, H, P, N, conv_ch = _dims(cfg)
    h = x @ p["w_in"]
    z, xBC_pre, dt = _split(cfg, h)
    xBC = jax.nn.silu(_causal_conv(xBC_pre, p["conv_w"], p["conv_b"]))
    xs, Bc, Cc = jnp.split(xBC, [d_in, d_in + N], axis=-1)
    v = xs.reshape(B, T, H, P)
    k = jnp.broadcast_to(Bc[:, :, None, :], (B, T, H, N))
    q = jnp.broadcast_to(Cc[:, :, None, :], (B, T, H, N))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    loga = -jnp.exp(p["A_log"]) * dt
    v_in = v * dt[..., None].astype(v.dtype)
    ssm_state = None if state is None else state["ssm"]
    y, ssm_out = ssm.ssd_chunked(
        q, k, v_in, loga, state=ssm_state, chunk=min(cfg.ssm_chunk, T)
    )
    y = y + v * p["D"][None, None, :, None].astype(v.dtype)
    y = y.reshape(B, T, d_in)
    y = _gated_norm(y, z, p["norm_scale"])
    out = y @ p["w_out"]
    new_state = None
    if state is not None:
        conv_tail = jnp.concatenate([state["conv"], xBC_pre], axis=1)[:, -(cfg.ssm_conv - 1) :]
        new_state = {"ssm": ssm_out, "conv": conv_tail}
    return out, new_state


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=None) -> Params:
    d_in, H, P, N, conv_ch = _dims(cfg)
    dt = dtype or cfg.jdtype
    return {
        "ssm": jnp.zeros((batch, H, N, P), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dt),
    }


def mamba_step(cfg: ModelConfig, p: Params, x: Array, state: Params) -> Tuple[Array, Params]:
    """Single-token decode.  x (B, 1, d)."""
    B = x.shape[0]
    d_in, H, P, N, conv_ch = _dims(cfg)
    h = x[:, 0] @ p["w_in"]
    z, xBC, dt = _split(cfg, h)
    window = jnp.concatenate([state["conv"], xBC[:, None, :]], axis=1)  # (B, K, C)
    conv = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xBC_t = jax.nn.silu(conv)
    xs, Bc, Cc = jnp.split(xBC_t, [d_in, d_in + N], axis=-1)
    v = xs.reshape(B, H, P)
    k = jnp.broadcast_to(Bc[:, None, :], (B, H, N))
    q = jnp.broadcast_to(Cc[:, None, :], (B, H, N))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    loga = -jnp.exp(p["A_log"]) * dt
    v_in = v * dt[..., None].astype(v.dtype)
    y, ssm_out = ssm.ssd_step(q, k, v_in, loga, state["ssm"])
    y = y + v * p["D"][None, :, None].astype(v.dtype)
    y = y.reshape(B, d_in)
    y = _gated_norm(y, z, p["norm_scale"])
    out = (y @ p["w_out"])[:, None, :]
    return out, {"ssm": ssm_out, "conv": window[:, 1:]}
