"""RWKV6 ("Finch") block: data-dependent per-channel decay linear attention.

Faithful structure: token-shift lerp mixing for (r, k, v, w, g), a low-rank
(LoRA) data-dependent decay ``w_t = exp(-exp(w0 + tanh(m_w @ Wa) @ Wb))``,
the wkv recurrence with bonus ``u`` (see ``ssm.gla_chunked``), per-head
group norm, silu-gated output, and a squared-ReLU channel-mix with its own
token shift and receptance gate.

Simplification vs. the reference implementation (noted in DESIGN.md): the
five mixing coefficients use independent learned lerp weights ``mu_*``
without the extra stacked-LoRA on the mix coefficients themselves; the
decay keeps its full data-dependent LoRA, which is the architectural
signature of RWKV6 vs RWKV5.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ssm
from .common import ModelConfig, Params, dense_init, split_keys

Array = jax.Array


def _dims(cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.n_heads if cfg.n_heads > 0 else d // 64
    Dk = d // H
    return d, H, Dk


def init_rwkv_time_mix(cfg: ModelConfig, key) -> Params:
    d, H, Dk = _dims(cfg)
    lora = max(32, d // 32)
    ks = split_keys(key, ["wr", "wk", "wv", "wg", "wo", "wa", "wb"])
    return {
        "mu": 0.5 * jnp.ones((5, d), cfg.jdtype),  # lerp coefs for r,k,v,w,g
        "wr": dense_init(ks["wr"], (d, d), cfg.jdtype),
        "wk": dense_init(ks["wk"], (d, d), cfg.jdtype),
        "wv": dense_init(ks["wv"], (d, d), cfg.jdtype),
        "wg": dense_init(ks["wg"], (d, d), cfg.jdtype),
        "w0": jnp.full((d,), -2.0, jnp.float32),  # base log-log decay
        "wa": dense_init(ks["wa"], (d, lora), cfg.jdtype),
        "wb": dense_init(ks["wb"], (lora, d), cfg.jdtype, scale=0.01),
        "u": (0.5 * jnp.ones((H, Dk), jnp.float32)),
        "gn_scale": jnp.ones((d,), cfg.jdtype),
        "gn_bias": jnp.zeros((d,), cfg.jdtype),
        "wo": dense_init(ks["wo"], (d, d), cfg.jdtype),
    }


def init_rwkv_channel_mix(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    ks = split_keys(key, ["wk", "wv", "wr"])
    return {
        "mu": 0.5 * jnp.ones((2, d), cfg.jdtype),  # lerp coefs for k, r
        "wk": dense_init(ks["wk"], (d, cfg.d_ff), cfg.jdtype),
        "wv": dense_init(ks["wv"], (cfg.d_ff, d), cfg.jdtype),
        "wr": dense_init(ks["wr"], (d, d), cfg.jdtype),
    }


def _shift(x: Array, last: Optional[Array]) -> Array:
    """Token shift: y_t = x_{t-1}; position 0 gets ``last`` (or zeros)."""
    pad = jnp.zeros_like(x[:, :1]) if last is None else last[:, None, :]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _group_norm(x: Array, H: int, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    """Per-head group norm over (B, T, d) viewed as (B, T, H, Dk)."""
    B, T, d = x.shape
    xf = x.reshape(B, T, H, d // H).astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = xf.var(-1, keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(B, T, d)
    return y.astype(x.dtype) * scale + bias


def time_mix(
    cfg: ModelConfig, p: Params, x: Array, state: Optional[Params] = None
) -> Tuple[Array, Optional[Params]]:
    """x (B, T, d); state {'shift': (B,d), 'wkv': (B,H,Dk,Dk)} for streaming."""
    B, T, d = x.shape
    _, H, Dk = _dims(cfg)
    xx = _shift(x, None if state is None else state["shift"])
    mu = p["mu"]
    mr = x + (xx - x) * mu[0]
    mk = x + (xx - x) * mu[1]
    mv = x + (xx - x) * mu[2]
    mw = x + (xx - x) * mu[3]
    mg = x + (xx - x) * mu[4]
    r = (mr @ p["wr"]).reshape(B, T, H, Dk)
    k = (mk @ p["wk"]).reshape(B, T, H, Dk)
    v = (mv @ p["wv"]).reshape(B, T, H, Dk)
    g = jax.nn.silu(mg @ p["wg"])
    # data-dependent decay (the RWKV6 signature)
    dd = jnp.tanh(mw @ p["wa"]) @ p["wb"]
    logw = -jnp.exp(
        jnp.clip(p["w0"] + dd.astype(jnp.float32), -8.0, 2.0)
    ).reshape(B, T, H, Dk)
    wkv_state = None if state is None else state["wkv"]
    if T == 1 and wkv_state is not None:  # decode fast path
        y, wkv_out = ssm.gla_step(
            r[:, 0], k[:, 0], v[:, 0], logw[:, 0], p["u"], wkv_state
        )
        y = y[:, None]
    else:
        y, wkv_out = ssm.gla_chunked(
            r, k, v, logw, p["u"], state=wkv_state, chunk=min(cfg.ssm_chunk, T)
        )
    y = _group_norm(y.reshape(B, T, d), H, p["gn_scale"], p["gn_bias"])
    out = (y * g) @ p["wo"]
    new_state = None
    if state is not None:
        new_state = {"shift": x[:, -1], "wkv": wkv_out}
    return out, new_state


def channel_mix(
    cfg: ModelConfig, p: Params, x: Array, state: Optional[Array] = None
) -> Tuple[Array, Optional[Array]]:
    xx = _shift(x, state)
    mk = x + (xx - x) * p["mu"][0]
    mr = x + (xx - x) * p["mu"][1]
    h = jnp.square(jax.nn.relu(mk @ p["wk"]))
    out = jax.nn.sigmoid(mr @ p["wr"]) * (h @ p["wv"])
    return out, (x[:, -1] if state is not None else None)


def init_rwkv_state(cfg: ModelConfig, batch: int) -> Params:
    d, H, Dk = _dims(cfg)
    return {
        "tm": {"shift": jnp.zeros((batch, d), cfg.jdtype), "wkv": jnp.zeros((batch, H, Dk, Dk), jnp.float32)},
        "cm": jnp.zeros((batch, d), cfg.jdtype),
    }
