"""Shared model-definition substrate: config, norms, RoPE, initializers.

Parameters are plain nested dicts of ``jax.Array`` (pytrees).  Layer stacks
are stored *stacked along a leading layer axis* and consumed with
``jax.lax.scan`` so that compile time and HLO size are O(1) in depth — a
hard requirement for lowering the 62/72-layer production configs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Any  # nested dict pytree

# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Union config covering every assigned architecture family."""

    name: str = "model"
    arch_type: str = "dense"  # dense | moe | ssm | hybrid | encdec | vlm | audio | cnn
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    head_dim: Optional[int] = None  # default d_model // n_heads
    d_ff: int = 512
    vocab_size: int = 256
    max_seq_len: int = 4096
    # --- norms / attention details ---
    norm: str = "rmsnorm"  # rmsnorm | layernorm | nonparametric_ln
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    mlp: str = "swiglu"  # swiglu | gelu | relu_sq
    attn_logit_softcap: Optional[float] = None
    # sliding-window attention: window size; pattern = how many local layers
    # per global layer (gemma3: 5 local : 1 global).
    sliding_window: Optional[int] = None
    local_global_ratio: Optional[int] = None  # e.g. 5 -> layers 0-4 local, 5 global
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: Optional[int] = None  # defaults to d_ff
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- SSM / hybrid ---
    ssm_d_state: int = 16
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 64
    ssm_heads: Optional[int] = None  # Mamba2-style heads; default d_inner // 64
    attn_every: int = 0  # hybrid: one attention layer per this many layers
    moe_every: int = 0  # hybrid: MoE MLP on layers where (l % moe_every)==moe_every-1
    # --- enc-dec / multimodal ---
    n_encoder_layers: int = 0
    frontend_tokens: int = 0  # audio frames / vision patches provided by stub
    # --- dtype / memory ---
    dtype: str = "float32"  # activation/param dtype for this instantiation
    remat: bool = True  # rematerialize each layer in backward (training)
    # unroll structural scans (layers/local-steps) — used by the dry-run's
    # shallow cost probes so XLA's cost_analysis sees every layer body.
    scan_unroll: bool = False
    # self-attention switches to the query-blocked streaming path (memory
    # O(block x S) instead of O(T x S)) when seq length exceeds this.
    attn_chunk: int = 2048
    # optional PartitionSpec tuple for the trailing (batch, seq, d) dims of
    # the residual stream — see repro/dist/constraints.py.
    act_spec: Optional[tuple] = None
    # optional PartitionSpec tuple for the MoE (E, capacity, d) dispatch
    # buffers (expert parallelism when E divides the model axis, else
    # capacity sharding); set by the launch layer.
    moe_buf_spec: Optional[tuple] = None
    # unroll the layer stack with per-layer STATIC windows: sliding-window
    # layers get the banded O(T*window) attention path instead of computing
    # (and masking) the full T x S score matrix (§Perf, gemma3 prefill).
    static_window_pattern: bool = False
    # --- FL execution (see repro/fl) ---
    fl_mode: str = "per_client"  # per_client | client_sequential | weighted_grad

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads if self.ssm_heads is not None else max(1, self.d_inner // 64)

    @property
    def ffe(self) -> int:
        return self.d_ff_expert if self.d_ff_expert is not None else self.d_ff

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (LeCun-ish), the zoo default."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def split_keys(key, names: Sequence[str]):
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, dim: int) -> Params:
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((dim,), cfg.jdtype)}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((dim,), cfg.jdtype), "bias": jnp.zeros((dim,), cfg.jdtype)}
    if cfg.norm == "nonparametric_ln":  # OLMo: LN without affine params
        return {}
    raise ValueError(f"unknown norm {cfg.norm}")


def apply_norm(cfg: ModelConfig, params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        inv = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (xf * inv).astype(x.dtype) * params["scale"]
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if cfg.norm == "layernorm":
        return y.astype(x.dtype) * params["scale"] + params["bias"]
    return y.astype(x.dtype)  # non-parametric


def rms_head_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head RMS norm used for qk_norm (Qwen3-style)."""
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * inv).astype(x.dtype) * scale


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, H, D); positions: broadcastable to (..., T)."""
    freqs = rope_frequencies(x.shape[-1], theta)  # (D/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., T, 1, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses / misc
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Token-level CE; logits (..., V), labels (...) int32.  fp32 internally."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return logz - gold


def count_params(params: Params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
