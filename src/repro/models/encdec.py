"""Encoder–decoder backbone (seamless-m4t-style, speech-to-text direction).

Per the assignment carve-out, the modality frontend (mel-spectrogram +
conv feature extractor) is a stub: ``input_specs()`` hands the encoder a
precomputed frame-embedding sequence of shape (B, frames, d_model).  The
backbone — a bidirectional transformer encoder plus a causal decoder with
cross-attention — is fully implemented and trained federatedly.

The assigned "24L" is split 24 encoder + 24 decoder layers, matching the
T2TT component of SeamlessM4T-large (see configs/seamless_m4t_large_v2.py).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import mlp as mlp_mod
from .common import (
    ModelConfig,
    Params,
    apply_norm,
    dense_init,
    embed_init,
    init_norm,
    softmax_cross_entropy,
    split_keys,
)

Array = jax.Array


def _init_enc_block(cfg: ModelConfig, key) -> Params:
    ks = split_keys(key, ["attn", "ffn"])
    return {
        "ln1": init_norm(cfg, cfg.d_model),
        "attn": attn_mod.init_attention(cfg, ks["attn"]),
        "ln2": init_norm(cfg, cfg.d_model),
        "ffn": mlp_mod.init_mlp(cfg, ks["ffn"]),
    }


def _init_dec_block(cfg: ModelConfig, key) -> Params:
    ks = split_keys(key, ["self", "cross", "ffn"])
    return {
        "ln1": init_norm(cfg, cfg.d_model),
        "self": attn_mod.init_attention(cfg, ks["self"]),
        "ln_x": init_norm(cfg, cfg.d_model),
        "cross": attn_mod.init_attention(cfg, ks["cross"], cross=True),
        "ln2": init_norm(cfg, cfg.d_model),
        "ffn": mlp_mod.init_mlp(cfg, ks["ffn"]),
    }


def init_encdec(cfg: ModelConfig, key) -> Params:
    ks = split_keys(key, ["embed", "enc", "dec", "head", "front"])
    enc_keys = jax.random.split(ks["enc"], cfg.n_encoder_layers)
    dec_keys = jax.random.split(ks["dec"], cfg.n_layers)
    return {
        "frontend_proj": dense_init(ks["front"], (cfg.d_model, cfg.d_model), cfg.jdtype),
        "embed": embed_init(ks["embed"], (cfg.vocab_size, cfg.d_model), cfg.jdtype),
        "encoder": jax.vmap(lambda k: _init_enc_block(cfg, k))(enc_keys),
        "enc_norm": init_norm(cfg, cfg.d_model),
        "decoder": jax.vmap(lambda k: _init_dec_block(cfg, k))(dec_keys),
        "final_norm": init_norm(cfg, cfg.d_model),
        "lm_head": dense_init(ks["head"], (cfg.d_model, cfg.vocab_size), cfg.jdtype),
    }


def encode(cfg: ModelConfig, params: Params, frames: Array, *, use_flash: bool = False) -> Array:
    """frames (B, F, d) stub frontend embeddings -> encoder memory (B, F, d)."""
    x = frames.astype(cfg.jdtype) @ params["frontend_proj"]

    def blk(lp, x):
        from repro.dist.constraints import constrain_act

        x = constrain_act(cfg, x)
        h = attn_mod.attention(
            cfg, lp["attn"], apply_norm(cfg, lp["ln1"], x), causal=False, use_flash=False
        )
        x = x + h
        h = mlp_mod.apply_mlp(cfg, lp["ffn"], apply_norm(cfg, lp["ln2"], x))
        return x + h

    if cfg.remat:
        blk = jax.checkpoint(blk)

    def body(x, lp):
        return blk(lp, x), None

    x, _ = jax.lax.scan(body, x, params["encoder"], unroll=cfg.n_encoder_layers if cfg.scan_unroll else 1)
    return apply_norm(cfg, params["enc_norm"], x)


def decode_train(
    cfg: ModelConfig, params: Params, tokens: Array, memory: Array, *, use_flash: bool = False
) -> Array:
    x = jnp.take(params["embed"], tokens, axis=0)

    def blk(lp, x, memory):
        from repro.dist.constraints import constrain_act

        x = constrain_act(cfg, x)
        h = attn_mod.attention(cfg, lp["self"], apply_norm(cfg, lp["ln1"], x), use_flash=use_flash)
        x = x + h
        h = attn_mod.attention(
            cfg, lp["cross"], apply_norm(cfg, lp["ln_x"], x), kv_source=memory, causal=False
        )
        x = x + h
        h = mlp_mod.apply_mlp(cfg, lp["ffn"], apply_norm(cfg, lp["ln2"], x))
        return x + h

    if cfg.remat:
        blk = jax.checkpoint(blk)

    def body(x, lp):
        return blk(lp, x, memory), None

    x, _ = jax.lax.scan(body, x, params["decoder"], unroll=cfg.n_layers if cfg.scan_unroll else 1)
    x = apply_norm(cfg, params["final_norm"], x)
    return x @ params["lm_head"]


def loss_fn(cfg: ModelConfig, params: Params, batch: dict, *, use_flash: bool = False):
    """batch: 'prefix' (B, F, d) frames, 'tokens' (B, T), 'labels' (B, T)."""
    memory = encode(cfg, params, batch["prefix"], use_flash=use_flash)
    logits = decode_train(cfg, params, batch["tokens"], memory, use_flash=use_flash)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    ce = softmax_cross_entropy(logits, jnp.maximum(labels, 0))
    if "ce_weight" in batch:
        seq_loss = jnp.sum(ce * mask, axis=-1) / jnp.maximum(jnp.sum(mask, -1), 1.0)
        loss = jnp.sum(batch["ce_weight"].astype(jnp.float32) * seq_loss)
    else:
        loss = jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"ce": loss, "moe_aux": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
# Decode serving: cached self-attention + precomputed cross K/V
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, memory_len: int) -> Params:
    kv = attn_mod.init_kv_cache(cfg, batch, max_len, layers_shape=(cfg.n_layers,))
    mem = jnp.zeros((batch, memory_len, cfg.d_model), cfg.jdtype)
    return {"kv": kv, "memory": mem}


def decode_step(cfg: ModelConfig, params: Params, cache: Params, token: Array, pos: Array):
    x = jnp.take(params["embed"], token, axis=0)
    memory = cache["memory"]

    B = token.shape[0]
    qpos = jnp.broadcast_to(pos, (B, 1))

    def body(x, xs):
        lp, c = xs
        h, c = attn_mod.decode_attention(cfg, lp["self"], apply_norm(cfg, lp["ln1"], x), c, pos)
        x = x + h
        h = attn_mod.attention(
            cfg, lp["cross"], apply_norm(cfg, lp["ln_x"], x), kv_source=memory,
            causal=False, positions=qpos,
        )
        x = x + h
        h = mlp_mod.apply_mlp(cfg, lp["ffn"], apply_norm(cfg, lp["ln2"], x))
        return x + h, c

    x, kv = jax.lax.scan(body, x, (params["decoder"], cache["kv"]), unroll=cfg.n_layers if cfg.scan_unroll else 1)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = (x @ params["lm_head"])[:, 0]
    return logits, {"kv": kv, "memory": memory}
