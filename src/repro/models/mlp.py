"""Feed-forward blocks: SwiGLU (llama-family), GELU (enc-dec), ReLU^2 (rwkv)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, Params, dense_init, split_keys


def init_mlp(cfg: ModelConfig, key, d_ff: int | None = None) -> Params:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    if cfg.mlp == "swiglu":
        ks = split_keys(key, ["wg", "wu", "wd"])
        return {
            "wg": dense_init(ks["wg"], (d, f), cfg.jdtype),
            "wu": dense_init(ks["wu"], (d, f), cfg.jdtype),
            "wd": dense_init(ks["wd"], (f, d), cfg.jdtype),
        }
    ks = split_keys(key, ["wu", "wd"])
    return {
        "wu": dense_init(ks["wu"], (d, f), cfg.jdtype),
        "wd": dense_init(ks["wd"], (f, d), cfg.jdtype),
    }


def apply_mlp(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    if cfg.mlp == "swiglu":
        g = jax.nn.silu(x @ params["wg"])
        return (g * (x @ params["wu"])) @ params["wd"]
    h = x @ params["wu"]
    if cfg.mlp == "gelu":
        h = jax.nn.gelu(h)
    elif cfg.mlp == "relu_sq":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(f"unknown mlp {cfg.mlp}")
    return h @ params["wd"]
