"""Mixture-of-Experts layer with capacity-based static dispatch.

TPU-native formulation: instead of per-token dynamic routing (GPU-style
gather of expert blocks), tokens are scattered into a static
``(n_experts, capacity, d)`` buffer — slots computed from a cumulative
count per expert, overflow tokens dropped (standard capacity-factor
semantics) — so every expert matmul is a fixed-shape
``(E, C, d) x (E, d, f)`` einsum that maps straight onto the MXU and
shards over the mesh ``model`` axis (expert parallelism) when E divides
the axis, or over ``f`` (tensor parallelism inside experts) otherwise.

The router aux (load-balance) loss follows Switch/DBRX convention:
``aux = E * sum_e f_e * P_e``.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, Params, dense_init, split_keys


def init_moe(cfg: ModelConfig, key) -> Params:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.ffe
    ks = split_keys(key, ["router", "wg", "wu", "wd"])
    return {
        "router": dense_init(ks["router"], (d, E), cfg.jdtype),
        "wg": dense_init(ks["wg"], (E, d, f), cfg.jdtype),
        "wu": dense_init(ks["wu"], (E, d, f), cfg.jdtype),
        "wd": dense_init(ks["wd"], (E, f, d), cfg.jdtype),
    }


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(128, -(-c // 128) * 128)  # 128-aligned (lanes + shardable)


def apply_moe(cfg: ModelConfig, params: Params, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (B, T, d) -> (y (B, T, d), aux_loss scalar)."""
    B, T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * T
    C = capacity(N, cfg)

    def _tok(t):
        # keep flattened (N, ...) token tensors sharded after the (B, T)
        # merge (the reshape otherwise drops the act_spec batch sharding)
        if cfg.act_spec is None:
            return t
        from jax.sharding import PartitionSpec as P

        ax = cfg.act_spec[0] or cfg.act_spec[1]
        if ax is None:
            return t
        return jax.lax.with_sharding_constraint(t, P(ax, *([None] * (t.ndim - 1))))

    xt = _tok(x.reshape(N, d))

    logits = (xt @ params["router"]).astype(jnp.float32)  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, K)  # (N, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- slot computation: position of each assignment within its expert --
    flat_expert = expert.reshape(-1)  # (N*K,) in route-priority order
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)  # (N*K, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot  # count of earlier same-expert
    pos = jnp.take_along_axis(pos, flat_expert[:, None], axis=1)[:, 0]  # (N*K,)
    keep = pos < C
    slot = jnp.where(keep, flat_expert * C + pos, E * C)  # E*C == dropped

    token_idx = jnp.repeat(jnp.arange(N), K)

    # ---- dispatch ---------------------------------------------------------
    def _constrain(b):
        if cfg.moe_buf_spec is None:
            return b
        from jax.sharding import PartitionSpec as P

        return jax.lax.with_sharding_constraint(b, P(*cfg.moe_buf_spec))

    buf = jnp.zeros((E * C, d), x.dtype)
    buf = buf.at[slot].add(xt[token_idx], mode="drop")
    buf = _constrain(buf.reshape(E, C, d))

    # ---- expert computation (static shapes, MXU-aligned) ------------------
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["wg"]))
    h = g * jnp.einsum("ecd,edf->ecf", buf, params["wu"])
    out = _constrain(jnp.einsum("ecf,efd->ecd", h, params["wd"]))
    out = out.reshape(E * C, d)

    # ---- combine ----------------------------------------------------------
    contrib = _tok(out[jnp.minimum(slot, E * C - 1)])
    contrib = jnp.where(keep[:, None], contrib, 0.0)
    contrib = contrib * gate.reshape(-1)[:, None].astype(x.dtype)
    y = _tok(jnp.zeros((N, d), x.dtype).at[token_idx].add(contrib))

    # ---- load-balance aux loss -------------------------------------------
    frac = jnp.mean(
        jax.nn.one_hot(expert, E, dtype=jnp.float32).sum(1), axis=0
    ) / K  # f_e: fraction of routed assignments per expert
    pmean = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * pmean)
    return y.reshape(B, T, d), aux.astype(jnp.float32)
