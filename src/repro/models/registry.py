"""Uniform model interface over every architecture family in the zoo.

``build(cfg)`` returns a :class:`ModelBundle` exposing:
  * ``init(key) -> params``
  * ``loss_fn(params, batch) -> (loss, metrics)``      (training objective)
  * ``init_cache(batch, max_len) -> cache``            (decode state)
  * ``decode_step(params, cache, token, pos)``         (one-token serve)

``batch`` is a dict with ``tokens``/``labels`` (LMs), plus ``prefix``
(frontend embeddings) for vlm/audio/encdec, or ``images``/``labels`` for
the CNN.  All functions are pure and jit/pjit-compatible.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

from .common import ModelConfig
from . import cnn as cnn_mod
from . import encdec as encdec_mod
from . import hybrid as hybrid_mod
from . import transformer as tr_mod

Params = Any


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: Any
    init: Callable
    loss_fn: Callable  # (params, batch, *, use_flash=False) -> (loss, metrics)
    forward: Optional[Callable] = None  # (params, batch) -> logits  (prefill)
    init_cache: Optional[Callable] = None  # (batch, max_len) -> cache
    decode_step: Optional[Callable] = None  # (params, cache, token, pos)
    has_decode: bool = True


def build(cfg) -> ModelBundle:
    if isinstance(cfg, cnn_mod.CNNConfig):
        return ModelBundle(
            cfg=cfg,
            init=partial(cnn_mod.init_cnn, cfg),
            loss_fn=lambda params, batch, **kw: cnn_mod.loss_fn(cfg, params, batch),
            has_decode=False,
        )
    assert isinstance(cfg, ModelConfig), cfg
    if cfg.arch_type == "hybrid":
        return ModelBundle(
            cfg=cfg,
            init=partial(hybrid_mod.init_hybrid, cfg),
            loss_fn=partial(hybrid_mod.loss_fn, cfg),
            forward=lambda params, batch, **kw: hybrid_mod.forward(
                cfg, params, batch["tokens"], **kw
            )[0],
            init_cache=partial(hybrid_mod.init_cache, cfg),
            decode_step=partial(hybrid_mod.decode_step, cfg),
        )
    if cfg.arch_type == "encdec" or cfg.arch_type == "audio":

        def _encdec_forward(params, batch, **kw):
            memory = encdec_mod.encode(cfg, params, batch["prefix"], **kw)
            return encdec_mod.decode_train(cfg, params, batch["tokens"], memory, **kw)

        return ModelBundle(
            cfg=cfg,
            init=partial(encdec_mod.init_encdec, cfg),
            loss_fn=partial(encdec_mod.loss_fn, cfg),
            forward=_encdec_forward,
            init_cache=partial(encdec_mod.init_cache, cfg),
            decode_step=partial(encdec_mod.decode_step, cfg),
        )
    # dense / moe / ssm / vlm all route through the generic LM
    return ModelBundle(
        cfg=cfg,
        init=partial(tr_mod.init_lm, cfg),
        loss_fn=partial(tr_mod.loss_fn, cfg),
        forward=lambda params, batch, **kw: tr_mod.forward(
            cfg, params, batch["tokens"], prefix_embeds=batch.get("prefix"), **kw
        )[0],
        init_cache=partial(tr_mod.init_cache, cfg),
        decode_step=partial(tr_mod.decode_step, cfg),
    )
