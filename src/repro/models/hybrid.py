"""Jamba-style hybrid: Mamba + attention 1:7 interleave with MoE MLPs.

Layer pattern per period of ``attn_every`` (=8) layers:
  position 0            -> grouped-query attention mixer
  positions 1..7        -> Mamba (SSD) mixers
MLP pattern: every ``moe_every``-th (=2) layer carries a MoE MLP
(odd positions), the rest a dense SwiGLU — matching Jamba's "MoE every
other layer" at 16 experts / top-2.

The stack is scanned over *periods* (9 for the 72-layer config); inside a
period the 8 heterogeneous sub-layers are unrolled, so HLO contains one
period body regardless of depth.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import mamba as mamba_mod
from . import mlp as mlp_mod
from . import moe as moe_mod
from .common import (
    ModelConfig,
    Params,
    apply_norm,
    dense_init,
    embed_init,
    init_norm,
    softmax_cross_entropy,
    split_keys,
)

Array = jax.Array


def _pattern(cfg: ModelConfig):
    """Static layer pattern within one period."""
    period = cfg.attn_every
    attn_pos = [0]
    mamba_pos = list(range(1, period))
    moe_pos = [j for j in range(period) if cfg.moe_every and j % cfg.moe_every == 1]
    return period, attn_pos, mamba_pos, moe_pos


def n_periods(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.attn_every == 0
    return cfg.n_layers // cfg.attn_every


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_period(cfg: ModelConfig, key) -> Params:
    period, attn_pos, mamba_pos, moe_pos = _pattern(cfg)
    keys = jax.random.split(key, 2 * period + 2)
    p: Params = {"mixers": {}, "ffns": {}, "norms1": {}, "norms2": {}}
    for j in range(period):
        kmix, kffn = keys[2 * j], keys[2 * j + 1]
        p["norms1"][f"l{j}"] = init_norm(cfg, cfg.d_model)
        p["norms2"][f"l{j}"] = init_norm(cfg, cfg.d_model)
        if j in attn_pos:
            p["mixers"][f"l{j}"] = attn_mod.init_attention(cfg, kmix)
        else:
            p["mixers"][f"l{j}"] = mamba_mod.init_mamba(cfg, kmix)
        if j in moe_pos:
            p["ffns"][f"l{j}"] = moe_mod.init_moe(cfg, kffn)
        else:
            p["ffns"][f"l{j}"] = mlp_mod.init_mlp(cfg, kffn)
    return p


def init_hybrid(cfg: ModelConfig, key) -> Params:
    ks = split_keys(key, ["embed", "layers", "head"])
    pk = jax.random.split(ks["layers"], n_periods(cfg))
    periods = jax.vmap(lambda k: _init_period(cfg, k))(pk)
    params = {
        "embed": embed_init(ks["embed"], (cfg.vocab_size, cfg.d_model), cfg.jdtype),
        "periods": periods,
        "final_norm": init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks["head"], (cfg.d_model, cfg.vocab_size), cfg.jdtype)
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _period_fwd(cfg: ModelConfig, pp: Params, x: Array, use_flash: bool):
    from repro.dist.constraints import constrain_act

    x = constrain_act(cfg, x)
    period, attn_pos, _, moe_pos = _pattern(cfg)
    aux = jnp.float32(0.0)
    for j in range(period):
        xn = apply_norm(cfg, pp["norms1"][f"l{j}"], x)
        if j in attn_pos:
            h = attn_mod.attention(cfg, pp["mixers"][f"l{j}"], xn, use_flash=use_flash)
        else:
            h, _ = mamba_mod.mamba_forward(cfg, pp["mixers"][f"l{j}"], xn)
        x = x + h
        xn = apply_norm(cfg, pp["norms2"][f"l{j}"], x)
        if j in moe_pos:
            h, a = moe_mod.apply_moe(cfg, pp["ffns"][f"l{j}"], xn)
            aux = aux + a
        else:
            h = mlp_mod.apply_mlp(cfg, pp["ffns"][f"l{j}"], xn)
        x = x + h
    return x, aux


def forward(cfg: ModelConfig, params: Params, tokens: Array, *, use_flash: bool = False):
    x = jnp.take(params["embed"], tokens, axis=0)

    period = lambda pp, x: _period_fwd(cfg, pp, x, use_flash)
    if cfg.remat:
        period = jax.checkpoint(period)

    def body(carry, pp):
        x, aux = carry
        x, a = period(pp, x)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params["periods"], unroll=n_periods(cfg) if cfg.scan_unroll else 1)
    x = apply_norm(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, aux


def loss_fn(cfg: ModelConfig, params: Params, batch: dict, *, use_flash: bool = False):
    logits, aux = forward(cfg, params, batch["tokens"], use_flash=use_flash)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    ce = softmax_cross_entropy(logits, jnp.maximum(labels, 0))
    if "ce_weight" in batch:
        seq_loss = jnp.sum(ce * mask, axis=-1) / jnp.maximum(jnp.sum(mask, -1), 1.0)
        loss = jnp.sum(batch["ce_weight"].astype(jnp.float32) * seq_loss)
    else:
        loss = jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + cfg.router_aux_coef * aux, {"ce": loss, "moe_aux": aux}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    """Per period: one attention KV cache + 7 mamba states, stacked over
    periods."""
    period, attn_pos, mamba_pos, _ = _pattern(cfg)
    NP = n_periods(cfg)
    kv = attn_mod.init_kv_cache(cfg, batch, max_len, layers_shape=(NP,))
    ms = mamba_mod.init_mamba_state(cfg, batch)
    mamba = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None, None], (NP, len(mamba_pos), *x.shape)), ms
    )
    return {"kv": kv, "mamba": mamba}


def decode_step(cfg: ModelConfig, params: Params, cache: Params, token: Array, pos: Array):
    period, attn_pos, mamba_pos, moe_pos = _pattern(cfg)
    x = jnp.take(params["embed"], token, axis=0)

    def body(x, xs):
        pp, pc = xs
        new_mamba = []
        for j in range(period):
            xn = apply_norm(cfg, pp["norms1"][f"l{j}"], x)
            if j in attn_pos:
                h, kv = attn_mod.decode_attention(cfg, pp["mixers"][f"l{j}"], xn, pc["kv"], pos)
                pc = {**pc, "kv": kv}
            else:
                mi = mamba_pos.index(j)
                st = jax.tree.map(lambda s: s[mi], pc["mamba"])
                h, st = mamba_mod.mamba_step(cfg, pp["mixers"][f"l{j}"], xn, st)
                new_mamba.append(st)
            x = x + h
            xn = apply_norm(cfg, pp["norms2"][f"l{j}"], x)
            if j in moe_pos:
                h, _ = moe_mod.apply_moe(cfg, pp["ffns"][f"l{j}"], xn)
            else:
                h = mlp_mod.apply_mlp(cfg, pp["ffns"][f"l{j}"], xn)
            x = x + h
        mamba_stacked = jax.tree.map(lambda *s: jnp.stack(s), *new_mamba)
        return x, {"kv": pc["kv"], "mamba": mamba_stacked}

    x, new_cache = jax.lax.scan(body, x, (params["periods"], cache), unroll=n_periods(cfg) if cfg.scan_unroll else 1)
    x = apply_norm(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (x @ head)[:, 0], new_cache
