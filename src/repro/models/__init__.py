from .common import ModelConfig, count_params
from .cnn import CNNConfig
from .registry import ModelBundle, build

__all__ = ["ModelConfig", "CNNConfig", "ModelBundle", "build", "count_params"]
