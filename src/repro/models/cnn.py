"""ResNet-20-style CNN for the paper's own CIFAR-10 experiment.

The paper trains ResNet-20 with BatchNorm; in the federated setting
BatchNorm statistics leak across the client/consensus boundary and are a
known FL pathology, so we use GroupNorm (8 groups) — a standard FL
substitution (noted in DESIGN.md §7).  Pure JAX, NHWC.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .common import Params, softmax_cross_entropy, split_keys

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str = "resnet20"
    n_classes: int = 10
    widths: Tuple[int, int, int] = (16, 32, 64)
    blocks_per_stage: int = 3
    image_size: int = 32
    channels: int = 3
    groups: int = 8
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


def _conv_init(key, k, cin, cout, dtype):
    fan_in = k * k * cin
    std = (2.0 / fan_in) ** 0.5
    return (jax.random.normal(key, (k, k, cin, cout), jnp.float32) * std).astype(dtype)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def _gn_init(c, dtype):
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}


def _gn(x, p, groups, eps=1e-5):
    B, H, W, C = x.shape
    g = min(groups, C)
    xf = x.reshape(B, H, W, g, C // g).astype(jnp.float32)
    mu = xf.mean((1, 2, 4), keepdims=True)
    var = xf.var((1, 2, 4), keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(B, H, W, C)
    return y.astype(x.dtype) * p["scale"] + p["bias"]


def init_cnn(cfg: CNNConfig, key) -> Params:
    ks = split_keys(key, ["stem", "stages", "fc"])
    params: Params = {
        "stem": {"w": _conv_init(ks["stem"], 3, cfg.channels, cfg.widths[0], cfg.jdtype),
                 "gn": _gn_init(cfg.widths[0], cfg.jdtype)},
        "stages": [],
    }
    cin = cfg.widths[0]
    skeys = jax.random.split(ks["stages"], len(cfg.widths) * cfg.blocks_per_stage * 3)
    ki = 0
    for s, cout in enumerate(cfg.widths):
        stage = []
        for b in range(cfg.blocks_per_stage):
            stride = 2 if (s > 0 and b == 0) else 1
            blk = {
                "w1": _conv_init(skeys[ki], 3, cin, cout, cfg.jdtype),
                "gn1": _gn_init(cout, cfg.jdtype),
                "w2": _conv_init(skeys[ki + 1], 3, cout, cout, cfg.jdtype),
                "gn2": _gn_init(cout, cfg.jdtype),
            }
            if stride != 1 or cin != cout:
                blk["wproj"] = _conv_init(skeys[ki + 2], 1, cin, cout, cfg.jdtype)
            ki += 3
            stage.append(blk)
            cin = cout
        params["stages"].append(stage)
    fk = jax.random.split(ks["fc"], 1)[0]
    params["fc"] = {
        "w": (jax.random.normal(fk, (cin, cfg.n_classes), jnp.float32) * 0.01).astype(cfg.jdtype),
        "b": jnp.zeros((cfg.n_classes,), cfg.jdtype),
    }
    return params


def forward(cfg: CNNConfig, params: Params, images: Array) -> Array:
    """images (B, H, W, C) -> logits (B, n_classes)."""
    x = images.astype(cfg.jdtype)
    x = jax.nn.relu(_gn(_conv(x, params["stem"]["w"]), params["stem"]["gn"], cfg.groups))
    for s, stage in enumerate(params["stages"]):
        for b, blk in enumerate(stage):
            stride = 2 if (s > 0 and b == 0) else 1
            h = jax.nn.relu(_gn(_conv(x, blk["w1"], stride), blk["gn1"], cfg.groups))
            h = _gn(_conv(h, blk["w2"]), blk["gn2"], cfg.groups)
            sc = _conv(x, blk["wproj"], stride) if "wproj" in blk else x
            x = jax.nn.relu(h + sc)
    x = x.mean((1, 2))
    return x @ params["fc"]["w"] + params["fc"]["b"]


def loss_fn(cfg: CNNConfig, params: Params, batch: dict):
    logits = forward(cfg, params, batch["images"])
    loss = jnp.mean(softmax_cross_entropy(logits, batch["labels"]))
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
    return loss, {"ce": loss, "acc": acc}
