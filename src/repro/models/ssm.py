"""Linear-recurrence sequence mixers: chunked SSD (Mamba2-style) and
per-channel gated linear attention (RWKV6-style), plus single-token decode.

TPU adaptation (see DESIGN.md §4): GPU Mamba/RWKV kernels are sequential
selective scans with fused shared-memory tiles.  On TPU the idiomatic
formulation is *chunkwise parallel*: the sequence is split into chunks of
``Q`` tokens; within a chunk the recurrence is evaluated as masked
matmuls (MXU work), and a tiny ``lax.scan`` carries the recurrent state
across chunks.  All exponentials are arranged as differences of cumulative
log-decays with non-positive exponents, so the math is overflow-free by
construction (no GLA-style secondary rescaling needed).

Conventions: q/k: (B, T, H, Dk), v: (B, T, H, Dv).
  * SSD  (scalar decay / head):  S_t = a_t S_{t-1} + k_t v_t^T,  y_t = q_t S_t
  * GLA  (per-channel decay):    S_t = diag(w_t) S_{t-1} + k_t v_t^T,
                                 y_t = q_t (S_{t-1} + diag(u) k_t v_t^T)
    (RWKV6 form: the current token enters through the bonus ``u``.)
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def _chunk(x: Array, q: int) -> Array:
    """(B, T, ...) -> (B, T//q, q, ...)."""
    b, t = x.shape[:2]
    assert t % q == 0, f"seq len {t} not divisible by chunk {q}"
    return x.reshape(b, t // q, q, *x.shape[2:])


# ---------------------------------------------------------------------------
# SSD: scalar per-head decay (Mamba2-style), chunked
# ---------------------------------------------------------------------------


def ssd_chunked(
    q: Array, k: Array, v: Array, loga: Array, state: Optional[Array] = None, chunk: int = 64
) -> Tuple[Array, Array]:
    """loga: (B, T, H) non-positive log decays.  Returns (y, final_state);
    state: (B, H, Dk, Dv)."""
    B, T, H, Dk = q.shape
    Dv = v.shape[-1]
    f32 = jnp.float32
    qc = _chunk(q, chunk).astype(f32)
    kc = _chunk(k, chunk).astype(f32)
    vc = _chunk(v, chunk).astype(f32)
    lc = _chunk(loga, chunk).astype(f32)  # (B, N, Q, H)
    c = jnp.cumsum(lc, axis=2)  # inclusive cumulative log decay

    # intra-chunk: y_t += sum_{s<=t} exp(c_t - c_s) (q_t . k_s) v_s
    scores = jnp.einsum("bnqhd,bnshd->bnhqs", qc, kc)
    decay = c[..., :, None, :].transpose(0, 1, 4, 2, 3) - c[..., None, :, :].transpose(0, 1, 4, 2, 3)
    # decay[b,n,h,t,s] = c_t - c_s
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    w = jnp.exp(jnp.where(tri, jnp.minimum(decay, 0.0), -jnp.inf))
    y_intra = jnp.einsum("bnhqs,bnshd->bnqhd", scores * w, vc)

    # chunk summaries
    clast = c[:, :, -1, :]  # (B, N, H)
    # state contribution of each chunk: sum_s exp(c_last - c_s) k_s v_s^T
    kdec = kc * jnp.exp(clast[:, :, None, :] - c)[..., None]
    chunk_states = jnp.einsum("bnshd,bnshe->bnhde", kdec, vc)  # (B,N,H,Dk,Dv)

    if state is None:
        state = jnp.zeros((B, H, Dk, Dv), f32)

    def step(S, inp):
        cs, cl, qdec_y = inp
        # y_inter for this chunk: exp(c_t) q_t . S_carry
        y_in = jnp.einsum("bqhd,bhde->bqhe", qdec_y, S)
        S_new = jnp.exp(cl)[..., None, None] * S + cs
        return S_new, y_in

    qdec = qc * jnp.exp(c)[..., None]  # (B,N,Q,H,Dk)
    # scan over chunks (leading axis N)
    xs = (
        chunk_states.transpose(1, 0, 2, 3, 4),
        clast.transpose(1, 0, 2),
        qdec.transpose(1, 0, 2, 3, 4),
    )
    state, y_inter = jax.lax.scan(step, state.astype(f32), xs)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)  # (B,N,Q,H,Dv)

    y = (y_intra + y_inter).reshape(B, T, H, Dv).astype(q.dtype)
    return y, state


def ssd_step(q: Array, k: Array, v: Array, loga: Array, state: Array) -> Tuple[Array, Array]:
    """Single-token decode.  q/k (B,H,Dk), v (B,H,Dv), loga (B,H)."""
    f32 = jnp.float32
    a = jnp.exp(loga.astype(f32))[..., None, None]
    S = a * state + jnp.einsum("bhd,bhe->bhde", k.astype(f32), v.astype(f32))
    y = jnp.einsum("bhd,bhde->bhe", q.astype(f32), S)
    return y.astype(q.dtype), S


# ---------------------------------------------------------------------------
# GLA: per-channel decay with bonus (RWKV6-style), chunked
# ---------------------------------------------------------------------------


def gla_chunked(
    r: Array,
    k: Array,
    v: Array,
    logw: Array,
    u: Array,
    state: Optional[Array] = None,
    chunk: int = 32,
) -> Tuple[Array, Array]:
    """RWKV6 wkv with per-channel data-dependent decay.

    r/k/logw: (B, T, H, Dk); v: (B, T, H, Dv); u: (H, Dk) bonus.
    Returns (y (B,T,H,Dv), final state (B,H,Dk,Dv)).
    """
    B, T, H, Dk = r.shape
    Dv = v.shape[-1]
    f32 = jnp.float32
    rc = _chunk(r, chunk).astype(f32)
    kc = _chunk(k, chunk).astype(f32)
    vc = _chunk(v, chunk).astype(f32)
    lw = _chunk(logw, chunk).astype(f32)  # (B,N,Q,H,Dk), <= 0
    c = jnp.cumsum(lw, axis=2)  # inclusive
    cprev = c - lw  # exclusive: decay accumulated before token t

    # intra-chunk, strictly causal: W[t,s] = sum_d r_td k_sd exp(cprev_t - c_s)_d
    # exponent cprev_t - c_s <= 0 for s <= t-1; mask s >= t.
    rt = rc.transpose(0, 1, 3, 2, 4)  # (B,N,H,Q,Dk)
    kt = kc.transpose(0, 1, 3, 2, 4)
    ct = c.transpose(0, 1, 3, 2, 4)
    cpt = cprev.transpose(0, 1, 3, 2, 4)
    dec = jnp.exp(
        jnp.minimum(cpt[..., :, None, :] - ct[..., None, :, :], 0.0)
    )  # (B,N,H,Q,Q,Dk)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    scores = jnp.einsum("bnhtd,bnhsd,bnhtsd->bnhts", rt, kt, dec)
    scores = jnp.where(tri, scores, 0.0)
    vt = vc.transpose(0, 1, 3, 2, 4)  # (B,N,H,Q,Dv)
    y_intra = jnp.einsum("bnhts,bnhse->bnhte", scores, vt)

    # bonus (current token): y_t += (r_t . (u * k_t)) v_t
    bonus = jnp.einsum("bnhtd,hd,bnhtd->bnht", rt, u.astype(f32), kt)
    y_intra = y_intra + bonus[..., None] * vt

    # inter-chunk
    clast = c[:, :, -1]  # (B,N,H,Dk)
    kdec = kc * jnp.exp(jnp.minimum(clast[:, :, None] - c, 0.0))
    chunk_states = jnp.einsum("bnshd,bnshe->bnhde", kdec, vc)
    rdec = rc * jnp.exp(cprev)  # exp(cprev) <= 1

    if state is None:
        state = jnp.zeros((B, H, Dk, Dv), f32)

    def step(S, inp):
        cs, cl, rd = inp
        y_in = jnp.einsum("bqhd,bhde->bqhe", rd, S)
        S_new = jnp.exp(cl)[..., None] * S + cs
        return S_new, y_in

    xs = (
        chunk_states.transpose(1, 0, 2, 3, 4),
        clast.transpose(1, 0, 2, 3),
        rdec.transpose(1, 0, 2, 3, 4),
    )
    state, y_inter = jax.lax.scan(step, state.astype(f32), xs)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4).transpose(0, 1, 3, 2, 4)  # (B,N,H,Q,Dv)

    y = (y_intra + y_inter).transpose(0, 1, 3, 2, 4).reshape(B, T, H, Dv)
    return y.astype(r.dtype), state


def gla_step(
    r: Array, k: Array, v: Array, logw: Array, u: Array, state: Array
) -> Tuple[Array, Array]:
    """Single-token RWKV6 decode.  r/k/logw (B,H,Dk), v (B,H,Dv), u (H,Dk)."""
    f32 = jnp.float32
    rf, kf, vf = r.astype(f32), k.astype(f32), v.astype(f32)
    kv = jnp.einsum("bhd,bhe->bhde", kf, vf)
    y = jnp.einsum("bhd,bhde->bhe", rf, state + u.astype(f32)[None, :, :, None] * kv)
    S = jnp.exp(logw.astype(f32))[..., None] * state + kv
    return y.astype(r.dtype), S


# ---------------------------------------------------------------------------
# Reference (sequential) implementations — oracles for tests
# ---------------------------------------------------------------------------


def ssd_reference(q, k, v, loga, state=None):
    B, T, H, Dk = q.shape
    Dv = v.shape[-1]
    S = jnp.zeros((B, H, Dk, Dv), jnp.float32) if state is None else state.astype(jnp.float32)
    ys = []
    for t in range(T):
        y, S = ssd_step(q[:, t], k[:, t], v[:, t], loga[:, t], S)
        ys.append(y)
    return jnp.stack(ys, axis=1), S


def gla_reference(r, k, v, logw, u, state=None):
    B, T, H, Dk = r.shape
    Dv = v.shape[-1]
    S = jnp.zeros((B, H, Dk, Dv), jnp.float32) if state is None else state.astype(jnp.float32)
    ys = []
    for t in range(T):
        y, S = gla_step(r[:, t], k[:, t], v[:, t], logw[:, t], u, S)
        ys.append(y)
    return jnp.stack(ys, axis=1), S
