"""Grouped-query attention with RoPE, qk-norm, sliding windows, KV caches.

One implementation serves every attention-bearing arch in the zoo:
  * GQA with arbitrary kv-head count (MQA when n_kv_heads == 1, gemma3).
  * Optional per-head RMS qk_norm (qwen3).
  * Optional sliding-window masking (gemma3 local layers).
  * Optional logit soft-capping.
  * Self- or cross-attention (seamless-m4t decoder).
  * Single-token decode against a preallocated KV cache.

The jnp path below is the reference; ``repro.kernels.flash_attention``
provides the Pallas TPU kernel for long-sequence prefill and is selected
via ``use_flash=True`` in the callers (``repro/models/transformer.py``).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .common import ModelConfig, Params, apply_rope, dense_init, rms_head_norm, split_keys

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_attention(cfg: ModelConfig, key, *, cross: bool = False) -> Params:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = split_keys(key, ["wq", "wk", "wv", "wo", "qs", "ks"])
    p = {
        "wq": dense_init(ks["wq"], (d, H, hd), cfg.jdtype),
        "wk": dense_init(ks["wk"], (d, KV, hd), cfg.jdtype),
        "wv": dense_init(ks["wv"], (d, KV, hd), cfg.jdtype),
        "wo": dense_init(ks["wo"], (H, hd, d), cfg.jdtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.jdtype)
        p["k_norm"] = jnp.ones((hd,), cfg.jdtype)
    del cross  # same parameter structure; kv source differs at apply time
    return p


# ---------------------------------------------------------------------------
# Masking helpers
# ---------------------------------------------------------------------------


def causal_mask(tq: int, tk: int, *, offset: int = 0, window: Optional[int] = None) -> jax.Array:
    """(tq, tk) boolean mask; query position i attends key j iff
    j <= i + offset (and i + offset - j < window when sliding)."""
    qi = jnp.arange(tq)[:, None] + offset
    kj = jnp.arange(tk)[None, :]
    m = kj <= qi
    if window is not None:
        m &= (qi - kj) < window
    return m


# ---------------------------------------------------------------------------
# Core attention
# ---------------------------------------------------------------------------


def _sdpa(q, k, v, mask, softcap: Optional[float]) -> jax.Array:
    """q (B,T,H,hd), k/v (B,S,KV,hd) -> (B,T,H,hd).  fp32 softmax."""
    B, T, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, T, KV, G, hd)
    kf = k.astype(jnp.float32)
    logits = jnp.einsum("btkgh,bskh->bkgts", qf, kf) / jnp.sqrt(hd).astype(jnp.float32)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    if mask is not None:
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", w, v.astype(jnp.float32))
    return out.reshape(B, T, H, hd).astype(q.dtype)


def _chunked_causal_sdpa(
    q, k, v, window, softcap: Optional[float], chunk: int, unroll: bool
) -> jax.Array:
    """Query-blocked causal attention: memory O(chunk x S) per block.

    ``window`` may be a traced scalar (per-layer sliding windows inside a
    layer scan).  Each block body is checkpointed so the backward pass
    recomputes its (chunk x S) logits instead of storing all of them.
    """
    B, T, H, hd = q.shape
    S = k.shape[1]
    nq = T // chunk
    qb = q.reshape(B, nq, chunk, H, hd).transpose(1, 0, 2, 3, 4)  # (nq,B,c,H,hd)
    offs = jnp.arange(nq) * chunk

    def block(qi, off):
        kj = jnp.arange(S)[None, :]
        qidx = off + jnp.arange(chunk)[:, None]
        m = kj <= qidx
        if window is not None:
            m &= (qidx - kj) < window
        return _sdpa(qi, k, v, m, softcap)

    block = jax.checkpoint(block)

    def body(_, xs):
        qi, off = xs
        return None, block(qi, off)

    _, ob = jax.lax.scan(body, None, (qb, offs), unroll=nq if unroll else 1)
    return ob.transpose(1, 0, 2, 3, 4).reshape(B, T, H, hd)


def banded_causal_sdpa(
    q, k, v, window: int, softcap: Optional[float], chunk: int
) -> jax.Array:
    """Statically-banded sliding-window attention: each query block only
    reads the (window + chunk) keys it can see.  FLOPs and memory are
    O(T * (window + chunk)) instead of O(T * S) — the static specialization
    of gemma3-style local layers (window must be a python int)."""
    B, T, H, hd = q.shape
    band = window + chunk  # static band width
    outs = []
    for o in range(0, T, chunk):
        qi = q[:, o : o + chunk]
        start = max(0, o + chunk - band)
        width = min(band, o + chunk)
        kb = jax.lax.dynamic_slice_in_dim(k, start, width, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, start, width, axis=1)
        qidx = o + jnp.arange(chunk)[:, None]
        kidx = start + jnp.arange(width)[None, :]
        m = (kidx <= qidx) & ((qidx - kidx) < window)
        outs.append(_sdpa(qi, kb, vb, m, softcap))
    return jnp.concatenate(outs, axis=1)


def attention(
    cfg: ModelConfig,
    params: Params,
    x: jax.Array,
    *,
    positions: Optional[jax.Array] = None,
    kv_source: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
    window: Optional[jax.Array] = None,
    static_window: Optional[int] = None,
    causal: bool = True,
    use_flash: bool = False,
) -> jax.Array:
    """Full-sequence attention.  ``kv_source`` switches to cross-attention
    (no causal mask, no RoPE sharing assumptions beyond positions given)."""
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    src = x if kv_source is None else kv_source
    S = src.shape[1]
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    k = jnp.einsum("bsd,dhk->bshk", src, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, params["wv"])
    if cfg.qk_norm:
        q = rms_head_norm(q, params["q_norm"])
        k = rms_head_norm(k, params["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, kv_positions, cfg.rope_theta)

    is_self_causal = kv_source is None and causal
    if is_self_causal and static_window is not None and T > cfg.attn_chunk:
        out = banded_causal_sdpa(
            q, k, v, static_window, cfg.attn_logit_softcap, cfg.attn_chunk
        )
    elif use_flash and is_self_causal and window is None:
        from repro.kernels import ops as _kops

        out = _kops.flash_attention(q, k, v, causal=True)
    elif is_self_causal and T > cfg.attn_chunk and T % cfg.attn_chunk == 0:
        out = _chunked_causal_sdpa(
            q, k, v, window, cfg.attn_logit_softcap, cfg.attn_chunk, cfg.scan_unroll
        )
    else:
        mask = causal_mask(T, S, window=window) if is_self_causal else None
        out = _sdpa(q, k, v, mask, cfg.attn_logit_softcap)
    return jnp.einsum("bthk,hkd->btd", out, params["wo"])


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, layers_shape=()) -> Params:
    KV, hd = cfg.n_kv_heads, cfg.hd
    shape = (*layers_shape, batch, max_len, KV, hd)
    return {
        "k": jnp.zeros(shape, cfg.jdtype),
        "v": jnp.zeros(shape, cfg.jdtype),
    }


def decode_attention(
    cfg: ModelConfig,
    params: Params,
    x: jax.Array,
    cache: Params,
    pos: jax.Array,
    *,
    window: Optional[int] = None,
) -> tuple[jax.Array, Params]:
    """One-token decode.  x (B, 1, d); cache k/v (B, S, KV, hd); ``pos`` the
    scalar index being written.  Returns (output (B,1,d), updated cache)."""
    B, _, _ = x.shape
    S = cache["k"].shape[1]
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    k_new = jnp.einsum("btd,dhk->bthk", x, params["wk"])
    v_new = jnp.einsum("btd,dhk->bthk", x, params["wv"])
    if cfg.qk_norm:
        q = rms_head_norm(q, params["q_norm"])
        k_new = rms_head_norm(k_new, params["k_norm"])
    posb = jnp.broadcast_to(pos, (B, 1))
    q = apply_rope(q, posb, cfg.rope_theta)
    k_new = apply_rope(k_new, posb, cfg.rope_theta)

    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, pos, 0, 0))

    idx = jnp.arange(S)
    valid = idx <= pos
    if window is not None:
        valid &= (pos - idx) < window
    mask = valid[None, :]  # (1, S) -> broadcast as (tq=1, tk=S)
    out = _sdpa(q, k, v, mask, cfg.attn_logit_softcap)
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return y, {"k": k, "v": v}
