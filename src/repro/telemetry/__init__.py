"""Observability subsystem (DESIGN.md §11): three dependency-free tiers.

1. **Device tier** (:mod:`repro.telemetry.device`) — per-client /
   per-link vector metrics computed *inside* the compiled round and
   stacked ``(K, n)`` per scan chunk: participation vectors, per-client
   bits-on-air, outage-streak ages (a traced ``(n,)`` carry), and the
   realized unbiasedness drift.
2. **Host tier** (:mod:`repro.telemetry.logger`,
   :mod:`repro.telemetry.manifest`) — one deduped append path for every
   metric stream, pluggable sinks (JSONL events, CSV summary,
   in-memory), structured health events (``health.nan``,
   ``health.recompile``), and a :class:`RunManifest` written at run
   start (config digest, strategy/channel/codec, mesh, backend, git
   SHA).
3. **Timing tier** (:mod:`repro.telemetry.timing`) — fenced wall-clock
   throughput, jit recompile tracking, and opt-in
   ``jax.profiler.trace`` capture windows.

Everything is stdlib + numpy + jax; nothing here imports the FL stack
(the trainer imports *us*), and with no sinks attached the whole layer
reduces to one numpy cast per chunk.
"""

from repro.telemetry.device import (
    VECTOR_METRICS,
    init_streak,
    instrument_round_fn,
    update_streak,
)
from repro.telemetry.logger import (
    SCALAR_STREAMS,
    CsvSummarySink,
    JsonlSink,
    MemorySink,
    MetricsLogger,
    MetricsSink,
)
from repro.telemetry.manifest import RunManifest, config_digest, git_sha
from repro.telemetry.timing import CompileTracker, ProfileWindow, ThroughputMeter

__all__ = [
    "VECTOR_METRICS",
    "SCALAR_STREAMS",
    "init_streak",
    "update_streak",
    "instrument_round_fn",
    "MetricsSink",
    "JsonlSink",
    "CsvSummarySink",
    "MemorySink",
    "MetricsLogger",
    "RunManifest",
    "config_digest",
    "git_sha",
    "CompileTracker",
    "ProfileWindow",
    "ThroughputMeter",
]
