"""Host-side metrics pipeline: one append path, pluggable sinks.

The :class:`MetricsLogger` is the single choke point every metric
stream passes through.  Both trainer execution paths — the per-round
host loop and the chunked scan engine — call the same
:meth:`MetricsLogger.log_rounds` with the same float-cast code, so the
two streams *cannot* drift (pre-telemetry they built their casts
independently); the legacy :class:`~repro.fl.trainer.TrainLog` remains
attached as a bitwise-compatible facade (same fields, same values, same
python types).

Events flow to pluggable sinks:

* :class:`JsonlSink` — append-only ``events.jsonl``, one compact JSON
  object per line, buffered (one write per chunk, not per round);
* :class:`CsvSummarySink` — per-round scalar table ``rounds.csv``;
* :class:`MemorySink` — in-process list (tests, report tooling).

Event kinds: ``round`` (per-round scalars), ``eval``, ``reopt``,
``timing`` (per-chunk wall clock + rounds/sec), ``health.nan`` (a
non-finite loss — emitted as a structured event instead of being
silently appended), ``health.recompile`` (jit cache growth), and
``summary.clients`` (end-of-run per-client aggregates of the
device-resident vector metrics).

Vector metrics (``(K, n)`` per chunk off the device) are accumulated
host-side as numpy — O(n) per round, no JSON cost — and exposed as
``logger.vector(name) -> (R, n)``; ``save_vectors`` dumps them as one
``.npz``.  Monotonic indexing: every event carries ``seq`` (emission
order) and round-scoped events carry their round index.
"""

from __future__ import annotations

import json
import pathlib
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.telemetry.device import VECTOR_METRICS

__all__ = ["MetricsSink", "JsonlSink", "CsvSummarySink", "MemorySink",
           "MetricsLogger", "SCALAR_STREAMS"]

#: scalar metric streams a round event may carry, mapped to their
#: TrainLog facade field (None = event-only, no facade list)
SCALAR_STREAMS = {
    "loss": "loss",
    "participation": "participation",
    "uplink_bits": "uplink_bits",
    "weight_sum": "weight_sums",
    "weight_drift": None,
    "delta_norm": None,
    # async execution mode (DESIGN.md §13): realized staleness profile.
    # Event-only — the TrainLog facade stays bitwise-identical for sync
    # runs and async runs read these off the round events.
    "mean_age": None,
    "max_age": None,
    "stale_frac": None,
}


class MetricsSink:
    """Sink protocol: receives event dicts, flushes on demand."""

    def emit(self, event: Dict[str, Any]) -> None:  # pragma: no cover
        raise NotImplementedError

    def flush(self) -> None:
        pass

    def close(self) -> None:
        self.flush()


class MemorySink(MetricsSink):
    """Keep events in-process (tests / report tooling)."""

    def __init__(self):
        self.events: List[Dict[str, Any]] = []

    def emit(self, event: Dict[str, Any]) -> None:
        self.events.append(event)

    def of_kind(self, kind: str) -> List[Dict[str, Any]]:
        return [e for e in self.events if e["event"] == kind]


class JsonlSink(MetricsSink):
    """Append-only JSONL event log, write-buffered.

    Lines are buffered host-side and flushed every ``buffer`` events
    (and at ``flush``/``close``), so steady-state training costs one
    ``write`` per chunk rather than one syscall per round.
    """

    def __init__(self, path, buffer: int = 256, resume: bool = False):
        self.path = pathlib.Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._buf: List[str] = []
        self._buffer = max(1, int(buffer))
        if not (resume and self.path.exists()):
            self.path.write_text("")  # truncate: one run per file
        # resume reopens in append mode: the stream continues after the
        # prior run's events.  Events emitted after the restored
        # checkpoint but before the kill stay in the file — the JSONL
        # stream is at-least-once across a resume; consumers dedupe on
        # (event, round) or take the last seq per key (DESIGN.md §12).

    def emit(self, event: Dict[str, Any]) -> None:
        self._buf.append(json.dumps(event, separators=(",", ":")))
        if len(self._buf) >= self._buffer:
            self.flush()

    def flush(self) -> None:
        if self._buf:
            with self.path.open("a") as f:
                f.write("\n".join(self._buf) + "\n")
            self._buf.clear()

    @staticmethod
    def load(path) -> List[Dict[str, Any]]:
        """Read an events.jsonl back into a list of dicts."""
        out = []
        for line in pathlib.Path(path).read_text().splitlines():
            line = line.strip()
            if line:
                out.append(json.loads(line))
        return out


class CsvSummarySink(MetricsSink):
    """Per-round scalar summary table (``rounds.csv``)."""

    _COLS = ("round", "loss", "participation", "uplink_bits", "weight_sum",
             "weight_drift")

    def __init__(self, path, resume: bool = False):
        self.path = pathlib.Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._rows: List[str] = [",".join(self._COLS)]
        self._written = False
        if resume and self.path.exists():
            rows = self.path.read_text().splitlines()
            if rows and rows[0] == self._rows[0]:
                self._rows = rows

    def emit(self, event: Dict[str, Any]) -> None:
        if event.get("event") != "round":
            return
        self._rows.append(",".join(
            repr(event[c]) if isinstance(event.get(c), float)
            else str(event.get(c, "")) for c in self._COLS))

    def trim_rounds_after(self, r: int) -> None:
        """Drop rows past round ``r`` — rounds the prior run logged
        after the checkpoint being resumed (they will be re-trained and
        re-logged), keeping the table exactly-once."""
        self._rows = [self._rows[0]] + [
            row for row in self._rows[1:]
            if row and int(row.split(",", 1)[0]) <= r
        ]

    def flush(self) -> None:
        self.path.write_text("\n".join(self._rows) + "\n")


class MetricsLogger:
    """The one metric append path (see module doc).

    ``log`` is the legacy :class:`~repro.fl.trainer.TrainLog` facade the
    trainer exposes; the logger owns it and keeps it bitwise-compatible
    with the pre-telemetry trainer.  ``sinks`` receive the event stream;
    an empty sink list costs one numpy cast per chunk and nothing else.
    """

    def __init__(self, sinks: Sequence[MetricsSink] = (), log=None):
        if log is None:
            from repro.fl.trainer import TrainLog
            log = TrainLog()
        self.log = log
        self.sinks = list(sinks)
        self._seq = 0  # monotonic event index across every kind
        self._vectors: Dict[str, List[np.ndarray]] = {}

    # -- event plumbing --------------------------------------------------
    def emit(self, kind: str, **payload: Any) -> None:
        if not self.sinks:
            self._seq += 1
            return
        event = {"event": kind, "seq": self._seq, **payload}
        self._seq += 1
        for s in self.sinks:
            s.emit(event)

    def flush(self) -> None:
        for s in self.sinks:
            s.flush()

    def close(self) -> None:
        self._emit_client_summary()
        for s in self.sinks:
            s.close()

    # -- checkpoint/resume (DESIGN.md §12) -------------------------------
    def checkpoint_state(self) -> Dict[str, Any]:
        """Everything needed to continue the metric streams seamlessly:
        the monotonic ``seq`` cursor, the full TrainLog facade, and the
        accumulated vector-metric histories."""
        import dataclasses as _dc

        return {
            "seq": int(self._seq),
            "log": _dc.asdict(self.log),
            "vectors": {k: np.concatenate(v, axis=0)
                        for k, v in self._vectors.items() if v},
        }

    def restore_state(self, state: Dict[str, Any]) -> None:
        """Reinstate a checkpointed stream position.

        The TrainLog lists are mutated *in place* so every alias
        (``trainer.log is metrics.log``) observes the restored history;
        sinks that can rewind (``trim_rounds_after``) drop rows the
        prior run logged past the checkpoint."""
        self._seq = int(state["seq"])
        for name, vals in state["log"].items():
            getattr(self.log, name)[:] = list(vals)
        self._vectors = {k: [np.asarray(v)]
                         for k, v in state.get("vectors", {}).items()}
        last = self.log.rounds[-1] if self.log.rounds else -1
        for s in self.sinks:
            trim = getattr(s, "trim_rounds_after", None)
            if trim is not None:
                trim(last)

    # -- the deduped round append path ----------------------------------
    def log_rounds(self, r0: int, metrics: Dict[str, Any], k: int = 1) -> None:
        """Append ``k`` rounds' metrics starting at round ``r0``.

        ``metrics`` holds device (or numpy) values: scalar streams as
        0-d (``k == 1``) or stacked ``(k,)`` arrays, vector streams as
        ``(n,)`` or ``(k, n)``.  This is the *only* float-cast path —
        the per-round loop and the chunked engine both land here, so
        their TrainLog streams are bitwise identical by construction
        (``np.float64`` widening of the device float32, exactly the
        cast both pre-telemetry paths performed).
        """
        cast = {}
        for name in SCALAR_STREAMS:
            if name in metrics:
                cast[name] = np.asarray(metrics[name],
                                        np.float64).reshape(k).tolist()
        rounds = list(range(r0, r0 + k))
        self.log.rounds.extend(rounds)
        for name, field in SCALAR_STREAMS.items():
            if field is not None and name in cast:
                getattr(self.log, field).extend(cast[name])
        for name in VECTOR_METRICS:
            if name in metrics:
                v = np.asarray(metrics[name])
                self._vectors.setdefault(name, []).append(v.reshape(k, -1))
        # health: a non-finite loss becomes a structured event instead of
        # a silently-logged value (the value still lands in the facade —
        # bitwise compatibility — but the event stream flags it)
        for i, lv in enumerate(cast.get("loss", ())):
            if not np.isfinite(lv):
                self.emit("health.nan", round=r0 + i, loss=lv)
        if self.sinks:
            for i, r in enumerate(rounds):
                self.emit("round", round=r,
                          **{name: vals[i] for name, vals in cast.items()})

    # -- other streams ---------------------------------------------------
    def log_eval(self, r: int, eval_metrics: Dict[str, float]) -> None:
        em = {key: float(v) for key, v in eval_metrics.items()}
        self.log.eval_rounds.append(r)
        self.log.eval_metrics.append(em)
        self.emit("eval", round=r, **em)

    def log_reopt(self, r: int, *, S_est: float, S_true: float,
                  p_err: float) -> None:
        self.log.reopt_rounds.append(r)
        self.log.est_p_err.append(p_err)
        self.log.S_est.append(S_est)
        self.log.S_true.append(S_true)
        self.emit("reopt", round=r, S_est=S_est, S_true=S_true, p_err=p_err)

    def log_timing(self, r0: int, rounds: int, seconds: float) -> None:
        self.emit("timing", round0=r0, rounds=rounds, seconds=seconds,
                  rounds_per_sec=rounds / seconds if seconds > 0 else 0.0)

    def log_recompiles(self, grew: Dict[str, int], r: int) -> None:
        for name, growth in grew.items():
            self.emit("health.recompile", round=r, fn=name, growth=growth)

    # -- vector metric access --------------------------------------------
    def vector(self, name: str) -> Optional[np.ndarray]:
        """Stacked ``(R, n)`` history of a vector metric (None if the
        stream was never produced — telemetry off)."""
        parts = self._vectors.get(name)
        if not parts:
            return None
        return np.concatenate(parts, axis=0)

    def save_vectors(self, path) -> Optional[pathlib.Path]:
        """Dump every vector stream into one ``.npz``; returns the path
        (None when no vector stream exists)."""
        arrays = {name: self.vector(name) for name in self._vectors}
        if not arrays:
            return None
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        np.savez(p, **arrays)
        return p

    def _emit_client_summary(self) -> None:
        """End-of-run per-client aggregates as one ``summary.clients``
        event: participation counts, bits-on-air totals, max streaks —
        the per-client histogram data without per-round JSON cost."""
        part = self.vector("client_participation")
        if part is None or not self.sinks:
            return
        bits = self.vector("client_uplink_bits")
        streak = self.vector("outage_streak")
        self.emit(
            "summary.clients",
            rounds=int(part.shape[0]),
            participation_count=part.sum(axis=0).astype(int).tolist(),
            participation_rate=(part.mean(axis=0)).round(6).tolist(),
            uplink_bits_total=(bits.sum(axis=0).tolist()
                               if bits is not None else None),
            outage_streak_max=(streak.max(axis=0).astype(int).tolist()
                               if streak is not None else None),
        )
