"""Structured run manifests: what exactly did this run execute?

A :class:`RunManifest` is written once at run start (``manifest.json``
next to the metric streams) and records everything needed to interpret
— or re-run — the metrics that follow: the full config with a stable
digest, the strategy / channel / codec names, the mesh shape, the jax
backend and device census, and the repo git SHA.  All host-side, all
stdlib: the telemetry layer stays dependency-free.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
import subprocess
import time
from typing import Any, Dict, Optional

__all__ = ["RunManifest", "config_digest", "git_sha"]


def _jsonable(obj: Any) -> Any:
    """Best-effort canonical JSON form (numpy scalars/arrays, dataclasses,
    mappings); unknown objects fall back to their repr."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return _jsonable(dataclasses.asdict(obj))
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    if hasattr(obj, "tolist"):  # numpy scalar or array
        return _jsonable(obj.tolist())
    if hasattr(obj, "item"):
        return obj.item()
    return repr(obj)


def config_digest(config: Dict[str, Any]) -> str:
    """sha256 over the canonical (sorted-key) JSON form of a config dict
    — stable across dict ordering and process restarts, so two runs with
    the same digest ran the same configuration."""
    canon = json.dumps(_jsonable(config), sort_keys=True,
                       separators=(",", ":"))
    return hashlib.sha256(canon.encode()).hexdigest()


def git_sha(cwd: Optional[str] = None) -> Optional[str]:
    """The repo HEAD SHA, or None outside a git checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=cwd, capture_output=True,
            text=True, timeout=5,
        )
    except (OSError, subprocess.SubprocessError):
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else None


@dataclasses.dataclass
class RunManifest:
    """One run's provenance record (see module doc)."""

    config: Dict[str, Any]
    config_digest: str
    strategy: Optional[str] = None
    channel: Optional[str] = None
    codec: Optional[str] = None
    mesh_shape: Optional[Dict[str, int]] = None
    backend: str = ""
    device_count: int = 0
    jax_version: str = ""
    git_sha: Optional[str] = None
    created_unix: float = 0.0
    # the checkpoint this run restored from (path or step label); None
    # for a from-scratch run (DESIGN.md §12)
    resumed_from: Optional[str] = None
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @classmethod
    def collect(cls, config: Dict[str, Any], *, strategy: Optional[str] = None,
                channel: Optional[str] = None, codec: Optional[str] = None,
                mesh_shape: Optional[Dict[str, int]] = None,
                resumed_from: Optional[str] = None,
                **extra: Any) -> "RunManifest":
        """Gather the environment-derived fields (backend, devices, jax
        version, git SHA) around the caller-supplied run identity."""
        import jax

        return cls(
            config=_jsonable(config),
            config_digest=config_digest(config),
            strategy=strategy,
            channel=channel,
            codec=codec,
            mesh_shape=dict(mesh_shape) if mesh_shape else None,
            backend=jax.default_backend(),
            device_count=jax.device_count(),
            jax_version=jax.__version__,
            git_sha=git_sha(cwd=str(pathlib.Path(__file__).parent)),
            created_unix=time.time(),
            resumed_from=str(resumed_from) if resumed_from is not None else None,
            extra=_jsonable(extra),
        )

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def write(self, path) -> pathlib.Path:
        """Write ``manifest.json`` (``path`` may be the file or a
        directory to drop it into); returns the written path."""
        p = pathlib.Path(path)
        if p.is_dir() or p.suffix != ".json":
            p.mkdir(parents=True, exist_ok=True)
            p = p / "manifest.json"
        else:
            p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.to_dict(), indent=1, sort_keys=True))
        return p
