"""Timing, throughput and profiling instrumentation.

Three host-side probes, all opt-in and all safe to leave attached:

* :class:`ThroughputMeter` — per-chunk wall-clock with explicit
  ``jax.block_until_ready`` fencing (async dispatch otherwise makes
  ``perf_counter`` deltas measure the *enqueue*, not the execution).
  Tracks rounds/sec per chunk and cumulatively; the ROADMAP's async
  direction measures convergence against wall-clock, which starts here.
* :class:`CompileTracker` — snapshots the jit cache sizes of registered
  compiled functions and reports growth, catching recompile regressions
  (a shape-unstable carry silently retracing every chunk turns a 20x
  scan speedup into a 0.1x slowdown; the telemetry stream now says so).
* :class:`ProfileWindow` — an opt-in ``jax.profiler`` trace capture
  over a round window (``--profile-dir`` / ``--profile-rounds`` in the
  launchers): starts the trace when the window opens, stops it when the
  window closes, never triggers otherwise.
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

import jax

__all__ = ["ThroughputMeter", "CompileTracker", "ProfileWindow"]


class ThroughputMeter:
    """Wall-clock rounds/sec with device fencing.

    Usage per execution block (one round or one K-round chunk)::

        meter.start()
        ... dispatch ... (+ host prefetch work)
        dt = meter.stop(rounds=k, fence=metrics)

    ``fence`` is block_until_ready'd before the clock stops, so the
    interval covers the device execution, not just its enqueue.  Fencing
    on the metrics the caller is about to read anyway adds no extra
    sync.
    """

    def __init__(self):
        self._t0: Optional[float] = None
        self.chunks: List[Dict[str, float]] = []
        self.total_rounds = 0
        self.total_seconds = 0.0

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, rounds: int, fence: Any = None) -> float:
        """Fence, stop the clock, record; returns the elapsed seconds."""
        if self._t0 is None:
            raise RuntimeError("ThroughputMeter.stop() without start()")
        if fence is not None:
            jax.block_until_ready(fence)
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self.chunks.append({"rounds": rounds, "seconds": dt,
                            "rounds_per_sec": rounds / dt if dt > 0 else 0.0})
        self.total_rounds += rounds
        self.total_seconds += dt
        return dt

    def rounds_per_sec(self) -> float:
        """Cumulative throughput over every recorded block."""
        return (self.total_rounds / self.total_seconds
                if self.total_seconds > 0 else 0.0)


class CompileTracker:
    """Detect recompiles of registered jitted functions.

    ``register(name, fn)`` snapshots the function's current jit cache
    size; ``check()`` returns ``{name: growth}`` for every function
    whose cache grew since the last call (one compile per distinct input
    shape is expected; growth *during steady-state training* is a
    regression).  Functions without a ``_cache_size`` probe (non-jit
    callables, older jax) are silently skipped.
    """

    def __init__(self):
        self._fns: Dict[str, Any] = {}
        self._seen: Dict[str, int] = {}

    @staticmethod
    def _size(fn) -> Optional[int]:
        probe = getattr(fn, "_cache_size", None)
        if probe is None:
            return None
        try:
            return int(probe())
        except Exception:
            return None

    def register(self, name: str, fn) -> None:
        if self._size(fn) is None:
            return
        self._fns[name] = fn
        self._seen[name] = self._size(fn) or 0

    def compile_counts(self) -> Dict[str, int]:
        """Current cache size per registered function."""
        return {n: self._size(f) or 0 for n, f in self._fns.items()}

    def check(self) -> Dict[str, int]:
        """Cache growth per function since the previous ``check()``."""
        grew: Dict[str, int] = {}
        for name, fn in self._fns.items():
            size = self._size(fn) or 0
            if size > self._seen[name]:
                grew[name] = size - self._seen[name]
            self._seen[name] = size
        return grew


class ProfileWindow:
    """An opt-in ``jax.profiler.trace`` capture over rounds
    ``[start, start + rounds)``.

    The trainer calls ``maybe_start(r)`` before executing a block
    beginning at round ``r`` and ``maybe_stop(r_next)`` after fencing
    the block that ends before round ``r_next``; the window opens/closes
    on the enclosing block boundaries (a chunked run profiles whole
    chunks).  ``close()`` force-stops a window left open at run end.
    """

    def __init__(self, profile_dir: str, start: int = 0, rounds: int = 1):
        if rounds <= 0:
            raise ValueError("profile window needs rounds >= 1")
        self.profile_dir = str(profile_dir)
        self.start = int(start)
        self.rounds = int(rounds)
        self.active = False
        self.done = False

    def maybe_start(self, r: int) -> bool:
        """Open the trace when block starting at round ``r`` enters the
        window; returns True when (already) capturing."""
        if self.active:
            return True
        if not self.done and r >= self.start:
            jax.profiler.start_trace(self.profile_dir)
            self.active = True
        return self.active

    def maybe_stop(self, r_next: int) -> bool:
        """Close the trace once execution has passed the window end
        (``r_next`` = first round not yet executed)."""
        if self.active and r_next >= self.start + self.rounds:
            jax.profiler.stop_trace()
            self.active = False
            self.done = True
        return self.done

    def close(self) -> None:
        if self.active:
            jax.profiler.stop_trace()
            self.active = False
            self.done = True
