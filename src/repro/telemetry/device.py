"""Device-resident telemetry: vector metrics computed inside the round.

The paper's whole argument is about *who got through* — collaborative
relaying exists to lift the participation of poorly-connected clients,
and the Theorem 1 variance bound is a function of per-link outage
statistics — so the fleet-scalar ``participation`` stream is not enough
to observe a run.  This module adds the per-client view without any
mid-scan host traffic:

* ``client_participation (n,)`` — this round's realized uplink vector
  (``tau_up``): which clients' updates reached the PS;
* ``client_uplink_bits (n,)`` — per-client bits-on-air, priced at the
  active wire codec's rate (the per-client decomposition of the scalar
  ``uplink_bits`` metric);
* ``outage_streak (n,)`` — consecutive rounds (including this one) each
  client's uplink has been down: the online view of blockage-burst
  sojourns (the quantity the Gilbert–Elliott gates of
  ``channel/markov.py`` model), carried as a traced ``(n,)`` int32 age
  vector through the scan carry exactly like the channel gate state;
* ``weight_drift`` — ``|sum(w) - 1|``, the realized unbiasedness drift
  of the scalar aggregation weights (condition (5) of the paper makes
  ``E[sum w] = 1``; NaN for strategies with no scalar collapse).

Inside the chunked scan engine the vectors come back stacked ``(K, n)``
per chunk, so nothing leaves the device mid-scan; the per-round loop
sees the same ``(n,)`` values one round at a time.  All functions here
are pure jnp — safe under ``jit`` / ``vmap`` / ``lax.scan`` and under
client-axis sharding (every op is lane-local in the client dim).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

__all__ = [
    "VECTOR_METRICS",
    "init_streak",
    "update_streak",
    "instrument_round_fn",
]

#: vector metric streams added by ``instrument_round_fn`` (all carry a
#: client axis; stacked ``(K, n)`` by the scan engine)
VECTOR_METRICS = ("client_participation", "client_uplink_bits",
                  "outage_streak")


def init_streak(n: int) -> jax.Array:
    """Zeroed ``(n,)`` int32 outage-age vector (no observed history)."""
    return jnp.zeros((n,), jnp.int32)


def update_streak(streak: jax.Array, tau_up: jax.Array) -> jax.Array:
    """Advance the outage-streak recurrence one round.

    ``streak[i]`` counts consecutive rounds client ``i``'s uplink has
    failed, *including* the current round: a delivered uplink resets to
    0, a blocked one increments.  Pure lane-local select — the same
    shape-stable carry discipline as the channel gate state.
    """
    return jnp.where(tau_up > 0, 0, streak + 1).astype(jnp.int32)


def instrument_round_fn(round_fn, wire_bits_per_coord):
    """Wrap a :func:`~repro.fl.round.make_round_fn` body with the
    device-resident vector metrics.

    The wrapped signature grows one trailing carry argument/result::

        wrapped(params, server_state, agg_state, batches,
                tau_up, tau_dd, A, streak)
            -> (params, server_state, agg_state, streak, metrics)

    where ``metrics`` is the base round's dict plus the
    :data:`VECTOR_METRICS` vectors and the ``weight_drift`` scalar.  The
    base body is untouched (the wrapper only *reads* its inputs and
    outputs), so the training trajectory and the scalar metric streams
    are bitwise identical with telemetry on or off.

    ``wire_bits_per_coord`` is the active strategy's rate method
    (``strategy.wire_bits_per_coord``, bits per coordinate as a function
    of the flat dim); the flat dim itself is read off the params at
    trace time, so the per-client bits fold to one static multiply in
    the compiled round.
    """
    from repro.core import flatten

    def wrapped(params, server_state, agg_state, batches,
                tau_up, tau_dd, A, streak):
        params, server_state, agg_state, metrics = round_fn(
            params, server_state, agg_state, batches, tau_up, tau_dd, A)
        streak = update_streak(streak, tau_up)
        d_flat = flatten.flat_spec(params).d
        bits = jnp.float32(d_flat * wire_bits_per_coord(d_flat))
        metrics = dict(
            metrics,
            client_participation=tau_up.astype(jnp.float32),
            client_uplink_bits=tau_up.astype(jnp.float32) * bits,
            outage_streak=streak,
            weight_drift=jnp.abs(metrics["weight_sum"] - 1.0),
        )
        return params, server_state, agg_state, streak, metrics

    return wrapped
