"""Federated dataset partitioning: IID and the paper's sort-and-partition
non-IID scheme (skew parameter ``s`` = max distinct labels per client)."""

from __future__ import annotations

from typing import List

import numpy as np

__all__ = ["partition_iid", "partition_sort_and_partition"]


def partition_iid(n_samples: int, n_clients: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_samples)
    return [np.sort(p) for p in np.array_split(perm, n_clients)]


def partition_sort_and_partition(
    labels: np.ndarray, n_clients: int, s: int, seed: int = 0
) -> List[np.ndarray]:
    """Sort by label, split into ``n_clients * s`` shards, deal ``s`` shards
    to each client at random (the paper's Sec. V scheme).  Each client ends
    up with samples from at most ``s`` distinct labels."""
    rng = np.random.default_rng(seed)
    order = np.argsort(labels, kind="stable")
    shards = np.array_split(order, n_clients * s)
    shard_ids = rng.permutation(n_clients * s)
    out = []
    for c in range(n_clients):
        take = shard_ids[c * s : (c + 1) * s]
        out.append(np.sort(np.concatenate([shards[t] for t in take])))
    return out
