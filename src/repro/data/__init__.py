from .synthetic import synthetic_cifar, synthetic_tokens, quadratic_problem
from .partition import partition_iid, partition_sort_and_partition
from .pipeline import ClientDataset, federated_batches, make_federated_clients

__all__ = [
    "synthetic_cifar",
    "synthetic_tokens",
    "quadratic_problem",
    "partition_iid",
    "partition_sort_and_partition",
    "ClientDataset",
    "federated_batches",
    "make_federated_clients",
]
