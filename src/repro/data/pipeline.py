"""Per-client batch streams for the FL trainer.

``ClientDataset`` wraps one client's local arrays and yields minibatches
with its own RNG (clients sample independently, as in local SGD).
``federated_batches`` stacks one minibatch per client into a leading
client axis — the layout the per-client execution mode consumes
(client axis ↔ mesh "data" axis).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

__all__ = ["ClientDataset", "federated_batches"]


@dataclasses.dataclass
class ClientDataset:
    arrays: Dict[str, np.ndarray]  # same leading dim N_i
    batch_size: int
    seed: int = 0

    def __post_init__(self):
        ns = {k: v.shape[0] for k, v in self.arrays.items()}
        assert len(set(ns.values())) == 1, f"ragged arrays {ns}"
        self.n = next(iter(ns.values()))
        self._rng = np.random.default_rng(self.seed)

    def next_batch(self) -> Dict[str, np.ndarray]:
        idx = self._rng.integers(0, self.n, size=self.batch_size)
        return {k: v[idx] for k, v in self.arrays.items()}


def federated_batches(clients: Sequence[ClientDataset]) -> Dict[str, np.ndarray]:
    """One synchronized round of minibatches, stacked (n_clients, B, ...)."""
    batches = [c.next_batch() for c in clients]
    return {k: np.stack([b[k] for b in batches]) for k in batches[0]}


def make_federated_clients(
    arrays: Dict[str, np.ndarray],
    partitions: List[np.ndarray],
    batch_size: int,
    seed: int = 0,
) -> List[ClientDataset]:
    return [
        ClientDataset({k: v[idx] for k, v in arrays.items()}, batch_size, seed=seed + 997 * i)
        for i, idx in enumerate(partitions)
    ]
