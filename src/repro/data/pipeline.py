"""Per-client batch streams for the FL trainer.

``ClientDataset`` wraps one client's local arrays and yields minibatches
with its own RNG (clients sample independently, as in local SGD).
``federated_batches`` stacks one minibatch per client into a leading
client axis — the layout the per-client execution mode consumes
(client axis ↔ mesh "data" axis).  ``stack_chunk_batches`` is the bulk
form the chunked scan engine feeds on: K rounds of T local steps for
every client gathered in one vectorized fancy-index per client, laid out
``(K, n, T, B, ...)``.

Bulk draws are *stream-equivalent* to repeated single draws: numpy's
``Generator.integers`` fills a ``(m, B)`` request with exactly the
values ``m`` successive ``(B,)`` requests would produce, so a trainer
consuming the stream in chunks of any size sees bitwise-identical
batches (asserted in ``tests/test_scan_engine.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

__all__ = ["ClientDataset", "federated_batches", "stack_chunk_batches"]


@dataclasses.dataclass
class ClientDataset:
    arrays: Dict[str, np.ndarray]  # same leading dim N_i
    batch_size: int
    seed: int = 0

    def __post_init__(self):
        ns = {k: v.shape[0] for k, v in self.arrays.items()}
        assert len(set(ns.values())) == 1, f"ragged arrays {ns}"
        self.n = next(iter(ns.values()))
        self._rng = np.random.default_rng(self.seed)

    def next_batch(self) -> Dict[str, np.ndarray]:
        idx = self._rng.integers(0, self.n, size=self.batch_size)
        return {k: v[idx] for k, v in self.arrays.items()}

    def next_batches(self, m: int) -> Dict[str, np.ndarray]:
        """``m`` successive minibatches in one vectorized gather:
        leaves ``(m, B, ...)``, same RNG stream as ``m`` ``next_batch``
        calls."""
        idx = self._rng.integers(0, self.n, size=(m, self.batch_size))
        return {k: v[idx] for k, v in self.arrays.items()}


def federated_batches(clients: Sequence[ClientDataset]) -> Dict[str, np.ndarray]:
    """One synchronized round of minibatches, stacked (n_clients, B, ...)."""
    batches = [c.next_batch() for c in clients]
    return {k: np.stack([b[k] for b in batches]) for k in batches[0]}


def stack_chunk_batches(
    clients: Sequence[ClientDataset], local_steps: int, rounds: int = 1
) -> Dict[str, np.ndarray]:
    """``rounds`` synchronized rounds of ``local_steps`` minibatches per
    client, stacked ``(rounds, n_clients, T, B, ...)``.

    One ``rounds * T``-deep gather per client replaces the old nested
    per-round / per-step python loops; with ``rounds=1`` this is exactly
    the per-round trainer layout (squeeze the leading axis).
    """
    m = rounds * local_steps
    per_client = [c.next_batches(m) for c in clients]

    def stack(key: str) -> np.ndarray:
        return np.stack(
            [pc[key].reshape(rounds, local_steps, *pc[key].shape[1:])
             for pc in per_client],
            axis=1,
        )

    return {k: stack(k) for k in per_client[0]}


def make_federated_clients(
    arrays: Dict[str, np.ndarray],
    partitions: List[np.ndarray],
    batch_size: int,
    seed: int = 0,
) -> List[ClientDataset]:
    return [
        ClientDataset({k: v[idx] for k, v in arrays.items()}, batch_size, seed=seed + 997 * i)
        for i, idx in enumerate(partitions)
    ]
