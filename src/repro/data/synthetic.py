"""Synthetic datasets (the container is offline — see DESIGN.md §7).

``synthetic_cifar`` is interface-compatible with CIFAR-10: (N, 32, 32, 3)
float images in 10 classes.  Classes are separable but noisy — each class
has a random smooth template plus per-sample noise — so learning curves
show the same qualitative convergence/ordering phenomena the paper reports
(the absolute accuracies differ from real CIFAR, which we note in
EXPERIMENTS.md).

``quadratic_problem`` builds the strongly-convex least-squares instance
used to validate Theorem 1 exactly (mu-strong convexity and L-smoothness
are explicit eigenvalue bounds).
"""

from __future__ import annotations

import numpy as np

__all__ = ["synthetic_cifar", "synthetic_tokens", "quadratic_problem"]


def synthetic_cifar(
    n: int = 10000,
    n_classes: int = 10,
    image_size: int = 32,
    noise: float = 0.6,
    seed: int = 0,
):
    """Returns (images (N,H,W,3) float32 in [-1, 1]-ish, labels (N,) int32)."""
    rng = np.random.default_rng(seed)
    # smooth class templates: low-frequency random fields
    freq = 4
    base = rng.normal(size=(n_classes, freq, freq, 3)).astype(np.float32)
    templates = np.stack(
        [
            np.kron(base[c], np.ones((image_size // freq, image_size // freq, 1), np.float32))
            for c in range(n_classes)
        ]
    )  # (C, H, W, 3)
    labels = rng.integers(0, n_classes, size=n).astype(np.int32)
    images = templates[labels] + noise * rng.normal(size=(n, image_size, image_size, 3)).astype(
        np.float32
    )
    return images.astype(np.float32), labels


def synthetic_tokens(
    n_seqs: int,
    seq_len: int,
    vocab: int,
    seed: int = 0,
    n_styles: int = 10,
):
    """Markov-ish token sequences with per-style transition structure, so an
    LM has signal to fit.  Returns (tokens (N, T) int32, styles (N,) int32).
    Styles play the role of "classes" for non-IID partitioning."""
    rng = np.random.default_rng(seed)
    styles = rng.integers(0, n_styles, size=n_seqs).astype(np.int32)
    # per-style preferred successor offset: tok_{t+1} = tok_t * a + b + noise
    a = rng.integers(1, 7, size=n_styles)
    b = rng.integers(0, vocab, size=n_styles)
    toks = np.empty((n_seqs, seq_len), dtype=np.int32)
    toks[:, 0] = rng.integers(0, vocab, size=n_seqs)
    for t in range(1, seq_len):
        clean = (toks[:, t - 1] * a[styles] + b[styles]) % vocab
        noise = rng.integers(0, vocab, size=n_seqs)
        use_noise = rng.random(n_seqs) < 0.1
        toks[:, t] = np.where(use_noise, noise, clean)
    return toks, styles


def quadratic_problem(n_clients: int, dim: int, mu: float = 1.0, L: float = 10.0,
                      hetero: float = 0.0, seed: int = 0):
    """Per-client quadratics f_i(x) = 0.5 (x - c_i)^T H (x - c_i) with common
    Hessian H (eigenvalues in [mu, L]) and centers c_i = c + hetero * d_i.
    The global optimum is x* = mean(c_i).  Returns dict of numpy arrays."""
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.normal(size=(dim, dim)))
    eig = np.linspace(mu, L, dim)
    H = (q * eig) @ q.T
    c = rng.normal(size=dim)
    centers = c[None, :] + hetero * rng.normal(size=(n_clients, dim))
    return {
        "H": H.astype(np.float64),
        "centers": centers.astype(np.float64),
        "x_star": centers.mean(axis=0),
        "mu": mu,
        "L": L,
    }
