"""Wire-format codecs for quantized relaying (DESIGN.md §8).

Relaying doubles each client's uplink payload, so the wire format of
the ``(n, d)`` update stack is the next scaling axis after connectivity
itself.  One protocol — :class:`WireCodec` (``encode``/``decode`` +
:class:`CodecDescriptor`) — and a string-keyed registry mirroring
``repro.strategies``::

    from repro import wire

    wire.available()                 # what the CLI / benches see
    codec = wire.get("int8", bits=4)
    enc, state = codec.encode(stack, codec.init_state(n, d))
    recon = codec.decode(enc)

    @wire.register("fp8")
    class FP8Codec(wire.WireCodec): ...

Built-in codecs:

* ``identity`` — the no-op format (infinite bits; the equivalence
  anchor: ``quantized(colrel, codec="identity")`` is bitwise colrel).
* ``int8`` — symmetric ``bits``-level quantization with stochastic
  rounding: unbiased by construction, per-client scales, and the
  affine ``(int8, scale)`` form the fused Pallas dequant-accumulate
  kernel streams directly.
* ``topk`` — deterministic top-k sparsification (biased, declared so).
* ``randk`` — uniform random-k sparsification; known gain ``k/d`` the
  strategy's unbiasedness-correction hook divides out.

The consuming strategy is ``strategies.get("quantized", codec=...)``;
importing this package registers the built-in codecs.
"""

from repro.wire.base import CodecDescriptor, WireCodec
from repro.wire.registry import available, get, register, resolve
from repro.wire.int8 import IdentityCodec, Int8StochasticCodec
from repro.wire.topk import RandKCodec, TopKCodec

__all__ = [
    "CodecDescriptor",
    "WireCodec",
    "available",
    "get",
    "register",
    "resolve",
    "IdentityCodec",
    "Int8StochasticCodec",
    "TopKCodec",
    "RandKCodec",
]
