"""Integer quantization with stochastic rounding (the ``int8`` codec).

Per-client affine quantization of the flattened update row: client
``i``'s row is scaled by ``s_i = max_j |x_ij| / L`` (``L = 2^(b-1) - 1``
levels for ``b`` bits) and rounded *stochastically* —

    q = floor(x / s + u),   u ~ U[0, 1)  i.i.d. per coordinate

so ``E[q · s] = x`` exactly: the wire format is unbiased by
construction and the PS-side aggregation needs no correction
(``descriptor().gain == 1``).  The price is quantization noise of
variance ``s² · f(1-f) <= s²/4`` per coordinate (``f`` the fractional
part), which adds on top of the connectivity-induced variance floor of
Theorem 1 — ``benchmarks/quant_bench.py`` traces exactly that curve as
``b`` sweeps down from 8.

The encoded form is ``(q int8 (n, d), s f32 (n, 1))`` — the affine
shape the fused Pallas dequant-accumulate kernel
(``kernels/fused_dequant.py``) consumes by folding ``s`` into the
aggregation weights, streaming the int8 stack through HBM once at a
quarter of the f32 traffic.  On TPU the same stochastic rounding is a
native ``pltpu.stochastic_round``; here encode is pure jnp so clients
(which quantize *before* the wire) stay backend-agnostic.

Randomness is codec state: a ``(2,)`` uint32 PRNG key threaded through
the compiled round inside ``agg_state``, split once per encode — fresh
draws every round, zero recompiles.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.wire import registry
from repro.wire.base import CodecDescriptor, State, WireCodec

__all__ = ["IdentityCodec", "Int8StochasticCodec"]


class IdentityCodec(WireCodec):
    """The no-op wire format (infinite bits): decode(encode(x)) is x.

    Exists so ``quantized(inner, codec="identity")`` is *bitwise* the
    inner strategy — the degenerate end of the variance-vs-bits curve
    and the equivalence anchor in ``tests/test_wire.py``.
    """

    name = "identity"

    def descriptor(self, d: int) -> CodecDescriptor:
        return CodecDescriptor(name=self.name, bits_per_coord=32.0,
                               unbiased=True)

    def encode(self, x: jax.Array, state: State) -> Tuple[jax.Array, State]:
        return x.astype(jnp.float32), state

    def decode(self, encoded: jax.Array) -> jax.Array:
        return encoded


class Int8StochasticCodec(WireCodec):
    """``b``-bit symmetric quantization with stochastic rounding.

    ``bits`` <= 8; the device container is int8 regardless (fewer bits
    just use fewer levels — the wire cost is ``bits`` per coordinate).
    """

    name = "int8"
    stateful = True
    supports_fused_dequant = True
    supports_segmented = True

    def __init__(self, bits: int = 8, seed: int = 0):
        if not 2 <= int(bits) <= 8:
            raise ValueError(f"bits must be in [2, 8], got {bits}")
        self.bits = int(bits)
        self.seed = int(seed)
        #: symmetric levels: q in [-L, L]
        self.levels = 2 ** (self.bits - 1) - 1

    def descriptor(self, d: int) -> CodecDescriptor:
        return CodecDescriptor(
            name=self.name,
            # + the one f32 scale amortized over the row
            bits_per_coord=self.bits + 32.0 / max(d, 1),
            unbiased=True,
            gain=1.0,
            # worst-case SR noise per coordinate, in units of the row
            # scale squared: Var = f(1-f) <= 1/4 at the quantization
            # grid pitch s = rowmax / L
            rel_variance=1.0 / (4.0 * self.levels**2),
        )

    def init_state(self, n: int, d: int) -> jax.Array:
        del n, d
        return jax.random.PRNGKey(self.seed)

    def encode(self, x: jax.Array, state: State) -> Tuple[tuple, State]:
        key, sub = jax.random.split(state)
        xf = x.astype(jnp.float32)
        # per-client row scale; floor avoids 0/0 on an all-zero update
        scale = jnp.max(jnp.abs(xf), axis=1, keepdims=True) / self.levels
        scale = jnp.maximum(scale, jnp.float32(1e-12))
        u = jax.random.uniform(sub, xf.shape, jnp.float32)
        q = jnp.floor(xf / scale + u)
        q = jnp.clip(q, -self.levels, self.levels).astype(jnp.int8)
        return (q, scale), key

    def encode_segments(self, segments, state: State) -> Tuple[tuple, State]:
        """Quantize per-leaf ``(n, d_i)`` segments against one row-global
        scale without assembling the stack (DESIGN.md §14).

        The row scale is the max over per-segment row maxima — max is
        exactly associative, so the scale is **bitwise** the monolithic
        ``encode`` scale.  The rounding noise draws come from per-segment
        ``fold_in`` subkeys instead of one monolithic ``uniform``; the
        draws are therefore distributionally identical but not the same
        realization as ``encode`` (same contract as the no-trace channel
        sampler), and the state advances by the same single ``split``.
        """
        key, sub = jax.random.split(state)
        maxima = [jnp.max(jnp.abs(s.astype(jnp.float32)), axis=1,
                          keepdims=True) for s in segments]
        rowmax = maxima[0]
        for m in maxima[1:]:
            rowmax = jnp.maximum(rowmax, m)
        scale = jnp.maximum(rowmax / self.levels, jnp.float32(1e-12))
        qs = []
        for i, s in enumerate(segments):
            xf = s.astype(jnp.float32)
            u = jax.random.uniform(jax.random.fold_in(sub, i), xf.shape,
                                   jnp.float32)
            q = jnp.clip(jnp.floor(xf / scale + u),
                         -self.levels, self.levels).astype(jnp.int8)
            qs.append(q)
        return (qs, scale), key

    def decode(self, encoded: tuple) -> jax.Array:
        q, scale = encoded
        return q.astype(jnp.float32) * scale


registry.register("identity", IdentityCodec)
registry.register("int8", Int8StochasticCodec)
