"""String-keyed codec registry, mirroring ``strategies/registry.py``.

``get("int8")`` / ``get("topk", fraction=0.25)`` instantiate registered
factories; ``register`` opens the family to out-of-tree wire formats
(the ``quantized`` strategy's ``codec=`` option and the quantization
benchmark matrix resolve through here, so a registered codec shows up
everywhere automatically).  ``resolve`` is the single funnel every
spelling goes through — registry names, already-built
:class:`~repro.wire.base.WireCodec` instances.

Example::

    from repro import wire

    wire.available()                 # ('identity', 'int8', 'randk', 'topk')
    codec = wire.get("int8", bits=4)

    @wire.register("fp8")
    class FP8Codec(wire.WireCodec): ...
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

from repro.wire.base import WireCodec

__all__ = ["register", "get", "available", "resolve"]

_FACTORIES: Dict[str, Callable[..., WireCodec]] = {}


def register(
    name: str,
    factory: Optional[Callable[..., WireCodec]] = None,
    *,
    overwrite: bool = False,
):
    """Register a codec factory (class or callable) under ``name``.

    Usable directly or as a class decorator::

        @wire.register("fp8")
        class FP8Codec(WireCodec): ...
    """

    def _do(f: Callable[..., WireCodec]):
        if not overwrite and name in _FACTORIES:
            raise ValueError(f"codec {name!r} already registered")
        _FACTORIES[name] = f
        return f

    return _do if factory is None else _do(factory)


def available() -> Tuple[str, ...]:
    """Registered codec names, sorted."""
    return tuple(sorted(_FACTORIES))


def get(name: str, **options) -> WireCodec:
    """Instantiate a registered codec by name."""
    try:
        factory = _FACTORIES[str(name)]
    except KeyError:
        raise KeyError(
            f"unknown wire codec {name!r}; have {available()}"
        ) from None
    codec = factory(**options)
    if not isinstance(codec, WireCodec):
        raise TypeError(
            f"factory for {name!r} returned {type(codec).__name__}, "
            "not a WireCodec"
        )
    return codec


def resolve(spec, **options) -> WireCodec:
    """Normalize any codec spelling — a :class:`WireCodec` instance
    (returned as-is) or a registry name — to an instance."""
    if isinstance(spec, WireCodec):
        if options:
            raise ValueError(
                f"cannot apply options {sorted(options)} to an "
                "already-constructed codec instance"
            )
        return spec
    return get(spec, **options)
