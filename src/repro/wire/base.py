"""The wire-format codec protocol (DESIGN.md §8).

Collaborative relaying doubles each client's uplink traffic — its own
update plus its neighbors' relayed consensus — so the wire format of the
``(n, d)`` update stack is the binding cost of peer-aided FL over
intermittent mmWave links (the relay-traffic framing of Yemini et al.,
arXiv:2205.10998, and FedDec, arXiv:2306.06715).  A :class:`WireCodec`
is the compression half of that story: a pure-JAX ``encode``/``decode``
pair over the dense update stack, plus a :class:`CodecDescriptor` that
tells the *strategy layer* how the codec perturbs the aggregation —
whether the reconstruction is unbiased, the known multiplicative gain to
divide out (the unbiasedness-correction hook), and a per-coordinate
noise proxy for the variance-vs-bits bookkeeping.

Design constraints, in order:

* **jit round-trips without recompiles.**  Encode/decode are pure
  functions of traced inputs; all shapes (quantization levels, top-k
  support size) are static Python values fixed at construction/trace
  time.  Stochastic codecs carry a PRNG key as *codec state*, threaded
  through the compiled round inside ``agg_state`` — a shape-stable
  ``(2,)`` uint32, so fresh randomness every round costs zero retraces.
* **the encoded form is a dense device representation.**  ``topk``
  conceptually ships ``k`` (index, value) pairs; on device it stays a
  masked dense array so shapes are static.  ``bits_per_coord`` in the
  descriptor accounts for the *wire* cost, not the device layout.
* **bias is the strategy's problem, not the codec's.**  ``decode``
  returns the raw reconstruction; a codec with a known multiplicative
  bias (e.g. rand-k keeps each coordinate with probability k/d, so
  ``E[decode] = (k/d)·x``) declares it as ``descriptor().gain`` and the
  consuming strategy divides it out.  This mirrors how the multihop
  strategy's Monte-Carlo correction restores condition (5) — one
  correction funnel, two sources of bias.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax

__all__ = ["CodecDescriptor", "WireCodec"]

State = Any
Encoded = Any


@dataclasses.dataclass(frozen=True)
class CodecDescriptor:
    """How a codec perturbs the aggregation — the strategy-facing contract.

    Attributes:
        name: registry key of the codec that produced this descriptor.
        bits_per_coord: average wire cost per coordinate of the encoded
            update (per-row side information such as scales amortized in).
        unbiased: True when ``E[decode(encode(x))] == x`` exactly (over
            the codec's own randomness), *after* dividing by ``gain``.
        gain: known multiplicative bias — ``E[decode(encode(x))] ==
            gain * x``.  The consuming strategy's unbiasedness-correction
            hook divides the decoded stack by this (1.0 = no correction).
        rel_variance: per-coordinate reconstruction-noise proxy in units
            of the per-client row scale squared (int8: ``1/(4·L²)`` for
            ``L`` quantization levels; rand-k after correction:
            ``d/k - 1`` in units of the coordinate's own energy).  0.0
            means "not modeled" (deterministic, data-dependent error —
            e.g. top-k).
    """

    name: str
    bits_per_coord: float
    unbiased: bool
    gain: float = 1.0
    rel_variance: float = 0.0


class WireCodec:
    """Base class / protocol for update-stack wire formats.

    Subclasses implement ``encode`` / ``decode`` (and ``descriptor``);
    everything operates on the dense flattened ``(n, d)`` update stack —
    pytree plumbing stays in the strategy layer (``core/flatten.py``).
    """

    #: registry key; set by subclasses
    name: str = "base"
    #: whether the codec carries state across rounds (e.g. a PRNG key)
    stateful: bool = False
    #: True when ``encode`` returns ``(q int8 (n, d), scale f32 (n, 1))``
    #: — the affine form the fused Pallas dequant-accumulate kernel
    #: (``kernels/fused_dequant.py``) consumes without ever
    #: materializing the dequantized f32 stack.
    supports_fused_dequant: bool = False
    #: True when :meth:`encode_segments` is implemented — the codec can
    #: quantize a list of per-leaf ``(n, d_i)`` column segments against
    #: one row-global scale, so the segment-streaming aggregation path
    #: (DESIGN.md §14) never assembles the monolithic ``(n, d)`` stack.
    supports_segmented: bool = False

    def descriptor(self, d: int) -> CodecDescriptor:
        """The bias/variance contract for flat dimension ``d``."""
        raise NotImplementedError

    def init_state(self, n: int, d: int) -> State:
        """Initial codec state for ``n`` clients and flat dim ``d``
        (``()`` for deterministic codecs)."""
        del n, d
        return ()

    def encode(self, x: jax.Array, state: State) -> Tuple[Encoded, State]:
        """Dense ``(n, d)`` f32 stack -> (encoded, next state)."""
        raise NotImplementedError

    def encode_segments(self, segments, state: State) -> Tuple[Encoded, State]:
        """Per-leaf ``[(n, d_i), ...]`` column segments -> ((encoded
        segment list, row scale), next state) without assembling the
        monolithic stack.  Only codecs declaring ``supports_segmented``
        implement this; the row scale must be *global* across segments
        (the same affine contract as :meth:`encode`)."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support segmented encode"
        )

    def decode(self, encoded: Encoded) -> jax.Array:
        """Encoded form -> reconstructed ``(n, d)`` f32 stack (raw — the
        strategy divides by ``descriptor().gain``)."""
        raise NotImplementedError

    # --------------------------------------------------------------------
    def __repr__(self) -> str:  # registry listings / error messages
        return f"{type(self).__name__}(name={self.name!r})"
