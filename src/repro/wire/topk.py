"""Sparsifying wire formats: ``topk`` (deterministic) and ``randk``.

``topk`` ships each client's ``k`` largest-magnitude coordinates and
zeros the rest — the classic gradient-sparsification format.  It is
*biased* (``E[decode] != x``: the dropped tail is systematically lost),
but the error concentrates in the smallest coordinates, so at equal
wire budget it often beats unbiased random sparsification on realized
error.  The descriptor declares ``unbiased=False`` and no ``gain`` —
there is no data-independent correction; the strategy layer leaves it
alone and the bias shows up honestly in the quantization benchmark.

``randk`` keeps ``k`` *uniformly random* coordinates per client row
instead.  Each coordinate survives with probability ``k/d``, so
``E[decode(encode(x))] = (k/d) · x`` — a known multiplicative bias the
descriptor exposes as ``gain = k/d``.  The consuming strategy's
unbiasedness-correction hook divides it out, which restores
``E = x`` at the cost of variance ``(d/k - 1)`` per unit of coordinate
energy — the sparsified twin of the rate/variance trade the paper's
Theorem 1 makes for connectivity.

Both keep a dense masked ``(n, d)`` device representation (static
shapes under jit); ``bits_per_coord`` accounts for the index+value wire
cost.  ``k`` is static — ``fraction`` is resolved against ``d`` at
trace time, so the support size never retraces.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.wire import registry
from repro.wire.base import CodecDescriptor, State, WireCodec

__all__ = ["TopKCodec", "RandKCodec"]


def _resolve_k(d: int, k: Optional[int], fraction: float) -> int:
    kk = int(k) if k is not None else int(round(fraction * d))
    return max(1, min(kk, d))


class TopKCodec(WireCodec):
    """Keep the ``k`` largest-|x| coordinates per client row."""

    name = "topk"

    def __init__(self, fraction: float = 0.1, k: Optional[int] = None):
        if k is None and not 0.0 < float(fraction) <= 1.0:
            raise ValueError(f"fraction must be in (0, 1], got {fraction}")
        self.fraction = float(fraction)
        self.k = None if k is None else int(k)

    def _k(self, d: int) -> int:
        return _resolve_k(d, self.k, self.fraction)

    def descriptor(self, d: int) -> CodecDescriptor:
        k = self._k(d)
        # k (value, index) pairs on the wire: 32-bit value + log2(d) index
        bits = k * (32.0 + math.log2(max(d, 2))) / max(d, 1)
        return CodecDescriptor(name=self.name, bits_per_coord=bits,
                               unbiased=False, gain=1.0, rel_variance=0.0)

    def encode(self, x: jax.Array, state: State) -> Tuple[jax.Array, State]:
        xf = x.astype(jnp.float32)
        n, d = xf.shape
        k = self._k(d)
        _, idx = jax.lax.top_k(jnp.abs(xf), k)  # (n, k)
        mask = jnp.zeros((n, d), jnp.float32)
        mask = mask.at[jnp.arange(n)[:, None], idx].set(1.0)
        return xf * mask, state

    def decode(self, encoded: jax.Array) -> jax.Array:
        return encoded


class RandKCodec(WireCodec):
    """Keep ``k`` uniformly random coordinates per client row.

    Unbiased after the strategy divides by ``gain = k/d``; the PRNG key
    is codec state threaded through ``agg_state`` like ``int8``'s.
    """

    name = "randk"
    stateful = True

    def __init__(self, fraction: float = 0.1, k: Optional[int] = None,
                 seed: int = 0):
        if k is None and not 0.0 < float(fraction) <= 1.0:
            raise ValueError(f"fraction must be in (0, 1], got {fraction}")
        self.fraction = float(fraction)
        self.k = None if k is None else int(k)
        self.seed = int(seed)

    def _k(self, d: int) -> int:
        return _resolve_k(d, self.k, self.fraction)

    def descriptor(self, d: int) -> CodecDescriptor:
        k = self._k(d)
        bits = k * (32.0 + math.log2(max(d, 2))) / max(d, 1)
        return CodecDescriptor(
            name=self.name,
            bits_per_coord=bits,
            unbiased=True,          # after dividing by gain
            gain=k / d,
            rel_variance=d / k - 1.0,
        )

    def init_state(self, n: int, d: int) -> jax.Array:
        del n, d
        return jax.random.PRNGKey(self.seed)

    def encode(self, x: jax.Array, state: State) -> Tuple[jax.Array, State]:
        key, sub = jax.random.split(state)
        xf = x.astype(jnp.float32)
        n, d = xf.shape
        k = self._k(d)
        # independent k-subset per row: rank i.i.d. uniforms, keep the
        # k smallest — exact sampling without replacement, one fused op
        u = jax.random.uniform(sub, (n, d), jnp.float32)
        _, idx = jax.lax.top_k(-u, k)  # (n, k) uniform k-subsets
        mask = jnp.zeros((n, d), jnp.float32)
        mask = mask.at[jnp.arange(n)[:, None], idx].set(1.0)
        return xf * mask, key

    def decode(self, encoded: jax.Array) -> jax.Array:
        return encoded


registry.register("topk", TopKCodec)
registry.register("randk", RandKCodec)
