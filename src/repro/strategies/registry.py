"""String-keyed strategy registry.

``get("colrel")`` / ``get("multihop", hops=3)`` instantiate registered
factories; ``register`` opens the family to out-of-tree schemes (the CLI
and benchmark matrices enumerate ``available()``, so a registered
strategy shows up everywhere automatically).  ``resolve`` is the single
funnel every legacy spelling goes through — ``Aggregation`` enum values,
plain strings, already-built instances, and the two deprecated fused
knobs (``Aggregation.COLREL_FUSED`` and ``RoundConfig.use_fused_kernel``)
which warn and forward onto the ``colrel`` strategy's ``fused`` option.

Typical use::

    from repro import strategies

    strategies.available()               # ('colrel', 'fedavg_blind', ...)
    s = strategies.get("colrel", fused="kernel")
    s = strategies.get("quantized", codec="int8",
                       codec_options={"bits": 4})

    @strategies.register("my_scheme")    # class decorator form
    class MyScheme(strategies.AggregationStrategy): ...

``available(include_deprecated=True)`` also lists warning aliases;
``canonical_name`` maps any spelling to its registry key without
instantiating (cheap validation).  The protocol a strategy implements
is documented in ``strategies/base.py`` and the authoring walkthrough
in ``docs/strategy-authoring.md``.
"""

from __future__ import annotations

import enum
import warnings
from typing import Callable, Dict, Optional, Tuple

from repro.strategies.base import AggregationStrategy

__all__ = [
    "register",
    "register_deprecated_alias",
    "get",
    "available",
    "canonical_name",
    "resolve",
]

_FACTORIES: Dict[str, Callable[..., AggregationStrategy]] = {}
# alias -> (target name, implied options, warning message)
_ALIASES: Dict[str, Tuple[str, dict, str]] = {}


def register(
    name: str,
    factory: Optional[Callable[..., AggregationStrategy]] = None,
    *,
    overwrite: bool = False,
):
    """Register a strategy factory (class or callable) under ``name``.

    Usable directly or as a class decorator::

        @strategies.register("quantized")
        class QuantizedRelay(AggregationStrategy): ...
    """

    def _do(f: Callable[..., AggregationStrategy]):
        if not overwrite and (name in _FACTORIES or name in _ALIASES):
            raise ValueError(f"strategy {name!r} already registered")
        # an overwritten deprecated alias must go, or get() would keep
        # resolving the alias and silently shadow the new factory
        _ALIASES.pop(name, None)
        _FACTORIES[name] = f
        return f

    return _do if factory is None else _do(factory)


def register_deprecated_alias(alias: str, target: str, message: str, **options):
    """Register ``alias`` to resolve to ``get(target, **options)`` with a
    DeprecationWarning carrying ``message``."""
    if alias in _FACTORIES or alias in _ALIASES:
        raise ValueError(f"strategy {alias!r} already registered")
    _ALIASES[alias] = (target, options, message)


def available(*, include_deprecated: bool = False) -> Tuple[str, ...]:
    """Registered strategy names (deprecated aliases excluded by default)."""
    names = set(_FACTORIES)
    if include_deprecated:
        names |= set(_ALIASES)
    return tuple(sorted(names))


def _as_name(spec) -> str:
    if isinstance(spec, enum.Enum):
        spec = spec.value
    return str(spec)


def canonical_name(spec) -> str:
    """Resolved registry name for any spelling, without instantiating or
    warning (used for cheap validation, e.g. RoundConfig.__post_init__)."""
    if isinstance(spec, AggregationStrategy):
        return spec.name
    name = _as_name(spec)
    if name in _ALIASES:
        return _ALIASES[name][0]
    if name not in _FACTORIES:
        raise KeyError(
            f"unknown aggregation strategy {name!r}; have {available()}"
        )
    return name


def get(name, **options) -> AggregationStrategy:
    """Instantiate a registered strategy by name (enum values accepted)."""
    name = _as_name(name)
    if name in _ALIASES:
        target, implied, message = _ALIASES[name]
        warnings.warn(message, DeprecationWarning, stacklevel=2)
        return get(target, **{**implied, **options})
    try:
        factory = _FACTORIES[name]
    except KeyError:
        raise KeyError(
            f"unknown aggregation strategy {name!r}; have {available()}"
        ) from None
    strategy = factory(**options)
    if not isinstance(strategy, AggregationStrategy):
        raise TypeError(
            f"factory for {name!r} returned {type(strategy).__name__}, "
            "not an AggregationStrategy"
        )
    return strategy


def resolve(spec, *, fused_kernel: bool = False, **options) -> AggregationStrategy:
    """Normalize any strategy spelling to an instance.

    ``spec`` may be an :class:`AggregationStrategy` (returned as-is), a
    registry name, or a legacy ``Aggregation`` enum value.
    ``fused_kernel=True`` is the deprecated ``RoundConfig`` boolean: it
    forwards to the colrel strategy's ``fused="kernel"`` execution
    option and warns.
    """
    if fused_kernel:
        if canonical_name(spec) != "colrel":
            raise ValueError(
                "use_fused_kernel only applies to the colrel strategy "
                f"(got {spec!r}); it would be silently inert"
            )
        warnings.warn(
            "use_fused_kernel is deprecated; use "
            "strategies.get('colrel', fused='kernel') instead",
            DeprecationWarning,
            stacklevel=2,
        )
        if isinstance(spec, AggregationStrategy):
            spec = "colrel"
        return get(spec, fused="kernel", **options)
    if isinstance(spec, AggregationStrategy):
        if options:
            raise ValueError(
                f"cannot apply options {sorted(options)} to an "
                "already-constructed strategy instance"
            )
        return spec
    return get(spec, **options)
