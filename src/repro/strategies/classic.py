"""Registry adapters for the paper's five original schemes.

Each class reproduces the exact arithmetic of the pre-registry code
(the old ``core/aggregation.aggregate`` branches and the scalar collapse
in ``fl/round._strategy_weights``) so the refactor is bit-identical on
fixed tau draws — golden-tested in ``tests/test_strategies.py``.

The old ``Aggregation.COLREL_FUSED`` enum value and the separate
``RoundConfig.use_fused_kernel`` boolean expressed one choice through
two APIs; both now collapse onto the ``fused`` execution option of the
single ``colrel`` strategy:

* ``fused=False``      — faithful two-stage path (Alg. 1 lines 8-11 +
  Alg. 2 line 5): relay mix across the client axis, then the blind PS
  sum, exercised per pytree leaf.
* ``fused="collapse"`` (or ``True``) — exact scalar collapse onto the
  effective weights ``w_j = sum_i tau_i tau_ji alpha_ij`` (the old
  ``COLREL_FUSED``).
* ``fused="kernel"``   — flatten-once fused Pallas aggregation: ravel
  the update pytree into one ``(n, d)`` stack and stream it through the
  mixing-mask + relay-mix + blind-sum kernel in a single HBM pass (the
  old ``use_fused_kernel=True``).  Falls back to the plain contraction
  under pjit so GSPMD can partition it (DESIGN.md §2).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import flatten
from repro.core import relay as relay_ops
from repro.strategies import registry
from repro.strategies.base import AggregationStrategy, ExecutionContext, State

__all__ = [
    "ColRelStrategy",
    "FedAvgPerfect",
    "FedAvgBlind",
    "FedAvgNonBlind",
]

_FUSED_MODES = (False, True, "collapse", "kernel")


class ColRelStrategy(AggregationStrategy):
    """The paper's collaborative relaying (Sec. II-C / Eq. (3))."""

    name = "colrel"
    needs_A = True
    scalar_collapsible = True

    def __init__(self, fused: "bool | str" = False):
        if fused not in _FUSED_MODES:
            raise ValueError(f"fused must be one of {_FUSED_MODES}, got {fused!r}")
        self.fused = "collapse" if fused is True else fused

    def weights(self, tau_up, tau_dd, A):
        n = tau_up.shape[0]
        t = tau_up.astype(jnp.float32)
        w = relay_ops.effective_weights(
            A.astype(jnp.float32), t, tau_dd.astype(jnp.float32)
        )
        return w / n

    def aggregate(self, updates, tau_up, tau_dd, A, state: State = ()):
        delta = relay_ops.colrel_round_delta(
            updates, A, tau_up, tau_dd, fused=bool(self.fused)
        )
        return delta, state

    def aggregate_tree(self, deltas, tau_up, tau_dd, A, state, ctx: ExecutionContext):
        if self.fused == "kernel":
            spec = flatten.flat_spec(deltas, stacked=True)
            if ctx.use_segments(spec.d):
                # segment streaming (DESIGN.md §14): collapse the weight
                # row once, stream each per-leaf (n, d_i) segment through
                # its own kernel pass, and reshape each partial delta
                # straight to its leaf — neither the (n, d) stack nor the
                # (d,) flat delta ever materializes.
                from repro.kernels import ops as kernel_ops

                w = kernel_ops.collapsed_weight_row(A, tau_up, tau_dd)
                segments = flatten.ravel_stacked_segments(
                    deltas, dtype=ctx.flat_dtype)
                leaves = [
                    kernel_ops.row_stream(
                        w, seg, block_d=ctx.fused_block_d).reshape(shape)
                    for seg, shape in zip(segments, spec.shapes)
                ]
                return jax.tree.unflatten(spec.treedef, leaves), state
            # flatten-once fused path: ravel the update pytree into a
            # single contiguous (n, d) stack, stream it through the fused
            # aggregation exactly once (mask + relay mix + blind PS sum,
            # fp32 accumulation), unravel the (d,) delta.
            stack = flatten.ravel_stacked(deltas, dtype=ctx.flat_dtype)
            if ctx.spmd_axes:
                # Sharded execution: express the pass as a plain
                # contraction so GSPMD partitions it (per-shard partial
                # products + one (d,) all-reduce).  An opaque pallas call
                # has no partitioning rule — it would be replicated,
                # gathering the full stack onto every chip.
                gflat = self.weights(tau_up, tau_dd, A) @ stack.astype(jnp.float32)
            else:
                from repro.kernels import ops as kernel_ops

                gflat = kernel_ops.fused_aggregate(
                    A, tau_up, tau_dd, stack, block_d=ctx.fused_block_d
                )
            return flatten.unravel(spec, gflat, dtype=jnp.float32), state
        if self.fused:  # "collapse": leaf-wise scalar weighting
            return super().aggregate_tree(deltas, tau_up, tau_dd, A, state, ctx)
        # faithful two-stage path: relay mix across the client axis, then
        # the blind PS sum — exercised leaf-wise.
        M = relay_ops.mixing_matrix(A.astype(jnp.float32), tau_dd.astype(jnp.float32))
        t = tau_up.astype(jnp.float32)
        gdelta = jax.tree.map(
            lambda D: jnp.tensordot(t, jnp.tensordot(M, D, axes=1), axes=1)
            / ctx.n_clients,
            deltas,
        )
        return gdelta, state


class FedAvgPerfect(AggregationStrategy):
    """Upper bound: everyone always arrives."""

    name = "fedavg_perfect"
    scalar_collapsible = True

    def weights(self, tau_up, tau_dd, A):
        n = tau_up.shape[0]
        return jnp.ones((n,), jnp.float32) / n

    def aggregate(self, updates, tau_up, tau_dd, A, state: State = ()):
        return jnp.mean(updates, axis=0), state


class FedAvgBlind(AggregationStrategy):
    """Sum of arrivals / n (OAC-style); biased whenever p_i < 1."""

    name = "fedavg_blind"
    scalar_collapsible = True
    unbiased_weight_sum = False  # E[sum w] = mean(p) < 1 by design

    def weights(self, tau_up, tau_dd, A):
        return tau_up.astype(jnp.float32) / tau_up.shape[0]

    def aggregate(self, updates, tau_up, tau_dd, A, state: State = ()):
        t = tau_up.astype(updates.dtype)
        return (t @ updates) / updates.shape[0], state


class FedAvgNonBlind(AggregationStrategy):
    """Sum of arrivals / #arrivals."""

    name = "fedavg_nonblind"
    scalar_collapsible = True

    def weights(self, tau_up, tau_dd, A):
        t = tau_up.astype(jnp.float32)
        return t / jnp.maximum(jnp.sum(t), 1.0)

    def aggregate(self, updates, tau_up, tau_dd, A, state: State = ()):
        t = tau_up.astype(updates.dtype)
        k = jnp.maximum(jnp.sum(t), 1.0)
        return (t @ updates) / k, state


registry.register("colrel", ColRelStrategy)
registry.register("fedavg_perfect", FedAvgPerfect)
registry.register("fedavg_blind", FedAvgBlind)
registry.register("fedavg_nonblind", FedAvgNonBlind)
registry.register_deprecated_alias(
    "colrel_fused",
    "colrel",
    "Aggregation.COLREL_FUSED is deprecated; use "
    "strategies.get('colrel', fused=True) instead",
    fused="collapse",
)
