"""Open aggregation-strategy family (DESIGN.md §6).

The paper's ColRel and its FedAvg baselines, FedDec-style multi-hop
relaying, memory-based implicit gossiping, and codec-compressed
quantized relaying (DESIGN.md §8), all behind one protocol
(:class:`AggregationStrategy`) and a string-keyed registry::

    from repro import strategies

    strategies.available()                   # what the CLI / benches see
    s = strategies.get("colrel", fused=True)
    s = strategies.get("multihop", hops=3)
    s = strategies.get("quantized", codec="int8", inner="colrel")

    @strategies.register("my_scheme")
    class MyScheme(strategies.AggregationStrategy): ...

Importing this package registers the built-in strategies; the
authoring guide is ``docs/strategy-authoring.md``.
"""

from repro.strategies.base import AggregationStrategy, ExecutionContext
from repro.strategies.registry import (
    available,
    canonical_name,
    get,
    register,
    register_deprecated_alias,
    resolve,
)
from repro.strategies.classic import (
    ColRelStrategy,
    FedAvgBlind,
    FedAvgNonBlind,
    FedAvgPerfect,
)
from repro.strategies.async_relay import AsyncRelayStrategy, delivered_mask
from repro.strategies.clustered import ClusteredColRelStrategy
from repro.strategies.multihop import MultiHopStrategy, multihop_correction
from repro.strategies.memory import MemoryStrategy
from repro.strategies.quantized import QuantizedStrategy

__all__ = [
    "AggregationStrategy",
    "ExecutionContext",
    "available",
    "canonical_name",
    "get",
    "register",
    "register_deprecated_alias",
    "resolve",
    "AsyncRelayStrategy",
    "delivered_mask",
    "ColRelStrategy",
    "ClusteredColRelStrategy",
    "FedAvgBlind",
    "FedAvgNonBlind",
    "FedAvgPerfect",
    "MultiHopStrategy",
    "multihop_correction",
    "MemoryStrategy",
    "QuantizedStrategy",
]
