"""Memory-based implicit gossiping (Xiang et al., arXiv:2404.10091).

When client ``i``'s uplink is blocked, plain FedAvg drops its update —
biasing the round toward well-connected clients, catastrophically so
under bursty (Gilbert–Elliott) blockage where the same clients vanish
for many consecutive rounds.  The memory scheme instead carries a
``(n, d)`` buffer of each client's *last successfully delivered*
consensus: a blocked link replays the stale contribution, so every
client enters every PS average with weight exactly ``1/n`` — fresh when
the link is up, remembered when it is down.  This is the implicit-
gossip debias: no ``1/p`` importance scaling, no oracle link knowledge.

Round recursion (PS-side):

    tilde   = (A * tau_dd^T) @ updates          # ColRel D2D consensus
    contrib = tau_up * tilde + (1 - tau_up) * buffer
    delta   = (1/n) sum_i contrib_i
    buffer' = contrib                            # updates only on arrival

With every link up (``tau ≡ 1``) the buffer is never consulted and the
round is exactly ColRel.  With ``A = I`` (no relaying) it is the pure
memory-FedAvg of the source paper.  The buffer is shape-stable
``(n, d)`` fp32 state threaded through the compiled round — taus change
every round without recompiling.

Execution: ``fused=False`` (default) is the faithful jnp path —
relay mix, select, accumulate as separate ops (the oracle).
``fused="kernel"`` gives the recursion the flatten-once kernel
treatment (``kernels/fused_memory.py``): one Pallas grid pass reads the
update stack and the replay buffer tile-by-tile, keeps the ``tilde``
consensus intermediate in VMEM, and writes only the ``(d,)`` delta and
the new buffer — keyed off ``aggregate_tree``'s ExecutionContext like
colrel's fused path, with the same pjit fallback (DESIGN.md §2/§8).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import flatten
from repro.core import relay as relay_ops
from repro.strategies import registry
from repro.strategies.base import AggregationStrategy, ExecutionContext, State

__all__ = ["MemoryStrategy"]

_FUSED_MODES = (False, "kernel")


class MemoryStrategy(AggregationStrategy):
    """Implicit gossip: blocked links replay the last received update."""

    name = "memory"
    needs_A = True
    scalar_collapsible = False  # stale replay cannot collapse to weights
    stateful = True

    def __init__(self, fused: "bool | str" = False):
        if fused not in _FUSED_MODES:
            raise ValueError(f"fused must be one of {_FUSED_MODES}, got {fused!r}")
        self.fused = fused

    def init_state(self, n: int, d: int) -> jax.Array:
        # zeros: a client blocked since round 0 contributes nothing until
        # its first successful delivery (equivalent to blind for that
        # client's cold start), then is always represented.
        return jnp.zeros((n, d), jnp.float32)

    def aggregate(self, updates, tau_up, tau_dd, A, state: State):
        n = updates.shape[0]
        x = updates.astype(jnp.float32)
        tilde = relay_ops.relay_mix(
            x, A.astype(jnp.float32), tau_dd.astype(jnp.float32)
        )
        t = tau_up.astype(jnp.float32)[:, None]
        contrib = t * tilde + (1.0 - t) * state
        delta = jnp.ones((n,), jnp.float32) @ contrib / n
        return delta, contrib

    def aggregate_tree(self, deltas, tau_up, tau_dd, A, state,
                       ctx: ExecutionContext):
        if self.fused == "kernel" and not ctx.spmd_axes:
            spec = flatten.flat_spec(deltas, stacked=True)
            from repro.kernels import ops as kernel_ops

            if ctx.use_segments(spec.d):
                # segment streaming (DESIGN.md §14): realized mask once,
                # then per-leaf passes that read the matching replay-
                # buffer columns and write each contrib segment back with
                # dynamic_update_slice — a sequential read-modify-write
                # on one buffer (segments are disjoint, each read precedes
                # its own write), so XLA updates the donated buffer in
                # place and the update stack never materializes.
                mix = kernel_ops.mixing_mask(A, tau_dd)
                segments = flatten.ravel_stacked_segments(
                    deltas, dtype=jnp.float32)
                n = state.shape[0]
                buf = state
                leaves = []
                for seg, off, sz, shape in zip(segments, spec.offsets,
                                               spec.sizes, spec.shapes):
                    buf_seg = jax.lax.slice(buf, (0, off), (n, off + sz))
                    dseg, contrib = kernel_ops.memory_stream(
                        mix, tau_up, seg, buf_seg,
                        block_d=ctx.fused_block_d)
                    buf = jax.lax.dynamic_update_slice(buf, contrib, (0, off))
                    leaves.append(dseg.reshape(shape))
                return jax.tree.unflatten(spec.treedef, leaves), buf
            # flatten-once + fused select-accumulate-update: the tilde
            # consensus intermediate lives in VMEM only; the kernel
            # writes exactly the (d,) delta and the new (n, d) buffer.
            stack = flatten.ravel_stacked(deltas, dtype=jnp.float32)
            gflat, contrib = kernel_ops.fused_memory_update(
                A, tau_up, tau_dd, stack, state, block_d=ctx.fused_block_d
            )
            return flatten.unravel(spec, gflat, dtype=jnp.float32), contrib
        # oracle (and pjit-shardable) path: flatten once, staged jnp ops.
        return super().aggregate_tree(deltas, tau_up, tau_dd, A, state, ctx)


registry.register("memory", MemoryStrategy)
