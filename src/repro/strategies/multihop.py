"""FedDec-style multi-hop relaying (Costantini et al., arXiv:2306.06715).

ColRel gives every update one D2D broadcast slot before the uplink; with
K relay slots an update can travel K hops, so clients with no direct
path to a well-connected relay still reach the PS through intermediate
neighbors.  Each hop re-applies the round's realized masked mixing
matrix ``M = A * tau_dd^T`` (block realizations persist across the
round's K broadcast slots — the channel-coherence-time assumption; the
``hop_mixing`` hook is where per-slot re-draws would plug in), so the
consensus the PS hears is ``tau_up @ M^K @ updates``.

Because every hop is linear, the K-hop scheme still collapses exactly
onto per-client scalar weights ``w = tau_up @ M^K`` — the strategy
implements both the multi-stage dense-stack path and the scalar fast
path, and at K=1 it is bit-identical to ``colrel``.

Unbiasedness correction: COPT-alpha's condition (5) makes the *one-hop*
expected weight ``E[w_j] = 1``; after K hops that no longer holds
(weights compound through intermediate links).  ``calibrate`` estimates
``c_j = E[(tau_up @ M^K)_j]`` by Monte Carlo over the link model and
rescales each source client by ``1 / (n c_j)``, restoring
``E[w_j] = 1/n`` per client — the K-hop analogue of (5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import relay as relay_ops
from repro.core.connectivity import LinkModel, sample_rounds
from repro.strategies import registry
from repro.strategies.base import AggregationStrategy, State

__all__ = ["MultiHopStrategy", "multihop_correction"]


def multihop_correction(
    model: LinkModel,
    A: np.ndarray,
    hops: int,
    *,
    draws: int = 4096,
    seed: int = 0,
) -> np.ndarray:
    """Monte-Carlo estimate of ``c_j = E[(tau_up @ M^K)_j]`` (n,).

    Host-side numpy; deterministic for a fixed seed.  Clients whose
    expected weight is ~0 (unreachable through any K-hop path) keep
    ``c_j = 1`` — no rescaling can make an unreachable client unbiased.
    """
    A = np.asarray(A, np.float64)
    rng = np.random.default_rng(seed)
    ups, dds = sample_rounds(model, rng, draws)  # (R, n), (R, n, n)
    M = A[None] * np.swapaxes(dds, 1, 2)  # (R, n, n) realized mixing
    w = ups
    for _ in range(int(hops)):
        w = np.einsum("ri,rij->rj", w, M)
    c = w.mean(axis=0)
    return np.where(c > 1e-6, c, 1.0)


class MultiHopStrategy(AggregationStrategy):
    """K-hop relay mixing with optional unbiasedness correction."""

    name = "multihop"
    needs_A = True
    scalar_collapsible = True

    def __init__(self, hops: int = 2, correction=None):
        if int(hops) < 1:
            raise ValueError(f"hops must be >= 1, got {hops}")
        self.hops = int(hops)
        # (n,) Monte-Carlo E[tau_up @ M^K]; None = uncorrected
        self.correction = (
            None if correction is None else jnp.asarray(correction, jnp.float32)
        )

    @property
    def calibration_tracks_A(self) -> bool:
        # the correction is E[tau @ M^K] for one specific alpha; it is a
        # baked closure constant of the compiled round, so an adaptive
        # A-swap would silently leave it stale (re-calibration through
        # carried state is a ROADMAP follow-on)
        return self.correction is not None

    def calibrate(self, model: LinkModel, A) -> "MultiHopStrategy":
        if self.correction is not None:
            return self
        return MultiHopStrategy(
            self.hops, correction=multihop_correction(model, A, self.hops)
        )

    # ------------------------------------------------------------------
    def hop_mixing(self, k: int, M: jax.Array, tau_dd: jax.Array) -> jax.Array:
        """Mixing matrix applied at hop ``k`` (0-indexed).  The default
        reuses the round's realized mask every slot; subclasses can
        re-mask per hop when per-slot tau draws are available."""
        del k, tau_dd
        return M

    def _source_scale(self, n: int) -> jax.Array:
        if self.correction is None:
            return jnp.full((n,), 1.0 / n, jnp.float32)
        return 1.0 / (n * self.correction)

    def weights(self, tau_up, tau_dd, A):
        n = tau_up.shape[0]
        t = tau_up.astype(jnp.float32)
        Af = A.astype(jnp.float32)
        td = tau_dd.astype(jnp.float32)
        # hop 1 via the shared effective-weights contraction: at K=1 this
        # is bit-identical to the colrel scalar collapse.
        w = relay_ops.effective_weights(Af, t, td)
        M = relay_ops.mixing_matrix(Af, td)
        for k in range(1, self.hops):
            w = w @ self.hop_mixing(k, M, td)
        return w * self._source_scale(n)

    def aggregate(self, updates, tau_up, tau_dd, A, state: State = ()):
        """Multi-stage dense-stack path: K successive relay broadcasts
        over the realized links, then the blind PS sum."""
        n = updates.shape[0]
        x = updates.astype(jnp.float32) * self._source_scale(n)[:, None]
        M = relay_ops.mixing_matrix(
            A.astype(jnp.float32), tau_dd.astype(jnp.float32)
        )
        for k in range(self.hops):
            x = self.hop_mixing(k, M, tau_dd) @ x  # broadcast slot k
        return tau_up.astype(jnp.float32) @ x, state


registry.register("multihop", MultiHopStrategy)
