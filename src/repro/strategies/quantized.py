"""Quantized relaying: any inner strategy behind a wire-format codec.

The paper's scheme doubles each client's uplink traffic (its own update
plus relayed neighbors'); ``quantized`` models the natural response —
compress the dense ``(n, d)`` update stack to a wire format *before*
the relay mix.  It wraps an arbitrary inner
:class:`~repro.strategies.base.AggregationStrategy` (``colrel`` by
default) and a :class:`~repro.wire.WireCodec` from the codec registry:

    strategies.get("quantized")                                # int8(colrel)
    strategies.get("quantized", codec="int8",
                   codec_options={"bits": 4})
    strategies.get("quantized", codec="topk", inner="multihop",
                   inner_options={"hops": 2})

**Unbiasedness-correction hook.**  The codec's
:class:`~repro.wire.CodecDescriptor` declares any known multiplicative
bias (``E[decode(encode(x))] = gain · x`` — e.g. ``randk``'s
``gain = k/d``); the strategy divides the decoded stack by it before
the inner aggregation, so an unbiased inner scheme stays unbiased
through the wire.  This is the same correction funnel the multihop
strategy's Monte-Carlo calibration uses for K-hop weight compounding —
wire bias and relay bias enter at one point each.

**State threading.**  Stochastic codecs carry a PRNG key; the strategy
threads ``(codec_state, inner_state)`` through the compiled round's
``agg_state``, so fresh quantization draws every round cost zero
retraces (asserted in ``tests/test_wire.py``).

**Execution.**  ``fused=False`` (default) is the dequant oracle: ravel
once, ``decode`` to an f32 stack, inner ``aggregate``.
``fused="kernel"`` streams the int8 affine wire form through the fused
Pallas dequantize-mix-accumulate kernel
(``kernels/fused_dequant.py``) — the f32 stack is never materialized —
keyed off ``aggregate_tree``'s ExecutionContext exactly like colrel's
``fused="kernel"``: under pjit (``ctx.spmd_axes``) it falls back to the
dense path so GSPMD can partition the contraction (DESIGN.md §2/§8).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import wire
from repro.core import flatten
from repro.strategies import registry
from repro.strategies.base import AggregationStrategy, ExecutionContext, State

__all__ = ["QuantizedStrategy"]

_FUSED_MODES = (False, "kernel")


class QuantizedStrategy(AggregationStrategy):
    """Codec-compressed wire format around an inner aggregation scheme."""

    name = "quantized"
    scalar_collapsible = False  # quantization happens on the dense stack
    stateful = True             # (codec_state, inner_state)

    def __init__(self, codec="int8", inner="colrel", fused: "bool | str" = False,
                 codec_options=None, inner_options=None):
        self.codec = wire.resolve(codec, **dict(codec_options or {}))
        self.inner = registry.resolve(inner, **dict(inner_options or {}))
        if isinstance(self.inner, QuantizedStrategy):
            raise ValueError("quantized strategies do not nest")
        if fused not in _FUSED_MODES:
            raise ValueError(f"fused must be one of {_FUSED_MODES}, got {fused!r}")
        if fused == "kernel":
            if not self.codec.supports_fused_dequant:
                raise ValueError(
                    f"codec {self.codec.name!r} has no int8 affine form; "
                    "the fused dequant kernel needs supports_fused_dequant"
                )
            if self.inner.name != "colrel":
                raise ValueError(
                    "the fused dequant kernel computes the colrel collapse; "
                    f"inner strategy {self.inner.name!r} cannot use it"
                )
        self.fused = fused
        # proxy the inner scheme's connectivity contract (instance
        # attributes shadow the class defaults)
        self.needs_A = self.inner.needs_A

    @property
    def calibration_tracks_A(self) -> bool:
        return self.inner.calibration_tracks_A

    def calibrate(self, model, A) -> "QuantizedStrategy":
        inner = self.inner.calibrate(model, A)
        if inner is self.inner:
            return self
        return QuantizedStrategy(codec=self.codec, inner=inner,
                                 fused=self.fused)

    # -- state -----------------------------------------------------------
    def init_state(self, n: int, d: int) -> State:
        return (self.codec.init_state(n, d), self.inner.init_state(n, d))

    def wire_bits_per_coord(self, d: int) -> float:
        return self.codec.descriptor(d).bits_per_coord

    # -- the wire --------------------------------------------------------
    def _debias(self, decoded, d: int):
        """The unbiasedness-correction hook: divide out the codec's
        declared multiplicative gain (a static Python float, so this
        folds into the compiled round for free)."""
        gain = self.codec.descriptor(d).gain
        if gain != 1.0:
            decoded = decoded / jnp.float32(gain)
        return decoded

    def aggregate(self, updates, tau_up, tau_dd, A, state: State):
        codec_state, inner_state = state
        encoded, codec_state = self.codec.encode(
            updates.astype(jnp.float32), codec_state
        )
        decoded = self._debias(self.codec.decode(encoded), updates.shape[-1])
        delta, inner_state = self.inner.aggregate(
            decoded, tau_up, tau_dd, A, inner_state
        )
        return delta, (codec_state, inner_state)

    def aggregate_tree(self, deltas, tau_up, tau_dd, A, state,
                       ctx: ExecutionContext):
        if self.fused == "kernel" and not ctx.spmd_axes:
            spec = flatten.flat_spec(deltas, stacked=True)
            from repro.kernels import ops as kernel_ops

            if ctx.use_segments(spec.d) and self.codec.supports_segmented:
                # segment streaming (DESIGN.md §14): quantize per-leaf
                # segments against one row-global scale, fold the scales
                # (and bias correction) into the collapsed weight row
                # once, stream each int8 segment through its own pass —
                # neither the f32 nor the int8 monolithic stack exists.
                codec_state, inner_state = state
                (qs, scale), codec_state = self.codec.encode_segments(
                    flatten.ravel_stacked_segments(deltas, dtype=jnp.float32),
                    codec_state)
                gain = self.codec.descriptor(spec.d).gain
                w = kernel_ops.collapsed_weight_row(A, tau_up, tau_dd)
                ws = w * (scale / jnp.float32(gain)).reshape(-1)
                leaves = [
                    kernel_ops.row_stream(
                        ws, q, block_d=ctx.fused_block_d).reshape(shape)
                    for q, shape in zip(qs, spec.shapes)
                ]
                return (jax.tree.unflatten(spec.treedef, leaves),
                        (codec_state, inner_state))
            # flatten-once + fused dequant: encode the raveled stack,
            # then stream the int8 payload through one Pallas pass with
            # the dequant scales (and the bias correction) folded into
            # the collapsed colrel weight row.
            stack = flatten.ravel_stacked(deltas, dtype=jnp.float32)
            codec_state, inner_state = state
            (q, scale), codec_state = self.codec.encode(stack, codec_state)
            gain = self.codec.descriptor(spec.d).gain
            gflat = kernel_ops.fused_dequant_aggregate(
                A, tau_up, tau_dd, q, scale / jnp.float32(gain),
                block_d=ctx.fused_block_d,
            )
            return (flatten.unravel(spec, gflat, dtype=jnp.float32),
                    (codec_state, inner_state))
        # dequant oracle (and the pjit-shardable path): flatten once,
        # decode to f32, inner dense aggregation.
        spec = flatten.flat_spec(deltas, stacked=True)
        stack = flatten.ravel_stacked(deltas, dtype=ctx.flat_dtype)
        gflat, state = self.aggregate(stack, tau_up, tau_dd, A, state)
        return flatten.unravel(spec, gflat, dtype=jnp.float32), state

    def __repr__(self) -> str:
        return (f"QuantizedStrategy(codec={self.codec.name!r}, "
                f"inner={self.inner.name!r}, fused={self.fused!r})")


registry.register("quantized", QuantizedStrategy)
