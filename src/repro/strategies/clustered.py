"""Block-sparse clustered ColRel: the population-scale strategy.

Same math as ``colrel`` restricted to a block-diagonal mixing matrix
(``core/blocks.py``): clients relay only within their cluster, so the
strategy consumes the relay weights and the D2D realizations in block
form — ``A`` and ``tau_dd`` are ``(C, m, m)`` tensors, not ``(n, n)``.
The round function never inspects those arguments' shapes (they are
opaque traced slots of ``fl/round.make_round_fn``), so the block layout
flows through the whole scan engine unchanged; only the strategy and the
channel agree on it.

Execution options mirror ``ColRelStrategy`` exactly:

* ``fused=False``      — faithful two-stage path, per pytree leaf:
  per-cluster relay mix, then the blind PS sum.
* ``fused="collapse"`` (or ``True``) — exact scalar collapse onto the
  blocked effective weights.
* ``fused="kernel"``   — flatten-once blocked Pallas aggregation
  (``kernels/relay_block.py``): the (n, d) stack crosses HBM once and
  the dense mask never exists.  Under pjit it falls back to the plain
  block contraction so GSPMD partitions the cluster axis (the block
  tensors shard along their leading axis together with the stack).

With C = 1 the cluster *is* the population and every path reproduces
``colrel`` bitwise — the block einsums lower to the same XLA
contractions as their dense twins (pinned in ``tests/test_clustered.py``
through the scan engine).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import blocks as block_ops
from repro.core import flatten
from repro.strategies import registry
from repro.strategies.base import AggregationStrategy, ExecutionContext, State

__all__ = ["ClusteredColRelStrategy"]

_FUSED_MODES = (False, True, "collapse", "kernel")


class ClusteredColRelStrategy(AggregationStrategy):
    """ColRel over C independent clusters; A / tau_dd are (C, m, m)."""

    name = "clustered"
    needs_A = True
    scalar_collapsible = True

    def __init__(self, fused: "bool | str" = False):
        if fused not in _FUSED_MODES:
            raise ValueError(f"fused must be one of {_FUSED_MODES}, got {fused!r}")
        self.fused = "collapse" if fused is True else fused

    def weights(self, tau_up, tau_dd, A):
        n = tau_up.shape[0]
        w = block_ops.block_effective_weights(
            A.astype(jnp.float32),
            tau_up.astype(jnp.float32),
            tau_dd.astype(jnp.float32),
        )
        return w / n

    def aggregate(self, updates, tau_up, tau_dd, A, state: State = ()):
        delta = block_ops.block_colrel_round_delta(
            updates, A, tau_up, tau_dd, fused=bool(self.fused)
        )
        return delta, state

    def aggregate_tree(self, deltas, tau_up, tau_dd, A, state, ctx: ExecutionContext):
        C, m, _ = A.shape
        if self.fused == "kernel":
            spec = flatten.flat_spec(deltas, stacked=True)
            if ctx.use_segments(spec.d):
                # segment streaming (DESIGN.md §14): collapse the per-
                # cluster weight rows once, stream each per-leaf segment
                # through its own blocked pass, reshape straight to the
                # leaf — the monolithic (n, d) stack never materializes.
                from repro.kernels import ops as kernel_ops

                w = kernel_ops.block_collapsed_weight_row(A, tau_up, tau_dd)
                segments = flatten.ravel_stacked_segments(
                    deltas, dtype=ctx.flat_dtype)
                leaves = [
                    kernel_ops.block_row_stream(
                        w, seg, block_d=ctx.fused_block_d).reshape(shape)
                    for seg, shape in zip(segments, spec.shapes)
                ]
                return jax.tree.unflatten(spec.treedef, leaves), state
            # flatten-once blocked path: ravel the update pytree into one
            # (n, d) stack, stream it through the blocked aggregation
            # exactly once (per-cluster mask + mix + blind sum, fp32
            # accumulation), unravel the (d,) delta.
            stack = flatten.ravel_stacked(deltas, dtype=ctx.flat_dtype)
            if ctx.spmd_axes:
                # Sharded execution: plain contraction so GSPMD partitions
                # the cluster axis (per-shard partial sums + one (d,)
                # all-reduce); an opaque pallas call would be replicated.
                gflat = self.weights(tau_up, tau_dd, A) @ stack.astype(jnp.float32)
            else:
                from repro.kernels import ops as kernel_ops

                gflat = kernel_ops.block_fused_aggregate(
                    A, tau_up, tau_dd, stack, block_d=ctx.fused_block_d
                )
            return flatten.unravel(spec, gflat, dtype=jnp.float32), state
        if self.fused:  # "collapse": leaf-wise scalar weighting
            return super().aggregate_tree(deltas, tau_up, tau_dd, A, state, ctx)
        # faithful two-stage path, leaf-wise: per-cluster relay mix then
        # the blind PS sum — the blocked twin of ColRel's tensordot pair.
        Mb = block_ops.block_mixing_matrix(
            A.astype(jnp.float32), tau_dd.astype(jnp.float32)
        )
        t = tau_up.astype(jnp.float32).reshape(C, m)
        gdelta = jax.tree.map(
            lambda D: jnp.einsum(
                "ci,ci...->...",
                t,
                jnp.einsum("cij,cj...->ci...",
                           Mb, D.reshape(C, m, *D.shape[1:])),
            )
            / ctx.n_clients,
            deltas,
        )
        return gdelta, state


registry.register("clustered", ClusteredColRelStrategy)
