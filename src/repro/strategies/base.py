"""The open aggregation-strategy protocol.

A strategy answers one question per round: *given the stacked client
updates and the realized connectivity, what delta does the PS apply?*
The paper's ColRel and its FedAvg baselines are five points in this
family; FedDec-style multi-hop relaying and memory-based implicit
gossiping are two more that the old closed ``Aggregation`` enum could
not express (they need multi-stage mixing / carried state, not just
scalar weights).

A strategy exposes up to three representations, from most to least
collapsed:

* ``weights(tau_up, tau_dd, A) -> (n,)`` — the scalar-collapse fast
  path: per-client weights ``w`` such that ``delta = w @ updates``.
  Only available when ``scalar_collapsible`` is True; it is what the
  ``client_sequential`` / ``weighted_grad`` execution modes consume
  (they never materialize the update stack) and what the ``weight_sum``
  metric logs.
* ``aggregate(updates, tau_up, tau_dd, A, state) -> (delta, state)`` —
  the general dense-stack path on the flattened ``(n, d)`` update
  buffer.  This is the only method a new strategy *must* implement; the
  default routes through ``weights``.  ``state`` threads a carried
  pytree through the compiled round (shape-stable across rounds so jit
  never recompiles; ``()`` for stateless schemes).
* ``aggregate_tree(deltas, ..., ctx) -> (gdelta, state)`` — the pytree
  entry the ``per_client`` round mode calls.  The default collapses to
  leaf-wise scalar weighting when possible and otherwise does the
  flatten-once ravel -> ``aggregate`` -> unravel dance (DESIGN.md §4).
  Strategies override it only to pick a different execution (e.g.
  ColRel's faithful two-stage path or its fused Pallas kernel).

All three are pure JAX functions of traced inputs: one compiled round
serves every round of training, including alpha swaps mid-run.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import flatten

__all__ = ["AggregationStrategy", "ExecutionContext"]

State = Any


@dataclasses.dataclass(frozen=True)
class ExecutionContext:
    """Execution knobs the round function hands to ``aggregate_tree``.

    These belong to *how* the round executes (RoundConfig), not to the
    strategy's math — the same strategy instance must produce the same
    trajectory under any context.
    """

    n_clients: int
    flat_dtype: Any = jnp.float32  # dtype of the raveled (n, d) stack
    fused_block_d: int = 2048      # d-axis tile for Pallas kernels
    spmd_axes: Optional[tuple] = None  # set when running under pjit
    #: flat-dim threshold for segment streaming (DESIGN.md §14): at
    #: ``d >= segment_d`` the kernel-fused strategies consume per-leaf
    #: (n, d_i) segments instead of materializing the monolithic (n, d)
    #: stack.  0 (default) disables segmenting — the monolithic path is
    #: the oracle and stays the golden-pinned default.
    segment_d: int = 0

    def use_segments(self, d: int) -> bool:
        """Whether the segment-streaming path engages for flat dim ``d``
        (never under pjit: GSPMD partitions the monolithic contraction)."""
        return 0 < self.segment_d <= d and not self.spmd_axes


class AggregationStrategy:
    """Base class / protocol for PS aggregation schemes."""

    #: registry key; set by subclasses
    name: str = "base"
    #: whether the scheme reads the relay weight matrix ``A`` (and hence
    #: benefits from COPT-alpha / adaptive re-optimization)
    needs_A: bool = False
    #: whether ``weights`` is available (delta == w @ updates exactly)
    scalar_collapsible: bool = False
    #: whether the scheme carries state across rounds
    stateful: bool = False
    #: contract checked by the conformance harness: after ``calibrate``
    #: against the fixture link stats, ``E[sum_j weights_j] = 1``
    #: (Eq. (5)).  Blind FedAvg declares False — its participation bias
    #: is the paper's motivating failure, not a bug.
    unbiased_weight_sum: bool = True

    @property
    def calibration_tracks_A(self) -> bool:
        """True when the strategy holds host-side constants calibrated
        against a specific alpha matrix (so swapping A mid-run — the
        adaptive schedule — would silently stale them)."""
        return False

    # -- state -----------------------------------------------------------
    def init_state(self, n: int, d: int) -> State:
        """Initial carried state for ``n`` clients and flat dim ``d``."""
        return ()

    def checkpoint_state(self, state: State) -> Any:
        """Checkpointable form of the carried state (DESIGN.md §12).

        The default returns the state pytree as-is — dict/list/tuple
        nests of arrays (including raw ``uint32`` PRNG keys) round-trip
        through the msgpack codec unchanged.  Override only when the
        carried state holds something the codec cannot express."""
        return state

    def restore_state(self, tree: Any) -> State:
        """Inverse of :meth:`checkpoint_state`: rebuild the carried
        state from its checkpointed form (arrays come back as numpy;
        re-device them so the first post-restore round sees the same
        abstract values — and hence the same jit cache entry — as the
        uninterrupted run)."""
        return jax.tree.map(jnp.asarray, tree)

    def calibrate(self, model, A) -> "AggregationStrategy":
        """Hook for host-side calibration against link statistics
        (e.g. unbiasedness corrections).  Returns a (possibly new)
        strategy instance; the default is a no-op."""
        del model, A
        return self

    def wire_bits_per_coord(self, d: int) -> float:
        """Average uplink wire cost per update coordinate (bits), for the
        bits-on-air accounting in the round metrics.  Schemes that ship
        uncoded f32 updates (everything but ``quantized``) cost 32;
        codec-compressed strategies report their
        :class:`~repro.wire.CodecDescriptor`'s ``bits_per_coord``."""
        del d
        return 32.0

    # -- the three representations --------------------------------------
    def weights(self, tau_up: jax.Array, tau_dd: jax.Array,
                A: jax.Array) -> Optional[jax.Array]:
        """Scalar collapse: (n,) weights with ``delta = w @ updates``,
        or None when the scheme does not collapse."""
        del tau_up, tau_dd, A
        return None

    def aggregate(self, updates: jax.Array, tau_up: jax.Array,
                  tau_dd: jax.Array, A: jax.Array,
                  state: State = ()) -> Tuple[jax.Array, State]:
        """Dense-stack path: ``(n, d)`` updates -> ``(d,)`` delta."""
        w = self.weights(tau_up, tau_dd, A)
        if w is None:
            raise NotImplementedError(
                f"{type(self).__name__} must implement aggregate() "
                "(it is not scalar-collapsible)"
            )
        return jnp.asarray(w, updates.dtype) @ updates, state

    def aggregate_tree(self, deltas, tau_up: jax.Array, tau_dd: jax.Array,
                       A: jax.Array, state: State,
                       ctx: ExecutionContext) -> Tuple[Any, State]:
        """Pytree path for stacked per-client update trees (leading axis
        ``n``).  Default: leaf-wise scalar weighting when collapsible,
        else the flatten-once dense-stack path."""
        w = self.weights(tau_up, tau_dd, A)
        if w is not None and not self.stateful:
            gdelta = jax.tree.map(lambda D: jnp.tensordot(w, D, axes=1), deltas)
            return gdelta, state
        spec = flatten.flat_spec(deltas, stacked=True)
        stack = flatten.ravel_stacked(deltas, dtype=ctx.flat_dtype)
        gflat, state = self.aggregate(stack, tau_up, tau_dd, A, state)
        return flatten.unravel(spec, gflat, dtype=jnp.float32), state

    # --------------------------------------------------------------------
    def __repr__(self) -> str:  # registry listings / error messages
        return f"{type(self).__name__}(name={self.name!r})"
