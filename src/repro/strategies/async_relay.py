"""Asynchronous opportunistic relaying: staleness-weighted aggregation
over a device-resident staging buffer.

The paper's rounds are synchronous: a client whose uplink is blocked
simply contributes nothing that round.  Real mmWave fleets instead keep
training through blockage bursts — the PS aggregates each client's
*last delivered* update, down-weighted by how stale it is (FedDec,
PAPERS.md 2306.06715), and a stale client's fresh update still gets out
when the channel puts it next to a connected peer (opportunistic
relaying, 2206.04742).

``AsyncRelayStrategy`` wraps an arbitrary inner
:class:`~repro.strategies.base.AggregationStrategy` (``colrel`` by
default) and carries two extra pieces of state through the compiled
round — both ride the existing ``agg_state`` slot of the
``lax.scan`` carry, so every execution mode (per-round / chunked /
no-trace / sharded) and the checkpoint/resume + telemetry machinery
work unchanged:

* ``age``     — traced ``(n,)`` int32: rounds since each client's update
  last reached the PS.  Resets to 0 on delivery, increments otherwise —
  the same recurrence as the telemetry outage streak.
* ``staging`` — ``(n, d)`` f32: each client's last-delivered flat
  update, aging in place on device.

**Delivery.**  Client ``i``'s fresh update reaches the PS this round iff
its own uplink is up (``tau_up[i]``) or — when ``opportunistic`` — some
peer ``j`` that heard ``i``'s D2D broadcast (``tau_dd[i, j]``, the
mixing-matrix orientation of ``core/relay.py``) has *its* uplink up and
relays on ``i``'s behalf.  Clustered ``(C, m, m)`` block taus take the
intra-cluster form of the same max.

**Staleness weighting.**  The PS always aggregates a full ``(n, d)``
stack (every client has *some* staged update), scaled by the normalized
decay ``gamma**age``: client ``i``'s multiplier is
``n * gamma**age_i / sum_j gamma**age_j``, so the total effective mass
stays ``n`` and the inner scheme sees full participation
(``tau_up = 1``).  With zero blockage every age is 0, every multiplier
is exactly ``1.0f``, and the round is **bitwise identical** to the sync
inner strategy (pinned in ``tests/test_property.py``).

**Relaying.**  The staged stack is re-relayed through the inner scheme's
own mixing algebra against the *current* ``tau_dd`` draw each round, so
COPT-alpha weights keep applying to whatever the PS is about to sum.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import flatten
from repro.strategies import registry
from repro.strategies.base import AggregationStrategy, ExecutionContext, State

__all__ = ["AsyncRelayStrategy", "delivered_mask"]


def delivered_mask(tau_up: jax.Array, tau_dd: jax.Array,
                   *, opportunistic: bool = True) -> jax.Array:
    """(n,) f32 indicator: whose *fresh* update reaches the PS this round.

    ``tau_dd[i, j]`` follows the ``core/relay.py`` mixing convention —
    client ``i``'s D2D broadcast reached peer ``j`` — so peer ``j`` can
    relay ``i``'s update exactly when ``tau_dd[i, j] * tau_up[j]``.
    Block ``(C, m, m)`` taus use the intra-cluster form.
    """
    t = tau_up.astype(jnp.float32)
    if not opportunistic:
        return t
    dd = tau_dd.astype(jnp.float32)
    if tau_dd.ndim == 3:  # clustered block form
        C, m = tau_dd.shape[0], tau_dd.shape[1]
        tb = t.reshape(C, m)
        relayed = jnp.max(dd * tb[:, None, :], axis=2).reshape(-1)
    else:
        relayed = jnp.max(dd * t[None, :], axis=1)
    return jnp.maximum(t, relayed)


class AsyncRelayStrategy(AggregationStrategy):
    """Staleness-weighted async aggregation around an inner scheme."""

    name = "async_colrel"
    scalar_collapsible = False  # the staged stack must materialize
    stateful = True             # {"age", "staging", "inner"}
    #: marks the async family for the round/trainer plumbing (duck-typed
    #: so fl/round.py never imports this module)
    is_async = True

    def __init__(self, inner="colrel", gamma: float = 0.9,
                 opportunistic: bool = True, inner_options=None):
        self.inner = registry.resolve(inner, **dict(inner_options or {}))
        if getattr(self.inner, "is_async", False):
            raise ValueError("async strategies do not nest")
        if not 0.0 < float(gamma) <= 1.0:
            raise ValueError(f"gamma must be in (0, 1], got {gamma!r}")
        self.gamma = float(gamma)
        self.opportunistic = bool(opportunistic)
        # proxy the inner scheme's connectivity contract (instance
        # attributes shadow the class defaults)
        self.needs_A = self.inner.needs_A
        self.name = f"async_{self.inner.name}"

    @property
    def calibration_tracks_A(self) -> bool:
        return self.inner.calibration_tracks_A

    def calibrate(self, model, A) -> "AsyncRelayStrategy":
        inner = self.inner.calibrate(model, A)
        if inner is self.inner:
            return self
        return AsyncRelayStrategy(inner=inner, gamma=self.gamma,
                                  opportunistic=self.opportunistic)

    def wire_bits_per_coord(self, d: int) -> float:
        return self.inner.wire_bits_per_coord(d)

    # -- state -----------------------------------------------------------
    def init_state(self, n: int, d: int) -> State:
        return {
            "age": jnp.zeros((n,), jnp.int32),
            "staging": jnp.zeros((n, d), jnp.float32),
            "inner": self.inner.init_state(n, d),
        }

    def checkpoint_state(self, state: State):
        return {
            "age": state["age"],
            "staging": state["staging"],
            "inner": self.inner.checkpoint_state(state["inner"]),
        }

    def restore_state(self, tree) -> State:
        return {
            "age": jnp.asarray(tree["age"], jnp.int32),
            "staging": jnp.asarray(tree["staging"], jnp.float32),
            "inner": self.inner.restore_state(tree["inner"]),
        }

    # -- the async carry --------------------------------------------------
    @staticmethod
    def _advance_age(age, deliv):
        """Where-free age recurrence: ``deliv`` is an exact {0., 1.}
        indicator, so ``(age + 1) * (1 - deliv)`` in int32 is bitwise the
        select form — a single fused multiply on the (n,) vector instead
        of a predicated copy.  (The staging refresh below keeps ``where``:
        arithmetic masking of fp payloads would perturb bitwise replay.)
        """
        return ((age + 1) * (1 - deliv.astype(jnp.int32))).astype(jnp.int32)

    def advance(self, age, staging, stack, tau_up, tau_dd):
        """One step of the carry recurrence: ``(delivered, age', staging')``.

        Delivered clients refresh their staged update and reset to age 0;
        blocked clients keep aging in place.
        """
        deliv = delivered_mask(tau_up, tau_dd, opportunistic=self.opportunistic)
        age = self._advance_age(age, deliv)
        staging = jnp.where(deliv[:, None] > 0, stack.astype(staging.dtype),
                            staging)
        return deliv, age, staging

    def staleness_weights(self, age: jax.Array) -> jax.Array:
        """Normalized decay ``gamma**age / sum gamma**age`` (sums to 1)."""
        s = jnp.power(jnp.float32(self.gamma), age.astype(jnp.float32))
        return s / jnp.sum(s)

    def _effective(self, age, staging):
        """Staleness-weighted staged stack with total mass ``n`` (so the
        multiplier is exactly ``1.0f`` per client at age 0)."""
        n = staging.shape[0]
        s = jnp.power(jnp.float32(self.gamma), age.astype(jnp.float32))
        scale = jnp.float32(n) / jnp.sum(s)
        return (s * scale)[:, None] * staging

    # -- aggregation ------------------------------------------------------
    def aggregate(self, updates, tau_up, tau_dd, A, state: State):
        deliv, age, staging = self.advance(
            state["age"], state["staging"], updates, tau_up, tau_dd)
        del deliv
        eff = self._effective(age, staging)
        delta, inner_state = self.inner.aggregate(
            eff, jnp.ones_like(tau_up), tau_dd, A, state["inner"])
        return delta, {"age": age, "staging": staging, "inner": inner_state}

    def aggregate_tree(self, deltas, tau_up, tau_dd, A, state,
                       ctx: ExecutionContext):
        spec = flatten.flat_spec(deltas, stacked=True)
        if (ctx.use_segments(spec.d) and not self.inner.stateful
                and self.inner.scalar_collapsible):
            return self._aggregate_segments(deltas, spec, tau_up, tau_dd, A,
                                            state, ctx)
        # flatten once into the staging layout, advance the carry, then
        # hand the re-stacked effective tree to the inner scheme so its
        # own execution path (faithful / fused / blocked) still applies.
        stack = flatten.ravel_stacked(deltas, dtype=ctx.flat_dtype)
        deliv, age, staging = self.advance(
            state["age"], state["staging"], stack, tau_up, tau_dd)
        del deliv
        eff = self._effective(age, staging)
        eff_tree = flatten.unravel_stacked(spec, eff, dtype=jnp.float32)
        gdelta, inner_state = self.inner.aggregate_tree(
            eff_tree, jnp.ones_like(tau_up), tau_dd, A, state["inner"], ctx)
        return gdelta, {"age": age, "staging": staging, "inner": inner_state}

    def _aggregate_segments(self, deltas, spec, tau_up, tau_dd, A, state, ctx):
        """Segment-streaming async round (DESIGN.md §14).

        The monolithic path materializes ~5 full-size (n, d) buffers
        (ravel, staging select, effective scaling, the re-stacked tree,
        the inner's re-ravel).  Here the staging buffer is the *only*
        (n, d) array: each leaf's segment is selected into the matching
        staging columns with ``where`` + ``dynamic_update_slice`` (a
        sequential read-modify-write on one buffer — donation-aliasable),
        and the staleness multipliers fold into the inner scheme's
        collapsed weight row (inner sees full participation), so the
        delta streams straight off the staging columns.  The fold changes
        the fp association (``(w·m) @ s`` vs ``w @ (m·s)``): deltas agree
        with the monolithic path to fp32 contraction tolerance, while
        ``age``/``staging`` — and hence the staleness metrics — stay
        bitwise (pinned in ``tests/test_larged.py``).
        """
        from repro.kernels import ops as kernel_ops

        deliv = delivered_mask(tau_up, tau_dd,
                               opportunistic=self.opportunistic)
        age = self._advance_age(state["age"], deliv)
        staging = state["staging"]
        n = staging.shape[0]
        segments = flatten.ravel_stacked_segments(deltas, dtype=ctx.flat_dtype)
        refresh = deliv[:, None] > 0
        for seg, off, sz in zip(segments, spec.offsets, spec.sizes):
            cur = jax.lax.slice(staging, (0, off), (n, off + sz))
            staging = jax.lax.dynamic_update_slice(
                staging, jnp.where(refresh, seg.astype(staging.dtype), cur),
                (0, off))
        s = jnp.power(jnp.float32(self.gamma), age.astype(jnp.float32))
        mult = s * (jnp.float32(n) / jnp.sum(s))
        w_eff = self.inner.weights(jnp.ones_like(tau_up), tau_dd, A) * mult
        leaves = [
            kernel_ops.row_stream(
                w_eff, jax.lax.slice(staging, (0, off), (n, off + sz)),
                block_d=ctx.fused_block_d).reshape(shape)
            for off, sz, shape in zip(spec.offsets, spec.sizes, spec.shapes)
        ]
        gdelta = jax.tree.unflatten(spec.treedef, leaves)
        return gdelta, {"age": age, "staging": staging,
                        "inner": state["inner"]}

    def __repr__(self) -> str:
        return (f"AsyncRelayStrategy(inner={self.inner.name!r}, "
                f"gamma={self.gamma!r}, opportunistic={self.opportunistic!r})")


registry.register("async_colrel", AsyncRelayStrategy)
