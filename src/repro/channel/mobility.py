"""Random-waypoint client mobility over the mmWave geometry.

Clients move on a 2-D plane (PS fixed) toward uniformly re-drawn
waypoints at a constant speed; every ``epoch`` rounds the geometric
mmWave :class:`LinkModel` is re-derived from the current positions via
:func:`repro.core.topology.mmwave_geometric` — so the marginals ``p``
and ``P`` *drift* and yesterday's optimal relay weights go stale.
Within an epoch, rounds are sampled i.i.d. from the epoch's model (the
paper's static law), which keeps the drift attributable purely to
geometry.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.channel.base import stacked_trace
from repro.core.connectivity import LinkModel, sample_round
from repro.core.topology import mmwave_geometric

__all__ = ["MobilityChannel"]


class MobilityChannel:
    """Waypoint trajectories re-deriving the mmWave ``LinkModel`` per epoch.

    Parameters
    ----------
    n: number of clients.
    area: half-width (meters) of the square region (centered on the PS)
        clients roam in.  The mmWave uplink dies off beyond ~250 m, so
        ``area ~ 300`` keeps clients drifting in and out of coverage.
    speed: meters moved per round.
    epoch: rounds between geometry refreshes (the model is piecewise
        static over epochs).
    init_positions: optional (n, 2) starting coordinates; random
        uniform in the region otherwise.
    """

    def __init__(
        self,
        n: int,
        *,
        area: float = 300.0,
        speed: float = 4.0,
        epoch: int = 20,
        seed: int = 0,
        ps_position: Sequence[float] = (0.0, 0.0),
        d2d_mode: str = "intermittent",
        rho: float = 0.0,
        init_positions: Optional[np.ndarray] = None,
    ):
        if epoch <= 0:
            raise ValueError("epoch must be positive")
        self._n = int(n)
        self.area = float(area)
        self.speed = float(speed)
        self.epoch = int(epoch)
        self.ps_position = tuple(ps_position)
        self.d2d_mode = d2d_mode
        self.rho = rho
        self._rng = np.random.default_rng(seed)
        if init_positions is not None:
            self.positions = np.array(init_positions, dtype=np.float64)
            if self.positions.shape != (self._n, 2):
                raise ValueError(f"init_positions must be ({n}, 2)")
        else:
            self.positions = self._draw_points(self._n)
        self._waypoints = self._draw_points(self._n)
        self._next = 0
        self._models: dict[int, LinkModel] = {}  # epoch index -> model
        self._models[0] = self._derive_model()

    # -- geometry ------------------------------------------------------
    def _draw_points(self, k: int) -> np.ndarray:
        return self._rng.uniform(-self.area, self.area, size=(k, 2))

    def _derive_model(self) -> LinkModel:
        return mmwave_geometric(
            self.positions, self.ps_position, d2d_mode=self.d2d_mode, rho=self.rho
        )

    def _advance(self) -> None:
        """Move every client one round toward its waypoint."""
        d = self._waypoints - self.positions
        dist = np.linalg.norm(d, axis=1)
        arrived = dist <= self.speed
        step = np.where(
            arrived[:, None], d, d * (self.speed / np.maximum(dist, 1e-12))[:, None]
        )
        self.positions = self.positions + step
        if arrived.any():
            self._waypoints[arrived] = self._draw_points(int(arrived.sum()))

    # -- ChannelProcess ------------------------------------------------
    @property
    def n(self) -> int:
        return self._n

    def tau_for_round(self, r: int) -> tuple[np.ndarray, np.ndarray]:
        if r != self._next:
            raise ValueError(
                f"MobilityChannel serves rounds in order; expected {self._next}, got {r}"
            )
        self._next += 1
        e = r // self.epoch
        if e not in self._models:
            self._models[e] = self._derive_model()
        tau = sample_round(self._models[e], self._rng)
        self._advance()
        return tau

    def trace(self, start: int, rounds: int) -> tuple[np.ndarray, np.ndarray]:
        # geometry advances (and may re-derive) every round, so there is
        # no block to vectorize — serve the bulk contract per-round
        return stacked_trace(self, start, rounds)

    def checkpoint_state(self) -> dict:
        """Full mobility state: RNG, geometry, and the already-derived
        models of the current-and-later epochs (DESIGN.md §12).

        The current epoch's model was derived from positions at the
        epoch boundary — positions that no longer exist mid-epoch — so
        it must ship in the checkpoint explicitly; stale earlier epochs
        are dropped (they can never be served again)."""
        from repro.ckpt.schema import rng_state_to_json
        cur = self._next // self.epoch
        return {
            "kind": type(self).__name__,
            "rng": rng_state_to_json(self._rng),
            "positions": np.array(self.positions),
            "waypoints": np.array(self._waypoints),
            "next": int(self._next),
            "models": {str(e): {"p": np.asarray(m.p), "P": np.asarray(m.P),
                                "E": np.asarray(m.E)}
                       for e, m in self._models.items() if e >= cur},
        }

    def restore_state(self, state: dict) -> None:
        from repro.ckpt.schema import rng_from_json
        if state.get("kind") != type(self).__name__:
            raise ValueError(
                f"checkpoint is for channel {state.get('kind')!r}; this "
                f"is a {type(self).__name__}")
        self._rng = rng_from_json(state["rng"])
        self.positions = np.asarray(state["positions"], np.float64)
        self._waypoints = np.asarray(state["waypoints"], np.float64)
        self._next = int(state["next"])
        self._models = {
            int(e): LinkModel(np.asarray(m["p"]), np.asarray(m["P"]),
                              np.asarray(m["E"]))
            for e, m in state["models"].items()
        }

    def model_for_round(self, r: int) -> LinkModel:
        e = r // self.epoch
        if e not in self._models:
            raise ValueError(f"epoch {e} not reached yet (round {r})")
        return self._models[e]
