"""Clustered channels: tau_dd served in (C, m, m) block form.

The dense channels (``base.py`` / ``markov.py``) emit an (n, n) tau_dd
per round — 2 GiB/round of mostly-structural zeros at n = 2^14 under
clustering.  These processes sample only the links that exist: one
uniform (or one Gilbert–Elliott gate chain) per *intra-cluster* pair,
C·m(m-1)/2 lanes total instead of n(n-1)/2, and assemble the block
tensor directly with the same per-pair lane gather as the dense
samplers — ``pair_lane_table(m)`` applied per cluster (the table indexes
locally, so one (m², ) table serves every cluster).

The round function treats tau_dd as an opaque traced slot, so the block
layout flows through ``make_scan_round_fn`` / ``FLTrainer`` unchanged;
only the ``clustered`` strategy interprets it.  Everything mirrors the
dense subsystem: :class:`ClusteredStaticChannel` is the paper's i.i.d.
law restricted to the block support, :class:`ClusteredMarkovChannel`
carries one GE gate per uplink and per intra-cluster pair (same
15-bit-lattice integer thresholds, same marginal-preservation fitting as
``gilbert_elliott``), and both expose ``scan_sampler()`` for the
no-trace in-scan mode.  ``trace`` / ``tau_for_round`` read the same
stream, so loop- and scan-driven training see identical draws.

Block tensors shard along their leading cluster axis — the same
``clients`` mesh axis as the (n, d) update stack (DESIGN.md §10).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.channel.base import BlockBufferedChannel, pair_lane_table
from repro.channel.markov import _LATTICE, channel_key
from repro.core.blocks import ClusteredLinkModel

__all__ = [
    "ClusteredGEParams",
    "gilbert_elliott_clustered",
    "clustered_static_scan_sampler",
    "clustered_ge_scan_sampler",
    "ClusteredStaticChannel",
    "ClusteredMarkovChannel",
]

_EPS = 1e-12


def _pair_params(model: ClusteredLinkModel):
    """Per-cluster unordered-pair marginals: (C, mp) each, mp = m(m-1)/2."""
    m = model.m
    iu, ju = np.triu_indices(m, k=1)
    pij = model.Pb[:, iu, ju]
    pji = model.Pb[:, ju, iu]
    e = model.Eb[:, iu, ju]
    return pij, pji, e


def _block_gather(tij, tji, lane):
    """Assemble (C, m, m) from per-pair draws ``tij``/``tji`` (..., C, mp)
    via the local pair-lane table — the blocked twin of the dense
    samplers' (n, n) gather, one gather per cluster row."""
    C, mp = tij.shape[-2:]
    m_sq = lane.shape[0]
    ones = jnp.ones((*tij.shape[:-1], 1), bool)
    cat = jnp.concatenate([tij, tji, ones], axis=-1)  # (..., C, 2mp+1)
    out = jnp.take(cat, lane, axis=-1)  # (..., C, m*m)
    m = int(np.sqrt(m_sq))
    return out.reshape(*tij.shape[:-1], m, m).astype(jnp.float32)


# ---------------------------------------------------------------------------
# i.i.d. clustered sampling (the paper's law on the block support)
# ---------------------------------------------------------------------------


def clustered_static_scan_sampler(model: ClusteredLinkModel):
    """In-scan i.i.d. sampler: ``sample_fn(state, key) -> (tau_up (n,),
    tau_b (C, m, m), state)`` — the block twin of
    :func:`repro.channel.base.static_scan_sampler`, same one-uniform
    reciprocity coupling per pair, carried state ``()``."""
    C, m = model.C, model.m
    n = model.n
    pij, pji, e = _pair_params(model)
    p = jnp.asarray(model.p, jnp.float32)
    pij = jnp.asarray(pij, jnp.float32)
    pji = jnp.asarray(pji, jnp.float32)
    e = jnp.asarray(e, jnp.float32)
    lane = jnp.asarray(pair_lane_table(m))

    def init_fn(key):
        del key
        return ()

    def sample_fn(state, key):
        k1, k2 = jax.random.split(key)
        tau_up = (jax.random.uniform(k1, (n,)) < p).astype(jnp.float32)
        uu = jax.random.uniform(k2, pij.shape)  # (C, mp)
        both = uu < e
        tij = both | ((uu >= e) & (uu < pij))
        tji = both | ((uu >= pij) & (uu < pij + pji - e))
        return tau_up, _block_gather(tij, tji, lane), state

    return init_fn, sample_fn


@partial(jax.jit, static_argnames=("rounds",))
def _static_block_trace(p, pij, pji, e, lane, key, *, rounds: int):
    """Bulk i.i.d. service: (R, n) uplinks + (R, C, m, m) blocks in one
    compiled pass (two bulk uniform draws, no per-round host loop)."""
    k1, k2 = jax.random.split(key)
    ups = (jax.random.uniform(k1, (rounds, *p.shape)) < p).astype(jnp.float32)
    uu = jax.random.uniform(k2, (rounds, *pij.shape))
    both = uu < e
    tij = both | ((uu >= e) & (uu < pij))
    tji = both | ((uu >= pij) & (uu < pij + pji - e))
    return ups, _block_gather(tij, tji, lane)


class ClusteredStaticChannel(BlockBufferedChannel):
    """The paper's i.i.d. channel on the block support, block-buffered.

    ``tau_for_round`` returns ``(tau_up (n,), tau_b (C, m, m))``;
    ``trace`` the bulk ``(K, n)`` / ``(K, C, m, m)`` forms.  Buffers are
    generated on device in one fused pass per block."""

    def __init__(self, model: ClusteredLinkModel, seed: int = 0, block: int = 256):
        super().__init__(model.n, block)
        self.model = model
        pij, pji, e = _pair_params(model)
        self._p = jnp.asarray(model.p, jnp.float32)
        self._pij = jnp.asarray(pij, jnp.float32)
        self._pji = jnp.asarray(pji, jnp.float32)
        self._e = jnp.asarray(e, jnp.float32)
        self._lane = jnp.asarray(pair_lane_table(model.m))
        self._key = channel_key(seed)

    def _generate_block(self, rounds: int):
        self._key, k = jax.random.split(self._key)
        return _static_block_trace(
            self._p, self._pij, self._pji, self._e, self._lane, k,
            rounds=rounds,
        )

    def _gen_state(self):
        from repro.ckpt.keys import encode_prng_key
        return {"key": encode_prng_key(self._key)}

    def _set_gen_state(self, state) -> None:
        from repro.ckpt.keys import decode_prng_key
        self._key = decode_prng_key(state["key"])

    def model_for_round(self, r: int) -> ClusteredLinkModel:
        return self.model

    def scan_sampler(self):
        """``(init_fn, sample_fn)`` drawing i.i.d. block rounds in-scan."""
        return clustered_static_scan_sampler(self.model)


# ---------------------------------------------------------------------------
# Gilbert–Elliott clustered chains
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ClusteredGEParams:
    """GE chain parameters on the block support: one gate per uplink and
    per intra-cluster unordered pair (``(C, mp)``, pair index local to
    the cluster via ``np.triu_indices(m, 1)``)."""

    model: ClusteredLinkModel
    pi_up: np.ndarray   # (n,)
    lam_up: np.ndarray  # (n,)
    pi_dd: np.ndarray   # (C, mp)
    lam_dd: np.ndarray  # (C, mp)

    @property
    def n(self) -> int:
        return self.model.n


def gilbert_elliott_clustered(
    model: ClusteredLinkModel,
    memory=0.9,
    occupancy=None,
) -> ClusteredGEParams:
    """Fit per-link GE chains matching ``model``'s marginals exactly —
    :func:`repro.channel.markov.gilbert_elliott` restricted to the links
    that exist (the fitting math is elementwise per link, so the block
    form is the same formulas on (C, mp) arrays)."""
    if isinstance(memory, tuple):
        lam_up_s, lam_dd_s = memory
    else:
        lam_up_s = lam_dd_s = float(memory)
    for lam in (lam_up_s, lam_dd_s):
        if not 0.0 <= lam < 1.0:
            raise ValueError(f"memory must be in [0, 1), got {lam}")

    pij, pji, eij = _pair_params(model)
    floor_up = model.p
    floor_dd = np.maximum(np.maximum(pij, pji), pij + pji - eij)
    if occupancy is None:
        pi_up, pi_dd = floor_up.copy(), floor_dd.copy()
    else:
        if not 0.0 < occupancy <= 1.0:
            raise ValueError(f"occupancy must be in (0, 1], got {occupancy}")
        pi_up = np.maximum(floor_up, occupancy)
        pi_dd = np.maximum(floor_dd, occupancy)
    pi_up = np.where(floor_up <= 0.0, 1.0, pi_up)
    pi_dd = np.where(floor_dd <= 0.0, 1.0, pi_dd)

    lam_up = np.where(pi_up >= 1.0, 0.0, np.full(model.n, lam_up_s))
    lam_dd = np.where(pi_dd >= 1.0, 0.0, np.full(pi_dd.shape, lam_dd_s))
    return ClusteredGEParams(model, pi_up, lam_up, pi_dd, lam_dd)


def _cge_arrays(params: ClusteredGEParams) -> dict:
    """Integer-threshold device operands (15-bit lattice, uint16 — same
    quantization argument as the dense sampler; cached on the params)."""
    cached = getattr(params, "_device_arrays_cache", None)
    if cached is not None:
        return cached
    model = params.model
    pij, pji, eij = _pair_params(model)
    pi_dd = np.maximum(params.pi_dd, _EPS)
    q_up = np.where(params.pi_up > 0,
                    model.p / np.maximum(params.pi_up, _EPS), 0.0)
    qij, qji, e_c = pij / pi_dd, pji / pi_dd, eij / pi_dd
    lattice = lambda p: np.rint(np.clip(p, 0.0, 1.0) * _LATTICE).astype(np.int64)
    thresh = lambda p: jnp.asarray(lattice(p), jnp.uint16)
    arrs = dict(
        t_g_up=thresh((1.0 - params.lam_up) * params.pi_up),
        t_b_up=thresh((1.0 - params.lam_up) * (1.0 - params.pi_up)),
        t_g_dd=thresh((1.0 - params.lam_dd) * params.pi_dd),
        t_b_dd=thresh((1.0 - params.lam_dd) * (1.0 - params.pi_dd)),
        t_q_up=thresh(q_up),
        t_qij=thresh(qij),
        t_e=thresh(e_c),
        t_mid=jnp.asarray(lattice(qij) + lattice(qji) - lattice(e_c),
                          jnp.uint16),
        pair_lane=jnp.asarray(pair_lane_table(model.m)),
        pi_up=jnp.asarray(params.pi_up, jnp.float32),
        pi_dd=jnp.asarray(params.pi_dd, jnp.float32),
    )
    object.__setattr__(params, "_device_arrays_cache", arrs)
    return arrs


def _cge_emit(arrs, sp, u_dd):
    """Conditional pair emissions given Good gates ``sp`` (..., C, mp)."""
    tij = sp & (u_dd < arrs["t_qij"])
    tji = sp & (
        (u_dd < arrs["t_e"])
        | ((u_dd >= arrs["t_qij"]) & (u_dd < arrs["t_mid"]))
    )
    return _block_gather(tij, tji, arrs["pair_lane"])


def _cge_core(arrs, state, key, *, rounds: int, n: int):
    """Blocked twin of ``markov._ge_core``: scan the gate chains, emit
    (R, n) uplinks + (R, C, m, m) blocks.  Same anatomy — one bulk
    uint16 draw, integer thresholds, gate-only scan payload, vectorized
    assembly after the loop."""
    C, mp = arrs["t_qij"].shape
    cm = C * mp
    lanes = 2 * n + 2 * cm
    u16 = jax.random.bits(key, (rounds, lanes), jnp.uint16)
    u15 = u16 >> jnp.uint16(1)
    u_gate = u15[:, : n + cm]
    u_up = u15[:, n + cm : 2 * n + cm]
    u_dd = u15[:, 2 * n + cm :].reshape(rounds, C, mp)
    t_g = jnp.concatenate([arrs["t_g_up"], arrs["t_g_dd"].reshape(cm)])
    t_b = jnp.concatenate([arrs["t_b_up"], arrs["t_b_dd"].reshape(cm)])

    def step(s, u):
        s = jnp.where(s, u >= t_b, u < t_g)
        return s, s

    end, gates = jax.lax.scan(step, state, u_gate)
    su = gates[:, :n]
    sp = gates[:, n:].reshape(rounds, C, mp)
    ups = (su & (u_up < arrs["t_q_up"])).astype(jnp.float32)
    return ups, _cge_emit(arrs, sp, u_dd), end


_cge_scan = partial(jax.jit, static_argnames=("rounds", "n"))(_cge_core)


def _cge_stationary_state(arrs, key):
    k1, k2 = jax.random.split(key)
    su = jax.random.uniform(k1, arrs["pi_up"].shape) < arrs["pi_up"]
    sp = jax.random.uniform(k2, arrs["pi_dd"].shape) < arrs["pi_dd"]
    return jnp.concatenate([su, sp.reshape(-1)])


def clustered_ge_scan_sampler(params: ClusteredGEParams):
    """Per-round GE sampler for in-scan use, block layout: the twin of
    :func:`repro.channel.markov.ge_scan_sampler` with a packed
    ``(n + C·mp,)`` gate state and (C, m, m) emissions."""
    arrs = _cge_arrays(params)
    n = params.n
    C, mp = arrs["t_qij"].shape
    cm = C * mp
    t_g = jnp.concatenate([arrs["t_g_up"], arrs["t_g_dd"].reshape(cm)])
    t_b = jnp.concatenate([arrs["t_b_up"], arrs["t_b_dd"].reshape(cm)])

    def init_fn(key):
        return _cge_stationary_state(arrs, key)

    def sample_fn(state, key):
        u15 = jax.random.bits(key, (2 * n + 2 * cm,), jnp.uint16) >> jnp.uint16(1)
        u_gate = u15[: n + cm]
        u_up = u15[n + cm : 2 * n + cm]
        u_dd = u15[2 * n + cm :].reshape(C, mp)
        state = jnp.where(state, u_gate >= t_b, u_gate < t_g)
        su, sp = state[:n], state[n:].reshape(C, mp)
        ups = (su & (u_up < arrs["t_q_up"])).astype(jnp.float32)
        return ups, _cge_emit(arrs, sp, u_dd), state

    return init_fn, sample_fn


class ClusteredMarkovChannel(BlockBufferedChannel):
    """GE bursty blockage on the block support, scan-generated ``block``
    rounds at a time with the chain state carried across blocks."""

    def __init__(self, params: ClusteredGEParams, seed: int = 0, block: int = 256):
        super().__init__(params.n, block)
        self.params = params
        self._arrs = _cge_arrays(params)
        self._key, k_init = jax.random.split(channel_key(seed))
        self._state = _cge_stationary_state(self._arrs, k_init)

    def _generate_block(self, rounds: int):
        self._key, k = jax.random.split(self._key)
        ups, dds, self._state = _cge_scan(
            self._arrs, self._state, k, rounds=rounds, n=self.n
        )
        return ups, dds

    def _gen_state(self):
        from repro.ckpt.keys import encode_prng_key
        return {"key": encode_prng_key(self._key),
                "state": np.asarray(self._state)}

    def _set_gen_state(self, state) -> None:
        from repro.ckpt.keys import decode_prng_key
        self._key = decode_prng_key(state["key"])
        self._state = jnp.asarray(state["state"])

    def model_for_round(self, r: int) -> ClusteredLinkModel:
        return self.params.model

    def scan_sampler(self):
        """``(init_fn, sample_fn)`` advancing the gates in-scan."""
        return clustered_ge_scan_sampler(self.params)
