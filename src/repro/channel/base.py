"""The ``ChannelProcess`` abstraction: one API for every link dynamic.

The paper (and ``core/connectivity.py``) treats links as Bernoulli draws
i.i.d. across rounds with oracle-known probabilities.  Real mmWave
blockages are bursty and time-correlated, and client mobility drifts the
marginals themselves.  Everything the trainer needs from a channel is

* ``tau_for_round(r)`` — the round-r connectivity realization
  ``(tau_up (n,), tau_dd (n, n))``, same conventions as
  :func:`repro.core.connectivity.sample_round`;
* ``model_for_round(r)`` — the *ground-truth* per-round marginals as a
  :class:`LinkModel` (the oracle view, used for evaluation / logging
  only; adaptive training must not peek at it).

Rounds are consumed in nondecreasing order (the FL trainer advances one
round at a time); stateful processes (Markov chains, mobility) may
refuse to rewind.

Concrete processes:

* :class:`StaticChannel` (here)           — the paper's i.i.d. model.
* :class:`~repro.channel.markov.MarkovChannel`     — Gilbert–Elliott
  bursty blockage, scan-sampled on device in blocks.
* :class:`~repro.channel.mobility.MobilityChannel` — waypoint mobility
  re-deriving the mmWave geometry every epoch.
"""

from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.connectivity import LinkModel, sample_round

__all__ = ["ChannelProcess", "StaticChannel"]


@runtime_checkable
class ChannelProcess(Protocol):
    """Anything that can serve per-round connectivity realizations."""

    @property
    def n(self) -> int: ...

    def tau_for_round(self, r: int) -> tuple[np.ndarray, np.ndarray]: ...

    def model_for_round(self, r: int) -> LinkModel: ...


class StaticChannel:
    """The paper's i.i.d. channel wrapped in the ``ChannelProcess`` API."""

    def __init__(self, model: LinkModel, seed: int = 0):
        self.model = model
        self._rng = np.random.default_rng(seed)
        self._next = 0

    @property
    def n(self) -> int:
        return self.model.n

    def tau_for_round(self, r: int) -> tuple[np.ndarray, np.ndarray]:
        if r != self._next:
            raise ValueError(
                f"StaticChannel serves rounds in order; expected {self._next}, got {r}"
            )
        self._next += 1
        return sample_round(self.model, self._rng)

    def model_for_round(self, r: int) -> LinkModel:
        return self.model
