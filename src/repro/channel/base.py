"""The ``ChannelProcess`` abstraction: one API for every link dynamic.

The paper (and ``core/connectivity.py``) treats links as Bernoulli draws
i.i.d. across rounds with oracle-known probabilities.  Real mmWave
blockages are bursty and time-correlated, and client mobility drifts the
marginals themselves.  Everything the trainer needs from a channel is

* ``tau_for_round(r)`` — the round-r connectivity realization
  ``(tau_up (n,), tau_dd (n, n))``, same conventions as
  :func:`repro.core.connectivity.sample_round`;
* ``trace(start, rounds)`` — the same stream served in bulk:
  ``(tau_up (K, n), tau_dd (K, n, n))`` for rounds ``[start, start+K)``.
  This is what the chunked scan engine (``FLTrainer.run(chunk=K)``,
  DESIGN.md §9) consumes — one call per chunk instead of one host
  round-trip per round, device-resident where the process samples on
  device.  ``trace`` and ``tau_for_round`` read the *same* underlying
  stream, so loop- and scan-driven training see bitwise-identical taus;
* ``model_for_round(r)`` — the *ground-truth* per-round marginals as a
  :class:`LinkModel` (the oracle view, used for evaluation / logging
  only; adaptive training must not peek at it).

Rounds are consumed in nondecreasing order (the FL trainer advances one
round at a time); stateful processes (Markov chains, mobility) may
refuse to rewind past their current buffer.

Processes that can sample connectivity as a pure-JAX recurrence
additionally expose ``scan_sampler() -> (init_fn, sample_fn)``; the scan
engine threads the returned state through the compiled multi-round
program so taus never materialize on host at all (the optional in-scan
sampler of :func:`repro.fl.round.make_scan_round_fn`).

Concrete processes:

* :class:`StaticChannel` (here)           — the paper's i.i.d. model,
  block-buffered through the vectorized multi-round sampler.
* :class:`~repro.channel.markov.MarkovChannel`     — Gilbert–Elliott
  bursty blockage, scan-sampled on device in blocks.
* :class:`~repro.channel.mobility.MobilityChannel` — waypoint mobility
  re-deriving the mmWave geometry every epoch.
"""

from __future__ import annotations

from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.connectivity import LinkModel, sample_rounds

__all__ = [
    "ChannelProcess",
    "BlockBufferedChannel",
    "StaticChannel",
    "pair_lane_table",
    "stacked_trace",
    "static_scan_sampler",
]


@runtime_checkable
class ChannelProcess(Protocol):
    """Anything that can serve per-round connectivity realizations."""

    @property
    def n(self) -> int: ...

    def tau_for_round(self, r: int) -> tuple[np.ndarray, np.ndarray]: ...

    def trace(self, start: int, rounds: int): ...

    def model_for_round(self, r: int) -> LinkModel: ...


def stacked_trace(channel, start: int, rounds: int):
    """Generic ``trace`` fallback: stack per-round service.

    For processes with per-round host state (e.g. mobility geometry
    advancing every round) there is nothing to vectorize; this keeps the
    trace contract — same stream as ``tau_for_round``, bulk layout —
    at the per-round cost.
    """
    ups, dds = zip(*(channel.tau_for_round(start + i) for i in range(rounds)))
    return np.stack(ups), np.stack(dds)


class BlockBufferedChannel:
    """Serve a per-round tau stream out of block-generated trace buffers.

    Subclasses implement ``_generate_block(rounds) -> (ups, dds)``
    (numpy or device arrays, shapes ``(R, n)`` / ``(R, n, n)``); this
    base serves both the per-round API and bulk ``trace`` slices from
    the same buffers, so the two consumption patterns — the host loop
    and the chunked scan engine — observe bitwise-identical streams
    regardless of chunk size.  Blocks are generated forward-only; the
    stream cannot rewind past the current buffer.
    """

    def __init__(self, n: int, block: int = 256):
        if block <= 0:
            raise ValueError("block must be positive")
        self._n = int(n)
        self.block = int(block)
        self._buf_start = 0  # first round of the current buffer
        self._ups = None
        self._dds = None
        self._ups_np = None  # lazy host view of the buffer (loop service)
        self._dds_np = None
        # generator state as of the current buffer's generation — what a
        # checkpoint stores so a restore regenerates this block bitwise
        self._pre_block = None

    @property
    def n(self) -> int:
        return self._n

    def _generate_block(self, rounds: int):
        raise NotImplementedError

    def _gen_state(self):
        """Subclass hook: the full generator/chain state whose capture
        (immediately before ``_generate_block``) makes that block's
        regeneration deterministic.  Must be a msgpack-codec-friendly
        pytree (use :func:`repro.ckpt.keys.encode_prng_key` for typed
        jax keys)."""
        raise NotImplementedError(
            f"{type(self).__name__} does not expose generator state")

    def _set_gen_state(self, state) -> None:
        """Subclass hook: inverse of :meth:`_gen_state`."""
        raise NotImplementedError(
            f"{type(self).__name__} does not expose generator state")

    def _advance_block(self) -> None:
        if self._ups is not None:
            self._buf_start += self._ups.shape[0]
        try:
            self._pre_block = self._gen_state()
        except NotImplementedError:
            self._pre_block = None  # subclass opted out of checkpointing
        self._ups, self._dds = self._generate_block(self.block)
        self._ups_np = self._dds_np = None

    def _ensure(self, r: int) -> None:
        if r < self._buf_start:
            raise ValueError(
                f"{type(self).__name__} cannot rewind to round {r} "
                f"(buffer starts at {self._buf_start})"
            )
        while self._ups is None or r >= self._buf_start + self._ups.shape[0]:
            self._advance_block()

    def tau_for_round(self, r: int) -> tuple[np.ndarray, np.ndarray]:
        self._ensure(r)
        if self._ups_np is None:
            # one host transfer per block, not per round
            self._ups_np = np.asarray(self._ups, np.float64)
            self._dds_np = np.asarray(self._dds, np.float64)
        i = r - self._buf_start
        return self._ups_np[i], self._dds_np[i]

    def checkpoint_state(self) -> dict:
        """The stream position + generator state (DESIGN.md §12).

        Rather than persisting the (large, device-resident) tau buffers,
        the checkpoint stores the generator state captured *before* the
        current block was generated plus the block's start round; a
        restore reinstates that state and clears the buffers, so the
        first post-restore service regenerates the identical block and
        the stream continues bitwise where it left off."""
        gen = self._gen_state() if self._ups is None else self._pre_block
        if self._ups is not None and gen is None:
            raise NotImplementedError(
                f"{type(self).__name__} does not expose generator state")
        return {"kind": type(self).__name__, "block": self.block,
                "buf_start": int(self._buf_start), "gen": gen}

    def restore_state(self, state: dict) -> None:
        """Inverse of :meth:`checkpoint_state` on a same-config channel."""
        if state.get("kind") != type(self).__name__:
            raise ValueError(
                f"checkpoint is for channel {state.get('kind')!r}; this "
                f"is a {type(self).__name__}")
        if int(state["block"]) != self.block:
            raise ValueError(
                f"checkpointed block size {state['block']} != {self.block} "
                "(the block size shapes the RNG stream)")
        self._set_gen_state(state["gen"])
        self._buf_start = int(state["buf_start"])
        self._ups = self._dds = self._ups_np = self._dds_np = None
        self._pre_block = None

    def trace(self, start: int, rounds: int):
        """Bulk service of rounds ``[start, start + rounds)``: ``(K, n)``
        uplinks and ``(K, n, n)`` D2D, concatenated across block refills.
        Device-resident when ``_generate_block`` samples on device."""
        parts_u, parts_d = [], []
        r = start
        while r < start + rounds:
            self._ensure(r)
            i = r - self._buf_start
            j = min(start + rounds - self._buf_start, self._ups.shape[0])
            parts_u.append(self._ups[i:j])
            parts_d.append(self._dds[i:j])
            r = self._buf_start + j
        if len(parts_u) == 1:
            return parts_u[0], parts_d[0]
        xp = jnp if isinstance(parts_u[0], jax.Array) else np
        return xp.concatenate(parts_u), xp.concatenate(parts_d)


def pair_lane_table(n: int) -> np.ndarray:
    """``(n*n,)`` gather lanes for assembling ``tau_dd`` from per-pair
    draws: entry ``(i, j)`` picks its unordered pair's tau_ij lane (upper
    triangle), tau_ji lane (lower triangle, offset by ``m``), or the
    constant-1 diagonal lane ``2m`` — the layout every sampler that emits
    the stacked ``[tij, tji, ones]`` form gathers through."""
    iu, ju = np.triu_indices(n, k=1)
    m = iu.shape[0]
    lane = np.full((n, n), 2 * m, np.int32)
    lane[iu, ju] = np.arange(m)
    lane[ju, iu] = m + np.arange(m)
    return lane.ravel()


def static_scan_sampler(model: LinkModel):
    """In-scan sampler for the paper's i.i.d. law: ``(init_fn, sample_fn)``.

    ``sample_fn(state, key)`` draws one round's ``(tau_up, tau_dd)``
    inside the compiled multi-round scan — the same one-uniform-per-pair
    reciprocity coupling as :func:`repro.core.connectivity.sample_round`,
    in pure jnp.  The process is i.i.d., so the carried state is ``()``.
    """
    n = model.n
    iu, ju = np.triu_indices(n, k=1)
    m = iu.shape[0]
    p = jnp.asarray(model.p, jnp.float32)
    pij = jnp.asarray(model.P[iu, ju], jnp.float32)
    pji = jnp.asarray(model.P[ju, iu], jnp.float32)
    e = jnp.asarray(model.E[iu, ju], jnp.float32)
    pair_lane = jnp.asarray(pair_lane_table(n))

    def init_fn(key):
        del key
        return ()

    def sample_fn(state, key):
        k1, k2 = jax.random.split(key)
        tau_up = (jax.random.uniform(k1, (n,)) < p).astype(jnp.float32)
        uu = jax.random.uniform(k2, (m,))
        both = uu < e
        tij = both | ((uu >= e) & (uu < pij))
        tji = both | ((uu >= pij) & (uu < pij + pji - e))
        cat = jnp.concatenate([tij, tji, jnp.ones((1,), bool)])
        tau_dd = jnp.take(cat, pair_lane).reshape(n, n).astype(jnp.float32)
        return tau_up, tau_dd, state

    return init_fn, sample_fn


class StaticChannel(BlockBufferedChannel):
    """The paper's i.i.d. channel wrapped in the ``ChannelProcess`` API.

    Rounds are pre-generated ``block`` at a time through the vectorized
    :func:`~repro.core.connectivity.sample_rounds` (batched RNG — no
    per-round host loop), and served per-round or as bulk traces from
    the same buffer.
    """

    def __init__(self, model: LinkModel, seed: int = 0, block: int = 256):
        super().__init__(model.n, block)
        self.model = model
        self._rng = np.random.default_rng(seed)

    def _generate_block(self, rounds: int):
        return sample_rounds(self.model, self._rng, rounds)

    def _gen_state(self):
        from repro.ckpt.schema import rng_state_to_json
        return rng_state_to_json(self._rng)

    def _set_gen_state(self, state) -> None:
        from repro.ckpt.schema import rng_from_json
        self._rng = rng_from_json(state)

    def model_for_round(self, r: int) -> LinkModel:
        return self.model

    def scan_sampler(self):
        """``(init_fn, sample_fn)`` drawing i.i.d. rounds inside the scan."""
        return static_scan_sampler(self.model)
