"""Adaptive consensus weights: estimate links online, re-run COPT-alpha.

Closes the loop the paper leaves open: ColRel's alpha matrix is computed
once from *oracle* link statistics, but under unknown/bursty/drifting
channels the PS must learn ``(p, P, E)`` from the realizations it sees
and periodically re-optimize.  :class:`AdaptiveWeightSchedule` owns a
:class:`~repro.channel.estimator.LinkEstimator` and, every ``every``
rounds (after ``warmup``), runs
:func:`repro.core.weights.optimize_weights` on the estimated model.

The re-optimized alpha is unbiased *under the estimated model* by
construction (COPT's constraint set); its residual bias under the true
model shrinks with the estimation error — logged per re-opt event.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

from repro.channel.estimator import LinkEstimator
from repro.core.weights import optimize_weights

__all__ = ["AdaptiveConfig", "AdaptiveWeightSchedule"]


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    every: int = 50  # re-optimization cadence K (rounds)
    warmup: int = 20  # min observed rounds before the first re-opt
    sweeps: int = 10  # COPT-alpha relax sweeps per re-opt
    fine_tune_sweeps: int = 10
    decay: float = 1.0  # estimator forgetting (1 = posterior, <1 = EWMA)
    prior: tuple = (0.5, 0.5)
    prune_below: float = 0.0

    def __post_init__(self):
        if self.every <= 0:
            raise ValueError("every must be positive")


class AdaptiveWeightSchedule:
    """Observe taus every round; hand back a fresh alpha every K rounds."""

    def __init__(self, n: int, cfg: AdaptiveConfig = AdaptiveConfig()):
        self.cfg = cfg
        self.estimator = LinkEstimator(
            n, prior=cfg.prior, decay=cfg.decay, prune_below=cfg.prune_below
        )
        self.events: List[Dict[str, Any]] = []

    def checkpoint_state(self) -> dict:
        """Posterior counts + the re-opt event log (DESIGN.md §12).

        Events are stored as one JSON string: their values may be numpy
        scalars, which the msgpack pytree codec refuses but ``.item()``
        maps to plain python for JSON."""
        import json

        return {
            "estimator": self.estimator.checkpoint_state(),
            "events": json.dumps(self.events,
                                 default=lambda o: o.item()),
        }

    def restore_state(self, state: dict) -> None:
        import json

        self.estimator.restore_state(state["estimator"])
        self.events = json.loads(state["events"])

    def step(
        self, r: int, tau_up: np.ndarray, tau_dd: np.ndarray
    ) -> Optional[np.ndarray]:
        """Ingest round r's realization; return a new A on re-opt rounds.

        Returns ``None`` on non-re-opt rounds.  Re-opts fire on the last
        round of each cadence window once ``warmup`` rounds were seen.
        """
        self.estimator.update(tau_up, tau_dd)
        seen = self.estimator.rounds
        if seen < self.cfg.warmup or (r + 1) % self.cfg.every != 0:
            return None
        model_hat = self.estimator.estimated_model()
        res = optimize_weights(
            model_hat,
            sweeps=self.cfg.sweeps,
            fine_tune_sweeps=self.cfg.fine_tune_sweeps,
        )
        self.events.append(
            {"round": r, "seen": seen, "S_est": res.S, "converged": res.converged}
        )
        return res.A
