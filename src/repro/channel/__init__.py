"""Dynamic channel simulation: temporally-correlated outage traces,
drifting geometry, online link estimation, adaptive consensus weights.

One protocol — :class:`ChannelProcess` (``tau_for_round(r)`` /
``model_for_round(r)``) — unifies the paper's i.i.d. model
(:class:`StaticChannel`), Gilbert–Elliott bursty blockage
(:class:`MarkovChannel`, scan-sampled on device), and waypoint mobility
(:class:`MobilityChannel`).  :class:`AdaptiveWeightSchedule` +
:class:`LinkEstimator` replace oracle link knowledge with online
estimates feeding periodic COPT-alpha re-optimization.
"""

from .base import ChannelProcess, StaticChannel
from .estimator import LinkEstimator
from .markov import (
    GEParams,
    MarkovChannel,
    channel_key,
    gilbert_elliott,
    sample_ge_rounds,
    sample_ge_rounds_host,
)
from .mobility import MobilityChannel
from .schedule import AdaptiveConfig, AdaptiveWeightSchedule

__all__ = [
    "ChannelProcess",
    "StaticChannel",
    "MarkovChannel",
    "MobilityChannel",
    "GEParams",
    "channel_key",
    "gilbert_elliott",
    "sample_ge_rounds",
    "sample_ge_rounds_host",
    "LinkEstimator",
    "AdaptiveConfig",
    "AdaptiveWeightSchedule",
]
