"""Dynamic channel simulation: temporally-correlated outage traces,
drifting geometry, online link estimation, adaptive consensus weights.

One protocol — :class:`ChannelProcess` (``tau_for_round(r)`` returns
the realized ``(tau_up (n,), tau_dd (n, n))`` indicators the round
consumes; ``model_for_round(r)`` the oracle marginals, for evaluation
only) — unifies the paper's i.i.d. model (:class:`StaticChannel`),
Gilbert–Elliott bursty blockage (:class:`MarkovChannel`, scan-sampled
on device with the static model's marginals preserved exactly), and
waypoint mobility (:class:`MobilityChannel`, geometry re-derived as
clients move).  :class:`AdaptiveWeightSchedule` +
:class:`LinkEstimator` replace oracle link knowledge with online
estimates feeding periodic COPT-alpha re-optimization.

Common entry points::

    from repro.configs import make_channel          # named presets
    ch = make_channel("markov", link_model, seed=0)
    tau_up, tau_dd = ch.tau_for_round(r)

    from repro.channel import AdaptiveConfig, AdaptiveWeightSchedule
    sched = AdaptiveWeightSchedule(n, AdaptiveConfig(every=50))
    trainer = FLTrainer(..., channel=ch, adaptive=sched)

Preset names and tuning guidance live in ``repro/configs/channels.py``
and ``docs/channel-presets.md``; the estimator/schedule design in
DESIGN.md §5.
"""

from .base import (
    BlockBufferedChannel,
    ChannelProcess,
    StaticChannel,
    stacked_trace,
    static_scan_sampler,
)
from .clustered import (
    ClusteredGEParams,
    ClusteredMarkovChannel,
    ClusteredStaticChannel,
    clustered_ge_scan_sampler,
    clustered_static_scan_sampler,
    gilbert_elliott_clustered,
)
from .estimator import LinkEstimator
from .markov import (
    GEParams,
    MarkovChannel,
    channel_key,
    ge_scan_sampler,
    gilbert_elliott,
    sample_ge_rounds,
    sample_ge_rounds_host,
)
from .mobility import MobilityChannel
from .schedule import AdaptiveConfig, AdaptiveWeightSchedule

__all__ = [
    "ChannelProcess",
    "BlockBufferedChannel",
    "StaticChannel",
    "MarkovChannel",
    "MobilityChannel",
    "ClusteredStaticChannel",
    "ClusteredMarkovChannel",
    "ClusteredGEParams",
    "gilbert_elliott_clustered",
    "clustered_static_scan_sampler",
    "clustered_ge_scan_sampler",
    "GEParams",
    "channel_key",
    "gilbert_elliott",
    "ge_scan_sampler",
    "sample_ge_rounds",
    "sample_ge_rounds_host",
    "stacked_trace",
    "static_scan_sampler",
    "LinkEstimator",
    "AdaptiveConfig",
    "AdaptiveWeightSchedule",
]
