"""Gilbert–Elliott bursty blockage chains, scan-sampled on device.

Each link carries a hidden two-state *gate* chain (Good/Bad — the mmWave
blocker): in Bad the link is down; in Good the link succeeds with the
conditional probability that restores the target per-round marginal.
The gate chain is parameterized by its stationary Good occupancy ``pi``
and its *memory* ``lam`` (the chain's second eigenvalue = the lag-1
autocorrelation of the gate):

    P(Bad -> Good)  = g = (1 - lam) * pi
    P(Good -> Bad)  = b = (1 - lam) * (1 - pi)

so the stationary law is ``Bernoulli(pi)`` for every ``lam`` and the
expected blockage burst lasts ``1/g`` rounds.  ``lam = 0`` recovers the
paper's i.i.d. channel *exactly*: gates are drawn fresh every round and
the per-round law of ``(tau_up, tau_dd)`` coincides with
:func:`repro.core.connectivity.sample_round` for the same
:class:`LinkModel` — burstiness is added without moving any marginal.

D2D pairs keep channel reciprocity: each unordered pair {i<j} shares one
gate chain (a blocker obstructs both directions), and conditional on
Good the ordered pair ``(tau_ij, tau_ji)`` is drawn from the same
one-uniform coupling as the static sampler, with the good-state joint
``E/pi`` preserving ``E[tau_ij tau_ji] = E_ij`` unconditionally.

Two samplers produce identical distributions:

* :func:`sample_ge_rounds_host` — the plain numpy per-round loop
  (reference; O(R) python iterations);
* :func:`sample_ge_rounds` — one fused :func:`jax.lax.scan` over rounds
  that emits the entire ``(R, n)`` / ``(R, n, n)`` tau tensor in a
  single device pass.  Perf anatomy (n=32, R=2000 on CPU): the scan body
  itself is trivial selects, so everything else is hoisted out of the
  loop — all randomness is one bulk ``jax.random.bits`` draw of 16-bit
  lanes (per-step key splitting would serialize threefry work and
  dominate), link tests compare those lanes against integer thresholds
  on a 15-bit lattice (``u >> 1 < round(p * 2^15)``, pure uint16, no
  float unpack; see ``_LATTICE`` — quantization <= 2^-16, far below any
  statistical resolution), and the ``(R, n, n)`` tensor is
  built by a vectorized pair-index *gather* after the scan (an XLA CPU
  scatter is ~10x slower).  Use :func:`channel_key` (``rbg`` impl) —
  threefry bit generation alone would be ~2.5x the whole budget.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.channel.base import BlockBufferedChannel, pair_lane_table
from repro.core.connectivity import LinkModel

__all__ = [
    "GEParams",
    "gilbert_elliott",
    "channel_key",
    "sample_ge_rounds",
    "sample_ge_rounds_host",
    "ge_scan_sampler",
    "MarkovChannel",
]

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class GEParams:
    """Gilbert–Elliott chain parameters for every link of a ``LinkModel``.

    ``pi_*`` are stationary Good-state occupancies, ``lam_*`` the gate
    memories; uplinks are indexed ``0..n-1``, D2D gates by the unordered
    pair index of ``np.triu_indices(n, 1)``.
    """

    model: LinkModel
    pi_up: np.ndarray  # (n,)
    lam_up: np.ndarray  # (n,)
    pi_dd: np.ndarray  # (m,) one gate per unordered pair {i<j}
    lam_dd: np.ndarray  # (m,)

    @property
    def n(self) -> int:
        return self.model.n

    def pair_indices(self) -> tuple[np.ndarray, np.ndarray]:
        return np.triu_indices(self.n, k=1)

    def expected_bad_burst(self) -> tuple[np.ndarray, np.ndarray]:
        """Mean blockage sojourn (rounds) for uplink and pair gates."""
        g_up = (1.0 - self.lam_up) * self.pi_up
        g_dd = (1.0 - self.lam_dd) * self.pi_dd
        return 1.0 / np.maximum(g_up, _EPS), 1.0 / np.maximum(g_dd, _EPS)

    def lag1_uplink(self) -> np.ndarray:
        """Lag-1 autocorrelation of tau_up[i]: q (1-pi) lam / (1-p)."""
        p, pi = self.model.p, self.pi_up
        q = np.where(pi > 0, p / np.maximum(pi, _EPS), 0.0)
        denom = np.maximum(1.0 - p, _EPS)
        return np.where(p < 1.0, q * (1.0 - pi) * self.lam_up / denom, 0.0)


def _conditionals(params: GEParams):
    """Good-state conditional laws (q_up, qij, qji, e_cond) + pair index."""
    model, n = params.model, params.n
    iu, ju = params.pair_indices()
    q_up = np.where(params.pi_up > 0, model.p / np.maximum(params.pi_up, _EPS), 0.0)
    pi = np.maximum(params.pi_dd, _EPS)
    qij = model.P[iu, ju] / pi
    qji = model.P[ju, iu] / pi
    e_c = model.E[iu, ju] / pi
    return q_up, qij, qji, e_c, iu, ju


def gilbert_elliott(
    model: LinkModel,
    memory: Union[float, tuple[float, float]] = 0.9,
    occupancy: Optional[float] = None,
) -> GEParams:
    """Fit GE chains whose per-round law matches ``model`` exactly.

    Parameters
    ----------
    memory:
        Gate lag-1 autocorrelation ``lam`` in ``[0, 1)``; a scalar, or a
        ``(lam_uplink, lam_d2d)`` pair.  ``0`` = the i.i.d. paper model;
        ``0.9`` means blockage bursts ~10x longer than i.i.d. draws.
    occupancy:
        Target Good-state occupancy ``pi``.  ``None`` fits the *tightest*
        feasible gate (``pi_up = p_i``; for pairs the Fréchet-driven
        floor) so that burstiness is maximal; a float is clipped up to
        feasibility per link.  Links with zero marginal get an inert
        always-Good gate.

    Feasibility: marginals require ``pi >= p`` (uplink) and
    ``pi >= max(p_ij, p_ji, p_ij + p_ji - E_ij)`` (pair — the lower
    Fréchet bound of the Good-state coupling).
    """
    if isinstance(memory, tuple):
        lam_up_s, lam_dd_s = memory
    else:
        lam_up_s = lam_dd_s = float(memory)
    for lam in (lam_up_s, lam_dd_s):
        if not 0.0 <= lam < 1.0:
            raise ValueError(f"memory must be in [0, 1), got {lam}")

    n = model.n
    iu, ju = np.triu_indices(n, k=1)
    pij, pji, eij = model.P[iu, ju], model.P[ju, iu], model.E[iu, ju]

    floor_up = model.p
    floor_dd = np.maximum(np.maximum(pij, pji), pij + pji - eij)
    if occupancy is None:
        pi_up, pi_dd = floor_up.copy(), floor_dd.copy()
    else:
        if not 0.0 < occupancy <= 1.0:
            raise ValueError(f"occupancy must be in (0, 1], got {occupancy}")
        pi_up = np.maximum(floor_up, occupancy)
        pi_dd = np.maximum(floor_dd, occupancy)
    # inert links: permanently-Good gate, zero conditional success.
    pi_up = np.where(floor_up <= 0.0, 1.0, pi_up)
    pi_dd = np.where(floor_dd <= 0.0, 1.0, pi_dd)

    lam_up = np.full(n, lam_up_s)
    lam_dd = np.full(iu.shape[0], lam_dd_s)
    # gates pinned at pi == 1 have no dynamics to remember
    lam_up = np.where(pi_up >= 1.0, 0.0, lam_up)
    lam_dd = np.where(pi_dd >= 1.0, 0.0, lam_dd)
    return GEParams(model, pi_up, lam_up, pi_dd, lam_dd)


# ---------------------------------------------------------------------------
# Host-loop reference sampler (numpy, one python iteration per round)
# ---------------------------------------------------------------------------


def sample_ge_rounds_host(
    params: GEParams, rng: np.random.Generator, rounds: int
) -> tuple[np.ndarray, np.ndarray]:
    """Reference per-round loop: (R, n) uplinks and (R, n, n) D2D.

    Deliberately written in the same per-round idiom as the static
    :func:`~repro.core.connectivity.sample_round` loop this subsystem
    replaces — one python iteration per round drawing an (n, n) uniform
    matrix with fresh pair-index extraction, the readable specification
    of the law (and the baseline ``benchmarks/channel_bench.py`` times
    the fused scan against).
    """
    n = params.n
    q_up, qij, qji, e_c, _, _ = _conditionals(params)
    g_up = (1.0 - params.lam_up) * params.pi_up
    b_up = (1.0 - params.lam_up) * (1.0 - params.pi_up)
    g_dd = (1.0 - params.lam_dd) * params.pi_dd
    b_dd = (1.0 - params.lam_dd) * (1.0 - params.pi_dd)

    iu0, ju0 = params.pair_indices()
    su = rng.random(n) < params.pi_up
    sp = rng.random(iu0.shape[0]) < params.pi_dd
    ups = np.empty((rounds, n))
    dds = np.empty((rounds, n, n))
    for r in range(rounds):
        iu, ju = np.triu_indices(n, k=1)  # as sample_round does, per call
        # gate transitions: one uniform per link
        u1 = rng.random(n)
        su = np.where(su, u1 >= b_up, u1 < g_up)
        u2 = np.triu(rng.random((n, n)), k=1)[iu, ju]
        sp = np.where(sp, u2 >= b_dd, u2 < g_dd)
        # conditional emissions given Good gates
        ups[r] = su & (rng.random(n) < q_up)
        uu = np.triu(rng.random((n, n)), k=1)[iu, ju]
        tij = sp & (uu < qij)
        tji = sp & ((uu < e_c) | ((uu >= qij) & (uu < qij + qji - e_c)))
        dd = np.eye(n)
        dd[iu, ju] = tij
        dd[ju, iu] = tji
        dds[r] = dd
    return ups, dds


# ---------------------------------------------------------------------------
# Fused device sampler: one lax.scan over rounds
# ---------------------------------------------------------------------------


# Uniform draws live on a 15-bit lattice: tests are `u < round(p * 2^15)`
# with u uniform on {0..2^15-1}.  15 (not 16) bits so the thresholds —
# which must reach 2^15 for an exact always-true p = 1 — still fit in
# uint16 and every comparison stays in the uint16 domain (a uint32
# promotion would materialize extra (R, ·) buffers on the hot path).
# p = 0 maps to threshold 0 (exact never-true); the law is quantized by
# at most 2^-16, far below any statistical resolution.
_LATTICE = 32768


def _ge_core(arrs, state, key, *, rounds: int, n: int):
    """Scan the gate chains for ``rounds`` steps and emit all taus.

    ``arrs``: dict of (device) per-link integer-threshold arrays;
    ``state``: ``(gate_up (n,) bool, gate_pair (m,) bool)``.  The scan
    body is pure integer compares + selects on (n,) / (m,) lanes; RNG
    and the (n, n) assembly happen outside the loop (see module doc).
    """
    m = arrs["t_qij"].shape[0]
    lanes = 2 * n + 2 * m  # per round: gate_up, gate_pair, cond_up, cond_pair
    u16 = jax.random.bits(key, (rounds, lanes), jnp.uint16)
    u15 = u16 >> jnp.uint16(1)  # one pass; see _LATTICE
    u_gate = u15[:, : n + m]
    u_up = u15[:, n + m : 2 * n + m]
    u_dd = u15[:, 2 * n + m :]
    t_g = jnp.concatenate([arrs["t_g_up"], arrs["t_g_dd"]])
    t_b = jnp.concatenate([arrs["t_b_up"], arrs["t_b_dd"]])

    # Only the gate recurrence is sequential — scan it with the smallest
    # possible per-step payload (one packed (n+m,) bool state).  The
    # conditional emissions are independent given the gates, so they run
    # below as a few big fused elementwise ops over the whole (R, ·)
    # trace instead of thousands of tiny ones inside the loop.
    def step(s, u):
        s = jnp.where(s, u >= t_b, u < t_g)
        return s, s

    end, gates = jax.lax.scan(step, jnp.concatenate(state), u_gate)
    state = (end[:n], end[n:])
    su, sp = gates[:, :n], gates[:, n:]
    ups = su & (u_up < arrs["t_q_up"])
    tij = sp & (u_dd < arrs["t_qij"])
    tji = sp & (
        (u_dd < arrs["t_e"])
        | ((u_dd >= arrs["t_qij"]) & (u_dd < arrs["t_mid"]))
    )
    # (R, n, n) assembly: one vectorized gather.  Entry (i, j) picks its
    # unordered pair's tau_ij lane (upper triangle), tau_ji lane (lower,
    # offset by m) or the constant-1 diagonal lane — an XLA CPU scatter
    # here is ~10x slower than this gather.
    cat = jnp.concatenate([tij, tji, jnp.ones((rounds, 1), bool)], axis=1)
    dds = (
        jnp.take(cat, jnp.asarray(arrs["pair_lane"]), axis=1)
        .reshape(rounds, n, n)
        .astype(jnp.float32)
    )
    return ups.astype(jnp.float32), dds, state


# steady-state entry (MarkovChannel blocks after the first): the caller
# carries the chain state across calls
_ge_scan = partial(jax.jit, static_argnames=("rounds", "n"))(_ge_core)


@partial(jax.jit, static_argnames=("rounds", "n"))
def _ge_scan_stationary(arrs, key, *, rounds: int, n: int):
    """One-shot entry: draw the initial gates from the stationary law and
    run the trace, all inside a single compiled program (eager init-state
    dispatches would cost a noticeable fraction of the whole pass)."""
    k1, k2, k_scan = jax.random.split(key, 3)
    su = jax.random.uniform(k1, arrs["pi_up"].shape) < arrs["pi_up"]
    sp = jax.random.uniform(k2, arrs["pi_dd"].shape) < arrs["pi_dd"]
    return _ge_core(arrs, (su, sp), k_scan, rounds=rounds, n=n)


def _device_arrays(params: GEParams) -> dict:
    """Integer-threshold device operands for ``_ge_scan`` (cached on the
    params instance: rebuilding them per call would cost host->device
    transfers comparable to the sampling pass itself)."""
    cached = getattr(params, "_device_arrays_cache", None)
    if cached is not None:
        return cached
    q_up, qij, qji, e_c, iu, ju = _conditionals(params)
    n = params.n
    lattice = lambda p: np.rint(np.clip(p, 0.0, 1.0) * _LATTICE).astype(np.int64)
    thresh = lambda p: jnp.asarray(lattice(p), jnp.uint16)
    # upper bound of the only-ji interval [t_qij, t_qij + t_qji - t_e);
    # summed on host (int64) — it can exceed the 15-bit lattice by the
    # rounding slack, which uint16 still holds exactly
    t_mid = lattice(qij) + lattice(qji) - lattice(e_c)
    arrs = dict(
        t_g_up=thresh((1.0 - params.lam_up) * params.pi_up),
        t_b_up=thresh((1.0 - params.lam_up) * (1.0 - params.pi_up)),
        t_g_dd=thresh((1.0 - params.lam_dd) * params.pi_dd),
        t_b_dd=thresh((1.0 - params.lam_dd) * (1.0 - params.pi_dd)),
        t_q_up=thresh(q_up),
        t_qij=thresh(qij),
        t_qji=thresh(qji),
        t_e=thresh(e_c),
        t_mid=jnp.asarray(t_mid, jnp.uint16),
        pair_lane=jnp.asarray(pair_lane_table(n)),
        pi_up=jnp.asarray(params.pi_up, jnp.float32),
        pi_dd=jnp.asarray(params.pi_dd, jnp.float32),
    )
    object.__setattr__(params, "_device_arrays_cache", arrs)
    return arrs


def channel_key(seed: int) -> jax.Array:
    """PRNG key for the channel samplers.

    Uses the ``rbg`` implementation: for this pure-simulation workload
    its statistical quality is ample, and threefry bit generation alone
    would cost more than the entire fused sampling pass on CPU.
    """
    return jax.random.key(seed, impl="rbg")


def _stationary_state(params: GEParams, key) -> tuple[jax.Array, jax.Array]:
    k1, k2 = jax.random.split(key)
    su = jax.random.uniform(k1, (params.n,)) < jnp.asarray(params.pi_up, jnp.float32)
    m = params.pi_dd.shape[0]
    sp = jax.random.uniform(k2, (m,)) < jnp.asarray(params.pi_dd, jnp.float32)
    return su, sp


def sample_ge_rounds(
    params: GEParams, key: jax.Array, rounds: int
) -> tuple[jax.Array, jax.Array]:
    """Fused multi-round GE sampling: (R, n) uplinks and (R, n, n) D2D.

    Same distribution as :func:`sample_ge_rounds_host`; the whole trace
    is generated in one compiled scan (chains start stationary).  Any
    PRNG key works; :func:`channel_key` is the fast choice.
    """
    ups, dds, _ = _ge_scan_stationary(
        _device_arrays(params), key, rounds=rounds, n=params.n
    )
    return ups, dds


# ---------------------------------------------------------------------------
# In-scan sampler: one round per step, for taus drawn inside the train scan
# ---------------------------------------------------------------------------


def ge_scan_sampler(params: GEParams):
    """Per-round GE sampler for in-scan use: ``(init_fn, sample_fn)``.

    ``init_fn(key)`` draws the packed ``(n + m,)`` bool gate state from
    the stationary law; ``sample_fn(state, key) -> (tau_up, tau_dd,
    state)`` advances every gate chain one round and emits that round's
    realization — the single-step form of :func:`_ge_core`, same integer
    thresholds, same pair-lane gather, for the scan engine's optional
    in-scan channel (:func:`repro.fl.round.make_scan_round_fn`).  Unlike
    the bulk sampler it splits one key per round (that is what a
    per-step recurrence costs), but the draws never leave the device.
    """
    arrs = _device_arrays(params)
    n = params.n
    m = int(arrs["t_qij"].shape[0])
    t_g = jnp.concatenate([arrs["t_g_up"], arrs["t_g_dd"]])
    t_b = jnp.concatenate([arrs["t_b_up"], arrs["t_b_dd"]])
    pair_lane = jnp.asarray(arrs["pair_lane"])

    def init_fn(key):
        su, sp = _stationary_state(params, key)
        return jnp.concatenate([su, sp])

    def sample_fn(state, key):
        u15 = jax.random.bits(key, (2 * n + 2 * m,), jnp.uint16) >> jnp.uint16(1)
        u_gate = u15[: n + m]
        u_up = u15[n + m : 2 * n + m]
        u_dd = u15[2 * n + m :]
        state = jnp.where(state, u_gate >= t_b, u_gate < t_g)
        su, sp = state[:n], state[n:]
        ups = su & (u_up < arrs["t_q_up"])
        tij = sp & (u_dd < arrs["t_qij"])
        tji = sp & (
            (u_dd < arrs["t_e"])
            | ((u_dd >= arrs["t_qij"]) & (u_dd < arrs["t_mid"]))
        )
        cat = jnp.concatenate([tij, tji, jnp.ones((1,), bool)])
        tau_dd = jnp.take(cat, pair_lane).reshape(n, n).astype(jnp.float32)
        return ups.astype(jnp.float32), tau_dd, state

    return init_fn, sample_fn


# ---------------------------------------------------------------------------
# ChannelProcess wrapper: block-wise scan generation, per-round service
# ---------------------------------------------------------------------------


class MarkovChannel(BlockBufferedChannel):
    """Serve a GE trace, scan-generating ``block`` rounds at a time on
    device and carrying the chain state across blocks.

    Buffers stay device-resident: ``trace(start, K)`` hands the chunked
    scan engine jax-array slices with no host materialization; only the
    per-round ``tau_for_round`` service transfers (once per block)."""

    def __init__(self, params: GEParams, seed: int = 0, block: int = 256):
        super().__init__(params.n, block)
        self.params = params
        self._key, k_init = jax.random.split(channel_key(seed))
        self._arrs = _device_arrays(params)
        self._state = _stationary_state(params, k_init)

    def _generate_block(self, rounds: int):
        self._key, k = jax.random.split(self._key)
        ups, dds, self._state = _ge_scan(
            self._arrs, self._state, k, rounds=rounds, n=self.n
        )
        return ups, dds

    def _gen_state(self):
        from repro.ckpt.keys import encode_prng_key
        su, sp = self._state
        return {"key": encode_prng_key(self._key),
                "su": np.asarray(su), "sp": np.asarray(sp)}

    def _set_gen_state(self, state) -> None:
        from repro.ckpt.keys import decode_prng_key
        self._key = decode_prng_key(state["key"])
        self._state = (jnp.asarray(state["su"]), jnp.asarray(state["sp"]))

    def model_for_round(self, r: int) -> LinkModel:
        return self.params.model

    def scan_sampler(self):
        """``(init_fn, sample_fn)`` advancing the GE chains in-scan."""
        return ge_scan_sampler(self.params)
