"""Online estimation of the link statistics ``(p, P, E)`` from realized taus.

The paper's COPT-alpha assumes the PS *knows* the link probabilities.
Under unknown or drifting channels the PS only observes connectivity
realizations — uplink successes directly, D2D receptions from the
clients' reports piggybacked on their uploads (standard in the implicit-
gossip / estimation literature; we assume full observability of the tau
tensors each round and document that as the simulation contract).

:class:`LinkEstimator` keeps exponentially-forgetting Beta-posterior
counts per link:

    s <- gamma * s + tau,   t <- gamma * t + 1,
    hat = (s + a) / (t + a + b)                       (posterior mean)

``gamma = 1`` is the full Beta(a, b) posterior (right for stationary
chains — the GE per-round marginal *is* stationary); ``gamma < 1`` is an
EWMA with effective window ``1/(1-gamma)`` (right for mobility drift).
Reciprocity ``E`` is estimated from the per-pair joint successes
``tau_ij * tau_ji`` with the same machinery.

:meth:`LinkEstimator.estimated_model` projects the raw estimates onto
the :class:`LinkModel` feasible set (unit diagonals, symmetric ``E``
inside the Fréchet bounds and above independence) so the result can be
fed straight into :func:`repro.core.weights.optimize_weights`.
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.connectivity import LinkModel

__all__ = ["LinkEstimator"]


class LinkEstimator:
    """Streaming ``(p, P, E)`` estimates from observed tau realizations."""

    def __init__(
        self,
        n: int,
        *,
        prior: tuple[float, float] = (0.5, 0.5),
        decay: float = 1.0,
        prune_below: float = 0.0,
    ):
        if not 0.0 < decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        if prior[0] <= 0 or prior[1] <= 0:
            raise ValueError("prior pseudo-counts must be positive")
        self.n = int(n)
        self.prior = (float(prior[0]), float(prior[1]))
        self.decay = float(decay)
        self.prune_below = float(prune_below)
        self.rounds = 0
        self._t = 0.0  # discounted round count (shared: every link observed)
        self._s_up = np.zeros(n)
        self._s_dd = np.zeros((n, n))
        self._s_joint = np.zeros((n, n))  # successes of tau_ij * tau_ji

    def update(self, tau_up: np.ndarray, tau_dd: np.ndarray) -> None:
        tau_up = np.asarray(tau_up, dtype=np.float64)
        tau_dd = np.asarray(tau_dd, dtype=np.float64)
        g = self.decay
        self._t = g * self._t + 1.0
        self._s_up = g * self._s_up + tau_up
        self._s_dd = g * self._s_dd + tau_dd
        self._s_joint = g * self._s_joint + tau_dd * tau_dd.T
        self.rounds += 1

    # -- checkpoint/resume (DESIGN.md §12) ----------------------------
    def checkpoint_state(self) -> dict:
        """The full posterior: discounted counts + round tally."""
        return {
            "rounds": int(self.rounds),
            "t": float(self._t),
            "s_up": np.array(self._s_up),
            "s_dd": np.array(self._s_dd),
            "s_joint": np.array(self._s_joint),
        }

    def restore_state(self, state: dict) -> None:
        self.rounds = int(state["rounds"])
        self._t = float(state["t"])
        self._s_up = np.asarray(state["s_up"], np.float64)
        self._s_dd = np.asarray(state["s_dd"], np.float64)
        self._s_joint = np.asarray(state["s_joint"], np.float64)

    # -- raw posterior means ------------------------------------------
    def _mean(self, s: np.ndarray) -> np.ndarray:
        a, b = self.prior
        return (s + a) / (self._t + a + b)

    @property
    def p_hat(self) -> np.ndarray:
        return self._mean(self._s_up)

    @property
    def P_hat(self) -> np.ndarray:
        P = self._mean(self._s_dd)
        np.fill_diagonal(P, 1.0)
        return P

    @property
    def E_hat(self) -> np.ndarray:
        E = self._mean(self._s_joint)
        E = 0.5 * (E + E.T)  # symmetrize (counts drift apart only via fp)
        np.fill_diagonal(E, 1.0)
        return E

    # -- projection to a feasible LinkModel ---------------------------
    def estimated_model(self) -> LinkModel:
        """Project ``(p_hat, P_hat, E_hat)`` onto the LinkModel feasible set.

        With ``prune_below > 0``, off-diagonal ``P`` entries under the
        threshold are zeroed — phantom links kept alive only by the prior
        would otherwise receive (high-variance) relay weight.
        """
        p = np.clip(self.p_hat, 0.0, 1.0)
        P = np.clip(self.P_hat, 0.0, 1.0)
        if self.prune_below > 0.0:
            off = ~np.eye(self.n, dtype=bool)
            P[off & (P < self.prune_below)] = 0.0
        np.fill_diagonal(P, 1.0)
        lo = np.maximum(P * P.T, np.maximum(0.0, P + P.T - 1.0))
        hi = np.minimum(P, P.T)
        E = np.clip(self.E_hat, lo, hi)
        E = 0.5 * (E + E.T)
        np.fill_diagonal(E, 1.0)
        return LinkModel(p, P, E)

    def errors(self, true_model: LinkModel) -> Dict[str, float]:
        """Max-abs estimation errors against an oracle model (logging)."""
        off = ~np.eye(self.n, dtype=bool)
        return {
            "p": float(np.max(np.abs(self.p_hat - true_model.p))),
            "P": float(np.max(np.abs((self.P_hat - true_model.P)[off]))),
            "E": float(np.max(np.abs((self.E_hat - true_model.E)[off]))),
        }
