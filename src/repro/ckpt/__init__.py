"""Fault-tolerant checkpoint/resume subsystem (DESIGN.md §12).

Four pieces:

* :mod:`repro.ckpt.schema` — the versioned run-state pytree:
  :func:`capture_run_state` / :func:`restore_run_state` cover params,
  optimizer state, strategy ``agg_state``, channel chain state + PRNG
  keys, client data-RNG streams, estimator posteriors, telemetry
  cursors and the round counter.
* :mod:`repro.ckpt.writer` — sharding-aware serialization with
  sha256-checksummed atomic commits and keep-last-k retention;
  :class:`AsyncCheckpointer` overlaps the write with the next chunk's
  device compute.
* :mod:`repro.ckpt.keys` — typed jax PRNG-key (de)serialization.
* :mod:`repro.ckpt.preemption` — :class:`PreemptionGuard`, latching
  SIGTERM/SIGINT so the launcher drains and commits before exit.

Entry points: ``FLTrainer.run(ckpt_dir=..., ckpt_every=...,
resume_from=...)`` and ``launch/train.py --ckpt-dir --ckpt-every
--resume``.
"""

from repro.ckpt.keys import decode_prng_key, encode_prng_key, is_encoded_key
from repro.ckpt.preemption import PreemptionGuard
from repro.ckpt.schema import (
    CKPT_VERSION,
    capture_run_state,
    restore_run_state,
    rng_from_json,
    rng_state_to_json,
)
from repro.ckpt.writer import (
    AsyncCheckpointer,
    CheckpointWriter,
    read_state,
    snapshot,
    write_state,
)

__all__ = [
    "CKPT_VERSION",
    "AsyncCheckpointer",
    "CheckpointWriter",
    "PreemptionGuard",
    "capture_run_state",
    "decode_prng_key",
    "encode_prng_key",
    "is_encoded_key",
    "read_state",
    "restore_run_state",
    "rng_from_json",
    "rng_state_to_json",
    "snapshot",
    "write_state",
]
