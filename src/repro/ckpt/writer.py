"""Async, sharding-aware checkpoint writer (DESIGN.md §12).

The write path is split in two so device compute and checkpoint I/O
overlap, in the spirit of maxtext's standalone checkpointer:

1. **snapshot** (caller thread, at a chunk boundary): walk the state
   pytree and replace every ``jax.Array`` with a :class:`_ArraySnap`
   holding *references* to its addressable shards.  jax arrays are
   immutable, so holding the references is free and safe — no device
   sync, no host copy.  Mutable host containers (numpy arrays, lists,
   dicts) are copied here, because the trainer keeps mutating them
   while the writer thread serializes.
2. **write** (background thread, overlapped with the next chunk's
   device execution): per shard, ``np.asarray(shard.data)`` pulls that
   shard's bytes to host — driven by each array's ``Sharding``, so a
   client-axis-sharded ``(n, d)`` stack is written shard-by-shard and
   never gathered — then the tree is serialized with the msgpack codec
   (``repro.checkpoint.io``), sha256-checksummed, and committed
   atomically.

Commit protocol: the payload is written to a temp file and renamed to
``ckpt_<step>.msgpack``; only then is the ``.sha256`` sidecar renamed
into place.  A checkpoint *exists* iff its sidecar exists, so a crash
mid-write leaves at most an ignored orphan payload, never a torn
checkpoint.  ``load`` re-hashes the payload against the sidecar and
refuses a mismatch.  After each commit, retention deletes committed
checkpoints beyond ``keep`` (sidecar first — deleting it atomically
un-commits the payload).
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import pathlib
import queue
import re
import tempfile
import threading
from typing import Any, List, Optional, Tuple

import jax
import msgpack
import numpy as np

from repro.checkpoint import io as ckpt_io

__all__ = [
    "snapshot",
    "write_state",
    "read_state",
    "CheckpointWriter",
    "AsyncCheckpointer",
]

_SHARDED = "__sharded__"
_STEP_RE = re.compile(r"^ckpt_(\d{8})\.msgpack$")


@dataclasses.dataclass
class _ArraySnap:
    """A jax array captured as per-shard device references (no copy)."""

    dtype: str
    shape: Tuple[int, ...]
    shards: List[Tuple[Tuple[int, ...], Tuple[int, ...], Any]]  # (start, stop, buf)


def _shard_bounds(index, shape) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    start, stop = [], []
    for sl, dim in zip(index, shape):
        a, b, step = sl.indices(dim)
        if step != 1:
            raise ValueError(f"non-contiguous shard slice {sl}")
        start.append(a)
        stop.append(b)
    return tuple(start), tuple(stop)


def snapshot(tree: Any, *, copy_arrays: bool = False) -> Any:
    """Copy-free capture of a state pytree: jax arrays become
    :class:`_ArraySnap` shard references, host state is deep-copied.

    ``copy_arrays=True`` takes a *device-side* copy of every jax array
    first (an async dispatch — no host sync) and references the copy's
    shards instead.  Required when the caller donates its carry buffers
    back into the next compiled step (DESIGN.md §14): donation deletes
    the original buffers while the writer thread may still be pulling
    them to host, so the snapshot must own its own storage.  The copies
    overlap the next step's compute exactly like the shard transfers do.
    """
    if type(tree) in ckpt_io._SCALARS:
        return tree
    if isinstance(tree, dict):
        return {k: snapshot(v, copy_arrays=copy_arrays)
                for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        t = [snapshot(v, copy_arrays=copy_arrays) for v in tree]
        return t if isinstance(tree, list) else tuple(t)
    if isinstance(tree, jax.Array):
        if copy_arrays:
            tree = jax.numpy.copy(tree)
        shards = [(*_shard_bounds(s.index, tree.shape), s.data)
                  for s in tree.addressable_shards]
        return _ArraySnap(str(tree.dtype), tuple(tree.shape), shards)
    if isinstance(tree, np.ndarray):
        return np.array(tree)  # the trainer may mutate host arrays later
    return tree


def _materialize(tree: Any) -> Any:
    """Writer-thread half of the snapshot: device->host per shard."""
    if type(tree) in ckpt_io._SCALARS:
        return tree
    if isinstance(tree, dict):
        return {k: _materialize(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        t = [_materialize(v) for v in tree]
        return t if isinstance(tree, list) else tuple(t)
    if isinstance(tree, _ArraySnap):
        return {_SHARDED: 1, "dtype": tree.dtype, "shape": list(tree.shape),
                "shards": [{"start": list(a), "stop": list(b),
                            "data": np.asarray(buf)}
                           for a, b, buf in tree.shards]}
    return tree


def _reassemble(tree: Any) -> Any:
    """Rebuild full arrays from decoded per-shard payloads."""
    if isinstance(tree, dict):
        if _SHARDED in tree:
            shards = tree["shards"]
            shape = tuple(tree["shape"])
            out = np.empty(shape, dtype=shards[0]["data"].dtype)
            for sh in shards:
                idx = tuple(slice(a, b) for a, b in zip(sh["start"], sh["stop"]))
                out[idx] = sh["data"]
            return out
        return {k: _reassemble(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        t = [_reassemble(v) for v in tree]
        return t if isinstance(tree, list) else tuple(t)
    return tree


def _sha_path(path: pathlib.Path) -> pathlib.Path:
    return path.with_suffix(path.suffix + ".sha256")


def _atomic_write(path: pathlib.Path, data: bytes) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    with tempfile.NamedTemporaryFile(dir=path.parent, delete=False) as f:
        f.write(data)
        tmp = f.name
    os.replace(tmp, path)


def write_state(path, tree: Any, *, snapshotted: bool = False) -> pathlib.Path:
    """Serialize one state pytree to ``path`` with the commit protocol
    (payload rename, then checksum sidecar rename)."""
    path = pathlib.Path(path)
    snap = tree if snapshotted else snapshot(tree)
    payload = msgpack.packb(ckpt_io._encode(_materialize(snap)),
                            use_bin_type=True)
    digest = hashlib.sha256(payload).hexdigest()
    _atomic_write(path, payload)
    _atomic_write(_sha_path(path), f"{digest}  {path.name}\n".encode())
    return path


def read_state(path) -> Any:
    """Load + verify one committed checkpoint file."""
    path = pathlib.Path(path)
    payload = path.read_bytes()
    sha = _sha_path(path)
    if sha.exists():
        want = sha.read_text().split()[0]
        got = hashlib.sha256(payload).hexdigest()
        if got != want:
            raise ValueError(
                f"checkpoint {path} is corrupt: sha256 {got[:12]}... != "
                f"recorded {want[:12]}...")
    return _reassemble(ckpt_io._decode(msgpack.unpackb(payload, raw=False)))


class CheckpointWriter:
    """Synchronous step-indexed checkpoint directory with retention.

    Files are ``ckpt_<step:08d>.msgpack`` (+ ``.sha256`` sidecar); a
    step is *committed* iff its sidecar exists.  ``save`` commits a new
    step, then garbage-collects committed steps beyond ``keep`` (newest
    kept; ``keep <= 0`` keeps everything).
    """

    def __init__(self, directory, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.keep = int(keep)

    def path_for(self, step: int) -> pathlib.Path:
        return self.dir / f"ckpt_{int(step):08d}.msgpack"

    def steps(self) -> List[int]:
        if not self.dir.is_dir():
            return []
        out = []
        for p in self.dir.iterdir():
            m = _STEP_RE.match(p.name)
            if m and _sha_path(p).exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def save(self, step: int, tree: Any, *, snapshotted: bool = False) -> pathlib.Path:
        path = write_state(self.path_for(step), tree, snapshotted=snapshotted)
        self._gc()
        return path

    def load(self, step: Optional[int] = None) -> Any:
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        return read_state(self.path_for(step))

    def _gc(self) -> None:
        if self.keep <= 0:
            return
        for step in self.steps()[:-self.keep]:
            p = self.path_for(step)
            _sha_path(p).unlink(missing_ok=True)  # un-commit first
            p.unlink(missing_ok=True)


_STOP = object()


class AsyncCheckpointer:
    """Background-thread checkpointing over a :class:`CheckpointWriter`.

    ``save(step, tree)`` snapshots on the caller thread (cheap: shard
    references + host copies) and returns immediately; serialization,
    per-shard host transfer, hashing, the atomic commit and retention
    all run on one daemon worker thread, overlapped with whatever the
    caller does next (the next chunk's device compute).  ``wait()``
    drains the queue and re-raises any writer-side failure; ``close()``
    drains and stops the worker.  Saves commit in submission order.
    """

    def __init__(self, directory, keep: int = 3, *, copy_arrays: bool = False):
        self.writer = CheckpointWriter(directory, keep=keep)
        #: snapshot with device-side copies — required when the caller
        #: donates its carry buffers into the next step (see snapshot())
        self.copy_arrays = bool(copy_arrays)
        self._q: "queue.Queue" = queue.Queue()
        self._error: Optional[BaseException] = None
        self._worker = threading.Thread(
            target=self._run, name="ckpt-writer", daemon=True)
        self._worker.start()

    # -- worker ----------------------------------------------------------
    def _run(self) -> None:
        try:
            # Linux nice() is per-thread: deprioritize the writer so it
            # fills scheduler gaps instead of preempting XLA's compute
            # pool (whose fork-join regions stall on the slowest worker)
            os.nice(10)
        except OSError:
            pass
        while True:
            item = self._q.get()
            try:
                if item is _STOP:
                    return
                step, snap = item
                self.writer.save(step, snap, snapshotted=True)
            except BaseException as e:  # surfaced at the next save/wait
                self._error = e
            finally:
                self._q.task_done()

    def _raise_pending(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint write failed") from err

    # -- API -------------------------------------------------------------
    def save(self, step: int, tree: Any) -> None:
        """Snapshot now, write in the background."""
        self._raise_pending()
        if not self._worker.is_alive():
            raise RuntimeError("AsyncCheckpointer is closed")
        self._q.put((int(step), snapshot(tree, copy_arrays=self.copy_arrays)))

    def wait(self) -> None:
        """Block until every queued save has committed."""
        self._q.join()
        self._raise_pending()

    def close(self) -> None:
        if self._worker.is_alive():
            self._q.put(_STOP)
            self._worker.join()
        self._raise_pending()

    # passthroughs
    def load(self, step: Optional[int] = None) -> Any:
        self.wait()
        return self.writer.load(step)

    def latest_step(self) -> Optional[int]:
        return self.writer.latest_step()
