"""PRNG-key (de)serialization for checkpointable state.

jax has two key flavors: raw ``uint32`` arrays (``jax.random.PRNGKey``)
and typed key arrays (``jax.random.key``, e.g. the channel subsystem's
``rbg`` keys).  Raw keys are ordinary arrays and round-trip through the
msgpack codec unchanged; typed keys carry an opaque extended dtype that
no serializer understands, so they are exchanged for a tagged dict of
``(impl name, key_data)`` and rebuilt with ``wrap_key_data`` — bitwise
the same key, same impl, on restore.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["encode_prng_key", "decode_prng_key", "is_encoded_key"]

_TAG = "__prng_key__"


def encode_prng_key(key: Any) -> Any:
    """Typed key array -> tagged dict; anything else passes through."""
    if isinstance(key, jax.Array) and jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        return {_TAG: str(jax.random.key_impl(key)),
                "data": np.asarray(jax.random.key_data(key))}
    return key


def is_encoded_key(obj: Any) -> bool:
    return isinstance(obj, dict) and _TAG in obj


def decode_prng_key(obj: Any) -> Any:
    """Inverse of :func:`encode_prng_key` (pass-through for raw keys)."""
    if is_encoded_key(obj):
        return jax.random.wrap_key_data(jnp.asarray(obj["data"]), impl=obj[_TAG])
    return jnp.asarray(obj)
