"""Preemption safety: turn SIGTERM/SIGINT into a clean final checkpoint.

Cluster schedulers preempt with SIGTERM (and humans with Ctrl-C); a
handler that raises mid-chunk would tear the run state between the
device program and the host bookkeeping.  :class:`PreemptionGuard`
instead *latches* the first signal: the training loop keeps running to
its next boundary, notices ``guard.triggered``, drains the in-flight
async save, commits a final checkpoint and exits cleanly.  A second
signal falls through to the original handler (usually: die now) so a
wedged drain can still be killed.
"""

from __future__ import annotations

import signal
from typing import Optional, Tuple

__all__ = ["PreemptionGuard"]


class PreemptionGuard:
    """Context manager latching SIGTERM/SIGINT into a ``triggered`` flag.

    ::

        with PreemptionGuard() as guard:
            for r in range(rounds):
                train_one(r)
                if guard.triggered:
                    save_final_checkpoint()
                    break
    """

    def __init__(self, signals: Tuple[int, ...] = (signal.SIGTERM,
                                                   signal.SIGINT)):
        self.signals = tuple(signals)
        self.triggered = False
        self.signum: Optional[int] = None
        self._previous: dict = {}

    def _handle(self, signum, frame):
        if self.triggered:
            # second signal: the drain is taking too long — defer to the
            # original disposition (default SIGTERM/SIGINT terminate)
            prev = self._previous.get(signum)
            if callable(prev):
                prev(signum, frame)
                return
            signal.signal(signum, prev if prev is not None else signal.SIG_DFL)
            signal.raise_signal(signum)
            return
        self.triggered = True
        self.signum = signum

    def __enter__(self) -> "PreemptionGuard":
        for s in self.signals:
            self._previous[s] = signal.signal(s, self._handle)
        return self

    def __exit__(self, *exc) -> None:
        for s, prev in self._previous.items():
            signal.signal(s, prev)
        self._previous.clear()
        return None
