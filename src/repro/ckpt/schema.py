"""The versioned run-state schema (DESIGN.md §12).

One checkpoint = one pytree capturing *everything* a training run
threads across rounds, so a restore continues bitwise-identically:

======================  =====================================================
key                     contents
======================  =====================================================
``version``             schema version (``CKPT_VERSION``)
``round``               the trainer's authoritative round counter
``strategy``            registry name (checked on restore — a checkpoint
                        from one aggregation scheme cannot silently seed
                        another)
``params``              model parameters
``server_state``        PS optimizer state
``agg_state``           the strategy's carried pytree, via its
                        ``checkpoint_state``/``restore_state`` hooks (memory
                        replay buffer, quantized codec PRNG key, ...)
``A``                   the live relay-weight matrix (the adaptive schedule
                        mutates it mid-run)
``streak``              telemetry outage-streak carry (None when telemetry
                        is off)
``clients``             per-client data-RNG generator states (JSON-encoded
                        ``bit_generator.state``) at the *consumed-round
                        boundary* — the chunked engine prefetches the next
                        chunk's batches before the checkpoint point, so the
                        trainer snapshots these before prefetching
``channel``             the channel process's generator/chain state, via its
                        ``checkpoint_state``/``restore_state`` (restores
                        regenerate the current block bitwise)
``no_trace``            the in-scan sampler carry ``{state, rng}`` (None
                        unless the run used ``no_trace=True``)
``adaptive``            estimator posteriors + re-opt event log (None
                        without a schedule)
``metrics``             ``MetricsLogger`` state: monotonic ``seq`` cursor,
                        the full TrainLog facade, accumulated vector streams
======================  =====================================================

Nothing here imports the trainer — capture/restore work on any object
with the ``FLTrainer`` state attributes, so the module stays free of
import cycles.
"""

from __future__ import annotations

import json
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CKPT_VERSION", "capture_run_state", "restore_run_state",
           "rng_state_to_json", "rng_from_json"]

CKPT_VERSION = 1


def rng_state_to_json(rng: np.random.Generator) -> str:
    """A numpy Generator's full state as a JSON string (PCG64 state is
    plain ints/dicts; JSON holds its 128-bit ints exactly)."""
    return json.dumps(rng.bit_generator.state)


def rng_from_json(s: str) -> np.random.Generator:
    """Rebuild a Generator mid-stream from :func:`rng_state_to_json`."""
    state = json.loads(s)
    rng = np.random.default_rng()
    if rng.bit_generator.state["bit_generator"] != state["bit_generator"]:
        raise ValueError(
            f"checkpointed RNG is a {state['bit_generator']}, default_rng "
            f"builds a {rng.bit_generator.state['bit_generator']}")
    rng.bit_generator.state = state
    return rng


def capture_run_state(trainer) -> Dict[str, Any]:
    """Snapshot a trainer's complete run state as one checkpointable
    pytree (host views are copied by the writer's ``snapshot``)."""
    channel = trainer.channel
    if not hasattr(channel, "checkpoint_state"):
        raise TypeError(
            f"{type(channel).__name__} does not implement "
            "checkpoint_state(); its tau stream cannot be resumed")
    no_trace = None
    if trainer._channel_rng is not None:
        no_trace = {"state": trainer._channel_state,
                    "rng": trainer._channel_rng}
    return {
        "version": CKPT_VERSION,
        "round": int(trainer.round),
        "strategy": trainer.strategy.name,
        "params": trainer.params,
        "server_state": trainer.server_state,
        "agg_state": trainer.strategy.checkpoint_state(trainer.agg_state),
        "A": trainer.A,
        "streak": trainer._streak,
        "clients": trainer._client_rng_states(),
        "channel": channel.checkpoint_state(),
        "no_trace": no_trace,
        "adaptive": (trainer.adaptive.checkpoint_state()
                     if trainer.adaptive is not None else None),
        "metrics": trainer.metrics.checkpoint_state(),
    }


def restore_run_state(trainer, state: Dict[str, Any]) -> None:
    """Reinstate a captured state onto a freshly-built trainer.

    The trainer must be assembled identically to the checkpointed one
    (same strategy, channel type, client count, telemetry flag) — the
    checkpoint carries *state*, not configuration; mismatches raise.
    """
    version = state.get("version")
    if version != CKPT_VERSION:
        raise ValueError(
            f"checkpoint schema version {version!r} != {CKPT_VERSION}")
    if state["strategy"] != trainer.strategy.name:
        raise ValueError(
            f"checkpoint was written by strategy {state['strategy']!r}; "
            f"this trainer runs {trainer.strategy.name!r}")
    if (state.get("streak") is not None) != bool(trainer.telemetry):
        raise ValueError(
            "telemetry mismatch: checkpoint "
            f"{'has' if state.get('streak') is not None else 'lacks'} a "
            "streak carry but the trainer's telemetry flag disagrees")

    trainer.params = jax.tree.map(jnp.asarray, state["params"])
    trainer.server_state = jax.tree.map(jnp.asarray, state["server_state"])
    trainer.agg_state = trainer.strategy.restore_state(state["agg_state"])
    trainer.A = jnp.asarray(state["A"], jnp.float32)
    trainer.round = int(state["round"])
    if state.get("streak") is not None:
        trainer._streak = jnp.asarray(state["streak"], jnp.int32)

    clients = state["clients"]
    if len(clients) != len(trainer.clients):
        raise ValueError(
            f"checkpoint has {len(clients)} client RNG streams; trainer "
            f"has {len(trainer.clients)} clients")
    for c, s in zip(trainer.clients, clients):
        c._rng = rng_from_json(s)
    trainer._data_rng_snapshot = None

    if not hasattr(trainer.channel, "restore_state"):
        raise TypeError(
            f"{type(trainer.channel).__name__} does not implement "
            "restore_state()")
    trainer.channel.restore_state(state["channel"])

    no_trace = state.get("no_trace")
    if no_trace is not None:
        trainer._channel_state = jax.tree.map(jnp.asarray, no_trace["state"])
        trainer._channel_rng = jnp.asarray(no_trace["rng"])

    adaptive = state.get("adaptive")
    if adaptive is not None:
        if trainer.adaptive is None:
            raise ValueError(
                "checkpoint carries adaptive-schedule state but the "
                "trainer has no schedule attached")
        trainer.adaptive.restore_state(adaptive)
    trainer.metrics.restore_state(state["metrics"])
