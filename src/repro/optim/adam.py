"""AdamW for the transformer training examples."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import Optimizer, tree_zeros_like
from .sgd import Schedule, _lr


def adamw(
    lr: Schedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": tree_zeros_like(params),
            "v": tree_zeros_like(params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        eta = _lr(lr, step)
        m = jax.tree.map(lambda g, m: b1 * m + (1 - b1) * g.astype(jnp.float32), grads, state["m"])
        v = jax.tree.map(
            lambda g, v: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), grads, state["v"]
        )
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def u(m, v, p):
            upd = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return -eta * upd

        return jax.tree.map(u, m, v, params), {"step": step, "m": m, "v": v}

    return Optimizer(init, update)
