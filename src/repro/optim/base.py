"""Minimal optimizer substrate (no optax in this container): an optimizer
is an (init, update) pair over pytrees, optax-style.

``update(grads, state, params) -> (updates, state)`` returns *additive*
updates; ``apply_updates`` adds them.  All states are pytrees so they
shard with the params under pjit.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], Any]
    update: Callable[[Params, Any, Params], tuple[Params, Any]]


def apply_updates(params: Params, updates: Params) -> Params:
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates)


def tree_zeros_like(params: Params, dtype=jnp.float32) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params)


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )
