"""Learning-rate schedules, including the paper/Theorem-1 inverse decay
``eta_r = (4/mu) / (r*T + 1)`` used by the strongly-convex validation."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def inverse_round_decay(c: float, period: int, offset: float = 1.0):
    """eta_r = c / (r * period + offset)  — Theorem 1's schedule."""
    return lambda step: jnp.float32(c) / (step.astype(jnp.float32) * period + offset)


def cosine_decay(lr: float, steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.minimum(step.astype(jnp.float32) / steps, 1.0)
        return jnp.float32(lr) * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))

    return f


def warmup_cosine(lr: float, warmup: int, steps: int, final_frac: float = 0.0):
    def f(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        t = jnp.clip((s - warmup) / jnp.maximum(steps - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.float32(lr) * jnp.where(s < warmup, warm, cos)

    return f
