from .sgd import sgd, sgd_momentum
from .adam import adamw
from .schedule import constant, cosine_decay, inverse_round_decay, warmup_cosine
from .base import Optimizer, apply_updates

__all__ = [
    "Optimizer",
    "apply_updates",
    "sgd",
    "sgd_momentum",
    "adamw",
    "constant",
    "cosine_decay",
    "inverse_round_decay",
    "warmup_cosine",
]
