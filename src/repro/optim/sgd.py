"""SGD and SGD-with-momentum (the paper's client and server optimizers).

The paper uses plain SGD at the clients (lr 0.05, l2 1e-4) and momentum
(beta = 0.9) applied at the PS on the aggregated round delta.
"""

from __future__ import annotations

from typing import Callable, Union

import jax
import jax.numpy as jnp

from .base import Optimizer, tree_zeros_like

Schedule = Union[float, Callable[[jax.Array], jax.Array]]


def _lr(schedule: Schedule, step):
    return schedule(step) if callable(schedule) else jnp.float32(schedule)


def sgd(lr: Schedule, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        eta = _lr(lr, state["step"])

        def u(g, p):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            return -eta * g

        return jax.tree.map(u, grads, params), {"step": state["step"] + 1}

    return Optimizer(init, update)


def sgd_momentum(lr: Schedule, beta: float = 0.9, weight_decay: float = 0.0,
                 nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32), "m": tree_zeros_like(params)}

    def update(grads, state, params):
        eta = _lr(lr, state["step"])

        def mom(g, m, p):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            return beta * m + g

        m = jax.tree.map(mom, grads, state["m"], params)
        if nesterov:
            upd = jax.tree.map(lambda g, m: -eta * (g.astype(jnp.float32) + beta * m), grads, m)
        else:
            upd = jax.tree.map(lambda m: -eta * m, m)
        return upd, {"step": state["step"] + 1, "m": m}

    return Optimizer(init, update)
