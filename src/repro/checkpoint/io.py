"""msgpack checkpointing for nested dict/list/tuple pytrees of arrays.

Arrays are stored as (dtype, shape, raw bytes); bfloat16 round-trips via a
uint16 view.  Scalars/ints/floats pass through.  Atomic write via rename.
"""

from __future__ import annotations

import os
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_BF16 = "bfloat16"

# exact types only — np.float64 subclasses float but must take the array
# branch so its dtype survives; type() membership also skips the (slow,
# ABC-dispatched) jax.Array isinstance for every scalar leaf
_SCALARS = frozenset((int, float, str, bool, type(None)))


def _encode(obj: Any) -> Any:
    if type(obj) in _SCALARS:
        return {"__t": "s", "v": obj}
    if isinstance(obj, dict):
        return {"__t": "d", "v": {k: _encode(v) for k, v in obj.items()}}
    if isinstance(obj, (list, tuple)):
        tag = "l" if isinstance(obj, list) else "t"
        if all(type(v) in _SCALARS for v in obj):
            # packed scalar sequence: one node instead of len(obj) wrapper
            # dicts (client RNG strings, metric streams — the bulk of a
            # checkpoint's python nodes)
            return {"__t": tag.upper(), "v": list(obj)}
        return {"__t": tag, "v": [_encode(v) for v in obj]}
    if isinstance(obj, (jax.Array, np.ndarray, np.generic)):
        # numpy scalars (np.float32(x), ...) ride as 0-d arrays so their
        # dtype survives the trip (a python float would widen them)
        arr = np.asarray(obj)
        if arr.dtype == jnp.bfloat16:
            return {"__t": "a", "dtype": _BF16, "shape": list(arr.shape),
                    "data": arr.view(np.uint16).tobytes()}
        return {"__t": "a", "dtype": str(arr.dtype), "shape": list(arr.shape),
                "data": arr.tobytes()}
    if isinstance(obj, (int, float, str, bool)) or obj is None:
        return {"__t": "s", "v": obj}
    raise TypeError(f"cannot checkpoint {type(obj)}")


def _decode(obj: Any) -> Any:
    t = obj["__t"]
    if t == "d":
        return {k: _decode(v) for k, v in obj["v"].items()}
    if t == "l":
        return [_decode(v) for v in obj["v"]]
    if t == "t":
        return tuple(_decode(v) for v in obj["v"])
    if t == "L":
        return list(obj["v"])
    if t == "T":
        return tuple(obj["v"])
    if t == "a":
        # frombuffer views the (immutable) msgpack payload, so the result
        # is read-only; copy so restored state is mutable like the
        # arrays it replaces (optimizer updates mutate in place).
        shape = tuple(obj["shape"])
        if obj["dtype"] == _BF16:
            return (np.frombuffer(obj["data"], np.uint16).reshape(shape)
                    .view(jnp.bfloat16).copy())
        return (np.frombuffer(obj["data"], np.dtype(obj["dtype"]))
                .reshape(shape).copy())
    return obj["v"]


def save_checkpoint(path: str, tree: Any) -> None:
    payload = msgpack.packb(_encode(jax.tree.map(np.asarray, tree)), use_bin_type=True)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with tempfile.NamedTemporaryFile(dir=d, delete=False) as f:
        f.write(payload)
        tmp = f.name
    os.replace(tmp, path)


def load_checkpoint(path: str) -> Any:
    with open(path, "rb") as f:
        return _decode(msgpack.unpackb(f.read(), raw=False))
