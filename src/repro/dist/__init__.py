"""Sharding substrate: mesh topology + activation/grad partitioning hooks.

``repro.dist`` is the one place that knows how federated clients and
tensor-parallel shards map onto a physical ``jax.sharding.Mesh``:

* ``mesh``        — production / debug mesh builders and the client-axis
                    bookkeeping (which mesh axes enumerate FL clients).
* ``constraints`` — in-graph sharding constraints: the residual-stream
                    ``constrain_act`` hook the models call every block, and
                    the small helpers ``repro.fl.round`` uses to pin the
                    vmapped client axis (``spmd_axis_name``) and the
                    gradient tree (``constrain_grads``) under pjit.

Everything degrades to a no-op on a single device / outside a mesh
context, so the same model code runs unmodified in smoke tests and on a
512-chip mesh.
"""

from .constraints import (
    constrain,
    constrain_act,
    constrain_grads,
    current_mesh,
    spmd_axis_name,
)
from .mesh import client_axes, make_debug_mesh, make_production_mesh, n_clients

__all__ = [
    "constrain",
    "constrain_act",
    "constrain_grads",
    "current_mesh",
    "spmd_axis_name",
    "client_axes",
    "make_debug_mesh",
    "make_production_mesh",
    "n_clients",
]
