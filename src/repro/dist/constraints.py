"""In-graph sharding constraints (the hooks models and the FL round call).

``constrain_act`` pins the residual stream to ``cfg.act_spec`` — a
PartitionSpec template for the trailing ``(batch, seq, d_model)`` dims set
by the launch layer (see ``repro.launch.steps``).  Without it the
partitioner tends to drift activations (and therefore every backward
intermediate) to replicated layouts on the large meshes.

All helpers are total no-ops when no mesh is active (smoke tests, single
device) and silently drop any axis that is absent from the mesh or does
not divide the corresponding dim, so one spec template serves every
(arch x mesh) combination.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple, Union

import jax
from jax.interpreters import pxla
from jax.sharding import NamedSharding, PartitionSpec as P

AxisEntry = Union[None, str, Tuple[str, ...]]
Params = Any


def current_mesh() -> Optional[jax.sharding.Mesh]:
    """The mesh installed by the enclosing ``with mesh:`` block, if any."""
    mesh = pxla.thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def _entry_axes(entry: AxisEntry) -> Tuple[str, ...]:
    if entry is None:
        return ()
    if isinstance(entry, str):
        return (entry,)
    return tuple(entry)


def _fit_spec(spec: Sequence[AxisEntry], shape: Tuple[int, ...], mesh) -> Optional[P]:
    """Align ``spec`` to the trailing dims of ``shape``, dropping any entry
    whose mesh axes are missing or whose product does not divide the dim."""
    ndim = len(shape)
    if len(spec) > ndim:
        return None
    out: list = [None] * ndim
    off = ndim - len(spec)
    nontrivial = False
    for i, entry in enumerate(spec):
        axes = tuple(
            a for a in _entry_axes(entry)
            if a in mesh.axis_names and mesh.shape[a] > 1
        )
        if not axes:
            continue
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if shape[off + i] % size != 0:
            continue
        out[off + i] = axes if len(axes) > 1 else axes[0]
        nontrivial = True
    return P(*out) if nontrivial else None


def constrain(x: jax.Array, spec: Sequence[AxisEntry]) -> jax.Array:
    """Constrain ``x`` to ``spec`` (trailing-dim aligned) under the active
    mesh; identity outside a mesh context."""
    mesh = current_mesh()
    if mesh is None:
        return x
    p = _fit_spec(tuple(spec), x.shape, mesh)
    if p is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, p))


def constrain_act(cfg, x: jax.Array) -> jax.Array:
    """Residual-stream hook: pin ``x`` to ``cfg.act_spec`` when set."""
    spec = getattr(cfg, "act_spec", None)
    if not spec:
        return x
    return constrain(x, spec)


def constrain_grads(grads: Params, grad_shardings: Optional[Params]) -> Params:
    """Pin a gradient pytree to the params' sharded layout (ZeRO/FSDP modes);
    identity when no shardings were provided."""
    if grad_shardings is None:
        return grads
    return jax.lax.with_sharding_constraint(grads, grad_shardings)


def spmd_axis_name(spmd_axes: Optional[Tuple[str, ...]]):
    """Normalize a RoundConfig.spmd_axes tuple into the form
    ``jax.vmap(spmd_axis_name=...)`` expects (None / name / tuple)."""
    if not spmd_axes:
        return None
    return spmd_axes if len(spmd_axes) > 1 else spmd_axes[0]
