"""Production meshes for the multi-pod dry-run.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run entry point must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* the
first jax call, and smoke tests must keep seeing 1 device.

Target hardware (roofline constants in launch/roofline.py): TPU v5e pods,
256 chips/pod, 16x16 single-pod mesh (data, model) and a 2-pod 512-chip
mesh (pod, data, model).  FL clients map onto the data axis — 16 clients
single-pod, 32 (pod x data collapsed) multi-pod.
"""

from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def client_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes that enumerate FL clients."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def n_clients(mesh) -> int:
    n = 1
    for a in client_axes(mesh):
        n *= mesh.shape[a]
    return n


def client_axis_spec(mesh):
    """PartitionSpec entry for a client-indexed dim: the client axes as a
    tuple when several enumerate clients (multi-pod), else the single axis
    name — the spelling every client-axis sharding rule shares."""
    ca = client_axes(mesh)
    return ca if len(ca) > 1 else ca[0]


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small host-device mesh for tests (requires >= data*model devices)."""
    return jax.make_mesh((data, model), ("data", "model"))
