from .round import RoundConfig, make_round_fn, make_scan_round_fn
from .trainer import FLTrainer, TrainLog
from .experiment import Experiment, ExperimentSpec, TOPOLOGIES, build_experiment

__all__ = [
    "RoundConfig",
    "make_round_fn",
    "make_scan_round_fn",
    "FLTrainer",
    "TrainLog",
    "Experiment",
    "ExperimentSpec",
    "TOPOLOGIES",
    "build_experiment",
]
