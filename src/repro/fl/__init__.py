from .round import RoundConfig, make_round_fn
from .trainer import FLTrainer, TrainLog

__all__ = ["RoundConfig", "make_round_fn", "FLTrainer", "TrainLog"]
