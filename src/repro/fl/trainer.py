"""Host-side FL training driver: samples connectivity, streams per-client
batches, invokes the compiled round function, tracks metrics, evaluates.

This is the entry point the paper-reproduction experiments and the
examples use on CPU; the production launch path (``repro/launch``) wraps
the same round function in pjit with mesh shardings.  Prefer building it
declaratively through :func:`repro.fl.experiment.build_experiment` — the
constructor below is the assembled form.

Connectivity comes from a :class:`~repro.channel.ChannelProcess` — the
paper's i.i.d. model (the default, built from ``link_model``), bursty
Gilbert–Elliott chains, or waypoint mobility.  With an
:class:`~repro.channel.AdaptiveWeightSchedule` attached, the trainer no
longer assumes oracle link knowledge: it estimates ``(p, P, E)`` online
from the realized taus and re-runs COPT-alpha every K rounds, swapping
the fresh alpha into the (traced, so recompile-free) ``A`` argument of
the compiled round.

Aggregation is a pluggable :class:`~repro.strategies.AggregationStrategy`
(``strategy=`` accepts a registry name or an instance); stateful
strategies' carried state (e.g. the memory strategy's replay buffer)
lives on the trainer and threads through the compiled round.

**Chunked execution** (DESIGN.md §9): ``run(rounds, chunk=K)`` drives
the multi-round scan engine — K rounds compiled into one device program
(:func:`~repro.fl.round.make_scan_round_fn`), connectivity served as a
bulk ``channel.trace`` per chunk, batches pre-stacked in one vectorized
gather with the next chunk prepared while the device executes the
current one, and per-round metrics bulk-appended from the stacked
``(K,)`` outputs.  The trajectory is bitwise-identical to the per-round
loop: both consume the same channel/batch streams and the scan body *is*
the loop's round function.  Adaptive re-optimization and eval stay
correct by construction — the chunk size must divide their cadences (and
re-opts then land exactly on chunk boundaries); otherwise the trainer
falls back to the per-round loop.

**Telemetry** (DESIGN.md §11): every metric stream — both execution
paths — routes through one :class:`~repro.telemetry.MetricsLogger`
append path; :class:`TrainLog` remains attached as the bitwise-compatible
facade (``trainer.log is trainer.metrics.log``).  ``telemetry=True``
additionally compiles the instrumented round (per-client participation /
bits-on-air vectors, a device-resident outage-streak carry, unbiasedness
drift), and ``profile=``/``run(log_every=)`` expose the opt-in profiler
window and throughput readout.  All of it is off by default and the
default path's TrainLog streams are unchanged to the bit.
"""

from __future__ import annotations

import dataclasses
import pathlib
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import strategies as strategy_registry
from repro.channel.base import ChannelProcess, StaticChannel
from repro.channel.schedule import AdaptiveWeightSchedule
from repro.core import LinkModel, variance_S
from repro.core.flatten import flat_spec
from repro.data.pipeline import ClientDataset, stack_chunk_batches
from repro.fl.round import (
    RoundConfig,
    make_async_round_fn,
    make_async_scan_round_fn,
    make_round_fn,
    make_scan_round_fn,
)
from repro.optim import Optimizer
from repro.telemetry import (
    CompileTracker,
    MetricsLogger,
    ProfileWindow,
    ThroughputMeter,
    init_streak,
)

Params = Any


@dataclasses.dataclass
class TrainLog:
    rounds: List[int] = dataclasses.field(default_factory=list)
    loss: List[float] = dataclasses.field(default_factory=list)
    eval_rounds: List[int] = dataclasses.field(default_factory=list)
    eval_metrics: List[Dict[str, float]] = dataclasses.field(default_factory=list)
    participation: List[float] = dataclasses.field(default_factory=list)
    # wire-format-aware uplink accounting: bits-on-air delivered to the PS
    # that round (participation x flat-dim x the active codec's
    # bits-per-coordinate) — np.cumsum(log.uplink_bits) is the x-axis of a
    # loss-vs-bytes curve
    uplink_bits: List[float] = dataclasses.field(default_factory=list)
    # realized sum of scalar aggregation weights (E = 1 when unbiased);
    # its dispersion is the realized counterpart of the variance proxy S.
    # NaN for strategies with no scalar collapse (e.g. memory).
    weight_sums: List[float] = dataclasses.field(default_factory=list)
    # adaptive re-optimization events (empty without a schedule)
    reopt_rounds: List[int] = dataclasses.field(default_factory=list)
    est_p_err: List[float] = dataclasses.field(default_factory=list)
    S_est: List[float] = dataclasses.field(default_factory=list)
    S_true: List[float] = dataclasses.field(default_factory=list)

    def to_dict(self):
        return dataclasses.asdict(self)


class FLTrainer:
    """Orchestrates pluggable-strategy FL training over an intermittent
    network (ColRel, FedAvg baselines, multihop, memory, ...)."""

    def __init__(
        self,
        loss_fn: Callable,
        init_params: Params,
        link_model: Optional[LinkModel],
        A: np.ndarray,
        clients: Sequence[ClientDataset],
        client_opt: Optimizer,
        server_opt: Optimizer,
        *,
        local_steps: int = 8,
        strategy: "str | strategy_registry.AggregationStrategy | None" = None,
        aggregation: "str | strategy_registry.AggregationStrategy | None" = None,
        mode: str = "per_client",
        use_fused_kernel: bool = False,
        seed: int = 0,
        eval_fn: Optional[Callable[[Params], Dict[str, float]]] = None,
        channel: Optional[ChannelProcess] = None,
        adaptive: Optional[AdaptiveWeightSchedule] = None,
        telemetry: bool = False,
        metrics: Optional[MetricsLogger] = None,
        profile: Optional[ProfileWindow] = None,
        async_options: Optional[Dict[str, Any]] = None,
        donate: bool = True,
        segment_d: int = 0,
    ):
        if strategy is not None and aggregation is not None:
            raise ValueError("pass strategy= or aggregation=, not both")
        spec = strategy if strategy is not None else (
            aggregation if aggregation is not None else "colrel")
        self.strategy = strategy_registry.resolve(
            spec, fused_kernel=use_fused_kernel)
        # async execution mode (DESIGN.md §13): wrap the configured
        # strategy in the staleness-weighted opportunistic-relaying
        # carrier and run it through the per_client engine — the async
        # state (age vector + staging buffer) rides ``agg_state``, so
        # every execution path below works unchanged.
        if mode == "async":
            if getattr(self.strategy, "is_async", False):
                if async_options:
                    raise ValueError(
                        "strategy is already async; pass gamma/opportunistic "
                        "through the strategy spec, not async_options")
            else:
                self.strategy = strategy_registry.AsyncRelayStrategy(
                    inner=self.strategy, **dict(async_options or {}))
            mode = "per_client"
        elif async_options:
            raise ValueError("async_options requires mode='async'")
        # an async strategy — whether wrapped above or registered directly
        # (strategy="async_colrel") — runs through the age-carrying builders
        self.async_mode = getattr(self.strategy, "is_async", False)
        if channel is None:
            if link_model is None:
                raise ValueError("provide link_model or channel")
            channel = StaticChannel(link_model, seed=seed)
        self.channel = channel
        self.adaptive = adaptive
        if adaptive is not None and not self.strategy.needs_A:
            raise ValueError(
                f"adaptive alpha re-optimization only affects strategies "
                f"that read A; {self.strategy.name!r} ignores it"
            )
        if adaptive is not None and self.strategy.calibration_tracks_A:
            raise ValueError(
                f"strategy {self.strategy.name!r} was calibrated against a "
                "fixed alpha; the adaptive schedule swaps alpha mid-run, "
                "which would silently stale the calibration — run it "
                "uncalibrated or without adaptive"
            )
        n = channel.n
        if link_model is not None and link_model.n != n:
            raise ValueError(f"link_model.n={link_model.n} != channel.n={n}")
        assert len(clients) == n, (len(clients), n)
        self.link_model = link_model if link_model is not None else channel.model_for_round(0)
        self.A = jnp.asarray(A, jnp.float32)
        self.clients = list(clients)
        # Buffer donation (DESIGN.md §14): the compiled round/scan carry
        # (params, server_state, agg_state, plus the sampled-scan channel
        # state / rng and the telemetry streak) is donated back into each
        # call, so XLA aliases the outputs onto the input buffers instead
        # of allocating a second copy of every carry array.  The caller's
        # init_params must then be defensively copied — donation would
        # delete the caller's own buffers on the first round.
        self.donate = bool(donate)
        if self.donate:
            init_params = jax.tree.map(jnp.array, init_params)
        self.params = init_params
        self.eval_fn = eval_fn
        rc = RoundConfig(
            n_clients=n, local_steps=local_steps, mode=mode,
            aggregation=self.strategy, segment_d=int(segment_d),
        )
        self.rc = rc
        self._loss_fn = loss_fn
        self._client_opt = client_opt
        self.server_opt = server_opt
        self.server_state = server_opt.init(init_params)
        self.agg_state = self.strategy.init_state(n, flat_spec(init_params).d)
        # telemetry (DESIGN.md §11): `telemetry=True` switches the
        # compiled round/scan to the instrumented signature (outage-streak
        # carry + (n,)-vector metrics); `metrics` is the host-side logger
        # every stream routes through (a bare facade-only one otherwise).
        self.telemetry = bool(telemetry)
        self.metrics = metrics if metrics is not None else MetricsLogger()
        self.profile = profile
        self.meter = ThroughputMeter()
        self.compiles = CompileTracker()
        self._warm_fns: set = set()
        self._streak = init_streak(n) if self.telemetry else None
        self._log_every = 0
        self._last_tlog = 0
        make_fn = make_async_round_fn if self.async_mode else make_round_fn
        self._make_scan_fn = (make_async_scan_round_fn if self.async_mode
                              else make_scan_round_fn)
        # donated argnums per signature: the carry slots only — never
        # batches (host-built each call), taus, or A (reused across calls)
        self._donate_round = ()
        self._donate_sampled = ()
        if self.donate:
            streak = (7,) if self.telemetry else ()
            self._donate_round = (0, 1, 2) + streak
            self._donate_sampled = (0, 1, 2, 4, 5) + streak
        self._round_fn = jax.jit(make_fn(
            loss_fn, client_opt, server_opt, rc, telemetry=self.telemetry),
            donate_argnums=self._donate_round)
        self.compiles.register("round_fn", self._round_fn)
        self._scan_fn = None  # built on first chunked run
        self._seed = seed
        # no-trace mode: in-scan sampler fn + carried (channel_state, rng)
        self._sampled_scan_fn = None
        self._sampled_init_fn = None
        self._channel_state = None
        self._channel_rng = None
        # checkpoint/resume (DESIGN.md §12): the authoritative round
        # counter, the client-RNG snapshot at the consumed-round boundary
        # (the chunked engine prefetches past it), and the per-run async
        # checkpointer wiring set up by `run`.
        self.round = 0
        self._data_rng_snapshot: Optional[List[str]] = None
        self._ckpt = None
        self._ckpt_every = 0
        self._ckpt_last = -1
        self.log = self.metrics.log

    # ------------------------------------------------------------------
    def _stack_batches(self, rounds: int = 1) -> Dict[str, np.ndarray]:
        """Stacked local-step batches: ``(n, T, B, ...)`` for ``rounds=1``
        (the per-round loop) or ``(rounds, n, T, B, ...)`` for a chunk —
        one vectorized gather per client, same RNG stream either way."""
        out = stack_chunk_batches(self.clients, self.rc.local_steps, rounds)
        if rounds == 1:
            out = {k: v[0] for k, v in out.items()}
            if self.rc.mode == "weighted_grad":
                out = {k: v[:, 0] for k, v in out.items()}  # T==1 collapse
        elif self.rc.mode == "weighted_grad":
            out = {k: v[:, :, 0] for k, v in out.items()}
        return out

    # -- checkpoint/resume (DESIGN.md §12) -----------------------------
    def _client_rng_states(self) -> List[str]:
        """Per-client data-RNG states at the consumed-round boundary.

        The chunked engine prefetches the next chunk's batches *before*
        the checkpoint point, so the live generators sit one chunk ahead
        of the boundary; ``_run_chunks`` snapshots the boundary states
        pre-prefetch and this prefers that snapshot."""
        if self._data_rng_snapshot is not None:
            return list(self._data_rng_snapshot)
        from repro.ckpt.schema import rng_state_to_json
        return [rng_state_to_json(c._rng) for c in self.clients]

    def save_checkpoint(self, path) -> pathlib.Path:
        """Synchronously write the complete run state to one file."""
        from repro.ckpt.schema import capture_run_state
        from repro.ckpt.writer import write_state
        return write_state(path, capture_run_state(self))

    def restore(self, source) -> int:
        """Restore from a checkpoint file or directory (latest step).

        The trainer must be assembled with the same configuration as the
        checkpointed run; returns the restored round counter."""
        from repro.ckpt.schema import restore_run_state
        from repro.ckpt.writer import CheckpointWriter, read_state
        p = pathlib.Path(source)
        state = CheckpointWriter(p).load() if p.is_dir() else read_state(p)
        restore_run_state(self, state)
        return self.round

    def _maybe_ckpt(self) -> None:
        """Periodic async save at a round/chunk boundary."""
        if self._ckpt is None or self._ckpt_every <= 0:
            return
        if self.round % self._ckpt_every == 0 and self.round != self._ckpt_last:
            from repro.ckpt.schema import capture_run_state
            self._ckpt.save(self.round, capture_run_state(self))
            self._ckpt_last = self.round

    def _finish_ckpt(self) -> None:
        """End-of-run: commit a final checkpoint, drain, shut down."""
        if self._ckpt is None:
            return
        try:
            if self.round != self._ckpt_last:
                from repro.ckpt.schema import capture_run_state
                self._ckpt.save(self.round, capture_run_state(self))
                self._ckpt_last = self.round
            self._ckpt.wait()
        finally:
            self._ckpt.close()
            self._ckpt = None

    # ------------------------------------------------------------------
    def _ingest_adaptive(self, r: int, tau_up: np.ndarray, tau_dd: np.ndarray,
                         verbose: bool) -> bool:
        """Feed one round's realization to the adaptive schedule; swap in
        the fresh alpha (and log the event) on re-opt rounds."""
        A_new = self.adaptive.step(r, tau_up, tau_dd)
        if A_new is None:
            return False
        self.A = jnp.asarray(A_new, jnp.float32)
        true_m = self.channel.model_for_round(r)
        info = self.adaptive.events[-1]
        self.metrics.log_reopt(
            r,
            S_est=float(info["S_est"]),
            S_true=float(variance_S(true_m, A_new)),
            p_err=self.adaptive.estimator.errors(true_m)["p"],
        )
        if verbose:
            print(
                f"  round {r+1:4d}  re-opt alpha: "
                f"S_est={info['S_est']:.3f} "
                f"S_true={self.log.S_true[-1]:.3f} "
                f"p_err={self.log.est_p_err[-1]:.3f}"
            )
        return True

    def _maybe_eval(self, r: int, eval_every: int, verbose: bool) -> None:
        if eval_every and (r + 1) % eval_every == 0 and self.eval_fn is not None:
            em = self.eval_fn(self.params)
            self.metrics.log_eval(r, em)
            if verbose:
                print(f"  round {r+1:4d}  loss={self.log.loss[-1]:.4f}  " +
                      "  ".join(f"{k}={v:.4f}" for k, v in em.items()))
        elif verbose and (r + 1) % 10 == 0:
            print(f"  round {r+1:4d}  loss={self.log.loss[-1]:.4f}")

    # ------------------------------------------------------------------
    def _run_one(self, r: int, eval_every: int, verbose: bool) -> None:
        """One communication round through the per-round compiled fn."""
        if self.profile is not None:
            self.profile.maybe_start(r)
        self.meter.start()
        tau_up, tau_dd = self.channel.tau_for_round(r)
        batches = self._stack_batches()
        args = (
            self.params,
            self.server_state,
            self.agg_state,
            jax.tree.map(jnp.asarray, batches),
            jnp.asarray(tau_up, jnp.float32),
            jnp.asarray(tau_dd, jnp.float32),
            self.A,
        )
        if self.telemetry:
            (self.params, self.server_state, self.agg_state, self._streak,
             metrics) = self._round_fn(*args, self._streak)
        else:
            (self.params, self.server_state, self.agg_state,
             metrics) = self._round_fn(*args)
        dt = self.meter.stop(1, fence=metrics)
        if self.profile is not None:
            self.profile.maybe_stop(r + 1)
        self.metrics.log_timing(r, 1, dt)
        self._log_compile_growth(r)
        self.metrics.log_rounds(r, metrics)
        if self.adaptive is not None:
            self._ingest_adaptive(r, np.asarray(tau_up), np.asarray(tau_dd),
                                  verbose)
        self._maybe_eval(r, eval_every, verbose)
        self._maybe_log_throughput(r + 1)
        self.round = r + 1
        self._data_rng_snapshot = None  # live RNGs sit at the boundary
        self._maybe_ckpt()

    # ------------------------------------------------------------------
    def _effective_chunk(self, chunk: int, eval_every: int) -> int:
        """Largest usable chunk: the requested one when it divides every
        host-side cadence (adaptive re-opt, eval) — so those events land
        exactly on chunk boundaries — else 1 (per-round fallback)."""
        if chunk <= 1:
            return 1
        if self.adaptive is not None and self.adaptive.cfg.every % chunk != 0:
            return 1
        if eval_every and eval_every % chunk != 0:
            return 1
        return chunk

    def _log_compile_growth(self, r: int) -> None:
        """Emit ``health.recompile`` for jit cache growth past each
        function's expected first compile."""
        grew = self.compiles.check()
        fresh = {}
        for name, growth in grew.items():
            if name in self._warm_fns:
                fresh[name] = growth
            else:
                self._warm_fns.add(name)
        if fresh:
            self.metrics.log_recompiles(fresh, r)

    def _maybe_log_throughput(self, r_next: int) -> None:
        if not self._log_every or r_next - self._last_tlog < self._log_every:
            return
        self._last_tlog = r_next
        import sys
        print(
            f"[telemetry] round {r_next}: "
            f"{self.meter.rounds_per_sec():.2f} rounds/s "
            f"({self.meter.total_rounds} rounds in "
            f"{self.meter.total_seconds:.2f}s)",
            file=sys.stderr,
        )

    def _run_chunks(self, r0: int, n_chunks: int, k: int,
                    eval_every: int, verbose: bool) -> None:
        """``n_chunks`` chunks of ``k`` rounds through the scan engine."""
        if self._scan_fn is None:
            self._scan_fn = jax.jit(self._make_scan_fn(
                self._loss_fn, self._client_opt, self.server_opt, self.rc,
                telemetry=self.telemetry),
                donate_argnums=self._donate_round)
            self.compiles.register("scan_fn", self._scan_fn)
        batches = self._stack_batches(k)
        for c in range(n_chunks):
            r = r0 + c * k
            if self.profile is not None:
                self.profile.maybe_start(r)
            self.meter.start()
            tau_up, tau_dd = self.channel.trace(r, k)
            args = (
                self.params,
                self.server_state,
                self.agg_state,
                jax.tree.map(jnp.asarray, batches),
                jnp.asarray(tau_up, jnp.float32),
                jnp.asarray(tau_dd, jnp.float32),
                self.A,
            )
            if self.telemetry:
                (self.params, self.server_state, self.agg_state,
                 self._streak, metrics) = self._scan_fn(*args, self._streak)
            else:
                (self.params, self.server_state, self.agg_state,
                 metrics) = self._scan_fn(*args)
            # host prefetch: the dispatch above is async, so stacking the
            # next chunk's batches overlaps this chunk's device execution.
            # A checkpoint taken at this boundary must see the client
            # RNGs *before* the prefetch advances them — snapshot first.
            from repro.ckpt.schema import rng_state_to_json
            self._data_rng_snapshot = [rng_state_to_json(cl._rng)
                                       for cl in self.clients]
            batches = self._stack_batches(k) if c + 1 < n_chunks else None
            dt = self.meter.stop(k, fence=metrics)
            if self.profile is not None:
                self.profile.maybe_stop(r + k)
            self.metrics.log_timing(r, k, dt)
            self._log_compile_growth(r + k - 1)
            self.metrics.log_rounds(r, metrics, k)
            if self.adaptive is not None:
                ups, dds = np.asarray(tau_up), np.asarray(tau_dd)
                for i in range(k):
                    swapped = self._ingest_adaptive(r + i, ups[i], dds[i],
                                                    verbose)
                    if swapped and i != k - 1:  # guarded by _effective_chunk
                        raise RuntimeError(
                            "adaptive re-opt fired mid-chunk (round "
                            f"{r + i}, chunk [{r}, {r + k})); the cadence "
                            "must be a multiple of chunk"
                        )
            self._maybe_eval(r + k - 1, eval_every, verbose)
            self._maybe_log_throughput(r + k)
            self.round = r + k
            self._maybe_ckpt()

    def _run_chunks_sampled(self, r0: int, k: int,
                            eval_every: int, verbose: bool) -> None:
        """One chunk of ``k`` rounds with connectivity drawn *inside* the
        compiled scan (``make_scan_round_fn(channel_sampler=...)``): no tau
        tensors ever materialize on host — the channel's gate state and a
        PRNG key thread through the device program instead."""
        if self._sampled_scan_fn is None:
            init_fn, sample_fn = self.channel.scan_sampler()
            self._sampled_scan_fn = jax.jit(self._make_scan_fn(
                self._loss_fn, self._client_opt, self.server_opt, self.rc,
                channel_sampler=sample_fn, telemetry=self.telemetry),
                donate_argnums=self._donate_sampled)
            self.compiles.register("sampled_scan_fn", self._sampled_scan_fn)
            self._sampled_init_fn = init_fn
        # state init is guarded separately from fn build: a restored run
        # arrives here with `_channel_state`/`_channel_rng` already set
        # (the checkpointed carry) and a fresh, unbuilt scan fn — the
        # lazy init must not clobber the restored carry.  The rng, not
        # the state, is the sentinel: static samplers carry state `()`.
        if self._channel_rng is None:
            key = jax.random.PRNGKey(self._seed)
            key, sub = jax.random.split(key)
            self._channel_state = self._sampled_init_fn(sub)
            self._channel_rng = key
        if self.profile is not None:
            self.profile.maybe_start(r0)
        self.meter.start()
        batches = self._stack_batches(k)
        args = (
            self.params,
            self.server_state,
            self.agg_state,
            jax.tree.map(jnp.asarray, batches),
            self._channel_state,
            self._channel_rng,
            self.A,
        )
        if self.telemetry:
            (self.params, self.server_state, self.agg_state,
             self._channel_state, self._channel_rng, self._streak,
             metrics) = self._sampled_scan_fn(*args, self._streak)
        else:
            (self.params, self.server_state, self.agg_state,
             self._channel_state, self._channel_rng,
             metrics) = self._sampled_scan_fn(*args)
        dt = self.meter.stop(k, fence=metrics)
        if self.profile is not None:
            self.profile.maybe_stop(r0 + k)
        self.metrics.log_timing(r0, k, dt)
        self._log_compile_growth(r0 + k - 1)
        self.metrics.log_rounds(r0, metrics, k)
        self._maybe_eval(r0 + k - 1, eval_every, verbose)
        self._maybe_log_throughput(r0 + k)
        self.round = r0 + k
        self._data_rng_snapshot = None  # no prefetch on this path
        self._maybe_ckpt()

    # ------------------------------------------------------------------
    def run(self, rounds: int, *, chunk: int = 1, eval_every: int = 0,
            verbose: bool = False, no_trace: bool = False,
            log_every: int = 0, ckpt_dir=None, ckpt_every: int = 0,
            ckpt_keep: int = 3, resume_from=None) -> TrainLog:
        """Train for ``rounds`` communication rounds.

        ``chunk=K`` compiles K rounds into one device program and syncs
        to the host only at chunk boundaries (bitwise-identical
        trajectory to the per-round loop).  Rounds that cannot form an
        aligned full chunk — leading rounds until the global round
        counter hits a multiple of K, and the tail remainder — run
        through the per-round path; if K does not divide the adaptive
        re-opt cadence or ``eval_every``, the whole run falls back to
        per-round execution.

        ``no_trace=True`` draws connectivity *inside* the compiled scan
        via the channel's ``scan_sampler()`` (the in-scan sampler of
        :func:`~repro.fl.round.make_scan_round_fn`): no tau tensors ever
        cross the host boundary — only the channel's packed gate state
        and a PRNG key thread through the program.  The draws come from
        the sampler's own jax PRNG stream, so the trajectory is
        distributionally identical (same marginals / GE dynamics) but not
        bitwise equal to the traced path.  Requires a channel exposing
        ``scan_sampler`` and no adaptive schedule (re-optimization needs
        the realized taus on host).

        ``log_every=N`` prints a cumulative rounds/sec line to stderr
        every N rounds (throughput is measured either way — see
        ``self.meter``).

        **Checkpoint/resume** (DESIGN.md §12): ``ckpt_dir`` enables
        checkpointing — an async save of the complete run state every
        ``ckpt_every`` rounds (``0`` = only the final end-of-run save),
        keep-last-``ckpt_keep`` retention.  When chunked, ``ckpt_every``
        must be a multiple of the chunk (the host only syncs at chunk
        boundaries).  ``resume_from`` (a checkpoint file or a ckpt
        directory, whose latest committed step is used) restores the
        state *first* and reinterprets ``rounds`` as the **total** round
        target: ``run(100, resume_from=ckpt_at_40)`` trains rounds
        40..99, continuing bitwise-identically to the uninterrupted run.
        """
        if resume_from is not None:
            self.restore(resume_from)
        start = self.round
        end = rounds if resume_from is not None else start + rounds
        if end < start:
            raise ValueError(
                f"resume target {end} is behind the restored round {start}")
        k = self._effective_chunk(int(chunk), eval_every)
        self._ckpt_every = int(ckpt_every)
        if ckpt_dir is not None:
            if self._ckpt_every > 0 and k > 1 and self._ckpt_every % k != 0:
                raise ValueError(
                    f"ckpt_every={ckpt_every} must be a multiple of the "
                    f"chunk size {k}: the chunked engine only reaches the "
                    "host at chunk boundaries")
            from repro.ckpt.writer import AsyncCheckpointer
            self._ckpt = AsyncCheckpointer(ckpt_dir, keep=ckpt_keep,
                                           copy_arrays=self.donate)
            self._ckpt_last = -1
        self._log_every = int(log_every)
        self._last_tlog = start
        if no_trace:
            if not hasattr(self.channel, "scan_sampler"):
                raise ValueError(
                    f"no_trace needs a channel with scan_sampler(); "
                    f"{type(self.channel).__name__} cannot sample in-scan"
                )
            if self.adaptive is not None:
                raise ValueError(
                    "no_trace is incompatible with adaptive re-optimization: "
                    "the estimator consumes realized taus on host, which "
                    "no_trace never materializes"
                )
            r = start
            while r < end:
                # any chunk size works (no trace stream to stay aligned
                # with); a short tail just retraces the jit once
                self._run_chunks_sampled(r, min(k, end - r), eval_every,
                                         verbose)
                r += min(k, end - r)
            return self._finish_run()
        r = start
        while r < end:
            if k > 1 and r % k == 0 and r + k <= end:
                n_chunks = (end - r) // k
                self._run_chunks(r, n_chunks, k, eval_every, verbose)
                r += n_chunks * k
            else:
                self._run_one(r, eval_every, verbose)
                r += 1
        return self._finish_run()

    def _finish_run(self) -> TrainLog:
        """End-of-run bookkeeping: final checkpoint commit + writer
        drain, close a dangling profile window and flush the sinks (the
        logger itself stays open — ``run`` may be called again; owners
        call ``self.metrics.close()`` at teardown)."""
        self._finish_ckpt()
        if self.profile is not None:
            self.profile.close()
        self.metrics.flush()
        return self.log
