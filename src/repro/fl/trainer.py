"""Host-side FL training driver: samples connectivity, streams per-client
batches, invokes the compiled round function, tracks metrics, evaluates.

This is the entry point the paper-reproduction experiments and the
examples use on CPU; the production launch path (``repro/launch``) wraps
the same round function in pjit with mesh shardings.  Prefer building it
declaratively through :func:`repro.fl.experiment.build_experiment` — the
constructor below is the assembled form.

Connectivity comes from a :class:`~repro.channel.ChannelProcess` — the
paper's i.i.d. model (the default, built from ``link_model``), bursty
Gilbert–Elliott chains, or waypoint mobility.  With an
:class:`~repro.channel.AdaptiveWeightSchedule` attached, the trainer no
longer assumes oracle link knowledge: it estimates ``(p, P, E)`` online
from the realized taus and re-runs COPT-alpha every K rounds, swapping
the fresh alpha into the (traced, so recompile-free) ``A`` argument of
the compiled round.

Aggregation is a pluggable :class:`~repro.strategies.AggregationStrategy`
(``strategy=`` accepts a registry name or an instance); stateful
strategies' carried state (e.g. the memory strategy's replay buffer)
lives on the trainer and threads through the compiled round.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import strategies as strategy_registry
from repro.channel.base import ChannelProcess, StaticChannel
from repro.channel.schedule import AdaptiveWeightSchedule
from repro.core import LinkModel, variance_S
from repro.core.flatten import flat_spec
from repro.data.pipeline import ClientDataset
from repro.fl.round import RoundConfig, make_round_fn
from repro.optim import Optimizer

Params = Any


@dataclasses.dataclass
class TrainLog:
    rounds: List[int] = dataclasses.field(default_factory=list)
    loss: List[float] = dataclasses.field(default_factory=list)
    eval_rounds: List[int] = dataclasses.field(default_factory=list)
    eval_metrics: List[Dict[str, float]] = dataclasses.field(default_factory=list)
    participation: List[float] = dataclasses.field(default_factory=list)
    # realized sum of scalar aggregation weights (E = 1 when unbiased);
    # its dispersion is the realized counterpart of the variance proxy S.
    # NaN for strategies with no scalar collapse (e.g. memory).
    weight_sums: List[float] = dataclasses.field(default_factory=list)
    # adaptive re-optimization events (empty without a schedule)
    reopt_rounds: List[int] = dataclasses.field(default_factory=list)
    est_p_err: List[float] = dataclasses.field(default_factory=list)
    S_est: List[float] = dataclasses.field(default_factory=list)
    S_true: List[float] = dataclasses.field(default_factory=list)

    def to_dict(self):
        return dataclasses.asdict(self)


class FLTrainer:
    """Orchestrates pluggable-strategy FL training over an intermittent
    network (ColRel, FedAvg baselines, multihop, memory, ...)."""

    def __init__(
        self,
        loss_fn: Callable,
        init_params: Params,
        link_model: Optional[LinkModel],
        A: np.ndarray,
        clients: Sequence[ClientDataset],
        client_opt: Optimizer,
        server_opt: Optimizer,
        *,
        local_steps: int = 8,
        strategy: "str | strategy_registry.AggregationStrategy | None" = None,
        aggregation: "str | strategy_registry.AggregationStrategy | None" = None,
        mode: str = "per_client",
        use_fused_kernel: bool = False,
        seed: int = 0,
        eval_fn: Optional[Callable[[Params], Dict[str, float]]] = None,
        channel: Optional[ChannelProcess] = None,
        adaptive: Optional[AdaptiveWeightSchedule] = None,
    ):
        if strategy is not None and aggregation is not None:
            raise ValueError("pass strategy= or aggregation=, not both")
        spec = strategy if strategy is not None else (
            aggregation if aggregation is not None else "colrel")
        self.strategy = strategy_registry.resolve(
            spec, fused_kernel=use_fused_kernel)
        if channel is None:
            if link_model is None:
                raise ValueError("provide link_model or channel")
            channel = StaticChannel(link_model, seed=seed)
        self.channel = channel
        self.adaptive = adaptive
        if adaptive is not None and not self.strategy.needs_A:
            raise ValueError(
                f"adaptive alpha re-optimization only affects strategies "
                f"that read A; {self.strategy.name!r} ignores it"
            )
        if adaptive is not None and self.strategy.calibration_tracks_A:
            raise ValueError(
                f"strategy {self.strategy.name!r} was calibrated against a "
                "fixed alpha; the adaptive schedule swaps alpha mid-run, "
                "which would silently stale the calibration — run it "
                "uncalibrated or without adaptive"
            )
        n = channel.n
        if link_model is not None and link_model.n != n:
            raise ValueError(f"link_model.n={link_model.n} != channel.n={n}")
        assert len(clients) == n, (len(clients), n)
        self.link_model = link_model if link_model is not None else channel.model_for_round(0)
        self.A = jnp.asarray(A, jnp.float32)
        self.clients = list(clients)
        self.params = init_params
        self.eval_fn = eval_fn
        rc = RoundConfig(
            n_clients=n, local_steps=local_steps, mode=mode,
            aggregation=self.strategy,
        )
        self.rc = rc
        self.server_opt = server_opt
        self.server_state = server_opt.init(init_params)
        self.agg_state = self.strategy.init_state(n, flat_spec(init_params).d)
        self._round_fn = jax.jit(make_round_fn(loss_fn, client_opt, server_opt, rc))
        self.log = TrainLog()

    # ------------------------------------------------------------------
    def _stack_batches(self) -> Dict[str, np.ndarray]:
        """(n_clients, T, B, ...) stacked local-step batches."""
        T = self.rc.local_steps
        per_client = []
        for c in self.clients:
            steps = [c.next_batch() for _ in range(T)]
            per_client.append({k: np.stack([s[k] for s in steps]) for k in steps[0]})
        out = {k: np.stack([pc[k] for pc in per_client]) for k in per_client[0]}
        if self.rc.mode == "weighted_grad":
            out = {k: v[:, 0] for k, v in out.items()}  # T==1 collapse
        return out

    def run(self, rounds: int, *, eval_every: int = 0, verbose: bool = False) -> TrainLog:
        start = self.log.rounds[-1] + 1 if self.log.rounds else 0
        for r in range(start, start + rounds):
            tau_up, tau_dd = self.channel.tau_for_round(r)
            batches = self._stack_batches()
            self.params, self.server_state, self.agg_state, metrics = self._round_fn(
                self.params,
                self.server_state,
                self.agg_state,
                jax.tree.map(jnp.asarray, batches),
                jnp.asarray(tau_up, jnp.float32),
                jnp.asarray(tau_dd, jnp.float32),
                self.A,
            )
            self.log.rounds.append(r)
            self.log.loss.append(float(metrics["loss"]))
            self.log.participation.append(float(metrics["participation"]))
            self.log.weight_sums.append(float(metrics["weight_sum"]))
            if self.adaptive is not None:
                A_new = self.adaptive.step(r, tau_up, tau_dd)
                if A_new is not None:
                    self.A = jnp.asarray(A_new, jnp.float32)
                    true_m = self.channel.model_for_round(r)
                    info = self.adaptive.events[-1]
                    self.log.reopt_rounds.append(r)
                    self.log.est_p_err.append(
                        self.adaptive.estimator.errors(true_m)["p"]
                    )
                    self.log.S_est.append(float(info["S_est"]))
                    self.log.S_true.append(float(variance_S(true_m, A_new)))
                    if verbose:
                        print(
                            f"  round {r+1:4d}  re-opt alpha: "
                            f"S_est={info['S_est']:.3f} "
                            f"S_true={self.log.S_true[-1]:.3f} "
                            f"p_err={self.log.est_p_err[-1]:.3f}"
                        )
            if eval_every and (r + 1) % eval_every == 0 and self.eval_fn is not None:
                em = self.eval_fn(self.params)
                self.log.eval_rounds.append(r)
                self.log.eval_metrics.append({k: float(v) for k, v in em.items()})
                if verbose:
                    print(f"  round {r+1:4d}  loss={self.log.loss[-1]:.4f}  " +
                          "  ".join(f"{k}={v:.4f}" for k, v in em.items()))
            elif verbose and (r + 1) % 10 == 0:
                print(f"  round {r+1:4d}  loss={self.log.loss[-1]:.4f}")
        return self.log
