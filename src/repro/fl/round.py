"""One federated round as a pure JAX function (jit / pjit compatible).

The round implements Algorithms 1 + 2 of the paper:
  1. every client runs ``T`` local SGD steps from the PS model (Alg. 1, 1-7),
  2. clients exchange updates over the sampled D2D links and each transmits
     a weighted consensus to the PS (Alg. 1, 8-11 / Eq. (3)),
  3. the PS applies whatever aggregation *strategy* the round was built
     with (the paper's ColRel, a FedAvg baseline, K-hop relaying, memory
     replay, or anything registered in ``repro.strategies``) and the
     server optimizer (global momentum in the paper's experiments).

Connectivity realizations ``tau_up (n,) / tau_dd (n, n)`` are *traced
inputs* so a single compiled round serves every round of training.
Strategy state (e.g. the memory strategy's replay buffer) threads
through the round as the ``agg_state`` pytree — shape-stable across
rounds, so tau/alpha swaps never recompile; stateless strategies carry
``()``.

Execution modes (DESIGN.md §3):
  * ``per_client``        — vmap over the client axis (client = mesh "data"
                            shard).  The one mode that materializes the
                            per-client update stack, so the only mode
                            open to non-scalar-collapsible strategies.
  * ``client_sequential`` — lax.scan over clients; peak memory is a single
    model copy regardless of n (for the 100B+ archs).  Mathematically
    identical; consumes the strategy's scalar collapse (a running
    weighted sum).
  * ``weighted_grad``     — the T=1 algebraic collapse: ColRel ==
    per-client-weighted data-parallel SGD, no per-client model copies.

Multi-round execution (DESIGN.md §9): :func:`make_scan_round_fn` wraps
the round body in a ``lax.scan`` over a leading K-round axis, so K
communication rounds run as one device program with a single host
round-trip — the chunked engine ``FLTrainer.run(chunk=K)`` and the
production launch path drive.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro import strategies as strategy_registry
from repro.core import flatten
from repro.core.aggregation import Aggregation
from repro.dist import constrain_grads, spmd_axis_name
from repro.optim import Optimizer
from repro.optim.base import global_norm
from repro.strategies.base import AggregationStrategy, ExecutionContext

Params = Any

StrategySpec = Union[Aggregation, str, AggregationStrategy]


@dataclasses.dataclass(frozen=True)
class RoundConfig:
    n_clients: int
    local_steps: int  # the paper's T
    mode: str = "per_client"  # per_client | client_sequential | weighted_grad
    # aggregation strategy: registry name, legacy Aggregation enum value,
    # or a constructed AggregationStrategy instance
    aggregation: StrategySpec = "colrel"
    use_flash: bool = False
    # Under pjit, pin the vmapped client axis to these mesh axes so each
    # client's divergent model copy lives on its own data shard.
    spmd_axes: Optional[tuple] = None
    # unroll the local-steps / client scans (dry-run cost probes)
    unroll: bool = False
    # DEPRECATED: forwards to the colrel strategy's fused="kernel"
    # execution option (strategies.get("colrel", fused="kernel")).
    use_fused_kernel: bool = False
    # dtype of the flattened (n, d) update stack ("float32" | "bfloat16");
    # accumulation is fp32 either way.
    flat_dtype: str = "float32"
    # d-axis tile of the fused kernel's grid
    fused_block_d: int = 2048
    # flat-dim threshold for segment-streaming aggregation (DESIGN.md
    # §14): at d >= segment_d the kernel-fused strategies stream per-leaf
    # (n, d_i) segments instead of materializing the monolithic (n, d)
    # stack; 0 keeps the monolithic path (the golden-pinned default).
    segment_d: int = 0

    def __post_init__(self):
        # fail at construction, not first trace; canonical_name does not
        # instantiate, so no deprecation warning fires twice
        name = strategy_registry.canonical_name(self.aggregation)
        if self.use_fused_kernel and name != "colrel":
            raise ValueError(
                "use_fused_kernel only applies to the colrel strategy "
                f"(got {self.aggregation}); it would be silently inert"
            )

    def resolve_strategy(self) -> AggregationStrategy:
        """The configured strategy instance (deprecated spellings warn)."""
        return strategy_registry.resolve(
            self.aggregation, fused_kernel=self.use_fused_kernel
        )

    def execution_context(self) -> ExecutionContext:
        return ExecutionContext(
            n_clients=self.n_clients,
            flat_dtype=jnp.dtype(self.flat_dtype),
            fused_block_d=self.fused_block_d,
            spmd_axes=self.spmd_axes,
            segment_d=self.segment_d,
        )


def _tree_sub(a: Params, b: Params) -> Params:
    return jax.tree.map(lambda x, y: (x.astype(jnp.float32) - y.astype(jnp.float32)), a, b)


def _local_sgd(loss_fn, client_opt: Optimizer, params: Params, batches: Params,
               unroll: bool = False):
    """T local SGD steps.  ``batches`` leaves have leading dim T."""

    def step(carry, batch):
        p, ostate = carry
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(p, batch)
        upd, ostate = client_opt.update(grads, ostate, p)
        p = jax.tree.map(lambda x, u: (x.astype(jnp.float32) + u).astype(x.dtype), p, upd)
        return (p, ostate), loss

    T = jax.tree.leaves(batches)[0].shape[0]
    (p_final, _), losses = jax.lax.scan(
        step, (params, client_opt.init(params)), batches, unroll=T if unroll else 1
    )
    return _tree_sub(p_final, params), jnp.mean(losses)


def make_round_fn(
    loss_fn: Callable,
    client_opt: Optimizer,
    server_opt: Optimizer,
    rc: RoundConfig,
    grad_shardings: Optional[Params] = None,
    telemetry: bool = False,
):
    """Returns round(params, server_state, agg_state, batches,
    tau_up, tau_dd, A) -> (params, server_state, agg_state, metrics).

    ``batches``: pytree with leaves shaped (n_clients, T, B, ...) for
    per_client/client_sequential, or (T=1 collapsed) (n_clients, B, ...)
    for weighted_grad.  ``agg_state`` is the strategy's carried state
    (``strategy.init_state(n, d)``; ``()`` for stateless strategies).

    ``telemetry=True`` wraps the body with the device-resident vector
    metrics (DESIGN.md §11): the signature grows one trailing ``streak``
    carry — ``round(params, server_state, agg_state, batches, tau_up,
    tau_dd, A, streak) -> (params, server_state, agg_state, streak,
    metrics)`` — and ``metrics`` additionally carries per-client
    ``client_participation`` / ``client_uplink_bits`` / ``outage_streak``
    ``(n,)`` vectors plus the ``weight_drift`` scalar.  The body itself
    is untouched, so trajectories and scalar metrics stay bitwise
    identical with telemetry on or off.
    """
    strategy = rc.resolve_strategy()
    ctx = rc.execution_context()
    if rc.mode != "per_client" and (strategy.stateful
                                    or not strategy.scalar_collapsible):
        # non-per_client modes consume only the scalar collapse and never
        # call aggregate/aggregate_tree, so a stateful strategy's carried
        # state would silently freeze at init_state
        raise ValueError(
            f"strategy {strategy.name!r} needs the per_client mode: only it "
            f"materializes the update stack that stateful or "
            f"non-scalar-collapsible strategies require (got mode={rc.mode!r})"
        )

    def client_delta(params, client_batches):
        return _local_sgd(loss_fn, client_opt, params, client_batches, unroll=rc.unroll)

    def round_fn(params, server_state, agg_state, batches, tau_up, tau_dd, A):
        # Realized scalar weights this round (for COLREL: the exact fused
        # collapse w_j = sum_i tau_i tau_ji alpha_ij, scaled 1/n).  Used by
        # the scalar-weight execution branches below and logged as
        # ``weight_sum`` — under the unbiasedness condition (5) its
        # expectation is 1, so its round-to-round dispersion is the
        # realized counterpart of the variance proxy S that COPT-alpha
        # (and the adaptive re-optimization schedule) minimize.  None for
        # strategies that do not collapse (their weight_sum logs as NaN).
        w_scalar = strategy.weights(tau_up, tau_dd, A)
        if rc.mode == "per_client":
            spmd = spmd_axis_name(rc.spmd_axes)
            deltas, losses = jax.vmap(
                client_delta, in_axes=(None, 0), spmd_axis_name=spmd
            )(params, batches)
            gdelta, agg_state = strategy.aggregate_tree(
                deltas, tau_up, tau_dd, A, agg_state, ctx
            )
            mean_loss = jnp.mean(losses)

        elif rc.mode == "client_sequential":
            w = w_scalar

            def body(carry, inp):
                acc, loss_acc = carry
                wi, client_batches = inp
                delta, loss = client_delta(params, client_batches)
                acc = jax.tree.map(lambda a, d: a + wi * d, acc, delta)
                return (acc, loss_acc + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gdelta, loss_sum), _ = jax.lax.scan(
                body, (zeros, 0.0), (w, batches),
                unroll=rc.n_clients if rc.unroll else 1,
            )
            mean_loss = loss_sum / rc.n_clients

        elif rc.mode == "weighted_grad":
            # T = 1 collapse: one backward pass over all clients' batches with
            # per-client loss weights — ColRel as weighted data parallelism.
            w = w_scalar
            spmd = spmd_axis_name(rc.spmd_axes)

            def weighted_loss(p):
                def per_client(batch):
                    return loss_fn(p, batch)[0]

                losses = jax.vmap(per_client, spmd_axis_name=spmd)(batches)  # (n,)
                return jnp.sum(w * losses), losses

            (_, losses), grads = jax.value_and_grad(weighted_loss, has_aux=True)(params)
            grads = constrain_grads(grads, grad_shardings)
            upd, _ = client_opt.update(grads, client_opt.init(params), params)
            gdelta = jax.tree.map(lambda u: u.astype(jnp.float32), upd)
            mean_loss = jnp.mean(losses)

        elif rc.mode == "weighted_flat":
            # Beyond-paper (exact) flattening of the T=1 round: instead of a
            # per-client vmap (which multiplies backward intermediates by a
            # lane factor), fold the client dim into the batch and weight
            # each SEQUENCE by w_{client(seq)} / B inside the loss.  Same
            # gradient as weighted_grad; one flat data-parallel backward.
            w = w_scalar
            n_total = jax.tree.leaves(batches)[0].shape[0]
            B_per = n_total // rc.n_clients
            seq_w = jnp.repeat(w, B_per) / B_per

            def flat_loss(p):
                return loss_fn(p, {**batches, "ce_weight": seq_w})[0]

            loss_val, grads = jax.value_and_grad(flat_loss)(params)
            # pin the gradient tree to the params' fully-sharded layout
            # (otherwise the partitioner may materialize it replicated
            # over the data axes — 100s of GB for the 100B+ archs)
            grads = constrain_grads(grads, grad_shardings)
            upd, _ = client_opt.update(grads, client_opt.init(params), params)
            gdelta = jax.tree.map(lambda u: u.astype(jnp.float32), upd)
            mean_loss = loss_val
        else:
            raise ValueError(f"unknown mode {rc.mode}")

        # PS applies the round delta through the server optimizer by feeding
        # the negative delta as a pseudo-gradient (FedOpt convention); with
        # sgd_momentum(lr=1, beta) this is exactly the paper's PS momentum.
        pseudo_grads = jax.tree.map(lambda d: -d, gdelta)
        upd, server_state = server_opt.update(pseudo_grads, server_state, params)
        new_params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, upd
        )
        participation = jnp.sum(tau_up.astype(jnp.float32))
        # Wire-format-aware uplink accounting: bits put on air by the
        # clients whose uplink delivered this round, priced at the active
        # codec's per-coordinate wire cost (32 bits/coord for uncoded f32;
        # the quantized strategy reports its codec descriptor).  d and the
        # rate are static, so this folds to one multiply in the compiled
        # round.
        d_flat = flatten.flat_spec(params).d
        bits_per_client = jnp.float32(
            d_flat * strategy.wire_bits_per_coord(d_flat))
        metrics = {
            "loss": mean_loss,
            "delta_norm": global_norm(gdelta),
            "participation": participation,
            "uplink_bits": participation * bits_per_client,
            "weight_sum": (jnp.sum(w_scalar) if w_scalar is not None
                           else jnp.float32(jnp.nan)),
        }
        return new_params, server_state, agg_state, metrics

    if not telemetry:
        return round_fn
    from repro.telemetry.device import instrument_round_fn

    # the wire rate is static per strategy (a function of the flat dim,
    # which the wrapper reads off params at trace time)
    return instrument_round_fn(round_fn, strategy.wire_bits_per_coord)


def make_scan_round_fn(
    loss_fn: Callable,
    client_opt: Optimizer,
    server_opt: Optimizer,
    rc: RoundConfig,
    grad_shardings: Optional[Params] = None,
    channel_sampler: Optional[Callable] = None,
    telemetry: bool = False,
):
    """The chunked multi-round engine: K rounds compiled into one program.

    Wraps the :func:`make_round_fn` body in a single ``lax.scan`` over a
    leading K-round axis, so a whole chunk of communication rounds runs
    on device with one host round-trip.  The scan carry is
    ``(params, server_state, agg_state)`` (plus ``(channel_state, rng)``
    with an in-scan sampler); per-round ``loss / participation /
    uplink_bits / weight_sum / delta_norm`` metrics come back stacked
    with a leading ``(K,)`` axis for bulk host-side logging.

    Two tau sources:

    * default — pre-generated **device-resident channel traces**:
      ``scan(params, server_state, agg_state, batches, tau_up, tau_dd,
      A)`` with ``tau_up (K, n)`` / ``tau_dd (K, n, n)`` scanned as
      per-round inputs (``ChannelProcess.trace`` produces them).  Since
      the body is the very ``round_fn`` the per-round loop jits, the
      K-round trajectory is *bitwise identical* to K sequential calls on
      the same inputs (asserted in ``tests/test_scan_engine.py``).
    * ``channel_sampler=(...)`` — an in-scan sampler ``sample_fn(state,
      key) -> (tau_up, tau_dd, state)`` (see
      ``ChannelProcess.scan_sampler``): connectivity is drawn *inside*
      the compiled program, no tau tensors ever materialize on host.
      Signature becomes ``scan(params, server_state, agg_state, batches,
      channel_state, rng, A) -> (params, server_state, agg_state,
      channel_state, rng, metrics)``.

    ``batches`` leaves carry a leading K axis on top of the per-round
    layout of the configured mode: ``(K, n, T, B, ...)`` for
    per_client / client_sequential, ``(K, n, B, ...)`` for
    weighted_grad.  K is baked into the trace via the input shapes —
    one compile per distinct chunk size, reused across chunks.

    ``telemetry=True`` (DESIGN.md §11) threads the ``(n,)`` int32
    outage-streak age vector through the scan carry — next to the
    channel gate state in the sampled variant — and stacks the vector
    metrics ``(K, n)``: both signatures grow one trailing ``streak``
    input and a ``streak`` result before ``metrics``, and nothing
    telemetry-related leaves the device mid-scan.
    """
    round_fn = make_round_fn(loss_fn, client_opt, server_opt, rc,
                             grad_shardings=grad_shardings,
                             telemetry=telemetry)
    return _scan_engine(round_fn, channel_sampler, telemetry)


def _scan_engine(round_fn, channel_sampler, telemetry):
    """Wrap a compiled round body in the K-round ``lax.scan`` closures.

    Shared by :func:`make_scan_round_fn` and
    :func:`make_async_scan_round_fn` — the async carry (age vector +
    staging buffer) lives inside ``agg_state``, so the scan signatures
    are identical for both.
    """
    if channel_sampler is None:
        if telemetry:

            def scan_traced_tel(params, server_state, agg_state, batches,
                                tau_up, tau_dd, A, streak):
                def body(carry, xs):
                    p, ss, ag, st = carry
                    b, tu, td = xs
                    p, ss, ag, st, metrics = round_fn(p, ss, ag, b, tu, td,
                                                      A, st)
                    return (p, ss, ag, st), metrics

                (params, server_state, agg_state, streak), metrics = (
                    jax.lax.scan(
                        body, (params, server_state, agg_state, streak),
                        (batches, tau_up, tau_dd),
                    )
                )
                return params, server_state, agg_state, streak, metrics

            return scan_traced_tel

        def scan_traced(params, server_state, agg_state, batches,
                        tau_up, tau_dd, A):
            def body(carry, xs):
                p, ss, ag = carry
                b, tu, td = xs
                p, ss, ag, metrics = round_fn(p, ss, ag, b, tu, td, A)
                return (p, ss, ag), metrics

            (params, server_state, agg_state), metrics = jax.lax.scan(
                body, (params, server_state, agg_state),
                (batches, tau_up, tau_dd),
            )
            return params, server_state, agg_state, metrics

        return scan_traced

    sample_fn = channel_sampler

    if telemetry:

        def scan_sampled_tel(params, server_state, agg_state, batches,
                             channel_state, rng, A, streak):
            def body(carry, b):
                p, ss, ag, cs, key, st = carry
                key, sub = jax.random.split(key)
                tu, td, cs = sample_fn(cs, sub)
                p, ss, ag, st, metrics = round_fn(p, ss, ag, b, tu, td, A, st)
                return (p, ss, ag, cs, key, st), metrics

            (params, server_state, agg_state, channel_state, rng, streak), \
                metrics = jax.lax.scan(
                    body,
                    (params, server_state, agg_state, channel_state, rng,
                     streak),
                    batches,
                )
            return (params, server_state, agg_state, channel_state, rng,
                    streak, metrics)

        return scan_sampled_tel

    def scan_sampled(params, server_state, agg_state, batches,
                     channel_state, rng, A):
        def body(carry, b):
            p, ss, ag, cs, key = carry
            key, sub = jax.random.split(key)
            tu, td, cs = sample_fn(cs, sub)
            p, ss, ag, metrics = round_fn(p, ss, ag, b, tu, td, A)
            return (p, ss, ag, cs, key), metrics

        (params, server_state, agg_state, channel_state, rng), metrics = (
            jax.lax.scan(
                body,
                (params, server_state, agg_state, channel_state, rng),
                batches,
            )
        )
        return params, server_state, agg_state, channel_state, rng, metrics

    return scan_sampled


def make_async_round_fn(
    loss_fn: Callable,
    client_opt: Optimizer,
    server_opt: Optimizer,
    rc: RoundConfig,
    grad_shardings: Optional[Params] = None,
    telemetry: bool = False,
):
    """Async execution mode: staleness-weighted opportunistic relaying.

    Same signature and carry structure as :func:`make_round_fn` — the
    async state (the traced ``(n,)`` int32 age vector and the ``(n, d)``
    staging buffer, DESIGN.md §13) lives *inside* ``agg_state``, where
    the strategy's :meth:`~repro.strategies.AsyncRelayStrategy.advance`
    recurrence updates it every round.  On top of the base metrics the
    round reports the realized staleness profile:

    * ``mean_age`` / ``max_age`` — the post-delivery age vector's mean
      and max (rounds since each client's update last reached the PS),
    * ``stale_frac`` — fraction of clients aggregating a stale update.

    ``rc.aggregation`` must be an async strategy (``async_colrel`` or an
    :class:`~repro.strategies.AsyncRelayStrategy` wrapping the desired
    inner scheme); building the async round over a sync strategy is
    refused rather than silently running sync semantics.
    """
    strategy = rc.resolve_strategy()
    if not getattr(strategy, "is_async", False):
        raise ValueError(
            f"make_async_round_fn needs an async strategy (e.g. "
            f"'async_colrel'), got {strategy.name!r}; wrap it in "
            f"AsyncRelayStrategy or use FLTrainer(mode='async')"
        )
    base = make_round_fn(loss_fn, client_opt, server_opt, rc,
                         grad_shardings=grad_shardings, telemetry=False)

    def round_fn(params, server_state, agg_state, batches, tau_up, tau_dd, A):
        params, server_state, agg_state, metrics = base(
            params, server_state, agg_state, batches, tau_up, tau_dd, A)
        age = agg_state["age"].astype(jnp.float32)
        metrics = dict(
            metrics,
            mean_age=jnp.mean(age),
            max_age=jnp.max(age),
            stale_frac=jnp.mean((age > 0).astype(jnp.float32)),
        )
        return params, server_state, agg_state, metrics

    if not telemetry:
        return round_fn
    from repro.telemetry.device import instrument_round_fn

    return instrument_round_fn(round_fn, strategy.wire_bits_per_coord)


def make_async_scan_round_fn(
    loss_fn: Callable,
    client_opt: Optimizer,
    server_opt: Optimizer,
    rc: RoundConfig,
    grad_shardings: Optional[Params] = None,
    channel_sampler: Optional[Callable] = None,
    telemetry: bool = False,
):
    """Chunked async engine: K staleness-weighted rounds in one scan.

    Identical scan signatures to :func:`make_scan_round_fn` (traced and
    in-scan-sampled variants, with or without telemetry) — the age
    vector and staging buffer ride the existing ``agg_state`` slot of
    the scan carry, so chunking, no-trace sampling, checkpoint/resume
    and the telemetry streak all compose with async execution for free.
    The per-round ``mean_age`` / ``max_age`` / ``stale_frac`` metrics
    come back stacked ``(K,)`` like every other scalar stream.
    """
    round_fn = make_async_round_fn(loss_fn, client_opt, server_opt, rc,
                                   grad_shardings=grad_shardings,
                                   telemetry=telemetry)
    return _scan_engine(round_fn, channel_sampler, telemetry)
