"""Declarative experiment assembly: one spec -> a ready-to-run trainer.

Before this module, every entry point (``examples/train_colrel_cifar``,
``benchmarks/common``, ``benchmarks/channel_bench``, ad-hoc tests)
re-implemented the same wiring: pick a topology, wrap it in a channel,
optimize or default the relay weights, partition data, build the model
and optimizers, then thread a dozen kwargs into ``FLTrainer``.
:class:`ExperimentSpec` names each of those choices once and
:func:`build_experiment` performs the assembly — including the
strategy-registry resolution, host-side strategy calibration (e.g. the
multihop unbiasedness correction) and the adaptive-alpha schedule.

    spec = ExperimentSpec(model="cifar_cnn", topology="fig2b",
                          strategy="multihop",
                          strategy_options={"hops": 2},
                          channel="markov", rounds=200)
    exp = build_experiment(spec)
    exp.run(verbose=True)

Model kinds:

* ``cifar_cnn`` / ``cifar_cnn_full`` — the paper's CIFAR-10 experiment
  (synthetic-CIFAR data, reduced or paper-width ResNet-20 CNN).
* ``quadratic`` — the strongly-convex heterogeneous quadratic used by
  the theory checks and benches (fast on CPU; exact optima known).
"""

from __future__ import annotations

import dataclasses
import pathlib
from typing import Any, Callable, Dict, Mapping, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import strategies as strategy_registry
from repro.channel import AdaptiveConfig, AdaptiveWeightSchedule
from repro.configs import colrel_paper, make_channel
from repro.core import (
    LinkModel,
    OptResult,
    fedavg_weights,
    importance_weights,
    optimize_weights,
    topology,
)
from repro.data import (
    partition_iid,
    partition_sort_and_partition,
    quadratic_problem,
    synthetic_cifar,
)
from repro.data.pipeline import ClientDataset, make_federated_clients
from repro.fl.trainer import FLTrainer, TrainLog
from repro.models import build
from repro.optim import sgd, sgd_momentum
from repro.telemetry import (
    CsvSummarySink,
    JsonlSink,
    MetricsLogger,
    ProfileWindow,
    RunManifest,
)

__all__ = ["TOPOLOGIES", "ExperimentSpec", "Experiment", "build_experiment"]

# Named topology factories (the paper's figures + synthetic layouts).
# Open like the strategy registry: assignment is registration.
TOPOLOGIES: Dict[str, Callable[[], LinkModel]] = {
    "fig2a": lambda: topology.paper_fig2a(),
    "fig2b": lambda: topology.paper_fig2b(),
    "mmwave_int": lambda: topology.paper_mmwave_layout(d2d_mode="intermittent"),
    "mmwave_perm": lambda: topology.paper_mmwave_layout(d2d_mode="permanent"),
    "no_collab": lambda: topology.no_collaboration(10, 0.3),
}


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """Everything that defines one federated experiment, declaratively.

    Task:
        model: ``"cifar_cnn"`` (reduced ResNet-20 on synthetic CIFAR),
            ``"cifar_cnn_full"`` (paper width), or ``"quadratic"`` (the
            strongly-convex theory-check task; fast on CPU).
        topology: named link topology (a key of :data:`TOPOLOGIES` —
            ``"fig2a"``, ``"fig2b"``, ``"mmwave_int"``, ...) or an
            explicit :class:`~repro.core.LinkModel`.
        non_iid_s: 0 = IID data; otherwise the number of
            sort-and-partition label shards per client (the paper's s).
        data_size / eval_size: synthetic train / eval set sizes.

    Protocol:
        strategy: aggregation scheme — a registry name
            (``strategies.available()``) or a constructed
            :class:`~repro.strategies.AggregationStrategy`.
        strategy_options: constructor kwargs for a named strategy,
            e.g. ``{"hops": 2}`` for multihop or ``{"codec": "int8",
            "codec_options": {"bits": 4}}`` for quantized.
        alpha: relay weight matrix.  ``"auto"`` = COPT-alpha when the
            strategy reads A and no adaptive schedule is attached, else
            identity-scaled FedAvg weights; ``"copt"`` / ``"fedavg"`` /
            ``"importance"`` force one; an explicit ``(n, n)`` array
            passes through.
        copt_sweeps: Gauss–Seidel sweeps for each COPT-alpha phase.
        mode: round execution mode (``"per_client"``,
            ``"client_sequential"``, ``"weighted_grad"``; DESIGN.md §3)
            — or ``"async"`` (DESIGN.md §13): wrap the strategy in
            staleness-weighted opportunistic relaying (the age vector +
            staging buffer ride the scan carry) and run it through the
            per_client engine.
        async_options: :class:`~repro.strategies.AsyncRelayStrategy`
            knobs for ``mode="async"`` — e.g. ``{"gamma": 0.8,
            "opportunistic": False}``.  Ignored unless the strategy
            needs wrapping (pass an ``async_*`` strategy spec to set
            them directly).
        local_steps: the paper's T (None = model-kind default).
        rounds: default round budget for :meth:`Experiment.run`.
        chunk: rounds per compiled scan chunk (DESIGN.md §9) — ``K > 1``
            runs K rounds as one device program with host syncs only at
            chunk boundaries, bitwise-identical to the per-round loop;
            must divide ``reopt_every`` / ``eval_every`` cadences (the
            trainer falls back to per-round otherwise).

    Channel:
        channel: dynamics preset name (``repro/configs/channels.py``:
            ``"static"``, ``"markov"``, ``"mobility"``, ...).
        adaptive: True = drop oracle link knowledge; estimate links
            online and re-run COPT-alpha periodically.
        reopt_every: adaptive re-optimization cadence in rounds.

    Optimization (None = model-kind / paper defaults):
        lr / weight_decay: client SGD hyperparameters.
        server_momentum: PS momentum (the paper's global momentum).
        batch_size: per-client batch size.
        seed: single seed for data, partitioning, channel and model init.

    Observability (DESIGN.md §11):
        telemetry: compile the instrumented round — per-client
            participation / bits-on-air vectors and the device-resident
            outage-streak carry (implied by ``metrics_dir``).
        metrics_dir: directory receiving ``events.jsonl`` (structured
            event stream), ``rounds.csv`` (per-round scalar table),
            ``manifest.json`` (run provenance: config digest, strategy /
            channel / codec, backend, git SHA) and — at
            :meth:`Experiment.close` — ``vectors.npz`` with the stacked
            ``(R, n)`` per-client metric histories.
        profile_dir / profile_start / profile_rounds: opt-in
            ``jax.profiler`` trace over rounds ``[profile_start,
            profile_start + profile_rounds)``.
        log_every: print a cumulative rounds/sec line every N rounds.

    Checkpointing (DESIGN.md §12):
        ckpt_dir: directory receiving ``ckpt_NNNNNNNN.msgpack`` run-state
            snapshots (async, sha256-committed).  None disables.
        ckpt_every: checkpoint cadence in rounds (must be a multiple of
            ``chunk``); 0 = a single final checkpoint at run end.
        ckpt_keep: committed checkpoints retained (keep-last-k GC).
        resume_from: checkpoint file — or directory, meaning its latest
            committed step — to restore before the first round.  Sinks
            open in append mode and the manifest records ``resumed_from``.
    """

    # -- task ----------------------------------------------------------
    model: str = "cifar_cnn"  # cifar_cnn | cifar_cnn_full | quadratic
    topology: Union[str, LinkModel] = "fig2b"
    non_iid_s: int = 0  # 0 = IID; else sort-and-partition shards per client
    data_size: int = 10000
    eval_size: int = 2000
    # -- protocol ------------------------------------------------------
    strategy: Union[str, strategy_registry.AggregationStrategy] = "colrel"
    strategy_options: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    # relay weight matrix: "auto" (copt when the strategy reads A and no
    # adaptive schedule, else identity), "copt", "fedavg", "importance",
    # or an explicit (n, n) array
    alpha: Union[str, np.ndarray] = "auto"
    copt_sweeps: int = 30
    mode: str = "per_client"
    # AsyncRelayStrategy kwargs for mode="async" (gamma, opportunistic)
    async_options: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    local_steps: Optional[int] = None  # None -> model-kind default
    rounds: int = 200
    chunk: int = 1  # rounds per compiled scan chunk (1 = per-round loop)
    # -- large-d engine (DESIGN.md §14) --------------------------------
    # d threshold for segment-streaming aggregation (0 = monolithic
    # stack); carry-buffer donation keeps one live (n, d) generation
    segment_d: int = 0
    donate: bool = True
    # -- channel -------------------------------------------------------
    channel: str = "static"  # preset name (repro/configs/channels.py)
    adaptive: bool = False   # online link estimation + periodic re-opt
    reopt_every: int = 50
    # -- optimization (None -> model-kind / paper defaults) ------------
    lr: Optional[float] = None
    weight_decay: Optional[float] = None
    server_momentum: Optional[float] = None
    batch_size: Optional[int] = None
    seed: int = 0
    # -- observability (DESIGN.md §11) ---------------------------------
    telemetry: bool = False        # device-resident vector metrics
    metrics_dir: Optional[str] = None   # events.jsonl/rounds.csv/manifest
    profile_dir: Optional[str] = None   # jax.profiler trace target
    profile_start: int = 0
    profile_rounds: int = 4
    log_every: int = 0             # stderr throughput cadence (0 = off)
    # -- checkpointing (DESIGN.md §12) ----------------------------------
    ckpt_dir: Optional[str] = None   # async checkpoint target (None = off)
    ckpt_every: int = 0              # cadence in rounds (0 = final-only)
    ckpt_keep: int = 3               # committed checkpoints retained
    resume_from: Optional[str] = None  # checkpoint file/dir to restore

    def replace(self, **kw) -> "ExperimentSpec":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass
class Experiment:
    """A built experiment: the trainer plus the assembly provenance."""

    spec: ExperimentSpec
    trainer: FLTrainer
    link_model: LinkModel
    A: np.ndarray
    strategy: strategy_registry.AggregationStrategy
    copt_result: Optional[OptResult] = None  # set when alpha came from COPT
    manifest: Optional[RunManifest] = None   # written when metrics_dir is set

    @property
    def log(self) -> TrainLog:
        return self.trainer.log

    @property
    def params(self):
        return self.trainer.params

    def run(self, rounds: Optional[int] = None, *, chunk: Optional[int] = None,
            eval_every: int = 0, verbose: bool = False,
            no_trace: bool = False,
            resume_from: Optional[str] = None) -> TrainLog:
        resume = resume_from if resume_from is not None else self.spec.resume_from
        return self.trainer.run(rounds if rounds is not None else self.spec.rounds,
                                chunk=chunk if chunk is not None else self.spec.chunk,
                                eval_every=eval_every, verbose=verbose,
                                no_trace=no_trace,
                                log_every=self.spec.log_every,
                                ckpt_dir=self.spec.ckpt_dir,
                                ckpt_every=self.spec.ckpt_every,
                                ckpt_keep=self.spec.ckpt_keep,
                                resume_from=resume)

    def close(self) -> None:
        """Finalize telemetry: per-client summary event, sink flush, and
        (with a ``metrics_dir``) the stacked vector histories as
        ``vectors.npz``.  Safe to call without telemetry; idempotent
        enough for teardown paths."""
        if self.spec.metrics_dir is not None:
            self.trainer.metrics.save_vectors(
                pathlib.Path(self.spec.metrics_dir) / "vectors.npz")
        self.trainer.metrics.close()


# ---------------------------------------------------------------------------
# assembly pieces
# ---------------------------------------------------------------------------


def _resolve_topology(spec: ExperimentSpec) -> LinkModel:
    if isinstance(spec.topology, LinkModel):
        return spec.topology
    try:
        return TOPOLOGIES[spec.topology]()
    except KeyError:
        raise KeyError(
            f"unknown topology {spec.topology!r}; have {sorted(TOPOLOGIES)}"
        ) from None


def _resolve_alpha(spec: ExperimentSpec, model: LinkModel,
                   strategy) -> "tuple[np.ndarray, Optional[OptResult]]":
    alpha = spec.alpha
    if isinstance(alpha, str):
        if alpha == "auto":
            # adaptive runs start blind (identity) and let re-opt take
            # over; strategies that ignore A get identity too
            alpha = "copt" if (strategy.needs_A and not spec.adaptive) else "fedavg"
        if alpha == "copt":
            res = optimize_weights(model, sweeps=spec.copt_sweeps,
                                   fine_tune_sweeps=spec.copt_sweeps)
            return res.A, res
        if alpha == "fedavg":
            return fedavg_weights(model.n), None
        if alpha == "importance":
            return importance_weights(model), None
        raise ValueError(f"unknown alpha spec {alpha!r}")
    return np.asarray(alpha, np.float64), None


def _adaptive_schedule(spec: ExperimentSpec, n: int) -> Optional[AdaptiveWeightSchedule]:
    if not spec.adaptive:
        return None
    return AdaptiveWeightSchedule(
        n,
        AdaptiveConfig(
            every=spec.reopt_every,
            warmup=min(spec.reopt_every, 20),
            # forget old evidence under drifting geometry
            decay=0.995 if str(spec.channel).startswith("mobility") else 1.0,
            prune_below=0.02,
        ),
    )


def _build_cifar(spec: ExperimentSpec, n: int):
    """(loss_fn, init_params, clients, client_opt, server_opt,
    local_steps, eval_fn) for the CIFAR CNN kinds."""
    setup = (colrel_paper.full() if spec.model == "cifar_cnn_full"
             else colrel_paper.reduced())
    batch_size = setup.batch_size if spec.batch_size is None else spec.batch_size
    images, labels = synthetic_cifar(n=spec.data_size, seed=spec.seed + 1)
    ev_img, ev_lab = synthetic_cifar(n=spec.eval_size, seed=spec.seed + 2)
    if spec.non_iid_s:
        parts = partition_sort_and_partition(labels, n, s=spec.non_iid_s,
                                             seed=spec.seed)
    else:
        parts = partition_iid(len(labels), n, seed=spec.seed)
    clients = make_federated_clients({"images": images, "labels": labels},
                                     parts, batch_size, seed=spec.seed)
    bundle = build(setup.cnn)

    @jax.jit
    def eval_fn(params):
        _, m = bundle.loss_fn(params, {"images": ev_img, "labels": ev_lab})
        return m

    return (
        bundle.loss_fn,
        bundle.init(jax.random.PRNGKey(spec.seed)),
        clients,
        sgd(setup.lr if spec.lr is None else spec.lr,
            weight_decay=setup.weight_decay if spec.weight_decay is None
            else spec.weight_decay),
        sgd_momentum(1.0, beta=setup.server_momentum
                     if spec.server_momentum is None else spec.server_momentum),
        setup.local_steps if spec.local_steps is None else spec.local_steps,
        eval_fn,
    )


def _build_quadratic(spec: ExperimentSpec, n: int):
    """Strongly-convex heterogeneous quadratic (the theory-check task)."""
    dim = 16
    prob = quadratic_problem(n, dim, mu=1.0, L=8.0, hetero=1.0, seed=spec.seed)
    H = jnp.asarray(prob["H"], jnp.float32)
    x_star = jnp.asarray(prob["x_star"], jnp.float32)

    def loss_fn(params, batch):
        x = params["x"]
        d = x - batch["center"][0]
        return 0.5 * d @ (H @ d) + 0.3 * batch["noise"][0] @ x, {}

    clients = []
    for i in range(n):
        c = prob["centers"][i].astype(np.float32)
        pool = np.random.default_rng(50 + i).normal(
            size=(2048, dim)).astype(np.float32)
        clients.append(ClientDataset(
            {"center": np.tile(c, (2048, 1)), "noise": pool},
            batch_size=1 if spec.batch_size is None else spec.batch_size,
            seed=spec.seed + i))

    def eval_fn(params):
        return {"dist2": float(jnp.sum((params["x"] - x_star) ** 2))}

    return (
        loss_fn,
        {"x": jnp.zeros(dim, jnp.float32)},
        clients,
        sgd(spec.lr if spec.lr is not None else 0.02),
        sgd_momentum(1.0, beta=spec.server_momentum
                     if spec.server_momentum is not None else 0.0),
        2 if spec.local_steps is None else spec.local_steps,
        eval_fn,
    )


_MODEL_BUILDERS = {
    "cifar_cnn": _build_cifar,
    "cifar_cnn_full": _build_cifar,
    "quadratic": _build_quadratic,
}


def build_experiment(spec: ExperimentSpec) -> Experiment:
    """Assemble model/data/topology/channel/strategy/optimizers from one
    spec.  Pure host-side wiring — nothing is compiled until ``run``."""
    if spec.model not in _MODEL_BUILDERS:
        raise KeyError(
            f"unknown model kind {spec.model!r}; have {sorted(_MODEL_BUILDERS)}"
        )
    link_model = _resolve_topology(spec)
    channel = make_channel(spec.channel, link_model, seed=spec.seed)
    # mobility derives its own (drifting) geometry; round-0 model otherwise
    # equals the chosen topology (markov preserves its marginals exactly)
    init_model = channel.model_for_round(0)
    n = init_model.n

    strategy = strategy_registry.resolve(spec.strategy, **dict(spec.strategy_options))
    if spec.adaptive and not strategy.needs_A:
        raise ValueError(
            f"adaptive alpha re-optimization only affects strategies that "
            f"read A; {strategy.name!r} ignores it"
        )
    A, copt_result = _resolve_alpha(spec, init_model, strategy)
    # host-side strategy calibration against the link statistics (e.g.
    # the multihop K-hop unbiasedness correction); no-op by default.
    # Skipped under the adaptive schedule: alpha starts blind and is
    # re-optimized mid-run, so a correction against the start alpha
    # would be stale from the first re-opt (FLTrainer rejects that).
    if not spec.adaptive:
        strategy = strategy.calibrate(init_model, A)

    loss_fn, init_params, clients, client_opt, server_opt, local_steps, eval_fn = (
        _MODEL_BUILDERS[spec.model](spec, n)
    )
    # observability wiring: a metrics_dir attaches the JSONL / CSV sinks
    # and writes the provenance manifest up front (so even a crashed run
    # is interpretable); it also implies the device tier.
    telemetry = spec.telemetry or spec.metrics_dir is not None
    metrics_logger = None
    manifest = None
    if spec.metrics_dir is not None:
        mdir = pathlib.Path(spec.metrics_dir)
        resuming = spec.resume_from is not None
        metrics_logger = MetricsLogger([
            JsonlSink(mdir / "events.jsonl", resume=resuming),
            CsvSummarySink(mdir / "rounds.csv", resume=resuming),
        ])
        codec = getattr(strategy, "codec", None)
        manifest = RunManifest.collect(
            dataclasses.asdict(spec),
            strategy=strategy.name,
            channel=spec.channel,
            codec=getattr(codec, "name", None),
            n_clients=n,
            mode=spec.mode,
            local_steps=local_steps,
            resumed_from=spec.resume_from,
        )
        manifest.write(mdir)
    profile = None
    if spec.profile_dir is not None:
        profile = ProfileWindow(spec.profile_dir, start=spec.profile_start,
                                rounds=spec.profile_rounds)
    trainer = FLTrainer(
        loss_fn, init_params, init_model, A, clients, client_opt, server_opt,
        local_steps=local_steps, strategy=strategy, mode=spec.mode,
        async_options=dict(spec.async_options) or None,
        donate=spec.donate, segment_d=spec.segment_d,
        seed=spec.seed, eval_fn=eval_fn, channel=channel,
        adaptive=_adaptive_schedule(spec, n),
        telemetry=telemetry, metrics=metrics_logger, profile=profile,
    )
    return Experiment(
        spec=spec, trainer=trainer, link_model=init_model,
        A=np.asarray(A), strategy=strategy, copt_result=copt_result,
        manifest=manifest,
    )
