"""Back-compat shim: mesh builders now live in ``repro.dist.mesh``.

The dist package is the canonical home for the mesh topology (it is
imported by model code via ``repro.dist.constraints`` and must stay free
of launch-layer dependencies); launch-side callers keep importing from
here unchanged.
"""

from repro.dist.mesh import (  # noqa: F401
    client_axes,
    client_axis_spec,
    make_debug_mesh,
    make_production_mesh,
    n_clients,
)

__all__ = ["client_axes", "client_axis_spec", "make_debug_mesh",
           "make_production_mesh", "n_clients"]
