"""Production FL training launcher.

Runs ColRel federated training of any assigned architecture on whatever
mesh the host provides: on a TPU pod this builds the production mesh and
pjits the round function with the sharding rules; on this CPU container
it runs the same code single-device with the reduced (smoke) config —
the end-to-end driver exercised in CI.

Connectivity is served by a :class:`~repro.channel.ChannelProcess`
(``--channel`` selects any preset from ``repro/configs/channels.py`` —
static i.i.d., bursty Gilbert–Elliott, mobility), and ``--chunk K``
switches to the compiled multi-round scan engine: K rounds per device
program, channel taus delivered as one bulk trace per chunk, metrics
synced to the host once per chunk (DESIGN.md §9).  ``--no-trace`` goes
one further: connectivity is drawn *inside* the compiled scan through
the channel's ``scan_sampler()``, so no tau tensors ever cross the host
boundary — only the packed gate state and a PRNG key carry over.

Observability (DESIGN.md §11): ``--metrics-dir DIR`` turns on the full
telemetry stack — the instrumented round (per-client participation /
bits-on-air vectors, device-resident outage-streak carry), a structured
``events.jsonl`` + ``rounds.csv`` + ``manifest.json`` in DIR, and
``vectors.npz`` with the stacked per-client histories at exit.
``--profile-dir`` captures a ``jax.profiler`` trace over
``--profile-rounds`` rounds; ``--log-every N`` prints cumulative
rounds/sec to stderr every N rounds.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --rounds 10 --smoke
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --rounds 64 --chunk 16 --channel markov --smoke \
        --metrics-dir /tmp/colrel_metrics
"""

from __future__ import annotations

import argparse
import contextlib
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import strategies as strategy_registry
from repro.ckpt import (
    CKPT_VERSION,
    AsyncCheckpointer,
    CheckpointWriter,
    PreemptionGuard,
    rng_from_json,
    rng_state_to_json,
)
from repro.configs.base import get_arch
from repro.configs.channels import CHANNEL_PRESETS, make_channel
from repro.core import optimize_weights, topology
from repro.core.flatten import flat_spec
from repro.fl.round import (
    RoundConfig,
    make_async_round_fn,
    make_async_scan_round_fn,
    make_round_fn,
    make_scan_round_fn,
)
from repro.models import build, count_params
from repro.optim import sgd, sgd_momentum
from repro.telemetry import (
    CsvSummarySink,
    JsonlSink,
    MetricsLogger,
    ProfileWindow,
    RunManifest,
    ThroughputMeter,
    init_streak,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--n-clients", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4, help="per-client batch")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--aggregation", default="colrel",
                    choices=sorted(strategy_registry.available()),
                    help="aggregation strategy (repro.strategies registry)")
    ap.add_argument("--fused-kernel", action="store_true",
                    help="flatten-once fused Pallas aggregation (colrel only)")
    ap.add_argument("--async-mode", action="store_true",
                    help="asynchronous opportunistic relaying: blocked "
                         "clients' last updates age in a device staging "
                         "buffer and the PS applies gamma^age staleness "
                         "weights (DESIGN.md §13); wraps --aggregation")
    ap.add_argument("--staleness-gamma", type=float, default=0.9,
                    help="staleness decay base gamma for --async-mode")
    ap.add_argument("--channel", default="static",
                    choices=sorted(CHANNEL_PRESETS),
                    help="connectivity dynamics preset (repro/configs/channels.py)")
    ap.add_argument("--chunk", type=int, default=1,
                    help="rounds per compiled scan chunk (1 = per-round loop)")
    ap.add_argument("--no-trace", action="store_true",
                    help="draw connectivity inside the compiled scan "
                         "(channel.scan_sampler; no tau tensors on host)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--p-up", type=float, default=0.3)
    ap.add_argument("--p-c", type=float, default=0.8)
    ap.add_argument("--metrics-dir", default=None,
                    help="telemetry output dir (events.jsonl, rounds.csv, "
                         "manifest.json, vectors.npz); also enables the "
                         "instrumented round")
    ap.add_argument("--log-every", type=int, default=0,
                    help="print cumulative rounds/sec every N rounds")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace into this dir")
    ap.add_argument("--profile-rounds", type=int, default=4,
                    help="profiler window length in rounds (with --profile-dir)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint directory (async sha256-committed "
                         "ckpt_NNNNNNNN.msgpack snapshots; DESIGN.md §12)")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint cadence in rounds (0 = final only; "
                         "must be a multiple of --chunk)")
    ap.add_argument("--ckpt-keep", type=int, default=3,
                    help="committed checkpoints retained (keep-last-k GC)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest committed checkpoint in "
                         "--ckpt-dir and continue to --rounds")
    ap.add_argument("--segment-d", type=int, default=0,
                    help="d threshold for segment-streaming aggregation "
                         "(0 = monolithic stack; DESIGN.md §14)")
    ap.add_argument("--no-donate", action="store_true",
                    help="disable carry-buffer donation (keeps two live "
                         "(n, d) generations; for A/B memory measurement)")
    args = ap.parse_args()

    # the fused kernel only exists on the colrel path; refuse the
    # silently-inert combination rather than measuring the wrong code.
    if args.fused_kernel and args.aggregation != "colrel":
        ap.error(f"--fused-kernel requires --aggregation colrel "
                 f"(got {args.aggregation})")
    if args.chunk < 1 or args.rounds % args.chunk != 0:
        ap.error(f"--chunk must be positive and divide --rounds "
                 f"(got chunk={args.chunk}, rounds={args.rounds})")
    if args.no_trace and args.chunk == 1:
        ap.error("--no-trace runs through the scan engine; pass --chunk K > 1")
    if (args.resume or args.ckpt_every) and not args.ckpt_dir:
        ap.error("--resume and --ckpt-every require --ckpt-dir")
    if args.ckpt_every and args.ckpt_every % args.chunk != 0:
        # the scan engine only reaches the host at chunk boundaries, so a
        # misaligned cadence cannot be honored — refuse, don't approximate
        ap.error(f"--ckpt-every must be a multiple of --chunk (got "
                 f"ckpt_every={args.ckpt_every}, chunk={args.chunk}): "
                 f"checkpoints commit at chunk boundaries only")
    strategy = strategy_registry.get(
        args.aggregation,
        **({"fused": "kernel"} if args.fused_kernel
           else {"fused": "collapse"} if args.aggregation == "colrel" else {}),
    )
    if args.async_mode:
        if getattr(strategy, "is_async", False):
            ap.error(f"--aggregation {args.aggregation} is already "
                     f"asynchronous; drop --async-mode")
        strategy = strategy_registry.AsyncRelayStrategy(
            inner=strategy, gamma=args.staleness_gamma)
    # async strategies (via --async-mode or --aggregation async_colrel)
    # route through the age-carrying round builders
    is_async = getattr(strategy, "is_async", False)
    mk_round = make_async_round_fn if is_async else make_round_fn
    mk_scan = make_async_scan_round_fn if is_async else make_scan_round_fn

    arch = get_arch(args.arch)
    cfg = arch.smoke() if args.smoke else arch.full()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    print(f"{cfg.name}: {count_params(params):,} params on "
          f"{len(jax.devices())} device(s)")

    n = args.n_clients
    link_model = topology.fully_connected(n, args.p_up, p_c=args.p_c, rho=1.0)
    channel = make_channel(args.channel, link_model, n=n, seed=args.seed)
    res = optimize_weights(link_model, sweeps=20, fine_tune_sweeps=20)
    print(f"COPT-alpha: S {res.S_init:.2f} -> {res.S:.2f}")
    A = jnp.asarray(res.A, jnp.float32)

    rc = RoundConfig(n_clients=n, local_steps=args.local_steps,
                     mode="per_client", aggregation=strategy,
                     segment_d=args.segment_d)
    # carry-slot donation (DESIGN.md §14): params / server_state /
    # agg_state (and the telemetry streak / no-trace channel carry) alias
    # their outputs, so one (n, d) generation stays live instead of two.
    donate = not args.no_donate
    server_opt = sgd_momentum(1.0, beta=0.9)
    sstate = server_opt.init(params)
    agg_state = strategy.init_state(n, flat_spec(params).d)

    # checkpoint discovery (DESIGN.md §12): find the restore source up
    # front so the telemetry sinks open in the right mode and the
    # manifest can record provenance
    resume_state = None
    resume_path = None
    if args.resume:
        reader = CheckpointWriter(args.ckpt_dir, keep=args.ckpt_keep)
        step = reader.latest_step()
        if step is None:
            ap.error(f"--resume: no committed checkpoint in {args.ckpt_dir}")
        resume_path = reader.path_for(step)
        resume_state = reader.load(step)
        print(f"resuming from {resume_path} (round {step})")

    # observability wiring (DESIGN.md §11)
    telemetry = args.metrics_dir is not None
    logger = None
    if telemetry:
        mdir = pathlib.Path(args.metrics_dir)
        logger = MetricsLogger([JsonlSink(mdir / "events.jsonl",
                                          resume=args.resume),
                                CsvSummarySink(mdir / "rounds.csv",
                                               resume=args.resume)])
        RunManifest.collect(
            vars(args), strategy=strategy.name, channel=args.channel,
            codec=getattr(getattr(strategy, "codec", None), "name", None),
            arch=cfg.name, n_clients=n,
            resumed_from=resume_path,
        ).write(mdir)
        print(f"telemetry -> {mdir}")
    profile = (ProfileWindow(args.profile_dir, rounds=args.profile_rounds)
               if args.profile_dir else None)
    meter = ThroughputMeter()
    streak = init_streak(n) if telemetry else None
    last_tlog = 0

    def tick(r0: int, k: int, metrics) -> None:
        """Per-block telemetry: fence + clock, log, throughput line."""
        nonlocal last_tlog
        dt = meter.stop(k, fence=metrics)
        if profile is not None:
            profile.maybe_stop(r0 + k)
        if logger is not None:
            logger.log_timing(r0, k, dt)
            logger.log_rounds(r0, metrics, k)
        if args.log_every and r0 + k - last_tlog >= args.log_every:
            last_tlog = r0 + k
            print(f"[telemetry] round {r0 + k}: "
                  f"{meter.rounds_per_sec():.2f} rounds/s", file=sys.stderr)

    def finish() -> None:
        _stack.close()  # reinstall the original signal handlers
        if profile is not None:
            profile.close()
        if logger is not None:
            logger.save_vectors(pathlib.Path(args.metrics_dir) / "vectors.npz")
            logger.close()
            print(f"telemetry: {meter.total_rounds} rounds in "
                  f"{meter.total_seconds:.2f}s "
                  f"({meter.rounds_per_sec():.2f} rounds/s)")

    rng = np.random.default_rng(args.seed)
    V, S, B, T = cfg.vocab_size, args.seq_len, args.batch, args.local_steps

    # apply the restored state: model/optimizer/strategy tensors, the
    # channel generator, the batch rng, and the telemetry cursors
    r_start = 0
    ch_state = ch_rng = None  # no-trace scan carry (set below when used)
    if resume_state is not None:
        if resume_state.get("version") != CKPT_VERSION:
            sys.exit(f"checkpoint version {resume_state.get('version')!r} != "
                     f"supported {CKPT_VERSION}")
        for field, want in (("kind", "launch"), ("strategy", strategy.name),
                            ("arch", cfg.name)):
            got = resume_state.get(field)
            if got != want:
                sys.exit(f"checkpoint {field} mismatch: saved {got!r}, "
                         f"launching {want!r}")
        if (resume_state.get("no_trace") is not None) != args.no_trace:
            sys.exit("checkpoint --no-trace mode does not match this launch; "
                     "resume with the same connectivity flags")
        params = jax.tree.map(jnp.asarray, resume_state["params"])
        sstate = jax.tree.map(jnp.asarray, resume_state["server_state"])
        agg_state = strategy.restore_state(resume_state["agg_state"])
        rng = rng_from_json(resume_state["rng"])
        channel.restore_state(resume_state["channel"])
        if telemetry:
            if resume_state.get("streak") is None:
                sys.exit("checkpoint carries no telemetry state but "
                         "--metrics-dir is set; resume with matching flags")
            streak = jnp.asarray(resume_state["streak"], jnp.int32)
            if logger is not None and resume_state.get("metrics") is not None:
                logger.restore_state(resume_state["metrics"])
        r_start = int(resume_state["round"])
        if r_start >= args.rounds:
            print(f"checkpoint already at round {r_start} >= --rounds "
                  f"{args.rounds}; nothing to do")
            return
        if r_start % args.chunk != 0:
            sys.exit(f"checkpoint round {r_start} is not a --chunk "
                     f"{args.chunk} boundary")
        last_tlog = r_start

    # async checkpointing + preemption safety (DESIGN.md §12): snapshots
    # enqueue at round boundaries and serialize on the writer thread,
    # overlapped with the next block's device compute; SIGTERM/SIGINT
    # latches and the loop drains + commits a final checkpoint at the
    # next boundary instead of dying mid-write.
    ckpt = (AsyncCheckpointer(args.ckpt_dir, keep=args.ckpt_keep,
                              copy_arrays=donate)
            if args.ckpt_dir else None)
    ckpt_last = -1
    _stack = contextlib.ExitStack()
    guard = _stack.enter_context(PreemptionGuard())

    def capture(r_next: int) -> dict:
        """The launcher's complete run state at round boundary r_next."""
        return {
            "version": CKPT_VERSION, "kind": "launch",
            "round": int(r_next), "strategy": strategy.name,
            "arch": cfg.name,
            "params": params, "server_state": sstate,
            "agg_state": strategy.checkpoint_state(agg_state),
            "rng": rng_state_to_json(rng),
            "channel": channel.checkpoint_state(),
            "no_trace": ({"state": ch_state, "rng": ch_rng}
                         if args.no_trace else None),
            "streak": streak,
            "metrics": logger.checkpoint_state() if logger else None,
        }

    def boundary(r_next: int) -> bool:
        """Periodic checkpoint + preemption check at a round boundary;
        True = stop the loop (``final_ckpt`` commits the last state)."""
        nonlocal ckpt_last
        if (ckpt is not None and args.ckpt_every
                and r_next % args.ckpt_every == 0 and r_next != ckpt_last):
            ckpt.save(r_next, capture(r_next))
            ckpt_last = r_next
        if guard.triggered:
            print(f"[ckpt] preempted (signal {guard.signum}) at round "
                  f"{r_next}; committing final checkpoint", file=sys.stderr)
            return True
        return False

    def final_ckpt(r_next: int) -> None:
        """Drain the async writer; commit a final checkpoint if the last
        boundary was not already saved."""
        nonlocal ckpt_last
        if ckpt is None:
            return
        if r_next != ckpt_last:
            ckpt.save(r_next, capture(r_next))
            ckpt_last = r_next
        ckpt.close()
        print(f"[ckpt] committed round {r_next} -> {args.ckpt_dir}")

    def make_batches(lead: tuple) -> dict:
        toks = rng.integers(0, V, size=(*lead, S + 1), dtype=np.int32)
        batches = {"tokens": jnp.asarray(toks[..., :-1]),
                   "labels": jnp.asarray(toks[..., 1:])}
        if cfg.frontend_tokens:
            batches["prefix"] = jnp.asarray(
                rng.normal(size=(*lead, cfg.frontend_tokens, cfg.d_model)),
                cfg.jdtype)
        return batches

    don_traced = ((0, 1, 2) + ((7,) if telemetry else ())) if donate else ()
    don_sampled = ((0, 1, 2, 4, 5) + ((7,) if telemetry else ())) if donate else ()

    if args.chunk == 1:
        round_fn = jax.jit(mk_round(bundle.loss_fn, sgd(0.25), server_opt,
                                    rc, telemetry=telemetry),
                           donate_argnums=don_traced)
        done = r_start
        for r in range(r_start, args.rounds):
            if profile is not None:
                profile.maybe_start(r)
            meter.start()
            tau_up, tau_dd = channel.tau_for_round(r)
            batches = make_batches((n, T, B))
            t0 = time.perf_counter()
            fn_args = (params, sstate, agg_state, batches,
                       jnp.asarray(tau_up, jnp.float32),
                       jnp.asarray(tau_dd, jnp.float32), A)
            if telemetry:
                params, sstate, agg_state, streak, metrics = round_fn(
                    *fn_args, streak)
            else:
                params, sstate, agg_state, metrics = round_fn(*fn_args)
            jax.block_until_ready(metrics["loss"])
            tick(r, 1, metrics)
            stale = (f"stale={float(metrics['stale_frac']):.2f}  "
                     if "stale_frac" in metrics else "")
            print(f"round {r:3d}  loss={float(metrics['loss']):.4f}  "
                  f"participants={int(metrics['participation'])}/{n}  "
                  f"|delta|={float(metrics['delta_norm']):.3f}  {stale}"
                  f"({time.perf_counter() - t0:.2f}s)")
            done = r + 1
            if boundary(done):
                break
        final_ckpt(done)
        finish()
        return

    # chunked scan engine: K rounds per device program, one host sync per
    # chunk; taus come from the channel's bulk trace service — or, with
    # --no-trace, are drawn inside the compiled scan (channel gate state +
    # PRNG key carried across chunks; no tau tensors ever on host)
    K = args.chunk
    if args.no_trace:
        if not hasattr(channel, "scan_sampler"):
            ap.error(f"--no-trace needs a channel with scan_sampler() "
                     f"(--channel {args.channel} cannot sample in-scan)")
        init_fn, sample_fn = channel.scan_sampler()
        scan_fn = jax.jit(mk_scan(
            bundle.loss_fn, sgd(0.25), server_opt, rc,
            channel_sampler=sample_fn, telemetry=telemetry),
            donate_argnums=don_sampled)
        ch_rng, sub = jax.random.split(jax.random.PRNGKey(args.seed))
        ch_state = init_fn(sub)
        if resume_state is not None:
            nt = resume_state["no_trace"]
            ch_state = jax.tree.map(jnp.asarray, nt["state"])
            ch_rng = jnp.asarray(nt["rng"])
    else:
        scan_fn = jax.jit(mk_scan(bundle.loss_fn, sgd(0.25),
                                  server_opt, rc,
                                  telemetry=telemetry),
                          donate_argnums=don_traced)
    done = r_start
    for c in range(r_start // K, args.rounds // K):
        r0 = c * K
        if profile is not None:
            profile.maybe_start(r0)
        meter.start()
        batches = make_batches((K, n, T, B))
        t0 = time.perf_counter()
        if args.no_trace:
            if telemetry:
                (params, sstate, agg_state, ch_state, ch_rng, streak,
                 metrics) = scan_fn(params, sstate, agg_state, batches,
                                    ch_state, ch_rng, A, streak)
            else:
                params, sstate, agg_state, ch_state, ch_rng, metrics = scan_fn(
                    params, sstate, agg_state, batches, ch_state, ch_rng, A)
        else:
            tau_up, tau_dd = channel.trace(r0, K)
            fn_args = (params, sstate, agg_state, batches,
                       jnp.asarray(tau_up, jnp.float32),
                       jnp.asarray(tau_dd, jnp.float32), A)
            if telemetry:
                params, sstate, agg_state, streak, metrics = scan_fn(
                    *fn_args, streak)
            else:
                params, sstate, agg_state, metrics = scan_fn(*fn_args)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        tick(r0, K, metrics)
        loss = np.asarray(metrics["loss"])
        part = np.asarray(metrics["participation"])
        bits = float(np.sum(np.asarray(metrics["uplink_bits"])))
        print(f"rounds {r0:3d}-{r0 + K - 1:3d}  "
              f"loss={loss[0]:.4f}->{loss[-1]:.4f}  "
              f"participants(mean)={part.mean():.1f}/{n}  "
              f"uplink={bits / 8e6:.1f} MB  "
              f"({dt:.2f}s, {K / dt:.1f} rounds/s)")
        done = r0 + K
        if boundary(done):
            break
    final_ckpt(done)
    finish()


if __name__ == "__main__":
    main()
