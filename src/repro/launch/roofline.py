"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds-per-step on the
TARGET hardware (TPU v5e):

    compute    = HLO_FLOPs_per_chip / PEAK_FLOPS          (197 TF/s bf16)
    memory     = HLO_bytes_per_chip / HBM_BW              (819 GB/s)
    collective = collective_bytes_per_chip / LINK_BW      (~50 GB/s/link)

``cost_analysis()`` of the compiled executable gives per-chip FLOPs and
bytes (the module is already SPMD-partitioned).  Collective bytes are NOT
in cost_analysis: we parse the optimized HLO and sum the *output* operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (output shapes in the partitioned module are per-chip,
so the sum is per-chip traffic; an all-reduce of a replicated buffer
counts its full ring volume approximately once — a standard first-order
model, documented in EXPERIMENTS.md).

MODEL_FLOPS uses the 6·N·D (train) / 2·N·D (inference) convention with
N = active parameters (MoE discounts unrouted experts).
"""

from __future__ import annotations

import re
from typing import Any, Dict

import numpy as np

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(segment):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum per-op-type output bytes of communication ops in optimized HLO."""
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", line)
        if not m:
            continue
        rhs = m.group(1)
        for c in _COLLECTIVES:
            # match the op name as the instruction, not inside metadata
            if re.search(rf"\)?\s{c}(?:-start|-done)?\(", rhs) or rhs.startswith(c):
                # shape segment = everything before the op name
                idx = rhs.find(c)
                out[c] += _shape_bytes(rhs[:idx])
                break
    return out


def roofline_terms(
    flops_per_chip: float,
    bytes_per_chip: float,
    coll_bytes_per_chip: float,
) -> Dict[str, float]:
    compute = flops_per_chip / PEAK_FLOPS
    memory = bytes_per_chip / HBM_BW
    collective = coll_bytes_per_chip / LINK_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    terms["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k]
    )
    return terms


# ---------------------------------------------------------------------------
# MODEL_FLOPS (useful-work reference)
# ---------------------------------------------------------------------------


def count_params_split(params_spec: Any, n_experts: int, top_k: int) -> Dict[str, float]:
    """Total and active parameter counts from a ShapeDtypeStruct pytree."""
    import jax

    total = 0
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_spec)[0]:
        n = int(np.prod(leaf.shape))
        total += n
        pstr = "/".join(str(getattr(k, "key", k)) for k in path)
        if n_experts > 0 and re.search(r"ffns?.*/(wg|wu|wd)$", pstr) and leaf.ndim >= 3:
            expert += n
    active = total
    if n_experts > 0 and expert:
        active = total - expert * (1.0 - top_k / n_experts)
    return {"total": float(total), "active": float(active)}


def model_flops(kind: str, n_active: float, tokens: float) -> float:
    """6ND for training (fwd+bwd), 2ND for inference forward."""
    return (6.0 if kind == "train" else 2.0) * n_active * tokens
