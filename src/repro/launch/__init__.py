"""Production launch layer: meshes, sharding rules, input specs, step
builders, the multi-pod dry-run, roofline extraction, and the train CLI.

NOTE: ``repro.launch.dryrun`` must be executed as its own process (it sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before jax
initializes); everything else here is import-safe.
"""
