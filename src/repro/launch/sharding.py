"""Sharding rules: PartitionSpecs for params, batches and caches per arch.

Parameter rules are path-pattern based (megatron-style tensor parallelism
over the ``model`` axis):

  * embeddings / lm_head           — vocab over ``model``
  * attention q/o projections      — heads over ``model``
  * attention k/v projections      — heads over ``model`` when the kv-head
    count divides the axis, else the d_model dim (GQA kv=8 < 16, MQA kv=1)
  * MLP up/gate | down             — d_ff over ``model`` (col | row)
  * MoE experts                    — expert axis over ``model`` when E
    divides it (dbrx/jamba E=16), else d_ff inside experts (granite E=40)
  * mamba / rwkv projections       — inner channel dim over ``model``
  * norms, scalars                 — replicated

In ``per_client`` FL mode params stay *replicated over the client axes*
(each client's divergent copy appears only inside the vmapped round body,
pinned to the data axis via ``spmd_axis_name``).  In ``client_sequential``
mode params are additionally sharded over the client/data axes FSDP-style
on the largest divisible dim.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig

Params = Any

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _path_str(path) -> str:
    return "/".join(
        str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))) for k in path
    )


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _divisible(dim: int, mesh: Mesh, axis: str) -> bool:
    return dim % _axis_size(mesh, axis) == 0 and _axis_size(mesh, axis) > 1


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # pattern on the path suffix -> spec template applied to the LAST ndims
    # (None entries replicate; "model" shards over the model axis).
    (r"embed$", ("model", None)),
    (r"lm_head$", (None, "model")),
    (r"frontend_proj$|proj$", (None, "model")),
    # attention
    (r"attn.*/wq$|self/wq$|cross/wq$|mixers/l\d+/wq$", (None, "model", None)),
    (r"wk$|wv$", (None, "model", None)),  # checked for divisibility below
    (r"wo$", ("model", None, None)),
    # dense mlp (2D weights named wg/wu/wd under ffn etc.)
    (r"wg$|wu$", (None, "model")),
    (r"wd$", ("model", None)),
    # moe (3D expert-stacked; expert axis first)
    (r"router$", (None, None)),
    # mamba / rwkv projections
    (r"w_in$", (None, "model")),
    (r"w_out$", ("model", None)),
    (r"wr$", (None, "model")),
    (r"tm/wk$|tm/wv$|tm/wg$", (None, "model")),
    (r"cm/wk$", (None, "model")),
    (r"cm/wv$", ("model", None)),
    (r"tm/wo$", ("model", None)),
    (r"wa$", (None, None)),
    (r"wb$", (None, None)),
)


def _param_spec(path: str, shape: Tuple[int, ...], cfg: ModelConfig, mesh: Mesh,
                fsdp_axes: Tuple[str, ...] = ()) -> P:
    ndim = len(shape)
    spec: list = [None] * ndim

    def fill_from_template(tmpl):
        # align template to the trailing dims (leading dims are layer stacks)
        off = ndim - len(tmpl)
        for i, ax in enumerate(tmpl):
            if ax is not None and _divisible(shape[off + i], mesh, ax):
                spec[off + i] = ax

    # MoE expert tensors: (.., E, d, f) / (.., E, f, d)
    if re.search(r"ffns?.*/(wg|wu|wd)$", path) and ndim >= 3 and cfg.n_experts > 0:
        e_dim = ndim - 3
        if _divisible(shape[e_dim], mesh, "model"):
            spec[e_dim] = "model"  # expert parallelism
        else:
            # tensor parallelism inside experts: shard the f dim
            f_dim = ndim - 2 if path.endswith("wd") else ndim - 1
            if _divisible(shape[f_dim], mesh, "model"):
                spec[f_dim] = "model"
        return _with_fsdp(path, spec, shape, mesh, fsdp_axes)

    # kv projections with few heads: fall back to sharding d_model
    if re.search(r"wk$|wv$", path) and ndim >= 3:
        off = ndim - 3
        if _divisible(shape[off + 1], mesh, "model"):
            spec[off + 1] = "model"
        elif _divisible(shape[off], mesh, "model"):
            spec[off] = "model"
        return _with_fsdp(path, spec, shape, mesh, fsdp_axes)

    for pat, tmpl in _RULES:
        if re.search(pat, path) and ndim >= len(tmpl):
            fill_from_template(tmpl)
            break
    return _with_fsdp(path, spec, shape, mesh, fsdp_axes)


def _with_fsdp(path, spec, shape, mesh, fsdp_axes) -> P:
    """client_sequential: additionally shard the largest free dim over the
    client/data axes (ZeRO-3-style fully sharded storage)."""
    if fsdp_axes:
        n = int(np.prod([_axis_size(mesh, a) for a in fsdp_axes]))
        if n > 1:
            free = [i for i, s in enumerate(spec) if s is None]
            # prefer the largest divisible free dim
            free.sort(key=lambda i: -shape[i])
            for i in free:
                if shape[i] % n == 0:
                    spec[i] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
                    break
    return P(*spec)


def param_shardings(cfg: ModelConfig, params_shape: Params, mesh: Mesh,
                    *, fsdp: bool = False) -> Params:
    """NamedShardings for a params (shape) pytree."""
    from repro.launch.mesh import client_axes

    fsdp_axes = client_axes(mesh) if fsdp else ()

    def f(path, leaf):
        spec = _param_spec(_path_str(path), leaf.shape, cfg, mesh, fsdp_axes)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(f, params_shape)


# ---------------------------------------------------------------------------
# batch / cache rules
# ---------------------------------------------------------------------------


def train_batch_shardings(mesh: Mesh, mode: str, batch_shape: Params,
                          *, scan: bool = False) -> Params:
    """Train batches.  per_client: leading client dim over the client axes.
    client_sequential: per-step batch dim (axis 2) over the client axes.
    ``scan=True``: leaves carry a leading K-round axis (the chunked scan
    engine's layout) — the round axis stays unsharded (it is the scan's
    sequential dim) and the per-round rules shift right by one."""
    from repro.launch.mesh import client_axes

    ca = client_axes(mesh)
    caxis = ca if len(ca) > 1 else ca[0]
    off = 1 if scan else 0

    def f(path, leaf):
        ndim = len(leaf.shape)
        spec = [None] * ndim
        if mode == "weighted_flat":
            # (C*B, ...) — fully shard the flat batch when it covers the mesh
            full = (*ca, "model")
            n_full = 1
            for a in full:
                n_full *= mesh.shape[a]
            spec[off] = full if leaf.shape[off] % n_full == 0 else caxis
        elif mode in ("per_client", "weighted_grad"):
            spec[off] = caxis  # (C, [T,] B, ...): client dim over client axes
        else:  # client_sequential: shard the per-step batch dim instead
            if ndim >= off + 3:
                spec[off + 2] = caxis  # (C, T, B, ...) -> shard B
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(f, batch_shape)


def serve_batch_sharding(mesh: Mesh, shape: Tuple[int, ...]) -> NamedSharding:
    """Serving batch (B, ...): batch over client axes when divisible."""
    from repro.launch.mesh import client_axes, n_clients

    ca = client_axes(mesh)
    caxis = ca if len(ca) > 1 else ca[0]
    spec = [None] * len(shape)
    if shape[0] % n_clients(mesh) == 0 and shape[0] > 1:
        spec[0] = caxis
    return NamedSharding(mesh, P(*spec))


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_shape: Params) -> Params:
    """KV caches (L, B, S, KV, hd) / recurrent states (L, B, H, ...).

    Batch shards over the client axes when divisible; heads shard over
    ``model`` when divisible, else the sequence dim shards over ``model``
    (sequence-parallel cache; attention then all-reduces over ``model``).
    """
    from repro.launch.mesh import client_axes, n_clients

    ca = client_axes(mesh)
    caxis = ca if len(ca) > 1 else ca[0]
    nc = n_clients(mesh)

    def f(path, leaf):
        shape = leaf.shape
        ndim = len(shape)
        spec = [None] * ndim
        p = _path_str(path)
        if ndim >= 2 and shape[1] % nc == 0 and shape[1] > 1:
            spec[1] = caxis  # batch dim
        if re.search(r"(^|/)(k|v)$", p) and ndim >= 5:
            if _divisible(shape[3], mesh, "model"):
                spec[3] = "model"  # kv heads
            elif _divisible(shape[2], mesh, "model"):
                spec[2] = "model"  # sequence-parallel cache
        elif re.search(r"ssm$|wkv$", p) and ndim >= 3:
            if _divisible(shape[2], mesh, "model"):
                spec[2] = "model"  # recurrent-state heads
        elif re.search(r"memory$", p) and ndim == 3:
            if _divisible(shape[2], mesh, "model"):
                spec[2] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(f, cache_shape)


def replicated(mesh: Mesh, tree: Params) -> Params:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


# ---------------------------------------------------------------------------
# client-axis rules (FL round operands)
# ---------------------------------------------------------------------------

_CLIENTS = "clients"


@dataclasses.dataclass(frozen=True)
class ShardingRule:
    """scalax-style declarative sharding rule (SNIPPETS.md §1).

    Ordered ``(path-pattern, templates)`` pairs: the first pattern that
    matches a leaf's path selects its template set, and the first template
    whose length equals the leaf's ndim (after skipping ``skip_leading``
    scan dims) is applied dim-by-dim.  The placeholder ``"clients"``
    resolves to the mesh's client axes (``pod``/``data``); any other
    entry names a mesh axis literally.  An axis that does not divide its
    dim is dropped (that dim replicates), so one rule serves every mesh
    shape — including the 1-device test mesh, where everything
    degenerates to replication.
    """

    rules: Tuple[Tuple[str, Tuple[Tuple[Optional[str], ...], ...]], ...]
    # leading dims excluded from matching (the scan engine's K-round axis:
    # it is the scan's sequential dim and must stay unsharded)
    skip_leading: int = 0

    def spec(self, path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
        from repro.launch.mesh import client_axes

        dims = shape[self.skip_leading:]
        spec: list = [None] * len(shape)
        for pat, templates in self.rules:
            if not re.search(pat, path):
                continue
            for tmpl in templates:
                if len(tmpl) != len(dims):
                    continue
                for i, ax in enumerate(tmpl):
                    if ax is None:
                        continue
                    axes = client_axes(mesh) if ax == _CLIENTS else (ax,)
                    size = int(np.prod([_axis_size(mesh, a) for a in axes]))
                    if size > 1 and dims[i] % size == 0:
                        spec[self.skip_leading + i] = (
                            axes if len(axes) > 1 else axes[0]
                        )
                break
            break
        return P(*spec)

    def shardings(self, mesh: Mesh, tree: Params) -> Params:
        """NamedShardings for a (shape) pytree, one leaf at a time."""

        def f(path, leaf):
            return NamedSharding(mesh, self.spec(_path_str(path), leaf.shape, mesh))

        return jax.tree_util.tree_map_with_path(f, tree)


def fl_round_rule(*, scan: bool = False) -> ShardingRule:
    """Connectivity operands of the FL round, sharded along the client axis.

    ``tau_up (n,)`` shards its client dim; dense ``tau_dd`` / ``A (n, n)``
    shard rows (the relaying client — the contraction's output axis);
    block ``(C, m, m)`` cluster tensors shard the cluster axis.  All use
    the same client mesh axes as the ``(n, ...)`` update stack, so the
    relay mix is shard-local and only the final blind PS sum crosses
    shards (one (d,) all-reduce).  ``scan=True`` skips the leading
    K-round axis of the chunked engine's trace layout.
    """
    return ShardingRule(
        rules=(
            (r"(^|/)tau_up$", ((_CLIENTS,),)),
            (r"(^|/)(tau_dd|tau_b|A|Ab)$",
             ((_CLIENTS, None), (_CLIENTS, None, None))),
        ),
        skip_leading=1 if scan else 0,
    )


def telemetry_rule(*, scan: bool = False) -> ShardingRule:
    """Telemetry operands of the instrumented FL round (DESIGN.md §11).

    The ``(n,)`` outage-streak carry and the per-client metric vectors
    (``client_participation`` / ``client_uplink_bits`` /
    ``outage_streak``) shard their client dim over the client axes —
    they are lane-local reads of the already-sharded ``tau_up`` — while
    the ``weight_drift`` scalar replicates (no matching rule -> P()).
    ``scan=True`` skips the leading K-round axis of the stacked
    ``(K, n)`` metric outputs; the streak *input* carries no K axis, so
    lower it with the default rule.  On a 1-device mesh everything
    degenerates to replication, same as :func:`fl_round_rule`.
    """
    return ShardingRule(
        rules=(
            (r"(^|/)(client_participation|client_uplink_bits"
             r"|outage_streak|streak)$", ((_CLIENTS,),)),
        ),
        skip_leading=1 if scan else 0,
    )


def client_state_shardings(mesh: Mesh, tree: Params, n_fl_clients: int) -> Params:
    """Strategy carried state (replay buffers etc.): any leaf whose leading
    axis is the client population shards it over the client axes — the
    memory strategy's ``(n, d)`` buffer and the async carry (the ``(n,)``
    int32 age vector and ``(n, d)`` staging buffer of
    :class:`~repro.strategies.AsyncRelayStrategy`, DESIGN.md §13) then
    live as per-shard slices next to the update stack instead of
    n_devices replicas.  Leaves of any other shape (scalars, codec
    state) replicate."""
    from repro.launch.mesh import client_axes

    ca = client_axes(mesh)
    nc = int(np.prod([_axis_size(mesh, a) for a in ca]))
    caxis = ca if len(ca) > 1 else ca[0]

    def f(leaf):
        spec: list = [None] * len(leaf.shape)
        if (len(leaf.shape) >= 1 and leaf.shape[0] == n_fl_clients
                and nc > 1 and n_fl_clients % nc == 0):
            spec[0] = caxis
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(f, tree)


def channel_state_sharding(mesh: Mesh, shape: Tuple[int, ...]) -> NamedSharding:
    """In-scan channel sampler state (``ge_scan_sampler`` /
    ``clustered_ge_scan_sampler``): the packed per-link gate vector shards
    over the client axes when its length divides evenly — the clustered
    layout's C·(m + m(m-1)/2) lanes always do once C covers the client
    axes — else it replicates (the dense n + n(n-1)/2 packing rarely
    divides, and at that size replication is cheap)."""
    from repro.launch.mesh import client_axes

    ca = client_axes(mesh)
    nc = int(np.prod([_axis_size(mesh, a) for a in ca]))
    caxis = ca if len(ca) > 1 else ca[0]
    spec: list = [None] * len(shape)
    if len(shape) >= 1 and nc > 1 and shape[0] % nc == 0:
        spec[0] = caxis
    return NamedSharding(mesh, P(*spec))
