"""Step builders + sharding assembly for the production launch/dry-run.

``build_step(arch_id, shape_name, mesh, ...)`` returns
``(step_fn, specs, in_shardings, out_shardings)`` ready for

    with mesh:
        jax.jit(step_fn, in_shardings=..., out_shardings=...).lower(**specs)

Step kinds per input shape (see configs/base.py):
  train_4k               -> one full ColRel FL round (T local SGD steps,
                            relay consensus, blind PS sum, PS momentum)
  prefill_32k            -> forward logits
  decode_32k / long_500k -> one-token serve step against a deep KV cache

``scan_rounds=K`` turns the train step into the chunked multi-round scan
engine (DESIGN.md §9): the same round body scanned over a leading K axis
— batches ``(K, C, T, B, ...)``, channel trace ``tau_up (K, C)`` /
``tau_dd (K, C, C)``, metrics stacked ``(K,)`` — so the production pjit
path compiles K communication rounds into one program exactly like
``FLTrainer.run(chunk=K)`` does on CPU.

``telemetry=True`` lowers the instrumented round instead (DESIGN.md
§11): one extra ``(C,)`` int32 outage-streak operand/result, per-client
metric vectors sharded along the client axes like ``tau_up`` (stacked
``(K, C)`` under ``scan_rounds``).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import strategies as strategy_registry
from repro.configs.base import get_arch
from repro.core import flatten
from repro.fl.round import (
    RoundConfig,
    StrategySpec,
    make_async_round_fn,
    make_async_scan_round_fn,
    make_round_fn,
    make_scan_round_fn,
)


def get_arch_cfg(arch_id: str):
    return get_arch(arch_id).full()
from repro.launch import sharding as shard_rules
from repro.launch.mesh import client_axes, n_clients
from repro.launch.specs import DRYRUN_LOCAL_STEPS, input_specs
from repro.models import build
from repro.optim import sgd, sgd_momentum

# Paper hyperparameters carried into the production round.
CLIENT_LR = 0.05
CLIENT_WD = 1e-4
SERVER_MOMENTUM = 0.9


def train_state_shardings(
    arch_id: str,
    shape_name: str,
    mesh,
    *,
    aggregation: StrategySpec = "colrel",
    fl_mode: str | None = None,
    cfg_override=None,
) -> Dict[str, Any]:
    """Shardings for the checkpointable train-state leaves.

    ``repro.ckpt`` writes each array per-shard via its ``Sharding`` (the
    ``(n, d)`` client-axis stacks never gather); on restore the reverse
    trip needs the same layouts to ``jax.device_put`` the reassembled
    hosts arrays back onto the production mesh.  Returns
    ``{"params", "server_state", "agg_state"}`` sharding trees matching
    :func:`build_step`'s train in/out shardings (DESIGN.md §12).
    """
    mode = fl_mode or (cfg_override or get_arch_cfg(arch_id)).fl_mode
    specs = input_specs(arch_id, shape_name, mesh, cfg=cfg_override,
                        fl_mode=mode)
    cfg = specs["cfg"]
    fsdp = mode in ("client_sequential", "weighted_grad", "weighted_flat")
    strategy = strategy_registry.resolve(aggregation)
    d_flat = flatten.flat_spec(specs["params"]).d
    agg_state = jax.eval_shape(
        lambda: strategy.init_state(n_clients(mesh), d_flat)
    )
    return {
        "params": shard_rules.param_shardings(cfg, specs["params"], mesh,
                                              fsdp=fsdp),
        "server_state": shard_rules.param_shardings(cfg, specs["server_state"],
                                                    mesh, fsdp=fsdp),
        "agg_state": shard_rules.client_state_shardings(mesh, agg_state,
                                                        n_clients(mesh)),
    }


def build_step(
    arch_id: str,
    shape_name: str,
    mesh,
    *,
    aggregation: StrategySpec = "colrel",
    fl_mode: str | None = None,
    cfg_override=None,
    scan_rounds: int | None = None,
    telemetry: bool = False,
) -> Tuple[Any, Dict[str, Any], Any, Any]:
    mode = fl_mode or (cfg_override or get_arch_cfg(arch_id)).fl_mode
    specs = input_specs(arch_id, shape_name, mesh, cfg=cfg_override, fl_mode=mode)
    cfg = specs["cfg"]
    fsdp = mode in ("client_sequential", "weighted_grad", "weighted_flat")
    ca = client_axes(mesh)

    if cfg.n_experts > 0:
        # expert-parallel dispatch buffers (all step kinds)
        if cfg.n_experts % mesh.shape["model"] == 0:
            cfg = cfg.replace(moe_buf_spec=("model", None, None))
        else:
            cfg = cfg.replace(moe_buf_spec=(None, "model", None))
        specs["cfg"] = cfg

    caxis_spec = ca if len(ca) > 1 else ca[0]
    if specs["kind"] == "train":
        # Residual-stream layout (see repro/dist/constraints.py):
        #  * fsdp (ZeRO) giants: per-client batch over the model axis when it
        #    divides, else sequence over model — makes the partitioner gather
        #    weights instead of all-reducing activation partials.
        #  * per_client archs: Megatron-SP-style sequence sharding over the
        #    model axis (the client lane is already pinned to the data axes
        #    via spmd_axis_name; without this, backward intermediates
        #    replicate over the model axis).
        C = n_clients(mesh)
        from repro.configs.base import INPUT_SHAPES

        B = INPUT_SHAPES[shape_name].global_batch // C
        if mode == "weighted_flat":
            # pin the flat batch to the full-mesh layout at every block
            # boundary (without this the partitioner drifts to replication)
            gb = INPUT_SHAPES[shape_name].global_batch
            full = (*ca, "model")
            n_full = 1
            for a in full:
                n_full *= mesh.shape[a]
            if gb % n_full == 0:
                cfg = cfg.replace(act_spec=(full, None, None))
            else:
                cfg = cfg.replace(act_spec=(caxis_spec, "model", None))
        elif fsdp and B % mesh.shape["model"] == 0:
            cfg = cfg.replace(act_spec=("model", None, None))
        else:
            cfg = cfg.replace(act_spec=(None, "model", None))
        specs["cfg"] = cfg
    elif specs["kind"] == "prefill" and (fsdp or cfg.n_experts == 0):
        # prefill: batch over the client axes, sequence over model.
        # (skipped for per_client MoE archs — sequence-sharded tokens fight
        # the capacity-dispatch scatter and regress memory; measured on
        # granite: 31 GB -> 122 GB with the constraint.)
        cfg = cfg.replace(act_spec=(caxis_spec, "model", None))
        specs["cfg"] = cfg
    bundle = build(cfg)

    if specs["kind"] == "train":
        strategy = strategy_registry.resolve(aggregation)
        rc = RoundConfig(
            n_clients=n_clients(mesh),
            local_steps=DRYRUN_LOCAL_STEPS,
            mode=mode,
            aggregation=strategy,
            spmd_axes=ca if mode in ("per_client", "weighted_grad") else None,
            unroll=getattr(cfg, "scan_unroll", False),
        )
        psh = shard_rules.param_shardings(cfg, specs["params"], mesh, fsdp=fsdp)
        # async strategies (DESIGN.md §13) lower through the async round
        # builders: same signatures, agg_state additionally carries the
        # (n,) age vector + (n, d) staging buffer (client-axis sharded by
        # client_state_shardings below) and three extra scalar metrics.
        is_async = getattr(strategy, "is_async", False)
        make_fn = make_async_round_fn if is_async else make_round_fn
        if scan_rounds:
            K = int(scan_rounds)
            make_fn = make_async_scan_round_fn if is_async else make_scan_round_fn
            # leading K-round axis on the scanned per-round inputs
            SDS = jax.ShapeDtypeStruct
            specs["batches"] = jax.tree.map(
                lambda s: SDS((K, *s.shape), s.dtype), specs["batches"])
            specs["tau_up"] = SDS((K, *specs["tau_up"].shape),
                                  specs["tau_up"].dtype)
            specs["tau_dd"] = SDS((K, *specs["tau_dd"].shape),
                                  specs["tau_dd"].dtype)
        round_fn = make_fn(
            bundle.loss_fn,
            sgd(CLIENT_LR, weight_decay=CLIENT_WD),
            sgd_momentum(1.0, beta=SERVER_MOMENTUM),
            rc,
            grad_shardings=psh if fsdp else None,
            telemetry=telemetry,
        )
        # strategy carried state (replay buffers etc.): lower against its
        # abstract shape; client-indexed leaves (the memory strategy's
        # (n, d) buffer) shard over the client axes next to the update
        # stack, everything else replicates
        d_flat = flatten.flat_spec(specs["params"]).d
        agg_state = jax.eval_shape(
            lambda: strategy.init_state(rc.n_clients, d_flat)
        )
        ssh = shard_rules.param_shardings(cfg, specs["server_state"], mesh, fsdp=fsdp)
        bsh = shard_rules.train_batch_shardings(
            mesh, mode, specs["batches"], scan=bool(scan_rounds))
        rep = NamedSharding(mesh, P())
        st_sh = shard_rules.client_state_shardings(mesh, agg_state, rc.n_clients)
        # connectivity realizations + relay weights shard along the client
        # axes together with the update stack (scalax-style rule,
        # launch/sharding.fl_round_rule): dense (n, n) operands shard rows,
        # block (C, m, m) cluster tensors shard the cluster axis; the scan
        # trace's leading K axis stays unsharded.  A carries no K axis.
        tau_sh = shard_rules.fl_round_rule(scan=bool(scan_rounds)).shardings(
            mesh, {"tau_up": specs["tau_up"], "tau_dd": specs["tau_dd"]})
        A_sh = shard_rules.fl_round_rule().shardings(mesh, {"A": specs["A"]})["A"]
        in_sh = (psh, ssh, st_sh, bsh, tau_sh["tau_up"], tau_sh["tau_dd"], A_sh)
        metrics_sh = {
            "loss": rep,
            "delta_norm": rep,
            "participation": rep,
            "uplink_bits": rep,
            "weight_sum": rep,
        }
        if is_async:
            metrics_sh = dict(metrics_sh, mean_age=rep, max_age=rep,
                              stale_frac=rep)
        out_sh = (psh, ssh, st_sh, metrics_sh)
        lower_args = (
            specs["params"],
            specs["server_state"],
            agg_state,
            specs["batches"],
            specs["tau_up"],
            specs["tau_dd"],
            specs["A"],
        )
        if telemetry:
            # instrumented round (DESIGN.md §11): an (n,) int32 outage-
            # streak carry rides as one extra operand/result, and the
            # metrics dict grows the per-client vector streams — the
            # vectors shard their client dim exactly like tau_up (they
            # are lane-local reads of it), stacked (K, n) under scan.
            import jax.numpy as jnp

            SDS = jax.ShapeDtypeStruct
            C = rc.n_clients
            specs["streak"] = SDS((C,), jnp.int32)
            streak_sh = shard_rules.telemetry_rule().shardings(
                mesh, {"streak": specs["streak"]})["streak"]
            lead = (int(scan_rounds),) if scan_rounds else ()
            vec = {
                "client_participation": SDS((*lead, C), jnp.float32),
                "client_uplink_bits": SDS((*lead, C), jnp.float32),
                "outage_streak": SDS((*lead, C), jnp.int32),
            }
            metrics_sh = dict(
                metrics_sh,
                weight_drift=rep,
                **shard_rules.telemetry_rule(
                    scan=bool(scan_rounds)).shardings(mesh, vec),
            )
            in_sh = (*in_sh, streak_sh)
            out_sh = (psh, ssh, st_sh, streak_sh, metrics_sh)
            lower_args = (*lower_args, specs["streak"])
        # the round's carry slots (params / server_state / agg_state, plus
        # the telemetry streak) alias their outputs 1:1 — consumers jit with
        # these to keep one live (n, d) generation instead of two
        # (DESIGN.md §14).  Taus, batches and A are never donated.
        round_fn.donate_argnums = (0, 1, 2) + ((7,) if telemetry else ())
        return round_fn, lower_args, in_sh, out_sh

    if specs["kind"] == "prefill":

        def prefill_step(params, batch):
            # serving prefill: populate activations, emit last-position
            # logits only (the full (B, S, V) tensor is never needed).
            return bundle.forward(params, batch)[:, -1, :]

        psh = shard_rules.param_shardings(cfg, specs["params"], mesh, fsdp=fsdp)
        bsh = jax.tree.map(
            lambda s: shard_rules.serve_batch_sharding(mesh, s.shape), specs["batch"]
        )
        B, S = specs["batch"]["tokens"].shape
        caxis = ca if len(ca) > 1 else ca[0]
        logits_spec = [None, "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None]
        if B % n_clients(mesh) == 0 and B > 1:
            logits_spec[0] = caxis
        out_sh = NamedSharding(mesh, P(*logits_spec))
        return prefill_step, (specs["params"], specs["batch"]), (psh, bsh), out_sh

    # decode
    def serve_step(params, cache, token, pos):
        return bundle.decode_step(params, cache, token, pos)

    psh = shard_rules.param_shardings(cfg, specs["params"], mesh, fsdp=fsdp)
    csh = shard_rules.cache_shardings(cfg, mesh, specs["cache"])
    tsh = shard_rules.serve_batch_sharding(mesh, specs["token"].shape)
    rep = NamedSharding(mesh, P())
    B = specs["token"].shape[0]
    caxis = ca if len(ca) > 1 else ca[0]
    lspec = [None, "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None]
    if B % n_clients(mesh) == 0 and B > 1:
        lspec[0] = caxis
    out_sh = (NamedSharding(mesh, P(*lspec)), csh)
    in_sh = (psh, csh, tsh, rep)
    lower_args = (specs["params"], specs["cache"], specs["token"], specs["pos"])
    return serve_step, lower_args, in_sh, out_sh
