import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against ShapeDtypeStruct inputs, capture memory / cost /
collective analyses, and emit the JSON records the roofline report reads.

MUST be invoked as its own process (the XLA flag above must precede any
jax initialization):

    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import math
import time
import traceback
from pathlib import Path

import jax

from repro.configs.base import CLI_ALIASES, INPUT_SHAPES, get_arch, supported_shapes
from repro import strategies as strategy_registry
from repro.launch.mesh import make_production_mesh, n_clients
from repro.launch.roofline import (
    collective_bytes,
    count_params_split,
    model_flops,
    roofline_terms,
)
from repro.launch.specs import DRYRUN_LOCAL_STEPS
from repro.launch.steps import build_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def _cost_dict(compiled) -> dict:
    """compiled.cost_analysis() returns a dict, a per-device list of dicts,
    or None depending on jax version/backend — normalize to one dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    return cost or {}


def _arg_bytes(lower_args, in_sh) -> tuple:
    """(global, per-shard) argument bytes from the lowering specs and the
    requested input shardings.  XLA's ``memory_analysis`` prices arguments
    at their unpartitioned size on some backends, so client-axis-sharded
    inputs (the (n, d) stacks, tau traces, block tensors) would read as
    fully replicated; ``Sharding.shard_shape`` gives the true per-device
    slice."""
    args = jax.tree.leaves(lower_args)
    shs = jax.tree.leaves(in_sh)
    assert len(args) == len(shs), (len(args), len(shs))
    total = per_shard = 0
    for a, s in zip(args, shs):
        nbytes = math.prod(a.shape) * a.dtype.itemsize
        total += nbytes
        per_shard += math.prod(s.shard_shape(a.shape)) * a.dtype.itemsize
    return int(total), int(per_shard)


def _tokens_for(shape_name: str, fl_mode: str) -> float:
    s = INPUT_SHAPES[shape_name]
    if s.kind == "train":
        t = 1 if fl_mode == "weighted_grad" else DRYRUN_LOCAL_STEPS
        return float(t * s.global_batch * s.seq_len)
    if s.kind == "prefill":
        return float(s.global_batch * s.seq_len)
    return float(s.global_batch)  # decode: one token per sequence


# ---------------------------------------------------------------------------
# Depth probes: XLA's cost_analysis counts a while-loop body ONCE, so rolled
# layer scans undercount FLOPs/bytes/collectives by ~n_layers.  Every arch
# here is linear in depth, so two shallow lowerings (1 and 2 depth units)
# give the exact per-unit increment:  cost(L) = cost(2) + (L-2) * delta.
# ---------------------------------------------------------------------------


def _depth_units(cfg) -> int:
    if cfg.arch_type == "hybrid":
        return cfg.n_layers // cfg.attn_every
    return cfg.n_layers


def _with_depth(cfg, k: int):
    # scan_unroll=True: probe lowerings unroll every structural scan so
    # cost_analysis sees each body exactly once per execution.
    if cfg.arch_type == "hybrid":
        return cfg.replace(n_layers=k * cfg.attn_every, scan_unroll=True)
    if cfg.arch_type in ("encdec", "audio"):
        return cfg.replace(n_layers=k, n_encoder_layers=k, scan_unroll=True)
    return cfg.replace(n_layers=k, scan_unroll=True)


def _probe_costs(arch_id, shape_name, mesh, aggregation, fl_mode, cfg, k) -> dict:
    step, lower_args, in_sh, out_sh = build_step(
        arch_id, shape_name, mesh, aggregation=aggregation, fl_mode=fl_mode,
        cfg_override=_with_depth(cfg, k),
    )
    with mesh:
        compiled = jax.jit(
            step, in_shardings=in_sh, out_shardings=out_sh,
            donate_argnums=getattr(step, "donate_argnums", ()),
        ).lower(*lower_args).compile()
        cost = _cost_dict(compiled)
        coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(sum(coll.values())),
        "coll_by_op": coll,
    }


def run_one(arch_id: str, shape_name: str, *, multi_pod: bool,
            aggregation: str = "colrel",
            fl_mode: str | None = None, tag: str = "",
            probe: bool = True, static_window: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    cfg_override = None
    if static_window:
        cfg_override = get_arch(arch_id).full().replace(static_window_pattern=True)
    t0 = time.time()
    step, lower_args, in_sh, out_sh = build_step(
        arch_id, shape_name, mesh, aggregation=aggregation, fl_mode=fl_mode,
        cfg_override=cfg_override,
    )
    with mesh:
        # donate the carry slots the step declares (train rounds): the
        # lowering then prices params/server_state/agg_state once via
        # input-output aliasing instead of twice (alias_size_in_bytes
        # shows the reclaimed residency)
        lowered = jax.jit(
            step, in_shardings=in_sh, out_shardings=out_sh,
            donate_argnums=getattr(step, "donate_argnums", ()),
        ).lower(*lower_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = _cost_dict(compiled)
        hlo = compiled.as_text()

    coll = collective_bytes(hlo)
    coll_total = float(sum(coll.values()))
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))

    # depth-probe correction for rolled scans
    cfg0 = cfg_override if cfg_override is not None else get_arch(arch_id).full()
    probe_info = None
    if probe:
        p1 = _probe_costs(arch_id, shape_name, mesh, aggregation, fl_mode, cfg0, 1)
        p2 = _probe_costs(arch_id, shape_name, mesh, aggregation, fl_mode, cfg0, 2)
        L = _depth_units(cfg0)
        # clamp: XLA may choose different collective strategies at different
        # depths, which can make a raw difference negative; the extrapolation
        # is never allowed below the 2-layer measurement itself.
        ext = lambda a, b: max(b + (L - 2) * (b - a), b)
        flops = ext(p1["flops"], p2["flops"])
        byts = ext(p1["bytes"], p2["bytes"])
        coll = {
            op: ext(p1["coll_by_op"][op], p2["coll_by_op"][op])
            for op in p2["coll_by_op"]
        }
        coll_total = float(sum(coll.values()))
        probe_info = {"units": L, "probe1": p1, "probe2": p2,
                      "rolled_flops": float(cost.get("flops", 0.0))}

    terms = roofline_terms(flops, byts, coll_total)

    from repro.models import build as build_model

    pcounts = count_params_split(
        jax.eval_shape(lambda k: build_model(cfg0).init(k), jax.random.PRNGKey(0)),
        cfg0.n_experts, cfg0.top_k,
    )
    kind = INPUT_SHAPES[shape_name].kind
    mflops = model_flops("train" if kind == "train" else "serve",
                         pcounts["active"],
                         _tokens_for(shape_name, fl_mode or cfg0.fl_mode))
    mflops_per_chip = mflops / chips

    mem_attrs = {}
    for a in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, a, None)
        if v is not None:
            mem_attrs[a] = int(v)
    arg_global, arg_shard = _arg_bytes(lower_args, in_sh)
    mem_attrs["argument_bytes_global"] = arg_global
    mem_attrs["argument_bytes_per_shard"] = arg_shard
    raw = mem_attrs.get("argument_size_in_bytes")
    if raw is not None and arg_shard < arg_global and raw >= arg_global:
        # XLA counted sharded arguments at full (replicated) size — report
        # the true per-shard residency; the raw figure stays for auditing.
        mem_attrs["argument_size_in_bytes_reported"] = raw
        mem_attrs["argument_size_in_bytes"] = arg_shard

    record = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "n_clients": n_clients(mesh),
        "aggregation": strategy_registry.canonical_name(aggregation),
        "fl_mode": fl_mode or cfg0.fl_mode,
        "tag": tag,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": byts,
        "collective_bytes_per_chip": coll_total,
        "collectives": coll,
        "roofline": terms,
        "params_total": pcounts["total"],
        "params_active": pcounts["active"],
        "model_flops_per_chip": mflops_per_chip,
        "useful_flop_ratio": (mflops_per_chip / flops) if flops else None,
        "memory_analysis": mem_attrs,
        "probe": probe_info,
    }
    print(f"== {arch_id} x {shape_name} x {record['mesh']} "
          f"(agg={record['aggregation']}, mode={record['fl_mode']}{', ' + tag if tag else ''})")
    print(f"   memory_analysis: {mem_attrs}")
    print(f"   cost_analysis: flops/chip={flops:.3e} bytes/chip={byts:.3e}")
    print(f"   collectives/chip: {coll_total:.3e} B  breakdown={ {k: f'{v:.2e}' for k, v in coll.items() if v} }")
    print(f"   roofline: compute={terms['compute_s']:.4f}s memory={terms['memory_s']:.4f}s "
          f"collective={terms['collective_s']:.4f}s -> {terms['bottleneck']}")
    print(f"   useful_flop_ratio={record['useful_flop_ratio'] and round(record['useful_flop_ratio'], 3)} "
          f"lower={t_lower:.1f}s compile={t_compile:.1f}s", flush=True)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (CLI form) or 'all'")
    ap.add_argument("--shape", default=None, help="input shape name or 'all'")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="every supported arch x shape")
    ap.add_argument("--aggregation", default="colrel",
                    choices=sorted(strategy_registry.available()))
    ap.add_argument("--fl-mode", default=None,
                    choices=[None, "per_client", "client_sequential",
                             "weighted_grad", "weighted_flat"])
    ap.add_argument("--tag", default="", help="label recorded for perf iterations")
    ap.add_argument("--static-window", action="store_true",
                    help="unrolled static local/global pattern (banded attention)")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    archs = list(CLI_ALIASES) if (args.all or args.arch in (None, "all")) else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_fail = 0
    for arch in archs:
        shapes = supported_shapes(arch) if (args.all or args.shape in (None, "all")) \
            else [args.shape]
        for shape in shapes:
            if shape not in supported_shapes(arch):
                print(f"-- skipping unsupported {arch} x {shape}")
                continue
            for mp in meshes:
                mesh_tag = "2x16x16" if mp else "16x16"
                suffix = f"_{args.tag}" if args.tag else ""
                fname = out_dir / f"{arch}_{shape}_{mesh_tag}_{args.aggregation}{suffix}.json"
                if args.skip_existing and fname.exists():
                    print(f"-- cached {fname.name}")
                    n_ok += 1
                    continue
                try:
                    # cost probes only on the single-pod mesh (the roofline
                    # table is single-pod; multi-pod proves lowering+memory)
                    rec = run_one(arch, shape, multi_pod=mp,
                                  aggregation=args.aggregation,
                                  fl_mode=args.fl_mode, tag=args.tag,
                                  probe=not mp, static_window=args.static_window)
                    fname.write_text(json.dumps(rec, indent=1))
                    n_ok += 1
                except Exception:
                    n_fail += 1
                    print(f"!! FAILED {arch} x {shape} x {mesh_tag}")
                    traceback.print_exc()
    print(f"\nDRY-RUN SUMMARY: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
