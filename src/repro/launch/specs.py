"""ShapeDtypeStruct stand-ins for every model input — the dry-run lowers
against these (weak-type-correct, shardable, zero device allocation).

``input_specs(arch_id, shape_name, mesh)`` returns a dict:
  train:   params/server_state/batches/tau_up/tau_dd/A  (the FL round)
  prefill: params/batch
  decode:  params/cache/token/pos
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import INPUT_SHAPES, get_arch
from repro.models import build
from repro.models.common import ModelConfig

SDS = jax.ShapeDtypeStruct

DRYRUN_LOCAL_STEPS = 2  # T for the dry-run round (paper uses 8; FLOPs scale linearly)


def _sds_like(tree: Any) -> Any:
    return jax.tree.map(lambda x: SDS(x.shape, x.dtype), tree)


def params_spec(bundle) -> Any:
    return jax.eval_shape(lambda k: bundle.init(k), jax.random.PRNGKey(0))


def _train_batch_spec(cfg: ModelConfig, n_clients: int, shape, mode: str) -> Dict[str, SDS]:
    S = shape.seq_len
    B = shape.global_batch // n_clients
    assert B >= 1, (shape.name, n_clients)
    if mode == "weighted_flat":  # flat T = 1 round: (C*B, ...) batches
        lead = (shape.global_batch,)
    elif mode == "weighted_grad":  # T = 1 collapse: (C, B, ...) batches
        lead = (n_clients, B)
    else:
        lead = (n_clients, DRYRUN_LOCAL_STEPS, B)
    spec = {
        "tokens": SDS((*lead, S), jnp.int32),
        "labels": SDS((*lead, S), jnp.int32),
    }
    if cfg.frontend_tokens:
        spec["prefix"] = SDS((*lead, cfg.frontend_tokens, cfg.d_model), cfg.jdtype)
    return spec


def input_specs(arch_id: str, shape_name: str, mesh, cfg: ModelConfig | None = None,
                fl_mode: str | None = None) -> Dict[str, Any]:
    """All lowering inputs for one (arch x input-shape) combination."""
    from repro.launch.mesh import n_clients as mesh_clients

    if cfg is None:
        cfg = get_arch(arch_id).full()
    fl_mode = fl_mode or cfg.fl_mode
    bundle = build(cfg)
    shape = INPUT_SHAPES[shape_name]
    C = mesh_clients(mesh)

    if shape.kind == "train":
        pspec = params_spec(bundle)
        from repro.optim import sgd_momentum

        sstate_spec = jax.eval_shape(
            lambda p: sgd_momentum(1.0, beta=0.9).init(p), pspec
        )
        return {
            "kind": "train",
            "cfg": cfg,
            "params": pspec,
            "server_state": sstate_spec,
            "batches": _train_batch_spec(cfg, C, shape, fl_mode),
            "tau_up": SDS((C,), jnp.float32),
            "tau_dd": SDS((C, C), jnp.float32),
            "A": SDS((C, C), jnp.float32),
        }

    if shape.kind == "prefill":
        B, S = shape.global_batch, shape.seq_len
        batch: Dict[str, Any] = {"tokens": SDS((B, S), jnp.int32)}
        if cfg.frontend_tokens:
            batch["prefix"] = SDS((B, cfg.frontend_tokens, cfg.d_model), cfg.jdtype)
        return {"kind": "prefill", "cfg": cfg, "params": params_spec(bundle), "batch": batch}

    # decode: one new token against a seq_len-deep cache
    B, S = shape.global_batch, shape.seq_len
    if cfg.arch_type in ("encdec", "audio"):
        cache_spec = jax.eval_shape(lambda: bundle.init_cache(B, S, cfg.frontend_tokens))
    else:
        cache_spec = jax.eval_shape(lambda: bundle.init_cache(B, S))
    return {
        "kind": "decode",
        "cfg": cfg,
        "params": params_spec(bundle),
        "cache": cache_spec,
        "token": SDS((B, 1), jnp.int32),
        "pos": SDS((), jnp.int32),
    }
