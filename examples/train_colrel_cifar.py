"""End-to-end reproduction of the paper's CIFAR-10 experiment (Sec. V).

ResNet-20-family CNN, n=10 clients, T=8 local steps, SGD lr=0.05 +
weight decay 1e-4, PS momentum 0.9 — every protocol constant at the
paper's value.  Data is synthetic-CIFAR (offline container; see
DESIGN.md §7).  Saves a JSON training log + msgpack checkpoint.

    PYTHONPATH=src python examples/train_colrel_cifar.py \
        --topology fig2b --strategy colrel --non-iid-s 3 --rounds 200

The whole experiment is one declarative :class:`ExperimentSpec`
(``repro/fl/experiment.py``); this script is argv -> spec -> run.
``--strategy`` enumerates the open strategy registry
(``repro.strategies``), so schemes registered out of tree — like the
beyond-paper ``multihop`` (K-hop relaying) and ``memory`` (implicit
gossip) strategies — appear here automatically; pass constructor
options as ``--strategy-opt hops=3``.

Beyond the paper, ``--channel`` swaps the i.i.d. connectivity for a
dynamic channel preset (``markov`` = bursty Gilbert–Elliott blockage
with the same marginals, ``mobility`` = waypoint-drifting mmWave
geometry; see ``repro/configs/channels.py``), and ``--adaptive`` drops
the oracle link knowledge: alpha is re-optimized every ``--reopt-every``
rounds from online link estimates.

    PYTHONPATH=src python examples/train_colrel_cifar.py \
        --channel markov --strategy memory --rounds 200
"""

import argparse
import json

from repro import strategies
from repro.checkpoint import save_checkpoint
from repro.configs import CHANNEL_PRESETS
from repro.fl import TOPOLOGIES, ExperimentSpec, build_experiment


def parse_opt(kv: str):
    """``key=value`` -> ``(key, typed value)``.

    Values are decoded, not passed through as bare strings: ints,
    floats, ``true``/``false`` and ``none`` all arrive as their Python
    types (strategy constructors like ``multihop(hops=3)`` take typed
    arguments).  Dotted keys address nested option dicts — see
    :func:`build_options`.
    """
    key, sep, raw = kv.partition("=")
    if not sep:
        raise argparse.ArgumentTypeError(f"expected key=value, got {kv!r}")
    for cast in (int, float):
        try:
            return key, cast(raw)
        except ValueError:
            pass
    low = raw.lower()
    if low in ("true", "false"):
        return key, low == "true"
    if low == "none":
        return key, None
    return key, raw


def build_options(pairs):
    """``[(key, value), ...]`` -> kwargs dict, expanding dotted keys
    into nested dicts: ``codec_options.bits=4`` becomes
    ``{"codec_options": {"bits": 4}}`` (how the ``quantized`` strategy's
    codec options are spelled on the command line)."""
    out = {}
    for key, value in pairs:
        parts = key.split(".")
        node = out
        for p in parts[:-1]:
            node = node.setdefault(p, {})
            if not isinstance(node, dict):
                raise SystemExit(f"--strategy-opt {key}: {p!r} is already "
                                 "a scalar option")
        leaf = parts[-1]
        if isinstance(node.get(leaf), dict) and not isinstance(value, dict):
            raise SystemExit(f"--strategy-opt {key}: {leaf!r} already holds "
                             "nested options")
        node[leaf] = value
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--topology", default="fig2b", choices=sorted(TOPOLOGIES))
    ap.add_argument("--strategy", default="colrel",
                    choices=sorted(strategies.available()))
    ap.add_argument("--strategy-opt", action="append", default=[],
                    type=parse_opt, metavar="KEY=VALUE",
                    help="strategy constructor option (repeatable, typed, "
                         "dotted keys nest), e.g. --strategy-opt hops=3 or "
                         "--strategy-opt codec_options.bits=4")
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--non-iid-s", type=int, default=0, help="0 = IID")
    ap.add_argument("--channel", default="static", choices=sorted(CHANNEL_PRESETS),
                    help="link dynamics preset (repro/configs/channels.py)")
    ap.add_argument("--adaptive", action="store_true",
                    help="estimate links online + re-optimize alpha "
                         "(no oracle link knowledge)")
    ap.add_argument("--reopt-every", type=int, default=50,
                    help="adaptive alpha re-optimization cadence (rounds)")
    ap.add_argument("--chunk", type=int, default=1,
                    help="rounds per compiled scan chunk (DESIGN.md §9); "
                         "must divide the eval/re-opt cadences or the "
                         "trainer falls back to the per-round loop")
    ap.add_argument("--full-width", action="store_true",
                    help="paper-width ResNet-20 (slow on CPU)")
    ap.add_argument("--out", default="colrel_cifar")
    ap.add_argument("--metrics-dir", default=None,
                    help="telemetry dir (events.jsonl, rounds.csv, "
                         "manifest.json, vectors.npz); implies the "
                         "instrumented round (DESIGN.md §11)")
    ap.add_argument("--log-every", type=int, default=0,
                    help="print cumulative rounds/sec every N rounds")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace into this dir")
    ap.add_argument("--profile-rounds", type=int, default=4,
                    help="profiler window length in rounds")
    args = ap.parse_args()

    strategy_options = build_options(args.strategy_opt)
    if args.adaptive:
        # derive the guard from the registry, not a hardcoded name list:
        # adaptive re-optimizes alpha, which only A-reading strategies use
        probe = strategies.get(args.strategy, **strategy_options)
        if not probe.needs_A:
            raise SystemExit(
                f"--adaptive re-optimizes the relay alpha, which "
                f"{args.strategy!r} ignores (needs_A=False); A-reading "
                f"strategies: "
                f"{[n for n in strategies.available() if strategies.get(n).needs_A]}"
            )

    spec = ExperimentSpec(
        model="cifar_cnn_full" if args.full_width else "cifar_cnn",
        topology=args.topology,
        non_iid_s=args.non_iid_s,
        strategy=args.strategy,
        strategy_options=strategy_options,
        channel=args.channel,
        adaptive=args.adaptive,
        reopt_every=args.reopt_every,
        rounds=args.rounds,
        chunk=args.chunk,
        metrics_dir=args.metrics_dir,
        log_every=args.log_every,
        profile_dir=args.profile_dir,
        profile_rounds=args.profile_rounds,
    )
    exp = build_experiment(spec)
    if exp.copt_result is not None:
        res = exp.copt_result
        print(f"COPT-alpha: S {res.S_init:.2f} -> {res.S:.2f}")
    elif args.adaptive:
        print(f"adaptive alpha: identity start, re-opt every {args.reopt_every}")
    exp.run(eval_every=max(args.rounds // 10, 1), verbose=True)
    exp.close()  # per-client summary event + vectors.npz + sink flush

    log = exp.log.to_dict()
    log["config"] = {**vars(args), "strategy_opt": strategy_options}
    with open(f"{args.out}.json", "w") as f:
        json.dump(log, f, indent=1)
    save_checkpoint(f"{args.out}.msgpack", exp.params)
    final = exp.log.eval_metrics[-1] if exp.log.eval_metrics else {}
    print(f"\nfinal: {final}  (log -> {args.out}.json, ckpt -> {args.out}.msgpack)")


if __name__ == "__main__":
    main()
