"""End-to-end reproduction of the paper's CIFAR-10 experiment (Sec. V).

ResNet-20-family CNN, n=10 clients, T=8 local steps, SGD lr=0.05 +
weight decay 1e-4, PS momentum 0.9 — every protocol constant at the
paper's value.  Data is synthetic-CIFAR (offline container; see
DESIGN.md §7).  Saves a JSON training log + msgpack checkpoint.

    PYTHONPATH=src python examples/train_colrel_cifar.py \
        --topology fig2b --strategy colrel --non-iid-s 3 --rounds 200
"""

import argparse
import json

import jax
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import colrel_paper
from repro.core import Aggregation, fedavg_weights, optimize_weights, topology
from repro.data import partition_iid, partition_sort_and_partition, synthetic_cifar
from repro.data.pipeline import make_federated_clients
from repro.fl import FLTrainer
from repro.models import build
from repro.optim import sgd, sgd_momentum

TOPOLOGIES = {
    "fig2a": lambda: topology.paper_fig2a(),
    "fig2b": lambda: topology.paper_fig2b(),
    "mmwave_int": lambda: topology.paper_mmwave_layout(d2d_mode="intermittent"),
    "mmwave_perm": lambda: topology.paper_mmwave_layout(d2d_mode="permanent"),
    "no_collab": lambda: topology.no_collaboration(10, 0.3),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--topology", default="fig2b", choices=sorted(TOPOLOGIES))
    ap.add_argument("--strategy", default="colrel",
                    choices=["colrel", "fedavg_blind", "fedavg_nonblind", "fedavg_perfect"])
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--non-iid-s", type=int, default=0, help="0 = IID")
    ap.add_argument("--full-width", action="store_true",
                    help="paper-width ResNet-20 (slow on CPU)")
    ap.add_argument("--out", default="colrel_cifar")
    args = ap.parse_args()

    setup = colrel_paper.full() if args.full_width else colrel_paper.reduced()
    link_model = TOPOLOGIES[args.topology]()

    if args.strategy == "colrel":
        res = optimize_weights(link_model, sweeps=30, fine_tune_sweeps=30)
        A, agg = res.A, Aggregation.COLREL
        print(f"COPT-alpha: S {res.S_init:.2f} -> {res.S:.2f}")
    else:
        A, agg = fedavg_weights(link_model.n), Aggregation(args.strategy)

    images, labels = synthetic_cifar(n=10000, seed=1)
    ev_img, ev_lab = synthetic_cifar(n=2000, seed=2)
    if args.non_iid_s:
        parts = partition_sort_and_partition(labels, link_model.n, s=args.non_iid_s)
    else:
        parts = partition_iid(len(labels), link_model.n)
    clients = make_federated_clients({"images": images, "labels": labels}, parts,
                                     setup.batch_size)

    bundle = build(setup.cnn)

    @jax.jit
    def eval_fn(params):
        _, m = bundle.loss_fn(params, {"images": ev_img, "labels": ev_lab})
        return m

    trainer = FLTrainer(
        bundle.loss_fn, bundle.init(jax.random.PRNGKey(0)), link_model, A, clients,
        sgd(setup.lr, weight_decay=setup.weight_decay),
        sgd_momentum(1.0, beta=setup.server_momentum),
        local_steps=setup.local_steps, aggregation=agg, seed=0,
        eval_fn=eval_fn,
    )
    trainer.run(args.rounds, eval_every=max(args.rounds // 10, 1), verbose=True)

    log = trainer.log.to_dict()
    log["config"] = vars(args)
    with open(f"{args.out}.json", "w") as f:
        json.dump(log, f, indent=1)
    save_checkpoint(f"{args.out}.msgpack", trainer.params)
    final = trainer.log.eval_metrics[-1] if trainer.log.eval_metrics else {}
    print(f"\nfinal: {final}  (log -> {args.out}.json, ckpt -> {args.out}.msgpack)")


if __name__ == "__main__":
    main()
