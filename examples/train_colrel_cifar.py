"""End-to-end reproduction of the paper's CIFAR-10 experiment (Sec. V).

ResNet-20-family CNN, n=10 clients, T=8 local steps, SGD lr=0.05 +
weight decay 1e-4, PS momentum 0.9 — every protocol constant at the
paper's value.  Data is synthetic-CIFAR (offline container; see
DESIGN.md §7).  Saves a JSON training log + msgpack checkpoint.

    PYTHONPATH=src python examples/train_colrel_cifar.py \
        --topology fig2b --strategy colrel --non-iid-s 3 --rounds 200

Beyond the paper, ``--channel`` swaps the i.i.d. connectivity for a
dynamic channel preset (``markov`` = bursty Gilbert–Elliott blockage
with the same marginals, ``mobility`` = waypoint-drifting mmWave
geometry; see ``repro/configs/channels.py``), and ``--adaptive`` drops
the oracle link knowledge: alpha is re-optimized every ``--reopt-every``
rounds from online link estimates.

    PYTHONPATH=src python examples/train_colrel_cifar.py \
        --channel markov --adaptive --rounds 200
"""

import argparse
import json

import jax
import numpy as np

from repro.channel import AdaptiveConfig, AdaptiveWeightSchedule
from repro.checkpoint import save_checkpoint
from repro.configs import CHANNEL_PRESETS, colrel_paper, make_channel
from repro.core import Aggregation, fedavg_weights, optimize_weights, topology
from repro.data import partition_iid, partition_sort_and_partition, synthetic_cifar
from repro.data.pipeline import make_federated_clients
from repro.fl import FLTrainer
from repro.models import build
from repro.optim import sgd, sgd_momentum

TOPOLOGIES = {
    "fig2a": lambda: topology.paper_fig2a(),
    "fig2b": lambda: topology.paper_fig2b(),
    "mmwave_int": lambda: topology.paper_mmwave_layout(d2d_mode="intermittent"),
    "mmwave_perm": lambda: topology.paper_mmwave_layout(d2d_mode="permanent"),
    "no_collab": lambda: topology.no_collaboration(10, 0.3),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--topology", default="fig2b", choices=sorted(TOPOLOGIES))
    ap.add_argument("--strategy", default="colrel",
                    choices=["colrel", "fedavg_blind", "fedavg_nonblind", "fedavg_perfect"])
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--non-iid-s", type=int, default=0, help="0 = IID")
    ap.add_argument("--channel", default="static", choices=sorted(CHANNEL_PRESETS),
                    help="link dynamics preset (repro/configs/channels.py)")
    ap.add_argument("--adaptive", action="store_true",
                    help="estimate links online + re-optimize alpha "
                         "(no oracle link knowledge)")
    ap.add_argument("--reopt-every", type=int, default=50,
                    help="adaptive alpha re-optimization cadence (rounds)")
    ap.add_argument("--full-width", action="store_true",
                    help="paper-width ResNet-20 (slow on CPU)")
    ap.add_argument("--out", default="colrel_cifar")
    args = ap.parse_args()

    setup = colrel_paper.full() if args.full_width else colrel_paper.reduced()
    link_model = TOPOLOGIES[args.topology]()
    channel = make_channel(args.channel, link_model, seed=0)
    # mobility derives its own (drifting) geometry; round-0 model otherwise
    # equals the chosen topology (markov preserves its marginals exactly)
    init_model = channel.model_for_round(0)

    adaptive = None
    if args.adaptive:
        if args.strategy != "colrel":
            raise SystemExit(
                "--adaptive re-optimizes the relay alpha, which only the "
                "colrel strategy reads; fedavg_* baselines ignore A"
            )
        adaptive = AdaptiveWeightSchedule(
            init_model.n,
            AdaptiveConfig(
                every=args.reopt_every,
                warmup=min(args.reopt_every, 20),
                # forget old evidence under drifting geometry
                decay=0.995 if args.channel.startswith("mobility") else 1.0,
                prune_below=0.02,
            ),
        )

    if args.strategy == "colrel":
        if args.adaptive:
            # no oracle link knowledge: start blind, let re-opt take over
            A, agg = fedavg_weights(init_model.n), Aggregation.COLREL
            print(f"adaptive alpha: identity start, re-opt every {args.reopt_every}")
        else:
            res = optimize_weights(init_model, sweeps=30, fine_tune_sweeps=30)
            A, agg = res.A, Aggregation.COLREL
            print(f"COPT-alpha: S {res.S_init:.2f} -> {res.S:.2f}")
    else:
        A, agg = fedavg_weights(init_model.n), Aggregation(args.strategy)

    images, labels = synthetic_cifar(n=10000, seed=1)
    ev_img, ev_lab = synthetic_cifar(n=2000, seed=2)
    if args.non_iid_s:
        parts = partition_sort_and_partition(labels, link_model.n, s=args.non_iid_s)
    else:
        parts = partition_iid(len(labels), link_model.n)
    clients = make_federated_clients({"images": images, "labels": labels}, parts,
                                     setup.batch_size)

    bundle = build(setup.cnn)

    @jax.jit
    def eval_fn(params):
        _, m = bundle.loss_fn(params, {"images": ev_img, "labels": ev_lab})
        return m

    trainer = FLTrainer(
        bundle.loss_fn, bundle.init(jax.random.PRNGKey(0)), init_model, A, clients,
        sgd(setup.lr, weight_decay=setup.weight_decay),
        sgd_momentum(1.0, beta=setup.server_momentum),
        local_steps=setup.local_steps, aggregation=agg, seed=0,
        eval_fn=eval_fn, channel=channel, adaptive=adaptive,
    )
    trainer.run(args.rounds, eval_every=max(args.rounds // 10, 1), verbose=True)

    log = trainer.log.to_dict()
    log["config"] = vars(args)
    with open(f"{args.out}.json", "w") as f:
        json.dump(log, f, indent=1)
    save_checkpoint(f"{args.out}.msgpack", trainer.params)
    final = trainer.log.eval_metrics[-1] if trainer.log.eval_metrics else {}
    print(f"\nfinal: {final}  (log -> {args.out}.json, ckpt -> {args.out}.msgpack)")


if __name__ == "__main__":
    main()
