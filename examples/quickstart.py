"""Quickstart: federated training of a small qwen3-family LM with ColRel
over an intermittently-connected network, vs blind FedAvg.

    PYTHONPATH=src python examples/quickstart.py [--rounds 15]

Demonstrates the full public API surface: topology -> COPT-alpha weight
optimization -> FLTrainer with the paper's protocol.
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.core import fedavg_weights, optimize_weights, topology
from repro.data import synthetic_tokens, partition_iid
from repro.data.pipeline import make_federated_clients
from repro.fl import FLTrainer
from repro.models import build, count_params
from repro.optim import sgd, sgd_momentum


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--local-steps", type=int, default=4)
    args = ap.parse_args()

    # 1. the intermittent network (paper Fig. 2b: heterogeneous uplinks)
    link_model = topology.paper_fig2b(p_c=0.9)
    print(f"uplink probabilities: {link_model.p}")

    # 2. optimize the consensus weights (Algorithm 3)
    res = optimize_weights(link_model, sweeps=25, fine_tune_sweeps=25)
    print(f"COPT-alpha: S {res.S_init:.1f} -> {res.S:.1f} "
          f"({res.S_init / res.S:.1f}x variance reduction)")

    # 3. model + federated data
    cfg = get_arch("qwen3-0.6b").smoke()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    print(f"model: {cfg.name} (reduced), {count_params(params):,} params")

    toks, _ = synthetic_tokens(600, 65, vocab=cfg.vocab_size, seed=0)
    arrays = {"tokens": toks[:, :-1], "labels": toks[:, 1:].astype(np.int32)}
    parts = partition_iid(600, link_model.n, seed=0)

    def run(strategy, A, tag):
        clients = make_federated_clients(arrays, parts, batch_size=8)
        t = FLTrainer(
            bundle.loss_fn, params, link_model, A, clients,
            sgd(0.25), sgd_momentum(1.0, beta=0.9),
            local_steps=args.local_steps, strategy=strategy, seed=0,
        )
        t.run(args.rounds)
        print(f"{tag:16s} loss: {t.log.loss[0]:.3f} -> {t.log.loss[-1]:.3f}")
        return t.log.loss[-1]

    colrel = run("colrel", res.A, "ColRel")
    blind = run("fedavg_blind", fedavg_weights(10), "FedAvg-blind")
    print(f"\nColRel final loss {colrel:.3f} vs blind {blind:.3f} "
          f"({'better' if colrel < blind else 'worse'})")


if __name__ == "__main__":
    main()
