"""Batched serving demo: prefill + KV-cache decode on a gemma3-family
model (sliding-window + global layers), greedy generation.

    PYTHONPATH=src python examples/serve_decode.py --batch 4 --gen 32
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.models import build, count_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_arch("gemma3-1b").smoke()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name} (reduced): {count_params(params):,} params, "
          f"window={cfg.sliding_window}, local:global={cfg.local_global_ratio}:1")

    B, P, G = args.batch, args.prompt_len, args.gen
    max_len = P + G
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab_size)

    decode = jax.jit(bundle.decode_step)
    cache = bundle.init_cache(B, max_len)

    # prefill by replaying the prompt through the decode path (exactly the
    # cache the prefill kernel would produce)
    t0 = time.perf_counter()
    for t in range(P):
        logits, cache = decode(params, cache, prompts[:, t : t + 1], jnp.int32(t))
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for t in range(P, P + G - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(t))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_gen = time.perf_counter() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"prefill: {P} tokens x {B} seqs in {t_prefill:.2f}s")
    print(f"decode:  {G - 1} steps in {t_gen:.2f}s "
          f"({B * (G - 1) / max(t_gen, 1e-9):.1f} tok/s)")
    for b in range(min(B, 2)):
        print(f"  seq{b}: ...{list(map(int, prompts[b, -5:]))} -> "
              f"{list(map(int, gen[b, :8]))}...")


if __name__ == "__main__":
    main()
